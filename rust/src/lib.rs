//! # lln-attention — Linear Log-Normal Attention, full-system reproduction
//!
//! Reproduction of *"Linear Log-Normal Attention with Unbiased
//! Concentration"* (ICLR 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build-time Python)** — Pallas kernels + a RoBERTa-lite JAX
//!   encoder, AOT-lowered once to HLO-text artifacts (`python/compile`).
//! * **L3 (this crate)** — coordinator: serving router + dynamic batcher,
//!   the training driver, the paper's analysis instruments (temperature,
//!   entropy, spectral gap, log-normal fitting, moment matching), native
//!   CPU baselines of every attention method, and the per-table/figure
//!   experiment harnesses.  Python is never on a request path.
//!
//! The crate mirror of this image is offline, so several substrates that
//! would normally be dependencies are implemented here (see DESIGN.md §3):
//! [`cli`], [`config`], [`util::json`], [`rng`], [`tensor`], [`linalg`],
//! [`stats`], [`testkit`], [`bench`].

pub mod analysis;
pub mod attention;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod tensor;
pub mod testkit;
pub mod training;
pub mod util;

/// Default artifacts directory relative to the repo root / cwd.
pub const ARTIFACTS_DIR: &str = "artifacts";
