//! Native (no-PJRT) analysis figures: figs. 2, 5, 6, 7.

use anyhow::Result;

use super::maybe_write_csv;
use crate::analysis::concentration::concentration_profile;
use crate::analysis::fenton;
use crate::analysis::lognormal::{histogram_study, sa_lognormal_check};
use crate::attention::{MomentMatcher, Method};
use crate::cli::Args;
use crate::util::print_table;

fn matcher(args: &Args) -> MomentMatcher {
    let dir = crate::runtime::artifacts_dir(args.get("artifacts"));
    MomentMatcher::from_artifacts(&dir).unwrap_or_else(|| {
        println!("(artifacts absent: fitting moment matching natively...)");
        MomentMatcher::fit(256, 64, &[0, 1])
    })
}

/// Fig 2: entropy + spectral gap vs input spread for each kernel.
pub fn run_fig2(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 128)?;
    let d = args.get_usize("d", 64)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let sigmas: Vec<f64> = vec![0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75, 2.0];
    let mm = matcher(args);

    println!("== Fig 2: attention concentration vs input spread (N={n}, d={d}) ==");
    println!("   kernels: softmax | lln+mm | lln (unmatched) | elu | relu | quadratic\n");
    let mut curves = Vec::new();
    let specs: Vec<(&str, Method, Option<&MomentMatcher>)> = vec![
        ("softmax", Method::Softmax, None),
        ("lln+mm", Method::Lln, Some(&mm)),
        ("lln", Method::Lln, None),
        ("elu", Method::Elu, None),
        ("relu", Method::Relu, None),
        ("quadratic", Method::Quadratic, None),
    ];
    for (label, method, mmref) in &specs {
        curves.push((*label, concentration_profile(*method, &sigmas, n, d, *mmref, seed)));
    }

    for metric in ["entropy[bits]", "spectral gap"] {
        println!("-- {metric} --");
        let mut rows = Vec::new();
        for (label, pts) in &curves {
            let mut row = vec![label.to_string()];
            for p in pts {
                let v = if metric.starts_with("entropy") {
                    p.entropy
                } else {
                    p.spectral_gap
                };
                row.push(format!("{v:.3}"));
            }
            rows.push(row);
        }
        let mut headers: Vec<String> = vec!["kernel".into()];
        headers.extend(sigmas.iter().map(|s| format!("s={s}")));
        let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
        print_table(&hrefs, &rows);
        println!();
    }

    // Shape check the paper claims: only matched LLN tracks softmax.
    use crate::analysis::ConcentrationPoint;
    let dev = |a: &[ConcentrationPoint], b: &[ConcentrationPoint]| {
        let mut total = 0.0;
        for (x, y) in a.iter().zip(b) {
            total += (x.entropy - y.entropy).abs();
        }
        total / a.len() as f64
    };
    let sm = &curves[0].1;
    println!(
        "mean |entropy - softmax|:  lln+mm={:.3}  lln={:.3}  elu={:.3}  relu={:.3}",
        dev(&curves[1].1, sm),
        dev(&curves[2].1, sm),
        dev(&curves[3].1, sm),
        dev(&curves[4].1, sm)
    );

    let rows: Vec<String> = curves
        .iter()
        .flat_map(|(label, pts)| {
            pts.iter().map(move |p| {
                format!("{label},{},{},{},{}", p.sigma, p.temperature, p.entropy, p.spectral_gap)
            })
        })
        .collect();
    maybe_write_csv(args, "fig2", "kernel,sigma,temperature,entropy,spectral_gap", &rows)?;
    Ok(())
}

/// Fig 5: SA log-normal parameters vs theory + moment-matching alignment.
pub fn run_fig5(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 256)?;
    let d = args.get_usize("d", 64)?;
    let mm = matcher(args);

    println!("== Fig 5a: SA log-normal parameters, measured vs theory (N={n}, d={d}) ==");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for sq in [0.6, 0.8, 1.0, 1.2, 1.4, 1.6] {
        let c = sa_lognormal_check(sq, sq, n, d, 11);
        rows.push(vec![
            format!("{sq:.1}"),
            format!("{:.3}", c.theory_sigma2),
            format!("{:.3}", c.measured_sigma2),
            format!("{:.2}", c.theory_mu),
            format!("{:.2}", c.measured_mu),
        ]);
        csv.push(format!(
            "{sq},{},{},{},{}",
            c.theory_sigma2,
            c.measured_sigma2,
            c.theory_mu,
            c.measured_mu
        ));
    }
    print_table(
        &["sigma_q=sigma_k", "sigma2 theory", "sigma2 measured", "mu theory", "mu measured"],
        &rows,
    );

    println!("\n== Fig 5b: LLN variance before/after moment matching ==");
    let mut rows = Vec::new();
    for sq in [0.9, 1.0, 1.1, 1.2, 1.3, 1.4] {
        let v_sm = crate::attention::moment_matching::measure_sm_log_variance(sq, sq, n, d, 13);
        let (alpha, beta) = mm.alpha_beta(sq as f64, sq as f64);
        let mut rng = crate::rng::Pcg64::seed(13);
        let q = crate::tensor::Mat::gaussian(n, d, sq, &mut rng);
        let k = crate::tensor::Mat::gaussian(n, d, sq, &mut rng);
        let v_matched = crate::stats::log_variance(
            &crate::attention::lln_attention_matrix(&q, &k, alpha, beta),
            1e-30,
        );
        let v_naive = crate::stats::log_variance(
            &crate::attention::lln_attention_matrix(&q, &k, 1.0, 1.0),
            1e-30,
        );
        rows.push(vec![
            format!("{sq:.1}"),
            format!("{v_sm:.3}"),
            format!("{v_matched:.3}"),
            format!("{v_naive:.3}"),
            format!("{alpha:.2}"),
        ]);
    }
    print_table(&["sigma", "SA var", "LLN var (mm)", "LLN var (a=b=1)", "alpha"], &rows);
    maybe_write_csv(args, "fig5", "sigma,theory_s2,measured_s2,theory_mu,measured_mu", &csv)?;
    Ok(())
}

/// Fig 6: Fenton approximation in moderate + broad regimes.
pub fn run_fig6(args: &Args) -> Result<()> {
    let d = args.get_usize("d", 64)?;
    let trials = args.get_usize("trials", 4000)?;

    println!("== Fig 6a: moderate regime — Fenton theory vs Monte-Carlo (d={d}) ==");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for p in fenton::moderate_sweep(d, trials, 5) {
        rows.push(vec![
            format!("{:.1}", p.s2),
            format!("{:.4}", p.fenton_theory),
            format!("{:.4}", p.measured),
            format!("{:+.1}%", 100.0 * (p.measured - p.fenton_theory) / p.fenton_theory),
        ]);
        csv.push(format!("{},{},{}", p.s2, p.fenton_theory, p.measured));
    }
    print_table(&["sigma^2", "Fenton", "measured", "err"], &rows);

    println!("\n== Fig 6b: broad regime — linearity of var(log sum) in sigma^2 ==");
    let (pts, (slope, intercept, r2)) = fenton::broad_sweep(d, trials, 6);
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|(s2, v)| vec![format!("{s2:.0}"), format!("{v:.3}")])
        .collect();
    print_table(&["sigma^2", "var(log sum)"], &rows);
    println!("linear fit: var = {slope:.4} * sigma^2 + {intercept:.3}   (r^2 = {r2:.4})");
    maybe_write_csv(args, "fig6", "s2,fenton,measured", &csv)?;
    Ok(())
}

/// Fig 7: log-attention histograms, SA vs LLN matched/unmatched.
pub fn run_fig7(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 256)?;
    let d = args.get_usize("d", 64)?;
    let sigma = args.get_f64("sigma", 1.2)?;
    let mm = matcher(args);
    let study = histogram_study(sigma, n, d, 48, &mm, 17);

    println!("== Fig 7: histogram of log attention weights (sigma={sigma}, N={n}, d={d}) ==");
    let render = |label: &str, h: &crate::stats::Histogram| {
        let dens = h.density();
        let max = dens.iter().cloned().fold(0.0, f64::max).max(1e-12);
        let bar: String = dens
            .iter()
            .map(|&v| {
                const SHADES: [char; 5] = [' ', '░', '▒', '▓', '█'];
                SHADES[((v / max) * 4.0).round() as usize]
            })
            .collect();
        println!("{label:>14} |{bar}|");
    };
    println!("  log P range: [{:.1}, {:.1}]", study.sa.lo, study.sa.hi);
    render("softmax", &study.sa);
    render("lln matched", &study.lln_matched);
    render("lln unmatched", &study.lln_unmatched);
    println!(
        "\nKS distance to SA:  matched = {:.4},  unmatched = {:.4}  (lower = closer)",
        study.ks_matched,
        study.ks_unmatched
    );

    let mut csv = Vec::new();
    let centers = study.sa.bin_centers();
    let (dsa, dm, du) =
        (study.sa.density(), study.lln_matched.density(), study.lln_unmatched.density());
    for i in 0..centers.len() {
        csv.push(format!("{},{},{},{}", centers[i], dsa[i], dm[i], du[i]));
    }
    maybe_write_csv(args, "fig7", "log_p,sa,lln_matched,lln_unmatched", &csv)?;
    Ok(())
}
