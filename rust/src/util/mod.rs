//! Shared utilities: JSON (manifest + metrics), bounded channels and a
//! thread pool (tokio substitute), the persistent compute pool behind
//! every `par_*` kernel, and timing helpers.

pub mod compute_pool;
pub mod json;
pub mod pool;

use std::time::Instant;

/// Wall-clock stopwatch for coarse phase timing.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Peak resident-set size of this process in megabytes (Linux), used by
/// the Table 2 memory column.  Falls back to the *current* RSS on
/// kernels whose procfs lacks `VmHWM` (some container runtimes).
pub fn peak_rss_mb() -> f64 {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: f64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    current_rss_mb()
}

/// Current resident-set size in megabytes.
pub fn current_rss_mb() -> f64 {
    if let Ok(statm) = std::fs::read_to_string("/proc/self/statm") {
        let fields: Vec<&str> = statm.split_whitespace().collect();
        if fields.len() > 1 {
            if let Ok(pages) = fields[1].parse::<f64>() {
                return pages * 4096.0 / (1024.0 * 1024.0);
            }
        }
    }
    0.0
}

/// Render a compact fixed-width table to stdout (experiment harness output).
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>width$}  ", c, width = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(current_rss_mb() > 0.0);
        assert!(peak_rss_mb() > 0.0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_ms() >= 4.0);
    }
}
