//! Micro/macro benchmark harness (criterion substitute).
//!
//! Warmup, timed iterations with per-iteration samples, mean / p50 / p95
//! and throughput reporting.  The `benches/*.rs` targets (built with
//! `harness = false`) compose these into the paper's tables.

use std::time::Instant;

/// One benchmark's collected samples (seconds per iteration).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    /// Optional work units per iteration (tokens, requests...) for throughput.
    pub units_per_iter: f64,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    pub fn percentile(&self, q: f64) -> f64 {
        crate::stats::percentile(&self.samples, q)
    }
    pub fn std(&self) -> f64 {
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>() / self.samples.len() as f64).sqrt()
    }
    pub fn throughput(&self) -> f64 {
        self.units_per_iter / self.mean()
    }

    pub fn report_line(&self) -> String {
        let m = self.mean();
        let unit = if m < 1e-3 {
            format!("{:8.1} us", m * 1e6)
        } else if m < 1.0 {
            format!("{:8.2} ms", m * 1e3)
        } else {
            format!("{:8.3} s ", m)
        };
        let tp = if self.units_per_iter > 0.0 {
            format!("  {:10.0} units/s", self.throughput())
        } else {
            String::new()
        };
        format!(
            "{:<40} {}  p50 {:8.2} ms  p95 {:8.2} ms  (n={}){}",
            self.name,
            unit,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.samples.len(),
            tp
        )
    }
}

/// Benchmark runner with time-budgeted sampling.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub time_budget_secs: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_iters: 2, min_iters: 5, max_iters: 200, time_budget_secs: 3.0, results: Vec::new() }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Self { warmup_iters: 1, min_iters: 3, max_iters: 30, time_budget_secs: 1.0, results: Vec::new() }
    }

    /// Time `f`, preventing dead-code elimination via the returned value.
    pub fn run<T>(&mut self, name: &str, units_per_iter: f64, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::new();
        let budget_start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && budget_start.elapsed().as_secs_f64() < self.time_budget_secs)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.results.push(BenchResult { name: name.to_string(), samples, units_per_iter });
        let r = self.results.last().unwrap();
        println!("{}", r.report_line());
        r
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Opaque value sink (std::hint::black_box wrapper kept local so the
/// harness compiles on stable if the hint ever changes).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Bench one [`AttentionBackend`](crate::attention::AttentionBackend)
/// forward at (n, d) on seeded Gaussian probes; returns the mean
/// seconds per forward.  The shared entry point for `kernel_micro` and
/// `attention_scaling`, so every bench target times methods through the
/// same registry dispatch the serving path uses.
pub fn run_attention_backend(
    b: &mut Bench,
    backend: &dyn crate::attention::AttentionBackend,
    n: usize,
    d: usize,
    seed: u64,
) -> f64 {
    let mut rng = crate::rng::Pcg64::seed(seed);
    let q = crate::tensor::Mat::gaussian(n, d, 1.0, &mut rng);
    let k = crate::tensor::Mat::gaussian(n, d, 1.0, &mut rng);
    let v = crate::tensor::Mat::gaussian(n, d, 1.0, &mut rng);
    let name = format!("backend {} n={n}", backend.name());
    b.run(&name, n as f64, || backend.forward(&q, &k, &v)).mean()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_stats() {
        let mut b = Bench { warmup_iters: 0, min_iters: 5, max_iters: 10, time_budget_secs: 0.2, results: vec![] };
        let r = b.run("noop", 1.0, || 42u64).clone();
        assert!(r.samples.len() >= 5);
        assert!(r.mean() >= 0.0);
        assert!(r.percentile(50.0) <= r.percentile(95.0) + 1e-12);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let mut b = Bench { warmup_iters: 0, min_iters: 1, max_iters: 3, time_budget_secs: 100.0, results: vec![] };
        let r = b.run("capped", 0.0, || ()).clone();
        assert!(r.samples.len() <= 3);
    }
}
