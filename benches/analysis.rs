//! Bench: substrate numerics — matmul, softmax, eigen, RNG — the pieces
//! every analysis figure is built from (§Perf L3 profile anchors).

use lln::bench::Bench;
use lln::rng::Pcg64;
use lln::tensor::Mat;

fn main() {
    let mut rng = Pcg64::seed(0);
    let mut b = Bench::new();

    println!("== tensor substrate ==");
    for n in [128usize, 256, 512] {
        let a = Mat::gaussian(n, n, 1.0, &mut rng);
        let c = Mat::gaussian(n, n, 1.0, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        b.run(&format!("matmul {n}x{n}"), flops, || a.matmul(&c));
        b.run(&format!("matmul_t {n}x{n}"), flops, || a.matmul_t(&c));
    }
    let mut p = Mat::gaussian(512, 512, 1.0, &mut rng);
    b.run("softmax_rows 512x512", 512.0 * 512.0, || {
        let mut q = p.clone();
        q.softmax_rows();
        q
    });
    p.softmax_rows();

    println!("\n== eigen / stats ==");
    b.run("spectral_gap 512", 1.0, || lln::linalg::spectral_gap(&p, 400, 1e-8));
    b.run("entropy 512", 1.0, || lln::stats::attention_entropy(&p));

    println!("\n== rng ==");
    let mut r2 = Pcg64::seed(1);
    b.run("gauss x100k", 1e5, || {
        let mut acc = 0.0f64;
        for _ in 0..100_000 {
            acc += r2.gauss();
        }
        acc
    });
    b.run("zipf x100k", 1e5, || {
        let mut acc = 0u64;
        for _ in 0..100_000 {
            acc += r2.zipf(8192, 1.1);
        }
        acc
    });
}
