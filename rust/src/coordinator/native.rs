//! Native-backend serving encoder: the coordinator's PJRT-free compute
//! path, used when AOT artifacts (or the PJRT runtime itself) are
//! unavailable and `ServeConfig::native_fallback` is set.
//!
//! tokens -> deterministic per-(token, position) Gaussian embedding ->
//! one [`AttentionBackend`] forward (q = k = v = embedding) -> mean pool
//! -> fixed seeded linear head -> logits.
//!
//! This is a degraded model (no trained weights), but it exercises the
//! full serving stack — routing, bucketing, dynamic batching, stats,
//! backpressure — with real attention compute, so the coordinator is
//! testable and benchable in environments without artifacts.

use crate::attention::{backend_for, AttentionBackend, AttnSpec, BackendParams, DecodeState, Method};
use crate::rng::Pcg64;
use crate::tensor::Mat;

/// Degraded-mode encoder defaults — the native fallback has no model
/// manifest to read these from, so they are fixed and documented here.
pub const NATIVE_D_MODEL: usize = 32;
pub const NATIVE_NUM_CLASSES: usize = 4;
pub const NATIVE_SEED: u64 = 0xC0DE;

/// Largest tile size <= 64 that divides `n` (BlockDiag/LLN+Diag need
/// the sequence length to be a multiple of the tile).
pub fn tile_for(n: usize) -> usize {
    let mut b = n.max(1).min(64);
    while n % b != 0 {
        b -= 1;
    }
    b
}

/// One bucket's native encoder (deterministic in `seed`).
pub struct NativeEncoder {
    backend: Box<dyn AttentionBackend>,
    d_model: usize,
    num_classes: usize,
    head: Mat,
    embed_seed: u64,
    /// `[compute] causal` — the default mask for requests that do not
    /// carry their own spec.
    default_causal: bool,
}

impl NativeEncoder {
    pub fn new(
        method: Method,
        d_model: usize,
        num_classes: usize,
        seq_len: usize,
        seed: u64,
        compute: &crate::config::ComputeConfig,
    ) -> Self {
        // Honor the configured tile when it divides the bucket length;
        // otherwise fall back to the largest tile that does.
        let block = if compute.block != 0 && seq_len % compute.block == 0 {
            compute.block
        } else {
            tile_for(seq_len)
        };
        let params =
            BackendParams { alpha: 2.0, beta: 2.0, block, ..BackendParams::from_compute(compute) };
        let mut rng = Pcg64::new(seed, 0x4EAD);
        let head = Mat::gaussian(d_model, num_classes, (1.0 / d_model as f32).sqrt(), &mut rng);
        Self {
            backend: backend_for(method, params),
            d_model,
            num_classes,
            head,
            embed_seed: seed,
            default_causal: compute.causal,
        }
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The attention method this encoder serves (the coordinator gates
    /// causal admission on `method().supports_masking()`).
    pub fn method(&self) -> Method {
        self.backend.method()
    }

    /// Deterministic per-(token, position) embedding.
    fn embed(&self, tokens: &[i32]) -> Mat {
        let n = tokens.len();
        let mut x = Mat::zeros(n, self.d_model);
        for (pos, &tok) in tokens.iter().enumerate() {
            self.embed_row_into(tok, pos, x.row_mut(pos));
        }
        x
    }

    /// One (token, position) embedding row — shared by the batch
    /// [`embed`](Self::embed) and the decode step so an incrementally
    /// decoded token sees bitwise the same embedding as a prefill row.
    fn embed_row_into(&self, tok: i32, pos: usize, out: &mut [f32]) {
        let stream = (tok as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ self.embed_seed;
        let mut rng = Pcg64::new(stream, pos as u64);
        rng.fill_gaussian(out, 0.0, 0.5);
    }

    /// Logits for one (bucket-padded) token sequence under the
    /// configured default mask (no key-length mask — the pre-spec
    /// behavior, kept for the full-bucket callers and tests).
    pub fn infer(&self, tokens: &[i32]) -> Vec<f32> {
        let spec = if self.default_causal { AttnSpec::CAUSAL } else { AttnSpec::FULL };
        self.infer_spec(tokens, &spec)
    }

    /// Logits for one bucket-padded token sequence under an explicit
    /// [`AttnSpec`] — the serving entry point: `spec.key_len` is the
    /// request's live length (padding rows never receive attention mass
    /// and are excluded from the pooled representation), `spec.causal`
    /// the request's mask.  Methods that cannot honor masks (see
    /// [`Method::supports_masking`]) degrade the *key-padding* mask to
    /// full attention over the padded bucket — exactly the pre-spec
    /// serving behavior — but panic on a causal spec, matching the
    /// backend policy (never silently attend the future).  Coordinator
    /// traffic never trips that panic: `run_batch` rejects causal
    /// members per request when the method cannot mask.
    pub fn infer_spec(&self, tokens: &[i32], spec: &AttnSpec) -> Vec<f32> {
        let method = self.backend.method();
        assert!(
            !spec.causal || method.supports_masking(),
            "{} cannot honor the causal mask (coordinator admission rejects these per request)",
            method.name()
        );
        let spec = if method.supports_spec(spec) { *spec } else { AttnSpec::FULL };
        let x = self.embed(tokens);
        let out = self.backend.forward(&x, &x, &x, &spec);
        // Pool only the live rows: padded tail rows carry no signal
        // once the key mask keeps attention off them.  key_limit is
        // already bounded by the row count; max(1) only guards the
        // divisor when there are no live rows.
        let live = spec.key_limit(out.rows());
        let mut pooled = vec![0.0f32; self.d_model];
        for i in 0..live {
            for (p, &o) in pooled.iter_mut().zip(out.row(i)) {
                *p += o;
            }
        }
        let inv = 1.0 / live.max(1) as f32;
        for p in pooled.iter_mut() {
            *p *= inv;
        }
        self.head.matvec_t(&pooled)
    }

    /// Open an incremental decode session for this encoder's method.
    /// `Err` (never a panic) when the method cannot honor the causal
    /// mask — the coordinator surfaces this through the session-open
    /// response.
    pub fn begin_decode(&self) -> Result<DecodeState, String> {
        self.backend.begin_decode(self.d_model, self.d_model)
    }

    /// One decode-session step: embed `token` at `pos`, advance the
    /// attention state by one token (q = k = v = the embedding row,
    /// matching [`infer_spec`](Self::infer_spec)'s batch construction),
    /// and return the new token's logits — the head applied to its
    /// attention output row (per-token, no pooling: the streaming
    /// decode signal).
    pub fn decode_step(&self, state: &mut DecodeState, pos: usize, token: i32) -> Vec<f32> {
        let mut x = vec![0.0f32; self.d_model];
        self.embed_row_into(token, pos, &mut x);
        let out = self.backend.decode_step(state, &x, &x, &x);
        self.head.matvec_t(&out)
    }

    /// Recompute the K/V rows a paged decode session pushed at `pos`
    /// for `token` — the embedding row itself on both sides (this
    /// encoder steps with q = k = v), and deterministic in (token,
    /// pos, seed), so a page refilled after LRU eviction is bitwise
    /// identical to the one that was evicted.
    pub fn recompute_kv_rows(&self, token: i32, pos: usize, k: &mut [f32], v: &mut [f32]) {
        assert_eq!(k.len(), self.d_model, "recompute key row dim mismatch");
        assert_eq!(v.len(), self.d_model, "recompute value row dim mismatch");
        self.embed_row_into(token, pos, k);
        v.copy_from_slice(k);
    }

    /// Reference for the decode path: per-token logits of a full causal
    /// batch forward over `tokens` (the head applied to every attention
    /// output row).  `decode_step` over the same tokens must reproduce
    /// these — bitwise for the linear prefix-state class.
    pub fn decode_logits_reference(&self, tokens: &[i32]) -> Vec<Vec<f32>> {
        let x = self.embed(tokens);
        let out = self.backend.forward(&x, &x, &x, &AttnSpec::CAUSAL);
        (0..out.rows()).map(|i| self.head.matvec_t(out.row(i))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ComputeConfig;

    #[test]
    fn tile_divides_common_buckets() {
        for n in [32usize, 48, 64, 96, 128, 512] {
            let b = tile_for(n);
            assert!(b >= 1 && b <= 64 && n % b == 0, "n={n} b={b}");
        }
        assert_eq!(tile_for(128), 64);
        assert_eq!(tile_for(96), 48);
    }

    #[test]
    fn infer_is_deterministic_and_finite() {
        let cc = ComputeConfig::default();
        let enc = NativeEncoder::new(Method::LlnDiag, 32, 4, 64, 9, &cc);
        let tokens: Vec<i32> = (0..64).map(|i| (i % 37) + 4).collect();
        let a = enc.infer(&tokens);
        let b = enc.infer(&tokens);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn infer_separates_different_inputs() {
        let cc = ComputeConfig::default();
        let enc = NativeEncoder::new(Method::Lln, 32, 4, 32, 1, &cc);
        let a = enc.infer(&vec![5i32; 32]);
        let b = enc.infer(&vec![6i32; 32]);
        assert_ne!(a, b);
    }

    #[test]
    fn every_method_serves_a_bucket() {
        let cc = ComputeConfig::default();
        for m in Method::ALL {
            let enc = NativeEncoder::new(m, 16, 4, 64, 3, &cc);
            let logits = enc.infer(&vec![7i32; 64]);
            assert_eq!(logits.len(), 4, "{m:?}");
            assert!(logits.iter().all(|x| x.is_finite()), "{m:?}");
        }
    }

    #[test]
    fn configured_compute_knobs_reach_the_backend() {
        // threads=1, chunk=16 and a dividing block must be accepted and
        // still produce the same deterministic logits as defaults (the
        // kernels are parallelism-invariant).
        let custom = ComputeConfig { threads: 1, block: 32, chunk: 16, ..Default::default() };
        let a = NativeEncoder::new(Method::Lln, 32, 4, 64, 9, &custom);
        let b = NativeEncoder::new(Method::Lln, 32, 4, 64, 9, &ComputeConfig::default());
        let tokens: Vec<i32> = (0..64).map(|i| (i % 11) + 4).collect();
        let (la, lb) = (a.infer(&tokens), b.infer(&tokens));
        for (x, y) in la.iter().zip(&lb) {
            assert!((x - y).abs() < 1e-4, "{la:?} vs {lb:?}");
        }
    }

    #[test]
    fn causal_config_changes_the_served_function() {
        // `[compute] causal = true` must actually change attention, and
        // stay deterministic.
        let tokens: Vec<i32> = (0..64).map(|i| (i % 29) + 4).collect();
        let bi = NativeEncoder::new(Method::Softmax, 32, 4, 64, 9, &ComputeConfig::default());
        let causal_cc = ComputeConfig { causal: true, ..Default::default() };
        let ca = NativeEncoder::new(Method::Softmax, 32, 4, 64, 9, &causal_cc);
        assert_ne!(bi.infer(&tokens), ca.infer(&tokens));
        assert_eq!(ca.infer(&tokens), ca.infer(&tokens));
    }

    #[test]
    fn infer_spec_masks_padding_out_of_the_logits() {
        // Two requests that differ only in their PAD tail must serve
        // identical logits once key_len masks the padding.
        let cc = ComputeConfig::default();
        let enc = NativeEncoder::new(Method::Lln, 32, 4, 64, 9, &cc);
        let live: Vec<i32> = (0..40).map(|i| (i % 13) + 4).collect();
        let mut padded_a = live.clone();
        padded_a.resize(64, crate::data::special::PAD);
        let mut padded_b = live.clone();
        padded_b.resize(64, 999); // garbage padding
        let spec = AttnSpec::padded(40);
        let a = enc.infer_spec(&padded_a, &spec);
        let b = enc.infer_spec(&padded_b, &spec);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "padding leaked into logits: {a:?} vs {b:?}");
        }
        // Without the mask the garbage tail changes the answer.
        let full_a = enc.infer_spec(&padded_a, &AttnSpec::FULL);
        let full_b = enc.infer_spec(&padded_b, &AttnSpec::FULL);
        assert_ne!(full_a, full_b);
    }

    #[test]
    fn every_maskable_method_serves_causal_padded_requests() {
        // Maskable methods honor causal+padded specs; Nystrom/Linformer
        // still serve the padded spec (degrading the key mask to full,
        // the pre-spec behavior) but refuse causal outright.
        let cc = ComputeConfig::default();
        for m in Method::ALL {
            let enc = NativeEncoder::new(m, 16, 4, 64, 3, &cc);
            let spec = if m.supports_masking() {
                AttnSpec::causal_padded(50)
            } else {
                AttnSpec::padded(50)
            };
            let logits = enc.infer_spec(&vec![7i32; 64], &spec);
            assert_eq!(logits.len(), 4, "{m:?}");
            assert!(logits.iter().all(|x| x.is_finite()), "{m:?}");
        }
    }

    #[test]
    fn decode_steps_reproduce_the_causal_forward_logits() {
        // Token-by-token decode through the encoder must match the
        // causal batch forward's per-row logits — bitwise for the
        // linear prefix-state class, kernel tolerance for the caches.
        let cc = ComputeConfig::default();
        let tokens: Vec<i32> = (0..48).map(|i| (i % 17) + 4).collect();
        for m in [Method::Lln, Method::Elu, Method::Softmax, Method::BlockDiag] {
            let enc = NativeEncoder::new(m, 16, 4, 48, 3, &cc);
            let want = enc.decode_logits_reference(&tokens);
            let mut state = enc.begin_decode().unwrap();
            for (pos, &tok) in tokens.iter().enumerate() {
                let got = enc.decode_step(&mut state, pos, tok);
                if matches!(m, Method::Lln | Method::Elu) {
                    assert_eq!(got, want[pos], "{m:?} step {pos} not bitwise");
                } else {
                    for (g, w) in got.iter().zip(&want[pos]) {
                        assert!((g - w).abs() < 1e-4, "{m:?} step {pos}: {got:?} vs {:?}", want[pos]);
                    }
                }
            }
            assert_eq!(state.len(), tokens.len());
        }
    }

    #[test]
    fn unmaskable_encoder_rejects_decode_sessions_as_err() {
        // begin_decode must be a clean Err (the session path never
        // panics a worker), for both unmaskable methods.
        let cc = ComputeConfig::default();
        for m in [Method::Nystrom, Method::Linformer] {
            let enc = NativeEncoder::new(m, 16, 4, 64, 3, &cc);
            let err = enc.begin_decode().unwrap_err();
            assert!(err.contains("causal"), "{m:?}: {err}");
        }
        // Maskable methods all open.
        for m in Method::ALL.iter().filter(|m| m.supports_masking()) {
            let enc = NativeEncoder::new(*m, 16, 4, 64, 3, &cc);
            assert!(enc.begin_decode().is_ok(), "{m:?} must open a decode session");
        }
    }

    #[test]
    #[should_panic(expected = "cannot honor the causal mask")]
    fn unmaskable_encoder_refuses_causal_spec() {
        let cc = ComputeConfig::default();
        let enc = NativeEncoder::new(Method::Nystrom, 16, 4, 64, 3, &cc);
        enc.infer_spec(&vec![7i32; 64], &AttnSpec::CAUSAL);
    }

    #[test]
    fn fused_softmax_bucket_matches_materialized_pipeline() {
        // `[compute] fused` flips an exact-softmax bucket between the
        // O(n·tile) streaming kernel and the materialized pipeline; the
        // served logits must agree to kernel tolerance for every tile /
        // unroll configuration a config file could set.
        let tokens: Vec<i32> = (0..96).map(|i| (i % 23) + 4).collect();
        let unfused_cc = ComputeConfig { fused: false, ..Default::default() };
        let reference = NativeEncoder::new(Method::Softmax, 32, 4, 96, 5, &unfused_cc).infer(&tokens);
        for (tile, unroll) in [(0usize, 0usize), (16, 1), (40, 2), (400, 8)] {
            let cc = ComputeConfig { tile, unroll, ..Default::default() };
            let enc = NativeEncoder::new(Method::Softmax, 32, 4, 96, 5, &cc);
            assert_eq!(enc.backend_name(), "softmax");
            let logits = enc.infer(&tokens);
            for (x, y) in logits.iter().zip(&reference) {
                assert!((x - y).abs() < 1e-3, "tile={tile} unroll={unroll}: {logits:?} vs {reference:?}");
            }
        }
    }
}
