//! Table 3: ViT-lite image classification (Dogs-vs-Cats stand-in) —
//! softmax vs LLN+Diag vs Linformer on oriented-texture images.

use anyhow::Result;

use super::maybe_write_csv;
use crate::cli::Args;
use crate::data::images::{ImageGen, PATCHES, PATCH_DIM};
use crate::runtime::{artifacts_dir, Engine, HostTensor};
use crate::training::driver::{accuracy_from_logits, TrainDriver};
use crate::util::print_table;

const METHODS: [&str; 3] = ["softmax", "lln_diag", "linformer"];

pub fn run_table3(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let steps = args.get_usize("steps", 200)?;
    let eval_batches = args.get_usize("eval-batches", 12)?;
    let lr = args.get_f64("lr", 1e-3)?;
    let methods = args.get_list("methods", &METHODS.join(","));
    let mut engine = Engine::new(&dir)?;

    println!("== Table 3: ViT-lite on synthetic oriented textures ({steps} steps) ==\n");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for method in &methods {
        let artifact = format!("train_vit_{method}");
        let mut driver = TrainDriver::new(&engine, &dir, &artifact)?;
        let mut gen = ImageGen::new(100);
        for step in 0..steps {
            let b = gen.batch(16);
            let warm = (steps / 10).max(1);
            let lr_t = if step < warm {
                lr * (step + 1) as f64 / warm as f64
            } else {
                lr
            };
            driver.step(
                &mut engine,
                lr_t,
                &[
                    HostTensor::F32 { shape: vec![16, PATCHES, PATCH_DIM], data: b.patches },
                    HostTensor::I32 { shape: vec![16], data: b.labels },
                ],
            )?;
        }
        let mut eval = ImageGen::new(999);
        let mut correct = 0.0;
        let mut total = 0usize;
        for _ in 0..eval_batches {
            let b = eval.batch(16);
            let outs = driver.eval(
                &mut engine,
                &[HostTensor::F32 { shape: vec![16, PATCHES, PATCH_DIM], data: b.patches }],
            )?;
            correct += accuracy_from_logits(outs[0].as_f32()?, &b.labels, 2) * 16.0;
            total += 16;
        }
        let acc = correct / total as f64;
        eprintln!("   [{method}] {:.1}%", acc * 100.0);
        rows.push(vec![method.to_string(), format!("{:.2}", acc * 100.0)]);
        csv.push(format!("{method},{}", acc * 100.0));
    }
    print_table(&["method", "accuracy [%]"], &rows);
    println!("\npaper shape: LLN+Diag ~ softmax, both > Linformer.");
    maybe_write_csv(args, "table3", "method,accuracy", &csv)?;
    Ok(())
}
