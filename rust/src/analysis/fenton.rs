//! Fenton (1960) log-normal-sum study (paper fig. 6 / Prop 4.1 proof).
//!
//! Validates the two regimes the proof leans on:
//!   moderate sigma^2:  var(log sum) ~ ln[(e^{s2} - 1)/d + 1]   (Fenton)
//!   broad sigma^2:     var(log sum) grows ~linearly in s2       (Romeo)

use crate::rng::Pcg64;
use crate::stats;

/// Fenton's moderate-regime prediction for the log-variance of a sum of
/// `d` iid zero-mean log-normals with log-variance `s2`.
pub fn fenton_sigma2(s2: f64, d: usize) -> f64 {
    (((s2.exp() - 1.0) / d as f64) + 1.0).ln()
}

/// Fenton–Wilkinson moment matching: the log-normal LN(mu_s, s2_s)
/// whose first two moments equal those of a sum of `d` iid LN(mu, s2)
/// variables *exactly* (that equality is the construction — the
/// approximation is only in pretending the sum is log-normal at all).
/// Returns `(mu_s, s2_s)`.
pub fn fenton_wilkinson_fit(mu: f64, s2: f64, d: usize) -> (f64, f64) {
    let mean = d as f64 * (mu + 0.5 * s2).exp();
    let var = d as f64 * (s2.exp() - 1.0) * (2.0 * mu + s2).exp();
    let s2_s = (1.0 + var / (mean * mean)).ln();
    let mu_s = mean.ln() - 0.5 * s2_s;
    (mu_s, s2_s)
}

/// First two moments (mean, variance) of LN(mu, s2).
pub fn lognormal_moments(mu: f64, s2: f64) -> (f64, f64) {
    let mean = (mu + 0.5 * s2).exp();
    let var = (s2.exp() - 1.0) * (2.0 * mu + s2).exp();
    (mean, var)
}

/// Empirical var(log sum_d exp(N(0, s2))) over `trials` Monte-Carlo draws.
pub fn lognormal_sum_variance(s2: f64, d: usize, trials: usize, seed: u64) -> f64 {
    let sigma = s2.sqrt();
    let mut rng = Pcg64::seed(seed);
    let mut logs = Vec::with_capacity(trials);
    for _ in 0..trials {
        let mut sum = 0.0f64;
        for _ in 0..d {
            sum += (sigma * rng.gauss()).exp();
        }
        logs.push(sum.ln());
    }
    let mu = logs.iter().sum::<f64>() / logs.len() as f64;
    logs.iter().map(|&x| (x - mu) * (x - mu)).sum::<f64>() / logs.len() as f64
}

/// One row of the fig. 6 output.
#[derive(Clone, Copy, Debug)]
pub struct FentonPoint {
    pub s2: f64,
    pub measured: f64,
    pub fenton_theory: f64,
}

/// Sweep the moderate regime (fig. 6a).
pub fn moderate_sweep(d: usize, trials: usize, seed: u64) -> Vec<FentonPoint> {
    [0.2, 0.4, 0.6, 0.8, 1.0, 1.2]
        .iter()
        .map(|&s2| FentonPoint {
            s2,
            measured: lognormal_sum_variance(s2, d, trials, seed),
            fenton_theory: fenton_sigma2(s2, d),
        })
        .collect()
}

/// Sweep the broad regime (fig. 6b) — returns (s2, measured) pairs plus
/// the linear-fit slope/intercept/r^2 over them.
pub fn broad_sweep(d: usize, trials: usize, seed: u64) -> (Vec<(f64, f64)>, (f64, f64, f64)) {
    let s2s: Vec<f64> = (0..9).map(|i| 4.0 + 2.0 * i as f64).collect();
    let pts: Vec<(f64, f64)> = s2s
        .iter()
        .map(|&s2| (s2, lognormal_sum_variance(s2, d, trials, seed)))
        .collect();
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let fit = stats::linear_fit(&xs, &ys);
    (pts, fit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fenton_theory_matches_measurement_in_moderate_regime() {
        // Paper fig. 6a: dashed theory lines align with empirical points.
        for p in moderate_sweep(64, 4000, 1) {
            let rel = (p.measured - p.fenton_theory).abs() / p.fenton_theory.max(1e-9);
            assert!(rel < 0.25, "{p:?} rel={rel}");
        }
    }

    #[test]
    fn broad_regime_grows_linearly() {
        // Paper fig. 6b: linear growth with good r^2.
        let (_pts, (slope, _b, r2)) = broad_sweep(64, 3000, 2);
        assert!(slope > 0.0);
        assert!(r2 > 0.98, "r2={r2}");
    }

    #[test]
    fn sum_variance_shrinks_with_more_terms() {
        // Averaging effect: more log-normal terms concentrate the sum.
        let few = lognormal_sum_variance(1.0, 8, 4000, 3);
        let many = lognormal_sum_variance(1.0, 256, 4000, 3);
        assert!(many < few, "few={few} many={many}");
    }

    #[test]
    fn fenton_wilkinson_preserves_mean_and_variance_analytically() {
        // FW is *defined* by moment preservation: the fitted log-normal's
        // first two moments must equal the sum's exactly.
        for (mu, s2, d) in [(0.0, 0.5, 16), (-0.5, 1.0, 64), (1.0, 0.25, 8), (-2.0, 2.0, 128)] {
            let (mu_s, s2_s) = fenton_wilkinson_fit(mu, s2, d);
            let (fit_mean, fit_var) = lognormal_moments(mu_s, s2_s);
            let (one_mean, one_var) = lognormal_moments(mu, s2);
            let (sum_mean, sum_var) = (d as f64 * one_mean, d as f64 * one_var);
            assert!(
                (fit_mean - sum_mean).abs() / sum_mean < 1e-10,
                "mean drift: {fit_mean} vs {sum_mean} (mu={mu} s2={s2} d={d})"
            );
            assert!(
                (fit_var - sum_var).abs() / sum_var < 1e-10,
                "variance drift: {fit_var} vs {sum_var} (mu={mu} s2={s2} d={d})"
            );
        }
    }

    #[test]
    fn fenton_wilkinson_reduces_to_fenton_sigma2_at_zero_mean() {
        for (s2, d) in [(0.2, 8), (0.8, 64), (1.2, 256)] {
            let (_, s2_s) = fenton_wilkinson_fit(0.0, s2, d);
            let direct = fenton_sigma2(s2, d);
            assert!((s2_s - direct).abs() < 1e-12, "{s2_s} vs {direct}");
        }
    }

    #[test]
    fn fenton_wilkinson_matches_monte_carlo_samples() {
        // Empirical mean/variance of actual log-normal sums match the FW
        // target moments (tolerances calibrated at ~4x the sampling
        // noise for 40k trials).
        let (mu, s2, d, trials) = (-0.5, 0.5, 32usize, 40_000usize);
        let sigma = s2.sqrt();
        let mut rng = Pcg64::seed(17);
        let mut sums = Vec::with_capacity(trials);
        for _ in 0..trials {
            let mut s = 0.0f64;
            for _ in 0..d {
                s += (mu + sigma * rng.gauss()).exp();
            }
            sums.push(s);
        }
        let emp_mean = sums.iter().sum::<f64>() / trials as f64;
        let emp_var =
            sums.iter().map(|&x| (x - emp_mean) * (x - emp_mean)).sum::<f64>() / trials as f64;
        let (mu_s, s2_s) = fenton_wilkinson_fit(mu, s2, d);
        let (fw_mean, fw_var) = lognormal_moments(mu_s, s2_s);
        assert!((emp_mean - fw_mean).abs() / fw_mean < 0.01, "mean {emp_mean} vs {fw_mean}");
        assert!((emp_var - fw_var).abs() / fw_var < 0.06, "var {emp_var} vs {fw_var}");
        // And the log-domain parameters track the FW fit (moderate regime).
        let logs: Vec<f64> = sums.iter().map(|&x| x.ln()).collect();
        let lmu = logs.iter().sum::<f64>() / trials as f64;
        let lvar = logs.iter().map(|&x| (x - lmu) * (x - lmu)).sum::<f64>() / trials as f64;
        assert!((lmu - mu_s).abs() < 0.01, "log-mean {lmu} vs {mu_s}");
        assert!((lvar - s2_s).abs() / s2_s < 0.08, "log-var {lvar} vs {s2_s}");
    }
}
