//! Table 1 (GLUE-like accuracy across methods) and Fig 10 (fixed
//! alpha/beta ablation).

use anyhow::Result;

use super::maybe_write_csv;
use crate::cli::Args;
use crate::data::tasks::{GlueGen, GlueTask};
use crate::runtime::{artifacts_dir, Engine, HostTensor};
use crate::training::driver::{accuracy_from_logits, TrainDriver};
use crate::util::print_table;

/// Train a classification artifact on a generator and return
/// (final accuracy, max grad norm, final loss).
pub fn train_and_eval_cls(
    engine: &mut Engine,
    dir: &std::path::Path,
    artifact: &str,
    train_gen: &mut dyn FnMut() -> (Vec<i32>, Vec<i32>, usize, usize),
    eval_gen: &mut dyn FnMut() -> (Vec<i32>, Vec<i32>, usize, usize),
    steps: usize,
    eval_batches: usize,
    lr: f64,
    num_classes: usize,
) -> Result<(f64, f64, f32)> {
    let mut driver = TrainDriver::new(engine, dir, artifact)?;
    let mut max_gnorm = 0.0f64;
    let mut last_loss = f32::NAN;
    for step in 0..steps {
        let (tokens, labels, b, n) = train_gen();
        // Linear warmup over the first 10%.
        let warm = (steps / 10).max(1);
        let lr_t = if step < warm { lr * (step + 1) as f64 / warm as f64 } else { lr };
        let out = driver.step(
            engine,
            lr_t,
            &[
                HostTensor::I32 { shape: vec![b, n], data: tokens },
                HostTensor::I32 { shape: vec![b], data: labels },
            ],
        )?;
        max_gnorm = max_gnorm.max(out.grad_norm as f64);
        last_loss = out.loss;
    }
    // Held-out accuracy.
    let mut correct_weighted = 0.0;
    let mut total = 0usize;
    for _ in 0..eval_batches {
        let (tokens, labels, b, n) = eval_gen();
        let outs = driver.eval(engine, &[HostTensor::I32 { shape: vec![b, n], data: tokens }])?;
        let logits = outs[0].as_f32()?;
        correct_weighted += accuracy_from_logits(logits, &labels, num_classes) * b as f64;
        total += b;
    }
    Ok((correct_weighted / total as f64, max_gnorm, last_loss))
}

const TABLE1_METHODS: &[&str] = &["softmax", "lln", "lln_diag", "elu", "performer", "nystrom"];

pub fn run_table1(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let steps = args.get_usize("steps", 250)?;
    let eval_batches = args.get_usize("eval-batches", 12)?;
    let lr = args.get_f64("lr", 1e-3)?;
    let methods = args.get_list("methods", &TABLE1_METHODS.join(","));
    let mut engine = Engine::new(&dir)?;

    println!("== Table 1: accuracy on the GLUE-like synthetic suite ==");
    println!("   ({} train steps/task, batch 16 x 128 tokens; chance = 33%/50%)\n", steps);

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for method in &methods {
        let artifact = format!("train_glue_{method}");
        let mut accs = Vec::new();
        for task in GlueTask::ALL {
            let mut tg = GlueGen::new(task, 512, 128, 100);
            let mut eg = GlueGen::new(task, 512, 128, 999); // held-out stream
            let mut train_fn = || {
                let b = tg.batch(16);
                (b.tokens, b.labels, 16usize, 128usize)
            };
            let mut eval_fn = || {
                let b = eg.batch(16);
                (b.tokens, b.labels, 16usize, 128usize)
            };
            let (acc, _gn, _loss) = train_and_eval_cls(
                &mut engine, &dir, &artifact, &mut train_fn, &mut eval_fn,
                steps, eval_batches, lr, 4,
            )?;
            accs.push(acc);
            eprintln!("   [{method}] {}: {:.1}%", task.name(), acc * 100.0);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        let mut row = vec![method.to_string()];
        row.extend(accs.iter().map(|a| format!("{:.1}", a * 100.0)));
        row.push(format!("{:.1}", avg * 100.0));
        csv.push(format!(
            "{method},{}",
            accs.iter().chain(std::iter::once(&avg)).map(|a| format!("{:.3}", a * 100.0)).collect::<Vec<_>>().join(",")
        ));
        rows.push(row);
    }
    print_table(
        &["method", "MNLI-like", "QNLI-like", "QQP-like", "SST2-like", "Avg"],
        &rows,
    );
    println!("\npaper shape: LLN+Diag ~ softmax > LLN > ELU > Performer-class baselines");
    maybe_write_csv(args, "table1", "method,nli,qnli,qqp,sst2,avg", &csv)?;
    Ok(())
}

pub fn run_fig10(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let steps = args.get_usize("steps", 200)?;
    let lr = args.get_f64("lr", 1e-3)?;
    let mut engine = Engine::new(&dir)?;

    println!("== Fig 10: LLN with fixed alpha = beta (SST2-like task) ==\n");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for alpha in ["0p5", "1p0", "2p0", "3p0", "4p0"] {
        let artifact = format!("train_fig10_a{alpha}");
        let mut tg = GlueGen::new(GlueTask::Sst2, 512, 128, 100);
        let mut eg = GlueGen::new(GlueTask::Sst2, 512, 128, 999);
        let mut train_fn = || {
            let b = tg.batch(16);
            (b.tokens, b.labels, 16usize, 128usize)
        };
        let mut eval_fn = || {
            let b = eg.batch(16);
            (b.tokens, b.labels, 16usize, 128usize)
        };
        let (acc, max_gnorm, _) = train_and_eval_cls(
            &mut engine, &dir, &artifact, &mut train_fn, &mut eval_fn, steps, 10, lr, 4,
        )?;
        let a = alpha.replace('p', ".");
        rows.push(vec![a.clone(), format!("{:.1}", acc * 100.0), format!("{max_gnorm:.2}")]);
        csv.push(format!("{a},{},{max_gnorm}", acc * 100.0));
    }
    print_table(&["alpha=beta", "accuracy [%]", "max grad-norm"], &rows);
    println!("\npaper shape: accuracy plateaus for alpha >= ~2 (the moment-matching");
    println!("range); grad-norm (the FP16 loss-scale telemetry proxy) grows with alpha.");
    maybe_write_csv(args, "fig10", "alpha,accuracy,max_grad_norm", &csv)?;
    Ok(())
}
