//! Property-based parity suite for the attention backends (built on
//! `lln::testkit`, the repo's proptest substitute).
//!
//! Pins the three invariants every backend must satisfy across random
//! shapes, scales, thread counts, and chunk sizes:
//!
//!   1. forward(q, k, v) ~= explicit_matrix(q, k) @ v for every method
//!      that exposes a dense matrix;
//!   2. every explicit attention matrix is row-stochastic (rows sum to
//!      1 +- 1e-4, entries >= 0) — modulo ReLU's degenerate all-zero
//!      rows, which carry no mass at all;
//!   3. the blocked/parallel kernels match the single-threaded scalar
//!      reference (bitwise for the row-partitioned kernels, within a
//!      scaled 1e-5 for the chunk-streamed reformulation);
//!   4. the fused O(n·tile) exact kernels match the dense
//!      `softmax_attention_matrix @ v` route within a scaled 1e-5 for
//!      every tile/unroll/thread configuration — explicitly including
//!      n not divisible by the tile and tile > n — while the register-
//!      blocked matmuls stay pinned to the old scalar `*_ref` loops;
//!   5. the causal/masked [`AttnSpec`] kernels match their dense masked
//!      references (fused-causal vs masked dense softmax, prefix-state
//!      causal linear vs masked dense linear) across off-tile shapes,
//!      and future keys have exactly zero influence on causal outputs;
//!   6. decode sessions replay the causal forward: for every maskable
//!      method, N `begin_decode` + `decode_step` calls reproduce the
//!      batch causal forward's rows — *bitwise* for the linear
//!      prefix-state path (the chunk-carry structure is shared with
//!      `linear_attention_causal`), within streaming tolerance for the
//!      KV-cache path — and interleaved sessions stay independent.
//!
//! Reproduce failures with `LLN_PROP_SEED=<seed> cargo test`.

use lln::attention::{self as att, backend_for, default_backend, AttnSpec, BackendParams, Method};
use lln::tensor::Mat;
use lln::testkit::{check, prop_assert, Gen, PropResult};

const FULL: AttnSpec = AttnSpec::FULL;

/// Random mask spec: full / causal / padded / causal+padded, with the
/// key length drawn around the key-set size (including 0 and over-long).
fn gen_spec(g: &mut Gen, nk: usize) -> AttnSpec {
    let causal = g.bool();
    let key_len = if g.bool() { Some(g.usize_in(0, nk + 8)) } else { None };
    AttnSpec { causal, key_len, scale: None }
}

fn gauss_mat(g: &mut Gen, rows: usize, cols: usize, std: f32) -> Mat {
    Mat::from_fn(rows, cols, |_, _| g.gauss_f32(std))
}

/// Max-abs closeness with tolerance scaled by the reference magnitude.
fn assert_close(a: &Mat, b: &Mat, base_tol: f32, what: &str) -> PropResult {
    let scale = b.data().iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1.0);
    let err = a.max_abs_diff(b);
    prop_assert(
        err <= base_tol * scale,
        format!("{what}: max|diff| = {err} (tol {} at scale {scale})", base_tol * scale),
    )
}

/// Methods exposing a dense stochastic matrix (parity-testable route).
const EXPLICIT_METHODS: [Method; 8] = [
    Method::Softmax,
    Method::Lln,
    Method::LlnDiag,
    Method::Elu,
    Method::Relu,
    Method::Quadratic,
    Method::Performer,
    Method::BlockDiag,
];

#[test]
fn forward_matches_explicit_matrix_route() {
    check(48, |g| {
        let block = *g.choose(&[4usize, 8, 16]);
        let n = block * g.usize_in(1, 4);
        let d = g.usize_in(4, 24);
        let alpha = g.f32_in(0.5, 1.5);
        let threads = g.usize_in(1, 4);
        let chunk = g.usize_in(1, 40);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        for m in EXPLICIT_METHODS {
            let params =
                BackendParams { alpha, beta: alpha, block, threads, chunk, ..Default::default() };
            let bk = backend_for(m, params);
            let p = match bk.explicit_matrix(&q, &k, &FULL) {
                Some(p) => p,
                None => return prop_assert(false, format!("{} lost its matrix", bk.name())),
            };
            assert_close(
                &bk.forward(&q, &k, &v, &FULL),
                &p.matmul(&v),
                5e-4,
                &format!("{} n={n} d={d} a={alpha}", bk.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn spec_forward_matches_explicit_matrix_route() {
    // The forward-vs-matrix parity invariant under random causal /
    // key_len masks, for every maskable method with a dense matrix.
    check(48, |g| {
        let block = *g.choose(&[4usize, 8, 16]);
        let n = block * g.usize_in(1, 4);
        let d = g.usize_in(4, 24);
        let alpha = g.f32_in(0.5, 1.5);
        let threads = g.usize_in(1, 4);
        let chunk = g.usize_in(1, 40);
        let spec = gen_spec(g, n);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        for m in EXPLICIT_METHODS {
            let params =
                BackendParams { alpha, beta: alpha, block, threads, chunk, ..Default::default() };
            let bk = backend_for(m, params);
            let p = match bk.explicit_matrix(&q, &k, &spec) {
                Some(p) => p,
                None => return prop_assert(false, format!("{} lost its matrix", bk.name())),
            };
            // Masked rows of a stochastic matrix must never carry mass
            // beyond their row limit.
            for i in 0..n {
                let lim = spec.row_limit(i, n);
                for (j, &x) in p.row(i).iter().enumerate() {
                    if j >= lim {
                        prop_assert(
                            x == 0.0,
                            format!("{} {spec:?}: mass at masked ({i},{j})", bk.name()),
                        )?;
                    }
                }
            }
            assert_close(
                &bk.forward(&q, &k, &v, &spec),
                &p.matmul(&v),
                5e-4,
                &format!("{} n={n} d={d} a={alpha} {spec:?}", bk.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn explicit_matrices_are_row_stochastic() {
    check(48, |g| {
        let block = *g.choose(&[4usize, 8, 16]);
        let n = block * g.usize_in(1, 4);
        let d = g.usize_in(12, 32);
        let alpha = g.f32_in(0.5, 2.0);
        let sigma = g.f32_in(0.3, 1.2);
        let q = gauss_mat(g, n, d, sigma);
        let k = gauss_mat(g, n, d, sigma);
        for m in EXPLICIT_METHODS {
            let params = BackendParams { alpha, beta: alpha, block, ..Default::default() };
            let p = backend_for(m, params).explicit_matrix(&q, &k, &FULL).unwrap();
            prop_assert(p.shape() == (n, n), format!("{m:?}: shape {:?}", p.shape()))?;
            for (ri, s) in p.row_sums().iter().enumerate() {
                let row_max = p.row(ri).iter().fold(0.0f32, |acc, &x| acc.max(x.abs()));
                // A ReLU row whose features all died carries no mass;
                // every other row must be a probability distribution.
                let degenerate = m == Method::Relu && row_max < 1e-6;
                prop_assert(
                    degenerate || (s - 1.0).abs() < 1e-4,
                    format!("{m:?} n={n} d={d}: row {ri} sums to {s}"),
                )?;
            }
            prop_assert(
                p.data().iter().all(|&x| x >= -1e-6),
                format!("{m:?}: negative attention weight"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn parallel_matmuls_match_scalar_reference() {
    check(64, |g| {
        let m = g.usize_in(1, 40);
        let kdim = g.usize_in(1, 32);
        let n = g.usize_in(1, 40);
        let threads = g.usize_in(1, 4);
        let a = gauss_mat(g, m, kdim, 1.0);
        let b = gauss_mat(g, kdim, n, 1.0);
        prop_assert(
            a.par_matmul(&b, threads).max_abs_diff(&a.matmul(&b)) < 1e-5,
            format!("par_matmul {m}x{kdim}x{n} t={threads}"),
        )?;
        let c = gauss_mat(g, n, kdim, 1.0);
        prop_assert(
            a.par_matmul_t(&c, threads).max_abs_diff(&a.matmul_t(&c)) < 1e-5,
            format!("par_matmul_t {m}x{kdim}x{n} t={threads}"),
        )
    });
}

#[test]
fn blocked_matmuls_match_scalar_reference_paths() {
    // The register-blocked kernels behind Mat::matmul / Mat::matmul_t
    // reorder f32 sums into LANES-wide accumulators; they must stay
    // within scaled epsilon of the original scalar loops (kept as
    // matmul_ref / matmul_t_ref), and the PR-1 parallel baseline must
    // stay bitwise-pinned to its scalar reference.
    check(48, |g| {
        let m = g.usize_in(1, 40);
        let kdim = g.usize_in(1, 80);
        let n = g.usize_in(1, 40);
        let threads = g.usize_in(1, 4);
        let a = gauss_mat(g, m, kdim, 1.0);
        let b = gauss_mat(g, kdim, n, 1.0);
        assert_close(
            &a.matmul(&b),
            &a.matmul_ref(&b),
            1e-5,
            &format!("matmul vs ref {m}x{kdim}x{n}"),
        )?;
        let c = gauss_mat(g, n, kdim, 1.0);
        assert_close(
            &a.matmul_t(&c),
            &a.matmul_t_ref(&c),
            1e-5,
            &format!("matmul_t vs ref {m}x{kdim}x{n}"),
        )?;
        prop_assert(
            a.par_matmul_t_ref(&c, threads).data() == a.matmul_t_ref(&c).data(),
            format!("par_matmul_t_ref not bitwise vs scalar ref {m}x{kdim}x{n} t={threads}"),
        )
    });
}

#[test]
fn fused_softmax_matches_dense_route() {
    // Shapes are deliberately off-tile: n, nk free in [1, 97], tile
    // drawn from a set that includes 1, non-divisors, and tile > n.
    check(48, |g| {
        let n = g.usize_in(1, 97);
        let nk = g.usize_in(1, 97);
        let d = g.usize_in(1, 24);
        let dv = g.usize_in(1, 16);
        let tile = *g.choose(&[1usize, 3, 8, 16, 33, 64, 128, 300]);
        let unroll = g.usize_in(0, 5);
        let threads = g.usize_in(1, 4);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, nk, d, 0.8);
        let v = gauss_mat(g, nk, dv, 1.0);
        let dense = att::softmax_attention_matrix(&q, &k).matmul(&v);
        let fused = att::fused_softmax_attention(&q, &k, &v, tile, unroll, threads);
        assert_close(
            &fused,
            &dense,
            1e-5,
            &format!("fused softmax n={n} nk={nk} d={d} dv={dv} tile={tile} u={unroll} t={threads}"),
        )
    });
}

#[test]
fn fused_quadratic_matches_dense_route() {
    check(32, |g| {
        let n = g.usize_in(1, 64);
        let nk = g.usize_in(1, 64);
        let d = g.usize_in(1, 16);
        let tile = *g.choose(&[1usize, 5, 16, 50, 200]);
        let unroll = g.usize_in(0, 5);
        let threads = g.usize_in(1, 4);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, nk, d, 0.8);
        let v = gauss_mat(g, nk, d, 1.0);
        let dense = att::quadratic_attention_matrix(&q, &k).matmul(&v);
        let fused = att::fused_quadratic_attention(&q, &k, &v, tile, unroll, threads);
        assert_close(
            &fused,
            &dense,
            2e-5,
            &format!("fused quadratic n={n} nk={nk} d={d} tile={tile} u={unroll} t={threads}"),
        )
    });
}

#[test]
fn fused_and_unfused_exact_backends_agree() {
    // The `fused` knob must be a pure perf/memory switch: Softmax and
    // Quadratic forwards agree across it within streaming tolerance.
    check(32, |g| {
        let n = g.usize_in(1, 80);
        let d = g.usize_in(2, 24);
        let tile = *g.choose(&[0usize, 7, 32, 130]);
        let unroll = g.usize_in(0, 5);
        let threads = g.usize_in(1, 4);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        // The agreement must hold under any mask, not just full — the
        // `fused` knob is a pure perf/memory switch in both regimes.
        let spec = gen_spec(g, n);
        for m in [Method::Softmax, Method::Quadratic] {
            let fused_params =
                BackendParams { tile, unroll, threads, ..Default::default() };
            let unfused_params = BackendParams { fused: false, threads, ..Default::default() };
            assert_close(
                &backend_for(m, fused_params).forward(&q, &k, &v, &spec),
                &backend_for(m, unfused_params).forward(&q, &k, &v, &spec),
                2e-5,
                &format!("{m:?} fused vs unfused n={n} d={d} tile={tile} u={unroll} t={threads} {spec:?}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn fused_causal_softmax_matches_masked_dense() {
    // The fused causal streaming kernel vs the dense masked reference,
    // over deliberately off-tile shapes: n, nk free in [1, 97], tile
    // drawn from a set including 1, non-divisors, and tile > n, plus
    // random key-length padding (0, partial, and over-long).
    check(48, |g| {
        let n = g.usize_in(1, 97);
        let nk = g.usize_in(1, 97);
        let d = g.usize_in(1, 24);
        let dv = g.usize_in(1, 16);
        let tile = *g.choose(&[1usize, 3, 8, 16, 33, 64, 128, 300]);
        let unroll = g.usize_in(0, 5);
        let threads = g.usize_in(1, 4);
        let key_len = if g.bool() { Some(g.usize_in(0, nk + 8)) } else { None };
        let spec = AttnSpec { causal: true, key_len, scale: None };
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, nk, d, 0.8);
        let v = gauss_mat(g, nk, dv, 1.0);
        let dense = att::softmax_attention_matrix_spec(&q, &k, &spec).matmul(&v);
        let fused = att::fused_softmax_attention_spec(&q, &k, &v, &spec, tile, unroll, threads);
        assert_close(
            &fused,
            &dense,
            1e-5,
            &format!(
                "fused causal n={n} nk={nk} d={d} dv={dv} tile={tile} u={unroll} t={threads} kl={key_len:?}"
            ),
        )
    });
}

#[test]
fn causal_linear_matches_masked_dense_linear() {
    // The O(N) prefix-state recurrence vs the dense masked linear
    // matrix, across chunk/thread partitions and key paddings.
    check(48, |g| {
        let n = g.usize_in(1, 80);
        let feat = g.usize_in(1, 16);
        let dv = g.usize_in(1, 16);
        let chunk = g.usize_in(1, 64);
        let threads = g.usize_in(1, 4);
        let alpha = g.f32_in(0.3, 1.2);
        let key_len = if g.bool() { Some(g.usize_in(0, n + 8)) } else { None };
        let spec = AttnSpec { causal: true, key_len, scale: None };
        let pq = att::lln_features(&gauss_mat(g, n, feat, 0.8), alpha);
        let pk = att::lln_features(&gauss_mat(g, n, feat, 0.8), alpha);
        let v = gauss_mat(g, n, dv, 1.0);
        let dense = att::linear_attention_matrix_spec(&pq, &pk, &spec).matmul(&v);
        let fast = att::linear_attention_causal(&pq, &pk, &v, key_len, chunk, threads);
        assert_close(
            &fast,
            &dense,
            5e-5,
            &format!("causal linear n={n} m={feat} dv={dv} chunk={chunk} t={threads} kl={key_len:?}"),
        )
    });
}

#[test]
fn future_keys_have_zero_influence_on_causal_outputs() {
    // Perturb every key/value row past a cut point: under the causal
    // mask, outputs at or before the cut must be *bitwise* unchanged —
    // the masked tiles are never read, not just small.
    check(32, |g| {
        let n = g.usize_in(2, 80);
        let d = g.usize_in(2, 16);
        let cut = g.usize_in(0, n - 1); // rows 0..=cut stay clean
        let tile = *g.choose(&[1usize, 7, 16, 50, 130]);
        let threads = g.usize_in(1, 4);
        let chunk = g.usize_in(1, 32);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for i in (cut + 1)..n {
            for j in 0..d {
                k2.set(i, j, k2.get(i, j) + 7.5);
                v2.set(i, j, v2.get(i, j) - 3.25);
            }
        }
        let spec = AttnSpec::CAUSAL;
        // Fused causal softmax.
        let a = att::fused_softmax_attention_spec(&q, &k, &v, &spec, tile, 0, threads);
        let b = att::fused_softmax_attention_spec(&q, &k2, &v2, &spec, tile, 0, threads);
        for i in 0..=cut {
            prop_assert(
                a.row(i) == b.row(i),
                format!("fused causal row {i} (cut {cut}, n={n}) saw future keys"),
            )?;
        }
        // Prefix-state causal linear.
        let pq = att::lln_features(&q, 1.1);
        let pk = att::lln_features(&k, 1.1);
        let pk2 = att::lln_features(&k2, 1.1);
        let la = att::linear_attention_causal(&pq, &pk, &v, None, chunk, threads);
        let lb = att::linear_attention_causal(&pq, &pk2, &v2, None, chunk, threads);
        for i in 0..=cut {
            prop_assert(
                la.row(i) == lb.row(i),
                format!("causal linear row {i} (cut {cut}, n={n}) saw future keys"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn parallel_softmax_matches_scalar_reference() {
    check(64, |g| {
        let m = g.usize_in(1, 48);
        let n = g.usize_in(1, 48);
        let threads = g.usize_in(1, 4);
        let base = gauss_mat(g, m, n, 3.0);
        let mut scalar = base.clone();
        scalar.softmax_rows();
        let mut par = base.clone();
        par.par_softmax_rows(threads);
        prop_assert(
            par.max_abs_diff(&scalar) < 1e-5,
            format!("par_softmax_rows {m}x{n} t={threads}"),
        )
    });
}

#[test]
fn streamed_linear_attention_matches_scalar_reference() {
    check(48, |g| {
        let nq = g.usize_in(1, 48);
        let nk = g.usize_in(1, 48);
        let feat = g.usize_in(1, 16);
        let dv = g.usize_in(1, 16);
        let chunk = g.usize_in(1, 64);
        let threads = g.usize_in(1, 4);
        let alpha = g.f32_in(0.3, 1.2);
        let pq = att::lln_features(&gauss_mat(g, nq, feat, 0.8), alpha);
        let pk = att::lln_features(&gauss_mat(g, nk, feat, 0.8), alpha);
        let v = gauss_mat(g, nk, dv, 1.0);
        let naive = att::linear_attention(&pq, &pk, &v);
        let fast = att::linear_attention_streamed(&pq, &pk, &v, chunk, threads);
        // 5e-5 scaled: the streamed form reorders f32 sums, so exact
        // 1e-5 holds at unit scale but needs headroom at |v|-scale.
        assert_close(
            &fast,
            &naive,
            5e-5,
            &format!("streamed nq={nq} nk={nk} m={feat} dv={dv} chunk={chunk} t={threads}"),
        )
    });
}

#[test]
fn backend_forwards_match_scalar_kernels() {
    check(32, |g| {
        let n = 8 * g.usize_in(1, 6);
        let d = g.usize_in(4, 24);
        let alpha = g.f32_in(0.5, 1.5);
        let threads = g.usize_in(1, 4);
        let chunk = g.usize_in(1, 32);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        // fused: false — this property pins the *materialized* pipeline
        // to the scalar kernels; the fused path has its own dense-route
        // parity property above.
        let params = BackendParams {
            alpha,
            beta: alpha,
            block: 8,
            threads,
            chunk,
            fused: false,
            ..Default::default()
        };

        let sm = backend_for(Method::Softmax, params).forward(&q, &k, &v, &FULL);
        prop_assert(
            sm.max_abs_diff(&att::softmax_attention(&q, &k, &v)) < 1e-6,
            format!("softmax backend diverged n={n} d={d} t={threads}"),
        )?;

        let lln = backend_for(Method::Lln, params).forward(&q, &k, &v, &FULL);
        assert_close(
            &lln,
            &att::lln_attention(&q, &k, &v, alpha, alpha),
            5e-5,
            &format!("lln backend n={n} d={d} t={threads} chunk={chunk}"),
        )?;

        let bd = backend_for(Method::BlockDiag, params).forward(&q, &k, &v, &FULL);
        assert_close(
            &bd,
            &att::blockdiag_attention(&q, &k, &v, 8),
            1e-6,
            &format!("blockdiag backend n={n} t={threads}"),
        )?;

        let diag = backend_for(Method::LlnDiag, params).forward(&q, &k, &v, &FULL);
        assert_close(
            &diag,
            &att::lln_diag_attention(&q, &k, &v, alpha, alpha, 8),
            5e-5,
            &format!("lln_diag backend n={n} t={threads}"),
        )
    });
}

#[test]
fn implicit_backends_produce_finite_shaped_outputs() {
    check(24, |g| {
        let lm = g.usize_in(2, 8);
        let n = lm * g.usize_in(1, 6);
        let d = g.usize_in(4, 16);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        for m in [Method::Nystrom, Method::Linformer] {
            let params = BackendParams { landmarks: lm, kproj: n.min(8), ..Default::default() };
            let bk = backend_for(m, params);
            prop_assert(bk.explicit_matrix(&q, &k, &FULL).is_none(), format!("{m:?} grew a matrix"))?;
            let out = bk.forward(&q, &k, &v, &FULL);
            prop_assert(out.shape() == (n, d), format!("{m:?}: shape {:?}", out.shape()))?;
            prop_assert(
                out.data().iter().all(|x| x.is_finite()),
                format!("{m:?}: non-finite output n={n} d={d}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn flops_models_are_positive_and_monotone() {
    check(24, |g| {
        let n1 = g.usize_in(64, 512);
        let n2 = n1 * g.usize_in(2, 8);
        let d = *g.choose(&[32usize, 64, 128]);
        for bk in att::all_backends() {
            let (f1, f2) = (bk.flops_model(n1, d, &FULL), bk.flops_model(n2, d, &FULL));
            prop_assert(
                f1 > 0.0 && f2 > f1,
                format!("{}: flops not monotone ({f1} -> {f2})", bk.name()),
            )?;
            // A mask can only remove work: causal/padded flops are
            // positive and never exceed the dense model; causal halves
            // (to leading order) the quadratic class.
            let fc = bk.flops_model(n1, d, &AttnSpec::CAUSAL);
            prop_assert(
                fc > 0.0 && fc <= f1,
                format!("{}: causal flops {fc} vs dense {f1}", bk.name()),
            )?;
            if !bk.method().is_linear() {
                let ratio = fc / f1;
                prop_assert(
                    (0.4..=0.6).contains(&ratio),
                    format!("{}: causal must ~halve quadratic flops ({ratio})", bk.name()),
                )?;
            }
            let fp = bk.flops_model(n1, d, &AttnSpec::padded(n1 / 2));
            prop_assert(
                fp > 0.0 && fp <= f1,
                format!("{}: padded flops {fp} vs dense {f1}", bk.name()),
            )?;
        }
        Ok(())
    });
}

/// Every maskable method (the decode-capable set).
const MASKABLE_METHODS: [Method; 8] = [
    Method::Softmax,
    Method::Lln,
    Method::LlnDiag,
    Method::Elu,
    Method::Relu,
    Method::Quadratic,
    Method::Performer,
    Method::BlockDiag,
];

#[test]
fn decode_steps_replay_the_causal_forward() {
    // For every maskable method: stepping a decode session token by
    // token reproduces the batch causal forward's rows on the same
    // Q/K/V.  Bitwise for the linear prefix-state class (LLN/ELU/ReLU —
    // the session shares the chunk-carry structure and FP order of
    // linear_attention_causal); within tolerance for the KV-cache class
    // and Performer's projected features.
    check(24, |g| {
        let block = *g.choose(&[4usize, 8, 16]);
        let n = block * g.usize_in(1, 5);
        let d = g.usize_in(4, 20);
        let alpha = g.f32_in(0.5, 1.4);
        let threads = g.usize_in(1, 4);
        let chunk = g.usize_in(1, 40);
        let tile = *g.choose(&[0usize, 7, 16, 33, 130]);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        for m in MASKABLE_METHODS {
            let params = BackendParams {
                alpha,
                beta: alpha,
                block,
                threads,
                chunk,
                tile,
                ..Default::default()
            };
            let bk = backend_for(m, params);
            let full = bk.forward(&q, &k, &v, &AttnSpec::CAUSAL);
            let mut state = match bk.begin_decode(d, d) {
                Ok(s) => s,
                Err(e) => return prop_assert(false, format!("{m:?} refused decode: {e}")),
            };
            for i in 0..n {
                let row = bk.decode_step(&mut state, q.row(i), k.row(i), v.row(i));
                if matches!(m, Method::Lln | Method::Elu | Method::Relu) {
                    prop_assert(
                        row == full.row(i),
                        format!(
                            "{m:?} n={n} d={d} chunk={chunk}: decode step {i} not bitwise \
                             vs causal forward"
                        ),
                    )?;
                } else {
                    let scale =
                        full.row(i).iter().fold(0.0f32, |mx, &x| mx.max(x.abs())).max(1.0);
                    for (a, b) in row.iter().zip(full.row(i)) {
                        prop_assert(
                            (a - b).abs() <= 5e-4 * scale,
                            format!(
                                "{m:?} n={n} d={d} tile={tile}: decode step {i} diverged \
                                 ({a} vs {b})"
                            ),
                        )?;
                    }
                }
            }
            prop_assert(
                state.len() == n,
                format!("{m:?}: state len {} after {n} steps", state.len()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn decode_state_is_flat_for_linear_methods_and_grows_for_caches() {
    // The acceptance shape of the memory story: prefix-state sessions
    // hold O(m·dv) bytes independent of the decoded length, cache
    // sessions grow linearly.
    check(8, |g| {
        let d = g.usize_in(4, 16);
        let steps = g.usize_in(8, 40);
        let q = gauss_mat(g, steps, d, 0.8);
        let k = gauss_mat(g, steps, d, 0.8);
        let v = gauss_mat(g, steps, d, 1.0);
        for m in MASKABLE_METHODS {
            let bk = backend_for(m, BackendParams::default());
            let mut state = bk.begin_decode(d, d).expect("maskable method must decode");
            let mut bytes_at_1 = 0usize;
            for i in 0..steps {
                bk.decode_step(&mut state, q.row(i), k.row(i), v.row(i));
                if i == 0 {
                    bytes_at_1 = state.state_bytes();
                }
            }
            let linear_state = matches!(m, Method::Lln | Method::Elu | Method::Relu | Method::Performer);
            if linear_state {
                prop_assert(
                    state.state_bytes() == bytes_at_1,
                    format!("{m:?}: prefix state grew {bytes_at_1} -> {}", state.state_bytes()),
                )?;
            } else {
                prop_assert(
                    state.state_bytes() > bytes_at_1,
                    format!("{m:?}: cache state did not grow ({bytes_at_1})"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn interleaved_decode_sessions_are_independent() {
    // Two sessions stepped in lockstep through the same backend must
    // produce exactly what each produces alone — no shared state.
    check(16, |g| {
        let n = 8 * g.usize_in(1, 4);
        let d = g.usize_in(4, 16);
        let q1 = gauss_mat(g, n, d, 0.8);
        let k1 = gauss_mat(g, n, d, 0.8);
        let v1 = gauss_mat(g, n, d, 1.0);
        let q2 = gauss_mat(g, n, d, 0.8);
        let k2 = gauss_mat(g, n, d, 0.8);
        let v2 = gauss_mat(g, n, d, 1.0);
        for m in [Method::Lln, Method::Softmax, Method::LlnDiag] {
            let bk = backend_for(m, BackendParams { block: 8, ..Default::default() });
            // Solo runs.
            let mut sa = bk.begin_decode(d, d).unwrap();
            let solo_a: Vec<Vec<f32>> =
                (0..n).map(|i| bk.decode_step(&mut sa, q1.row(i), k1.row(i), v1.row(i))).collect();
            let mut sb = bk.begin_decode(d, d).unwrap();
            let solo_b: Vec<Vec<f32>> =
                (0..n).map(|i| bk.decode_step(&mut sb, q2.row(i), k2.row(i), v2.row(i))).collect();
            // Interleaved.
            let mut ia = bk.begin_decode(d, d).unwrap();
            let mut ib = bk.begin_decode(d, d).unwrap();
            for i in 0..n {
                let ra = bk.decode_step(&mut ia, q1.row(i), k1.row(i), v1.row(i));
                let rb = bk.decode_step(&mut ib, q2.row(i), k2.row(i), v2.row(i));
                prop_assert(ra == solo_a[i], format!("{m:?}: session A step {i} contaminated"))?;
                prop_assert(rb == solo_b[i], format!("{m:?}: session B step {i} contaminated"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn unmaskable_methods_refuse_decode_without_panicking() {
    for m in [Method::Nystrom, Method::Linformer] {
        let err = default_backend(m).begin_decode(16, 16).unwrap_err();
        assert!(err.contains("causal"), "{m:?}: {err}");
    }
}

#[test]
fn default_backends_cover_every_method() {
    for (bk, m) in att::all_backends().iter().zip(Method::ALL) {
        assert_eq!(bk.method(), m);
        assert_eq!(bk.name(), m.name());
        assert_eq!(Method::parse(bk.name()), Some(m));
    }
    // And the registry is consistent with single-method construction.
    for m in Method::ALL {
        assert_eq!(default_backend(m).method(), m);
    }
}

// ---------------------------------------------------------------------------
// Monomorphized head-dim kernels + low-precision KV storage
// ---------------------------------------------------------------------------

/// Methods whose forward/decode hot loops route through the
/// [`KernelDispatch`](lln::tensor::KernelDispatch) microkernels (every
/// maskable method minus ReLU/LLN+Diag, which are covered transitively
/// via the shared linear/blockdiag kernels the others exercise).
const DISPATCHED_METHODS: [Method; 6] = [
    Method::Softmax,
    Method::Quadratic,
    Method::BlockDiag,
    Method::Lln,
    Method::Elu,
    Method::Performer,
];

#[test]
fn specialized_head_dim_kernels_are_bitwise_identical_to_generic() {
    // The tentpole golden: for each specialized instance D ∈ {32, 64,
    // 128}, a backend constructed with `[compute] head_dim = D` (whose
    // dispatch table pins the const-generic microkernels) produces
    // *bitwise* the outputs of one pinned to the generic runtime-dim
    // loops (any head_dim with no specialized instance).  The spec
    // kernels are token-for-token copies of the generic loops, so any
    // FP reassociation is a bug, not a tolerance.
    check(12, |g| {
        let d = *g.choose(&[32usize, 64, 128]);
        let n = g.usize_in(3, 33);
        let spec = gen_spec(g, n);
        let threads = g.usize_in(1, 4);
        let tile = *g.choose(&[0usize, 7, 16, 130]);
        let chunk = g.usize_in(1, 40);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        for m in DISPATCHED_METHODS {
            if !m.supports_spec(&spec) {
                continue;
            }
            let base = BackendParams { threads, tile, chunk, ..Default::default() };
            let spec_bk = backend_for(m, BackendParams { head_dim: d, ..base });
            let gen_bk = backend_for(m, BackendParams { head_dim: d + 1, ..base });
            let a = spec_bk.forward(&q, &k, &v, &spec);
            let b = gen_bk.forward(&q, &k, &v, &spec);
            prop_assert(
                a == b,
                format!("{m:?} n={n} d={d} tile={tile} {spec:?}: specialized forward not bitwise"),
            )?;
            // The per-token decode hot path, where the construction-time
            // dispatch table matters most.
            let (mut sa, mut sb) = (spec_bk.begin_decode(d, d), gen_bk.begin_decode(d, d));
            if let (Ok(sa), Ok(sb)) = (sa.as_mut(), sb.as_mut()) {
                for i in 0..n.min(8) {
                    let ra = spec_bk.decode_step(sa, q.row(i), k.row(i), v.row(i));
                    let rb = gen_bk.decode_step(sb, q.row(i), k.row(i), v.row(i));
                    prop_assert(
                        ra == rb,
                        format!("{m:?} d={d} step {i}: specialized decode not bitwise"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn f32_precision_backends_are_a_bitwise_escape_hatch() {
    // `[compute] precision = "f32"` (the default) must leave every
    // backend bitwise-untouched: the storage wrapper is only applied
    // for narrower precisions.
    check(8, |g| {
        let n = g.usize_in(2, 24);
        let d = g.usize_in(4, 20);
        let spec = gen_spec(g, n);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        for m in MASKABLE_METHODS {
            if !m.supports_spec(&spec) {
                continue;
            }
            let f32_bk = backend_for(
                m,
                BackendParams { precision: lln::lowp::Precision::F32, ..Default::default() },
            );
            let plain = backend_for(m, BackendParams::default());
            let a = f32_bk.forward(&q, &k, &v, &spec);
            let b = plain.forward(&q, &k, &v, &spec);
            prop_assert(a == b, format!("{m:?} n={n} d={d}: f32 precision changed bits"))?;
        }
        Ok(())
    });
}

#[test]
fn low_precision_kv_storage_stays_within_documented_tolerances() {
    // Storage-only quantization: K/V are encoded at rest and decoded
    // to f32 before arithmetic, so the forward drifts from the f32
    // reference by at most the element-wise storage error amplified by
    // the row-stochastic mix — generous documented bounds: bf16 (8-bit
    // mantissa) 5e-2, f16 (11-bit) 1e-2, int8-kv (per-row affine over
    // the observed range) 2.5e-1, all scaled by the reference row max.
    check(12, |g| {
        use lln::lowp::Precision;
        let n = g.usize_in(2, 28);
        let d = g.usize_in(4, 20);
        let causal = g.bool();
        let spec = AttnSpec { causal, key_len: None, scale: None };
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        for m in [Method::Softmax, Method::Lln, Method::Quadratic, Method::BlockDiag] {
            let f32_out = backend_for(m, BackendParams::default()).forward(&q, &k, &v, &spec);
            for (prec, tol) in [
                (Precision::Bf16, 5e-2f32),
                (Precision::F16, 1e-2),
                (Precision::Int8Kv, 2.5e-1),
            ] {
                let bk = backend_for(m, BackendParams { precision: prec, ..Default::default() });
                let out = bk.forward(&q, &k, &v, &spec);
                assert_close(
                    &out,
                    &f32_out,
                    tol,
                    &format!("{m:?} n={n} d={d} {} storage", prec.name()),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn quantized_decode_replays_the_quantized_batch_forward() {
    // Under int8-kv the decode session quantizes each K/V row once at
    // push — a pure per-row function, so the batch forward (which
    // round-trips K/V through the same quantizer) sees identical
    // decoded values and the replay matches within the usual KV-cache
    // streaming tolerance, NOT the (much looser) quantization bound.
    check(10, |g| {
        use lln::lowp::Precision;
        let n = g.usize_in(2, 24);
        let d = g.usize_in(4, 20);
        let q = gauss_mat(g, n, d, 0.8);
        let k = gauss_mat(g, n, d, 0.8);
        let v = gauss_mat(g, n, d, 1.0);
        for prec in [Precision::Bf16, Precision::Int8Kv] {
            let bk = backend_for(
                Method::Softmax,
                BackendParams { precision: prec, ..Default::default() },
            );
            let full = bk.forward(&q, &k, &v, &AttnSpec::CAUSAL);
            let mut st = match bk.begin_decode(d, d) {
                Ok(s) => s,
                Err(e) => return prop_assert(false, format!("refused decode: {e}")),
            };
            for i in 0..n {
                let row = bk.decode_step(&mut st, q.row(i), k.row(i), v.row(i));
                let scale = full.row(i).iter().fold(0.0f32, |mx, &x| mx.max(x.abs())).max(1.0);
                for (a, b) in row.iter().zip(full.row(i)) {
                    prop_assert(
                        (a - b).abs() <= 1e-3 * scale,
                        format!("{} step {i}: {a} vs {b}", prec.name()),
                    )?;
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Backward kernels: finite-difference gradient checks + fused-vs-dense parity
// ---------------------------------------------------------------------------
//
// The f32 backward kernels are checked against central differences of
// *f64 reference forwards* implementing the same math (same masks via
// AttnSpec::row_limit, same EPS/clamp constants): the FD of the f64
// function is the true gradient to ~1e-10, so the measured error is
// the f32 analytic backward's own — the acceptance bound is a
// norm-wise relative error < 1e-3.

fn to_f64(m: &Mat) -> Vec<f64> {
    m.data().iter().map(|&x| x as f64).collect()
}

/// Norm-wise relative error between an analytic f32 gradient and an
/// f64 finite-difference estimate.
fn grad_rel_err(analytic: &[f32], fd: &[f64]) -> f64 {
    assert_eq!(analytic.len(), fd.len());
    let mut d2 = 0.0f64;
    let mut na = 0.0f64;
    let mut nf = 0.0f64;
    for (&a, &b) in analytic.iter().zip(fd) {
        let a = a as f64;
        d2 += (a - b) * (a - b);
        na += a * a;
        nf += b * b;
    }
    d2.sqrt() / (na.sqrt() + nf.sqrt() + 1e-12)
}

/// Central differences of `f` over every coordinate of `x`.
fn central_diff(x: &mut [f64], mut f: impl FnMut(&[f64]) -> f64, h: f64) -> Vec<f64> {
    (0..x.len())
        .map(|i| {
            let orig = x[i];
            x[i] = orig + h;
            let fp = f(x);
            x[i] = orig - h;
            let fm = f(x);
            x[i] = orig;
            (fp - fm) / (2.0 * h)
        })
        .collect()
}

/// f64 reference loss `Σ w ∘ softmax_attention(q, k, v)` under a spec
/// (masked rows carry no mass; fully masked rows are zero).
#[allow(clippy::too_many_arguments)]
fn softmax_loss_f64(
    q: &[f64],
    k: &[f64],
    v: &[f64],
    w: &[f64],
    nq: usize,
    nk: usize,
    d: usize,
    dv: usize,
    scale: f64,
    spec: &AttnSpec,
) -> f64 {
    let mut loss = 0.0f64;
    for i in 0..nq {
        let lim = spec.row_limit(i, nk);
        if lim == 0 {
            continue;
        }
        let qrow = &q[i * d..(i + 1) * d];
        let mut scores = Vec::with_capacity(lim);
        let mut m = f64::NEG_INFINITY;
        for j in 0..lim {
            let krow = &k[j * d..(j + 1) * d];
            let s: f64 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f64>() * scale;
            m = m.max(s);
            scores.push(s);
        }
        let mut sum = 0.0f64;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            sum += *s;
        }
        for t in 0..dv {
            let mut o = 0.0f64;
            for (j, p) in scores.iter().enumerate() {
                o += p * v[j * dv + t];
            }
            loss += w[i * dv + t] * o / sum;
        }
    }
    loss
}

/// f64 reference loss for linearized attention with explicit feature
/// maps (EPS = 1e-6 in the denominator, like the f32 kernels); q/k
/// rows are aligned (n x n problem).
#[allow(clippy::too_many_arguments)]
fn linear_loss_f64(
    q: &[f64],
    k: &[f64],
    v: &[f64],
    w: &[f64],
    n: usize,
    d: usize,
    dv: usize,
    spec: &AttnSpec,
    fq: &dyn Fn(f64) -> f64,
    fk: &dyn Fn(f64) -> f64,
) -> f64 {
    const EPS: f64 = 1e-6;
    let mut loss = 0.0f64;
    for i in 0..n {
        let lim = spec.row_limit(i, n);
        let pq: Vec<f64> = q[i * d..(i + 1) * d].iter().map(|&x| fq(x)).collect();
        let mut den = EPS;
        let mut num = vec![0.0f64; dv];
        for j in 0..lim {
            let pk: Vec<f64> = k[j * d..(j + 1) * d].iter().map(|&x| fk(x)).collect();
            let dot: f64 = pq.iter().zip(&pk).map(|(a, b)| a * b).sum();
            den += dot;
            for (o, &vv) in num.iter_mut().zip(&v[j * dv..(j + 1) * dv]) {
                *o += dot * vv;
            }
        }
        for t in 0..dv {
            loss += w[i * dv + t] * num[t] / den;
        }
    }
    loss
}

/// f64 reference loss for the quadratic kernel κ(q,k) = (q·k)².
#[allow(clippy::too_many_arguments)]
fn quadratic_loss_f64(
    q: &[f64],
    k: &[f64],
    v: &[f64],
    w: &[f64],
    n: usize,
    d: usize,
    dv: usize,
    spec: &AttnSpec,
) -> f64 {
    const EPS: f64 = 1e-6;
    let mut loss = 0.0f64;
    for i in 0..n {
        let lim = spec.row_limit(i, n);
        let qrow = &q[i * d..(i + 1) * d];
        let mut den = EPS;
        let mut num = vec![0.0f64; dv];
        for j in 0..lim {
            let krow = &k[j * d..(j + 1) * d];
            let s: f64 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
            let wgt = s * s;
            den += wgt;
            for (o, &vv) in num.iter_mut().zip(&v[j * dv..(j + 1) * dv]) {
                *o += wgt * vv;
            }
        }
        for t in 0..dv {
            loss += w[i * dv + t] * num[t] / den;
        }
    }
    loss
}

/// f64 twin of the kernels' clamped exp (EXP_CLAMP = 30).
fn cexp64(x: f64) -> f64 {
    x.clamp(-30.0, 30.0).exp()
}

/// The specs every gradient check runs under: full, causal, and both
/// key_len paddings — the acceptance matrix.
fn gradcheck_specs(n: usize) -> [AttnSpec; 4] {
    [
        AttnSpec::FULL,
        AttnSpec::CAUSAL,
        AttnSpec::causal_padded(n / 2 + 1),
        AttnSpec::padded(n - 2),
    ]
}

#[test]
fn softmax_backward_matches_f64_finite_differences() {
    let (n, d, dv) = (8usize, 5usize, 4usize);
    let mut rng = lln::rng::Pcg64::seed(0xFD01);
    let q = Mat::gaussian(n, d, 0.7, &mut rng);
    let k = Mat::gaussian(n, d, 0.7, &mut rng);
    let v = Mat::gaussian(n, dv, 0.9, &mut rng);
    let w = Mat::gaussian(n, dv, 1.0, &mut rng);
    let h = 1e-4;
    for spec in gradcheck_specs(n) {
        let bk = default_backend(Method::Softmax);
        let (_, cache) = bk.forward_train(&q, &k, &v, &spec).unwrap();
        let g = bk.backward(&q, &k, &v, &spec, &cache, &w).unwrap();
        let scale = spec.resolve_scale(d) as f64;
        let (qf, kf, vf, wf) = (to_f64(&q), to_f64(&k), to_f64(&v), to_f64(&w));
        let fd_q = central_diff(&mut qf.clone(), |x| {
            softmax_loss_f64(x, &kf, &vf, &wf, n, n, d, dv, scale, &spec)
        }, h);
        let fd_k = central_diff(&mut kf.clone(), |x| {
            softmax_loss_f64(&qf, x, &vf, &wf, n, n, d, dv, scale, &spec)
        }, h);
        let fd_v = central_diff(&mut vf.clone(), |x| {
            softmax_loss_f64(&qf, &kf, x, &wf, n, n, d, dv, scale, &spec)
        }, h);
        for (name, an, fd) in [
            ("dq", g.dq.data(), &fd_q),
            ("dk", g.dk.data(), &fd_k),
            ("dv", g.dv.data(), &fd_v),
        ] {
            let err = grad_rel_err(an, fd);
            assert!(err < 1e-3, "softmax {spec:?} {name}: rel err {err}");
        }
    }
}

#[test]
fn lln_backward_matches_f64_finite_differences_including_alpha_beta() {
    let (n, d, dv) = (8usize, 5usize, 4usize);
    let (alpha, beta) = (1.2f32, 0.9f32);
    let mut rng = lln::rng::Pcg64::seed(0xFD02);
    let q = Mat::gaussian(n, d, 0.6, &mut rng);
    let k = Mat::gaussian(n, d, 0.6, &mut rng);
    let v = Mat::gaussian(n, dv, 0.9, &mut rng);
    let w = Mat::gaussian(n, dv, 1.0, &mut rng);
    let h = 1e-4;
    for spec in gradcheck_specs(n) {
        let bk = backend_for(
            Method::Lln,
            BackendParams { alpha, beta, threads: 1, chunk: 3, ..Default::default() },
        );
        let (_, cache) = bk.forward_train(&q, &k, &v, &spec).unwrap();
        let g = bk.backward(&q, &k, &v, &spec, &cache, &w).unwrap();
        let (qf, kf, vf, wf) = (to_f64(&q), to_f64(&k), to_f64(&v), to_f64(&w));
        let (a64, b64) = (alpha as f64, beta as f64);
        let loss = |qx: &[f64], kx: &[f64], vx: &[f64], a: f64, b: f64| {
            linear_loss_f64(qx, kx, vx, &wf, n, d, dv, &spec, &|x| cexp64(a * x), &|x| {
                cexp64(b * x)
            })
        };
        let fd_q = central_diff(&mut qf.clone(), |x| loss(x, &kf, &vf, a64, b64), h);
        let fd_k = central_diff(&mut kf.clone(), |x| loss(&qf, x, &vf, a64, b64), h);
        let fd_v = central_diff(&mut vf.clone(), |x| loss(&qf, &kf, x, a64, b64), h);
        for (name, an, fd) in [
            ("dq", g.dq.data(), &fd_q),
            ("dk", g.dk.data(), &fd_k),
            ("dv", g.dv.data(), &fd_v),
        ] {
            let err = grad_rel_err(an, fd);
            assert!(err < 1e-3, "lln {spec:?} {name}: rel err {err}");
        }
        // dα / dβ: perturb the exponents themselves.
        let mut ab = vec![a64, b64];
        let fd_ab = central_diff(&mut ab, |x| loss(&qf, &kf, &vf, x[0], x[1]), h);
        let err_a = grad_rel_err(&[g.dalpha], &fd_ab[..1]);
        let err_b = grad_rel_err(&[g.dbeta], &fd_ab[1..]);
        assert!(err_a < 1e-3, "lln {spec:?} dalpha: rel err {err_a}");
        assert!(err_b < 1e-3, "lln {spec:?} dbeta: rel err {err_b}");
    }
}

#[test]
fn elu_backward_matches_f64_finite_differences() {
    let (n, d, dv) = (7usize, 4usize, 3usize);
    let mut rng = lln::rng::Pcg64::seed(0xFD03);
    let q = Mat::gaussian(n, d, 0.8, &mut rng);
    let k = Mat::gaussian(n, d, 0.8, &mut rng);
    let v = Mat::gaussian(n, dv, 0.9, &mut rng);
    let w = Mat::gaussian(n, dv, 1.0, &mut rng);
    let elu64 = |x: f64| if x > 0.0 { x + 1.0 } else { x.exp() };
    let h = 1e-4;
    for spec in gradcheck_specs(n) {
        let bk = backend_for(Method::Elu, BackendParams { threads: 1, ..Default::default() });
        let (_, cache) = bk.forward_train(&q, &k, &v, &spec).unwrap();
        let g = bk.backward(&q, &k, &v, &spec, &cache, &w).unwrap();
        let (qf, kf, vf, wf) = (to_f64(&q), to_f64(&k), to_f64(&v), to_f64(&w));
        let fd_q = central_diff(&mut qf.clone(), |x| {
            linear_loss_f64(x, &kf, &vf, &wf, n, d, dv, &spec, &elu64, &elu64)
        }, h);
        let fd_k = central_diff(&mut kf.clone(), |x| {
            linear_loss_f64(&qf, x, &vf, &wf, n, d, dv, &spec, &elu64, &elu64)
        }, h);
        let fd_v = central_diff(&mut vf.clone(), |x| {
            linear_loss_f64(&qf, &kf, x, &wf, n, d, dv, &spec, &elu64, &elu64)
        }, h);
        for (name, an, fd) in [
            ("dq", g.dq.data(), &fd_q),
            ("dk", g.dk.data(), &fd_k),
            ("dv", g.dv.data(), &fd_v),
        ] {
            let err = grad_rel_err(an, fd);
            assert!(err < 1e-3, "elu {spec:?} {name}: rel err {err}");
        }
    }
}

#[test]
fn quadratic_backward_matches_f64_finite_differences() {
    let (n, d, dv) = (8usize, 4usize, 3usize);
    let mut rng = lln::rng::Pcg64::seed(0xFD04);
    let q = Mat::gaussian(n, d, 0.8, &mut rng);
    let k = Mat::gaussian(n, d, 0.8, &mut rng);
    let v = Mat::gaussian(n, dv, 0.9, &mut rng);
    let w = Mat::gaussian(n, dv, 1.0, &mut rng);
    let h = 1e-4;
    for spec in gradcheck_specs(n) {
        let bk = default_backend(Method::Quadratic);
        let (_, cache) = bk.forward_train(&q, &k, &v, &spec).unwrap();
        let g = bk.backward(&q, &k, &v, &spec, &cache, &w).unwrap();
        let (qf, kf, vf, wf) = (to_f64(&q), to_f64(&k), to_f64(&v), to_f64(&w));
        let fd_q = central_diff(&mut qf.clone(), |x| {
            quadratic_loss_f64(x, &kf, &vf, &wf, n, d, dv, &spec)
        }, h);
        let fd_k = central_diff(&mut kf.clone(), |x| {
            quadratic_loss_f64(&qf, x, &vf, &wf, n, d, dv, &spec)
        }, h);
        let fd_v = central_diff(&mut vf.clone(), |x| {
            quadratic_loss_f64(&qf, &kf, x, &wf, n, d, dv, &spec)
        }, h);
        for (name, an, fd) in [
            ("dq", g.dq.data(), &fd_q),
            ("dk", g.dk.data(), &fd_k),
            ("dv", g.dv.data(), &fd_v),
        ] {
            let err = grad_rel_err(an, fd);
            assert!(err < 1e-3, "quadratic {spec:?} {name}: rel err {err}");
        }
    }
}

/// f64 reference loss for block-diagonal softmax attention: each
/// diagonal `block`×`block` tile is softmax attention under the
/// tile-local spec (keys shifted by the tile offset, scale pinned to
/// the global resolved value) — the same tiling as
/// `blockdiag_attention_spec_fwd_train`.
#[allow(clippy::too_many_arguments)]
fn blockdiag_loss_f64(
    q: &[f64],
    k: &[f64],
    v: &[f64],
    w: &[f64],
    n: usize,
    d: usize,
    dv: usize,
    block: usize,
    spec: &AttnSpec,
) -> f64 {
    let scale = spec.resolve_scale(d) as f64;
    let mut loss = 0.0f64;
    for b0 in (0..n).step_by(block) {
        let ts = AttnSpec {
            causal: spec.causal,
            key_len: spec.key_len.map(|kl| kl.saturating_sub(b0)),
            scale: spec.scale,
        };
        loss += softmax_loss_f64(
            &q[b0 * d..(b0 + block) * d],
            &k[b0 * d..(b0 + block) * d],
            &v[b0 * dv..(b0 + block) * dv],
            &w[b0 * dv..(b0 + block) * dv],
            block,
            block,
            d,
            dv,
            scale,
            &ts,
        );
    }
    loss
}

/// f64 reference loss for Performer (FAVOR+) attention: the positive
/// feature lift φ(x) = m^{-1/2}·exp(clamp(proj·x̃ − ‖x̃‖²/2)) with
/// x̃ = x/d^{1/4} (row-coupled, so it cannot ride `linear_loss_f64`'s
/// per-element maps), then linearized attention with EPS = 1e-6.
#[allow(clippy::too_many_arguments)]
fn performer_loss_f64(
    q: &[f64],
    k: &[f64],
    v: &[f64],
    w: &[f64],
    n: usize,
    d: usize,
    dv: usize,
    proj: &Mat,
    spec: &AttnSpec,
) -> f64 {
    const EPS: f64 = 1e-6;
    let m = proj.cols();
    let fscale = 1.0 / (m as f64).sqrt();
    let dscale = 1.0 / (d as f64).powf(0.25);
    let lift = |x: &[f64]| -> Vec<f64> {
        let mut out = vec![0.0f64; n * m];
        for i in 0..n {
            let xs: Vec<f64> = x[i * d..(i + 1) * d].iter().map(|&a| a * dscale).collect();
            let sq: f64 = xs.iter().map(|&a| a * a).sum::<f64>() * 0.5;
            for j in 0..m {
                let u: f64 = xs
                    .iter()
                    .enumerate()
                    .map(|(t, &a)| a * proj.get(t, j) as f64)
                    .sum();
                out[i * m + j] = fscale * cexp64(u - sq);
            }
        }
        out
    };
    let pq = lift(q);
    let pk = lift(k);
    let mut loss = 0.0f64;
    for i in 0..n {
        let lim = spec.row_limit(i, n);
        let mut den = EPS;
        let mut num = vec![0.0f64; dv];
        for j in 0..lim {
            let dot: f64 = pq[i * m..(i + 1) * m]
                .iter()
                .zip(&pk[j * m..(j + 1) * m])
                .map(|(a, b)| a * b)
                .sum();
            den += dot;
            for (o, &vv) in num.iter_mut().zip(&v[j * dv..(j + 1) * dv]) {
                *o += dot * vv;
            }
        }
        for t in 0..dv {
            loss += w[i * dv + t] * num[t] / den;
        }
    }
    loss
}

#[test]
fn lln_diag_backward_matches_f64_finite_differences_including_alpha_beta() {
    // The hybrid out = 0.5·(long LLN + block-diagonal softmax): both
    // halves' chain rules must survive the 0.5 cotangent split.
    let (n, d, dv, block) = (8usize, 5usize, 4usize, 4usize);
    let (alpha, beta) = (1.2f32, 0.9f32);
    let mut rng = lln::rng::Pcg64::seed(0xFD05);
    let q = Mat::gaussian(n, d, 0.6, &mut rng);
    let k = Mat::gaussian(n, d, 0.6, &mut rng);
    let v = Mat::gaussian(n, dv, 0.9, &mut rng);
    let w = Mat::gaussian(n, dv, 1.0, &mut rng);
    let h = 1e-4;
    for spec in gradcheck_specs(n) {
        let bk = backend_for(
            Method::LlnDiag,
            BackendParams { alpha, beta, block, threads: 1, chunk: 3, ..Default::default() },
        );
        let (_, cache) = bk.forward_train(&q, &k, &v, &spec).unwrap();
        let g = bk.backward(&q, &k, &v, &spec, &cache, &w).unwrap();
        let (qf, kf, vf, wf) = (to_f64(&q), to_f64(&k), to_f64(&v), to_f64(&w));
        let (a64, b64) = (alpha as f64, beta as f64);
        let loss = |qx: &[f64], kx: &[f64], vx: &[f64], a: f64, b: f64| {
            let long = linear_loss_f64(qx, kx, vx, &wf, n, d, dv, &spec, &|x| cexp64(a * x), &|x| {
                cexp64(b * x)
            });
            let short = blockdiag_loss_f64(qx, kx, vx, &wf, n, d, dv, block, &spec);
            0.5 * (long + short)
        };
        let fd_q = central_diff(&mut qf.clone(), |x| loss(x, &kf, &vf, a64, b64), h);
        let fd_k = central_diff(&mut kf.clone(), |x| loss(&qf, x, &vf, a64, b64), h);
        let fd_v = central_diff(&mut vf.clone(), |x| loss(&qf, &kf, x, a64, b64), h);
        for (name, an, fd) in [
            ("dq", g.dq.data(), &fd_q),
            ("dk", g.dk.data(), &fd_k),
            ("dv", g.dv.data(), &fd_v),
        ] {
            let err = grad_rel_err(an, fd);
            assert!(err < 1e-3, "lln_diag {spec:?} {name}: rel err {err}");
        }
        // dα / dβ flow only through the long half (the diagonal tiles
        // are plain softmax), but the FD of the hybrid sees that too.
        let mut ab = vec![a64, b64];
        let fd_ab = central_diff(&mut ab, |x| loss(&qf, &kf, &vf, x[0], x[1]), h);
        let err_a = grad_rel_err(&[g.dalpha], &fd_ab[..1]);
        let err_b = grad_rel_err(&[g.dbeta], &fd_ab[1..]);
        assert!(err_a < 1e-3, "lln_diag {spec:?} dalpha: rel err {err_a}");
        assert!(err_b < 1e-3, "lln_diag {spec:?} dbeta: rel err {err_b}");
    }
}

#[test]
fn performer_backward_matches_f64_finite_differences() {
    let (n, d, dv) = (8usize, 5usize, 4usize);
    let mut rng = lln::rng::Pcg64::seed(0xFD06);
    let q = Mat::gaussian(n, d, 0.6, &mut rng);
    let k = Mat::gaussian(n, d, 0.6, &mut rng);
    let v = Mat::gaussian(n, dv, 0.9, &mut rng);
    let w = Mat::gaussian(n, dv, 1.0, &mut rng);
    // Same deterministic FAVOR+ projection the backend builds for
    // (d, features=0 → m=d, seed=7 default).
    let proj = att::performer_projection(d, d, 7);
    let h = 1e-4;
    for spec in gradcheck_specs(n) {
        let bk = backend_for(
            Method::Performer,
            BackendParams { threads: 1, chunk: 3, ..Default::default() },
        );
        let (_, cache) = bk.forward_train(&q, &k, &v, &spec).unwrap();
        let g = bk.backward(&q, &k, &v, &spec, &cache, &w).unwrap();
        let (qf, kf, vf, wf) = (to_f64(&q), to_f64(&k), to_f64(&v), to_f64(&w));
        let fd_q = central_diff(&mut qf.clone(), |x| {
            performer_loss_f64(x, &kf, &vf, &wf, n, d, dv, &proj, &spec)
        }, h);
        let fd_k = central_diff(&mut kf.clone(), |x| {
            performer_loss_f64(&qf, x, &vf, &wf, n, d, dv, &proj, &spec)
        }, h);
        let fd_v = central_diff(&mut vf.clone(), |x| {
            performer_loss_f64(&qf, &kf, x, &wf, n, d, dv, &proj, &spec)
        }, h);
        for (name, an, fd) in [
            ("dq", g.dq.data(), &fd_q),
            ("dk", g.dk.data(), &fd_k),
            ("dv", g.dv.data(), &fd_v),
        ] {
            let err = grad_rel_err(an, fd);
            assert!(err < 1e-3, "performer {spec:?} {name}: rel err {err}");
        }
        // The projection is a fixed operand, not a parameter.
        assert_eq!(g.dalpha, 0.0);
        assert_eq!(g.dbeta, 0.0);
    }
}

#[test]
fn blockdiag_backward_matches_f64_finite_differences() {
    let (n, d, dv, block) = (8usize, 5usize, 4usize, 4usize);
    let mut rng = lln::rng::Pcg64::seed(0xFD07);
    let q = Mat::gaussian(n, d, 0.7, &mut rng);
    let k = Mat::gaussian(n, d, 0.7, &mut rng);
    let v = Mat::gaussian(n, dv, 0.9, &mut rng);
    let w = Mat::gaussian(n, dv, 1.0, &mut rng);
    let h = 1e-4;
    for spec in gradcheck_specs(n) {
        let bk = backend_for(
            Method::BlockDiag,
            BackendParams { block, threads: 1, ..Default::default() },
        );
        let (_, cache) = bk.forward_train(&q, &k, &v, &spec).unwrap();
        let g = bk.backward(&q, &k, &v, &spec, &cache, &w).unwrap();
        let (qf, kf, vf, wf) = (to_f64(&q), to_f64(&k), to_f64(&v), to_f64(&w));
        let fd_q = central_diff(&mut qf.clone(), |x| {
            blockdiag_loss_f64(x, &kf, &vf, &wf, n, d, dv, block, &spec)
        }, h);
        let fd_k = central_diff(&mut kf.clone(), |x| {
            blockdiag_loss_f64(&qf, x, &vf, &wf, n, d, dv, block, &spec)
        }, h);
        let fd_v = central_diff(&mut vf.clone(), |x| {
            blockdiag_loss_f64(&qf, &kf, x, &wf, n, d, dv, block, &spec)
        }, h);
        for (name, an, fd) in [
            ("dq", g.dq.data(), &fd_q),
            ("dk", g.dk.data(), &fd_k),
            ("dv", g.dv.data(), &fd_v),
        ] {
            let err = grad_rel_err(an, fd);
            assert!(err < 1e-3, "blockdiag {spec:?} {name}: rel err {err}");
        }
    }
}

#[test]
fn fused_softmax_backward_matches_dense_masked_backward() {
    // The fused O(n·tile) recompute backward vs the dense masked
    // reference backward, across random shapes, masks, scales, and
    // tiles (including tile = 1 and tile > n).
    check(32, |g| {
        let causal = g.bool();
        let nq = g.usize_in(1, 40);
        let nk = if causal { nq } else { g.usize_in(1, 40) };
        let spec = AttnSpec {
            causal,
            key_len: if g.bool() { Some(g.usize_in(0, nk + 5)) } else { None },
            scale: if g.bool() { Some(g.f32_in(0.05, 0.6)) } else { None },
        };
        let d = g.usize_in(2, 16);
        let dv = g.usize_in(1, 12);
        let tile = *g.choose(&[1usize, 5, 16, 0, 200]);
        let q = gauss_mat(g, nq, d, 0.8);
        let k = gauss_mat(g, nk, d, 0.8);
        let v = gauss_mat(g, nk, dv, 1.0);
        let d_out = gauss_mat(g, nq, dv, 1.0);
        let (out, rm, rs) = att::grad::fused_softmax_attention_spec_fwd_train(&q, &k, &v, &spec, tile);
        let (dq, dk, dvm) = att::grad::fused_softmax_attention_spec_bwd(
            &q, &k, &v, &spec, &out, &rm, &rs, &d_out, tile,
        );
        let (dq2, dk2, dv2) = att::grad::softmax_attention_spec_bwd_dense(&q, &k, &v, &spec, &d_out);
        let what = format!("nq={nq} nk={nk} d={d} dv={dv} tile={tile} {spec:?}");
        assert_close(&dq, &dq2, 5e-4, &format!("fused-vs-dense bwd dq {what}"))?;
        assert_close(&dk, &dk2, 5e-4, &format!("fused-vs-dense bwd dk {what}"))?;
        assert_close(&dvm, &dv2, 5e-4, &format!("fused-vs-dense bwd dv {what}"))
    });
}

// ---------------------------------------------------------------------------
// Persistent compute pool: determinism + concurrency
// ---------------------------------------------------------------------------

#[test]
fn pooled_tensor_kernels_are_bitwise_stable_across_thread_counts() {
    // Every span's output is written only by its owner and each row's
    // arithmetic never depends on span boundaries, so par_* must be
    // *bitwise* equal to the single-threaded kernel at every worker
    // count — shapes chosen above PAR_MIN_ELEMS so the pool really runs.
    check(16, |g| {
        let m = g.usize_in(64, 90);
        let kdim = g.usize_in(4, 24);
        let n = g.usize_in(64, 90);
        let a = gauss_mat(g, m, kdim, 1.0);
        let b = gauss_mat(g, kdim, n, 1.0);
        let c = gauss_mat(g, n, kdim, 1.0);
        let mm = a.matmul(&b);
        let mt = a.matmul_t(&c);
        let sm = gauss_mat(g, m, n, 1.0);
        let mut sm_ser = sm.clone();
        sm_ser.softmax_rows();
        for &t in &[2usize, 3, 5, 8] {
            prop_assert(
                a.par_matmul(&b, t).data() == mm.data(),
                format!("par_matmul not bitwise {m}x{kdim}x{n} t={t}"),
            )?;
            prop_assert(
                a.par_matmul_t(&c, t).data() == mt.data(),
                format!("par_matmul_t not bitwise {m}x{kdim}x{n} t={t}"),
            )?;
            let mut s = sm.clone();
            s.par_softmax_rows(t);
            prop_assert(
                s.data() == sm_ser.data(),
                format!("par_softmax_rows not bitwise {m}x{n} t={t}"),
            )?;
        }
        // Below the element threshold the pool is skipped outright, so
        // tiny outputs are bitwise-trivially identical too.
        let ta = gauss_mat(g, 5, kdim, 1.0);
        let tb = gauss_mat(g, kdim, 6, 1.0);
        prop_assert(
            ta.par_matmul(&tb, 4).data() == ta.matmul(&tb).data(),
            "small par_matmul must fall back to the serial kernel".to_string(),
        )?;
        Ok(())
    });
}

#[test]
fn pooled_fused_train_kernels_match_serial_across_thread_counts() {
    // forward_train is row-local, so the pooled variant is bitwise at
    // every thread count; the backward's dQ rows are span-local
    // (bitwise) while dK/dV come from a fixed-order reduction of span
    // partials (tolerance-level vs the serial association).
    check(24, |g| {
        let causal = g.bool();
        let nq = g.usize_in(2, 40);
        let nk = if causal { nq } else { g.usize_in(1, 40) };
        let spec = AttnSpec {
            causal,
            key_len: if g.bool() { Some(g.usize_in(0, nk + 5)) } else { None },
            scale: None,
        };
        let d = g.usize_in(2, 16);
        let dv = g.usize_in(1, 12);
        let tile = *g.choose(&[1usize, 5, 0, 64]);
        let q = gauss_mat(g, nq, d, 0.8);
        let k = gauss_mat(g, nk, d, 0.8);
        let v = gauss_mat(g, nk, dv, 1.0);
        let d_out = gauss_mat(g, nq, dv, 1.0);
        let what = format!("nq={nq} nk={nk} d={d} dv={dv} tile={tile} {spec:?}");

        let (o, rm, rs) = att::grad::fused_softmax_attention_spec_fwd_train(&q, &k, &v, &spec, tile);
        let (dq, dk, dvm) = att::grad::fused_softmax_attention_spec_bwd(
            &q, &k, &v, &spec, &o, &rm, &rs, &d_out, tile,
        );
        let (oq, den) = att::grad::fused_quadratic_attention_spec_fwd_train(&q, &k, &v, &spec, tile);
        let (qdq, qdk, qdv) =
            att::grad::fused_quadratic_attention_spec_bwd(&q, &k, &v, &spec, &oq, &den, &d_out, tile);

        for &t in &[2usize, 3, 5] {
            let (o2, rm2, rs2) =
                att::grad::fused_softmax_attention_spec_fwd_train_par(&q, &k, &v, &spec, tile, t);
            prop_assert(
                o2.data() == o.data() && rm2 == rm && rs2 == rs,
                format!("pooled softmax fwd_train not bitwise t={t} {what}"),
            )?;
            let (dq2, dk2, dv2) = att::grad::fused_softmax_attention_spec_bwd_par(
                &q, &k, &v, &spec, &o, &rm, &rs, &d_out, tile, t,
            );
            prop_assert(
                dq2.data() == dq.data(),
                format!("pooled softmax bwd dq not bitwise t={t} {what}"),
            )?;
            assert_close(&dk2, &dk, 5e-5, &format!("pooled softmax bwd dk t={t} {what}"))?;
            assert_close(&dv2, &dvm, 5e-5, &format!("pooled softmax bwd dv t={t} {what}"))?;

            let (oq2, den2) =
                att::grad::fused_quadratic_attention_spec_fwd_train_par(&q, &k, &v, &spec, tile, t);
            prop_assert(
                oq2.data() == oq.data() && den2 == den,
                format!("pooled quadratic fwd_train not bitwise t={t} {what}"),
            )?;
            let (qdq2, qdk2, qdv2) = att::grad::fused_quadratic_attention_spec_bwd_par(
                &q, &k, &v, &spec, &oq, &den, &d_out, tile, t,
            );
            prop_assert(
                qdq2.data() == qdq.data(),
                format!("pooled quadratic bwd dq not bitwise t={t} {what}"),
            )?;
            assert_close(&qdk2, &qdk, 5e-5, &format!("pooled quadratic bwd dk t={t} {what}"))?;
            assert_close(&qdv2, &qdv, 5e-5, &format!("pooled quadratic bwd dv t={t} {what}"))?;
        }
        Ok(())
    });
}

#[test]
fn causal_linear_recurrence_and_backward_are_chunk_deterministic() {
    // The chunked recurrence's summation order is a function of `chunk`
    // alone: at a fixed chunk the forward and the pooled backward must
    // be bitwise identical at every thread count (the scheduling may
    // differ; the arithmetic may not).  Against the serial backward the
    // chunked association differs, so that comparison is tolerance.
    check(16, |g| {
        let n = g.usize_in(2, 60);
        let m = g.usize_in(2, 12);
        let dv = g.usize_in(1, 10);
        let chunk = *g.choose(&[1usize, 3, 7, 16]);
        let key_len = if g.bool() { Some(g.usize_in(0, n + 4)) } else { None };
        let pq = gauss_mat(g, n, m, 0.7).map(|x| x.abs());
        let pk = gauss_mat(g, n, m, 0.7).map(|x| x.abs());
        let v = gauss_mat(g, n, dv, 1.0);
        let d_out = gauss_mat(g, n, dv, 1.0);
        let kern = lln::tensor::KernelDispatch::Auto;
        let what = format!("n={n} m={m} dv={dv} chunk={chunk} kl={key_len:?}");

        let base = att::linear_attention_causal_dispatch(&pq, &pk, &v, key_len, chunk, 2, kern);
        for &t in &[1usize, 3, 4, 7] {
            let out = att::linear_attention_causal_dispatch(&pq, &pk, &v, key_len, chunk, t, kern);
            prop_assert(
                out.data() == base.data(),
                format!("causal recurrence not bitwise across threads t={t} {what}"),
            )?;
        }

        for causal in [true, false] {
            let spec = AttnSpec { causal, key_len, scale: None };
            let out = att::linear_attention_spec(&pq, &pk, &v, &spec, chunk, 1);
            let (sdq, sdk, sdv) = att::grad::linear_attention_spec_bwd(&pq, &pk, &v, &spec, &out, &d_out);
            let (bdq, bdk, bdv) = att::grad::linear_attention_spec_bwd_par(
                &pq, &pk, &v, &spec, &out, &d_out, chunk, 2,
            );
            for &t in &[3usize, 5] {
                let (dq, dk, dvm) = att::grad::linear_attention_spec_bwd_par(
                    &pq, &pk, &v, &spec, &out, &d_out, chunk, t,
                );
                prop_assert(
                    dq.data() == bdq.data() && dk.data() == bdk.data() && dvm.data() == bdv.data(),
                    format!("pooled linear bwd not bitwise across threads t={t} causal={causal} {what}"),
                )?;
            }
            assert_close(&bdq, &sdq, 5e-4, &format!("pooled linear bwd dq causal={causal} {what}"))?;
            assert_close(&bdk, &sdk, 5e-4, &format!("pooled linear bwd dk causal={causal} {what}"))?;
            assert_close(&bdv, &sdv, 5e-4, &format!("pooled linear bwd dv causal={causal} {what}"))?;
        }
        Ok(())
    });
}

#[test]
fn compute_pool_survives_concurrent_hammering() {
    // Several coordinator-style threads hammer the shared pool with
    // pooled kernels and training fwd/bwd steps at once.  Every caller
    // must get exactly its own task's bitwise result back (no cross-task
    // contamination) and the whole thing must drain (no deadlock —
    // callers participate in stealing while they wait).
    use std::sync::atomic::{AtomicUsize, Ordering};
    let failures = AtomicUsize::new(0);
    let spec = AttnSpec { causal: true, key_len: None, scale: None };
    std::thread::scope(|s| {
        for worker in 0..4u64 {
            let failures = &failures;
            let spec = &spec;
            s.spawn(move || {
                let mut rng = lln::rng::Pcg64::seed(0xC0FFEE ^ worker);
                for round in 0..6usize {
                    let n = 64 + (worker as usize * 7 + round) % 17;
                    let d = 4 + (worker as usize + round) % 9;
                    let a = Mat::gaussian(n, d, 1.0, &mut rng);
                    let b = Mat::gaussian(d, n, 1.0, &mut rng);
                    let expect = a.matmul(&b);
                    if a.par_matmul(&b, 4).data() != expect.data() {
                        failures.fetch_add(1, Ordering::Relaxed);
                    }
                    let q = Mat::gaussian(24, d, 0.8, &mut rng);
                    let k = Mat::gaussian(24, d, 0.8, &mut rng);
                    let v = Mat::gaussian(24, d, 1.0, &mut rng);
                    let d_out = Mat::gaussian(24, d, 1.0, &mut rng);
                    let (o, rm, rs) =
                        att::grad::fused_softmax_attention_spec_fwd_train(&q, &k, &v, spec, 8);
                    let (o2, rm2, rs2) = att::grad::fused_softmax_attention_spec_fwd_train_par(
                        &q, &k, &v, spec, 8, 3,
                    );
                    if o2.data() != o.data() || rm2 != rm || rs2 != rs {
                        failures.fetch_add(1, Ordering::Relaxed);
                    }
                    let (dq, _, _) = att::grad::fused_softmax_attention_spec_bwd(
                        &q, &k, &v, spec, &o, &rm, &rs, &d_out, 8,
                    );
                    let (dq2, _, _) = att::grad::fused_softmax_attention_spec_bwd_par(
                        &q, &k, &v, spec, &o, &rm, &rs, &d_out, 8, 3,
                    );
                    if dq2.data() != dq.data() {
                        failures.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    assert_eq!(failures.load(Ordering::Relaxed), 0, "cross-task contamination under load");
    let t = lln::util::compute_pool::telemetry();
    assert!(t.spawns_avoided > 0, "the pooled kernels above must have scheduled tasks");
}
