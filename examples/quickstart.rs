//! Quickstart: load an AOT LLN-attention kernel, execute it through the
//! PJRT runtime, cross-check against the native Rust implementation, and
//! demo moment matching.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use lln::attention::{self, MomentMatcher};
use lln::rng::Pcg64;
use lln::runtime::{artifacts_dir, Engine, HostTensor};
use lln::tensor::Mat;

fn main() -> Result<()> {
    let dir = artifacts_dir(None);
    println!("loading artifacts from {} ...", dir.display());
    let mut engine = Engine::new(&dir)?;

    // 1. Moment matching (paper eq. 10): derive alpha/beta from live stats.
    let mm = MomentMatcher { a: engine.manifest().mm_a, b: engine.manifest().mm_b };
    let (sigma_q, sigma_k) = (1.1f64, 0.9f64);
    let (alpha, beta) = mm.alpha_beta(sigma_q, sigma_k);
    println!(
        "moment matching: sigma_q={sigma_q} sigma_k={sigma_k} -> alpha={alpha:.3} beta={beta:.3}"
    );

    // 2. Run the AOT Pallas LLN kernel on random Gaussian inputs.
    let (n, d) = (256usize, 64usize);
    let mut rng = Pcg64::seed(0);
    let q = Mat::gaussian(n, d, sigma_q as f32, &mut rng);
    let k = Mat::gaussian(n, d, sigma_k as f32, &mut rng);
    let v = Mat::gaussian(n, d, 1.0, &mut rng);
    let outs = engine.execute(
        "attn_lln_n256",
        &[
            HostTensor::from_mat(&q),
            HostTensor::from_mat(&k),
            HostTensor::from_mat(&v),
            HostTensor::scalar_f32(alpha),
            HostTensor::scalar_f32(beta),
        ],
    )?;
    let kernel_out = outs[0].to_mat()?;

    // 3. Cross-check against the native implementation.
    let native = attention::lln_attention(&q, &k, &v, alpha, beta);
    let err = kernel_out.max_abs_diff(&native);
    println!("PJRT kernel vs native Rust: max |diff| = {err:.2e}");
    assert!(err < 2e-3);

    // 4. Show that the LLN matrix's concentration matches softmax's.
    let p_lln = attention::lln_attention_matrix(&q, &k, alpha, beta);
    let p_sm = attention::softmax_attention_matrix(&q, &k);
    println!(
        "entropy:      lln={:.3} bits   softmax={:.3} bits",
        lln::stats::attention_entropy(&p_lln),
        lln::stats::attention_entropy(&p_sm),
    );
    println!(
        "spectral gap: lln={:.3}        softmax={:.3}",
        lln::linalg::spectral_gap(&p_lln, 400, 1e-8).gap,
        lln::linalg::spectral_gap(&p_sm, 400, 1e-8).gap,
    );
    println!("quickstart OK");
    Ok(())
}
