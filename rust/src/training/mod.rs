//! Training orchestration: the Rust-side loop around the AOT train-step
//! executables (paper figs. 8/9 pipelines; Table 1/3/4 task training).

pub mod driver;
pub mod metrics;

pub use driver::{StepTelemetry, TrainDriver};
pub use metrics::MetricsLog;
