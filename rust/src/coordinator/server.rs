//! The threaded serving coordinator.
//!
//! Workers are generic over a [`BatchExec`] — either the PJRT engine
//! path (AOT artifacts) or the native [`AttentionBackend`] encoder
//! ([`super::native`]) when artifacts/PJRT are unavailable — so the
//! batching loop, stats, and backpressure behave identically on both.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use super::batcher::{plan_batches, should_fire};
use super::native::NativeEncoder;
use super::{pad_to_bucket, pick_bucket, Request, Response};
use crate::attention::Method;
use crate::config::ServeConfig;
use crate::runtime::{Engine, HostTensor, ParamStore};
use crate::util::pool::{Channel, SendError};

/// Rolling serving metrics (shared across workers).
#[derive(Default)]
pub struct ServeStats {
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub latencies_ms: Vec<f64>,
    pub batch_sizes: Vec<usize>,
}

impl ServeStats {
    pub fn p50_latency(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            crate::stats::percentile(&self.latencies_ms, 50.0)
        }
    }
    pub fn p95_latency(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            crate::stats::percentile(&self.latencies_ms, 95.0)
        }
    }
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }
}

/// The running coordinator: submit requests, read stats, shut down.
pub struct Coordinator {
    cfg: ServeConfig,
    queues: Vec<(usize, Channel<Request>)>, // (bucket_len, queue)
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<ServeStats>>,
    next_id: AtomicU64,
    draining: Arc<AtomicBool>,
    started_at: Instant,
}

impl Coordinator {
    /// Spawn `cfg.workers` workers per bucket.  Each worker owns its
    /// executor — a PJRT engine with the bucket's executables + resident
    /// params, or the native-backend encoder fallback — and all workers
    /// of a bucket drain the same MPMC queue.
    pub fn start(cfg: ServeConfig, artifacts: &std::path::Path) -> Result<Self> {
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let draining = Arc::new(AtomicBool::new(false));
        let mut queues = Vec::new();
        let mut workers = Vec::new();
        for &bucket in &cfg.buckets {
            let q: Channel<Request> = Channel::bounded(cfg.queue_capacity);
            queues.push((bucket, q.clone()));
            for w in 0..cfg.workers.max(1) {
                let cfgc = cfg.clone();
                let dir = artifacts.to_path_buf();
                let statsc = Arc::clone(&stats);
                let drainc = Arc::clone(&draining);
                let qc = q.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("lln-worker-n{bucket}-{w}"))
                        .spawn(move || {
                            if let Err(e) = worker_loop(cfgc, dir, bucket, qc, statsc, drainc) {
                                eprintln!("worker n{bucket}-{w} died: {e:#}");
                            }
                        })
                        .expect("spawn worker"),
                );
            }
        }
        Ok(Self {
            cfg,
            queues,
            workers,
            stats,
            next_id: AtomicU64::new(1),
            draining,
            started_at: Instant::now(),
        })
    }

    /// Submit a request; returns the response receiver.  Errors on
    /// over-length input or queue-full backpressure.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<mpsc::Receiver<Response>> {
        let bucket = pick_bucket(&self.cfg.buckets, tokens.len())
            .ok_or_else(|| anyhow!("sequence length {} exceeds all buckets", tokens.len()))?;
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            enqueued_at: Instant::now(),
            resp: tx,
        };
        let queue = &self.queues.iter().find(|(b, _)| *b == bucket).unwrap().1;
        match queue.try_send(req) {
            Ok(()) => Ok(rx),
            Err(SendError::Full(_)) => {
                self.stats.lock().unwrap().rejected += 1;
                bail!("backpressure: bucket n{bucket} queue full")
            }
            Err(SendError::Closed(_)) => bail!("coordinator shutting down"),
        }
    }

    /// Submit and block for the result.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        let rx = self.submit(tokens)?;
        rx.recv().map_err(|_| anyhow!("worker dropped response"))
    }

    pub fn stats(&self) -> Arc<Mutex<ServeStats>> {
        Arc::clone(&self.stats)
    }

    pub fn uptime_secs(&self) -> f64 {
        self.started_at.elapsed().as_secs_f64()
    }

    /// Drain queues and join workers.
    pub fn shutdown(mut self) {
        self.draining.store(true, Ordering::SeqCst);
        for (_, q) in &self.queues {
            q.close();
        }
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

/// One worker's batch executor: given the bucket-padded token buffer,
/// produce per-request logits rows.  The batching loop above is the
/// same for every implementation.
trait BatchExec {
    /// Executable batch capacity to plan for (PJRT batches are static;
    /// the native path accepts any size up to `max_batch`).
    fn plan_capacity(&self, members: usize, max_batch: usize) -> usize;

    /// `tokens` holds `capacity * bucket` ids (`real` live rows, the
    /// rest phantom padding).  Returns `real` logit rows.
    fn run(&mut self, tokens: Vec<i32>, capacity: usize, real: usize, bucket: usize)
        -> Result<Vec<Vec<f32>>>;
}

/// PJRT path: resident params + the bucket's b1/bN executables.
struct PjrtExec {
    engine: Engine,
    exe_b1: String,
    exe_bn: String,
    param_lits: Vec<Literal>,
    num_classes: usize,
}

impl PjrtExec {
    fn new(cfg: &ServeConfig, dir: &std::path::Path, bucket: usize) -> Result<Self> {
        let mut engine = Engine::new(dir)?;
        let exe_b1 = format!("serve_{}_b1_n{}", cfg.method, bucket);
        let exe_bn = format!("serve_{}_b{}_n{}", cfg.method, cfg.max_batch, bucket);
        engine.warmup(&[&exe_b1, &exe_bn])?;

        // Resident parameters: built once, reused for every call.
        let model_tag = engine.manifest().artifact(&exe_b1)?.meta.get("model").cloned()
            .ok_or_else(|| anyhow!("{exe_b1}: missing model meta"))?;
        let model = engine.manifest().model(&model_tag)?.clone();
        let params = ParamStore::load_initial(dir, &model)?;
        let param_lits: Vec<Literal> = params.to_literals()?;
        let num_classes: usize = {
            let spec = engine.manifest().artifact(&exe_b1)?;
            *spec.outputs[0].shape.last().unwrap_or(&4)
        };
        Ok(Self { engine, exe_b1, exe_bn, param_lits, num_classes })
    }
}

impl BatchExec for PjrtExec {
    fn plan_capacity(&self, members: usize, max_batch: usize) -> usize {
        if members == 1 {
            1
        } else {
            max_batch
        }
    }

    fn run(
        &mut self,
        tokens: Vec<i32>,
        capacity: usize,
        real: usize,
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let exe = if capacity == 1 { self.exe_b1.clone() } else { self.exe_bn.clone() };
        let tok_lit = HostTensor::I32 { shape: vec![capacity, bucket], data: tokens }.to_literal()?;
        let mut args: Vec<&Literal> = self.param_lits.iter().collect();
        args.push(&tok_lit);
        let outs = self.engine.execute_literals(&exe, &args)?;
        let logits = outs[0].to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        let nc = self.num_classes;
        Ok((0..real).map(|i| logits[i * nc..(i + 1) * nc].to_vec()).collect())
    }
}

/// Native path: the [`AttentionBackend`](crate::attention::AttentionBackend)
/// encoder — no artifacts, no PJRT, still the full serving pipeline.
struct NativeExec {
    encoder: NativeEncoder,
}

impl NativeExec {
    fn new(cfg: &ServeConfig, bucket: usize) -> Result<Self> {
        // A typo'd method must fail loudly, not silently serve lln_diag.
        let method = Method::parse(&cfg.method)
            .ok_or_else(|| anyhow!("unknown serving method {:?}", cfg.method))?;
        Ok(Self {
            encoder: NativeEncoder::new(
                method,
                super::native::NATIVE_D_MODEL,
                super::native::NATIVE_NUM_CLASSES,
                bucket,
                super::native::NATIVE_SEED,
                &cfg.compute,
            ),
        })
    }
}

impl BatchExec for NativeExec {
    fn plan_capacity(&self, members: usize, _max_batch: usize) -> usize {
        members
    }

    fn run(
        &mut self,
        tokens: Vec<i32>,
        _capacity: usize,
        real: usize,
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        Ok((0..real).map(|i| self.encoder.infer(&tokens[i * bucket..(i + 1) * bucket])).collect())
    }
}

/// Per-bucket worker: owns its executor and loops batching until the
/// queue closes.
fn worker_loop(
    cfg: ServeConfig,
    dir: std::path::PathBuf,
    bucket: usize,
    queue: Channel<Request>,
    stats: Arc<Mutex<ServeStats>>,
    draining: Arc<AtomicBool>,
) -> Result<()> {
    let mut exec: Box<dyn BatchExec> = match PjrtExec::new(&cfg, &dir, bucket) {
        Ok(e) => Box::new(e),
        Err(e) if cfg.native_fallback => {
            eprintln!(
                "worker n{bucket}: PJRT path unavailable ({e:#}); serving via native {} backend \
                 (degraded: untrained weights)",
                cfg.method
            );
            Box::new(NativeExec::new(&cfg, bucket)?)
        }
        Err(e) => return Err(e),
    };

    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Top up the pending set.
        let drain = draining.load(Ordering::SeqCst);
        if pending.len() < cfg.max_batch {
            match queue.recv_timeout(Duration::from_millis(cfg.batch_timeout_ms.max(1))) {
                Ok(Some(req)) => {
                    pending.push(req);
                    // opportunistically grab whatever else is queued
                    pending.extend(queue.drain_up_to(cfg.max_batch - pending.len()));
                }
                Ok(None) => {}
                Err(_) if pending.is_empty() => return Ok(()), // closed + drained
                Err(_) => {}
            }
        }
        let oldest_ms = pending
            .first()
            .map(|r| r.enqueued_at.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        if !should_fire(pending.len(), cfg.max_batch, oldest_ms, cfg.batch_timeout_ms as f64, drain) {
            continue;
        }
        for plan in plan_batches(pending.len(), cfg.max_batch) {
            let batch: Vec<Request> = plan.members.iter().map(|_| pending.remove(0)).collect();
            let capacity = exec.plan_capacity(batch.len(), cfg.max_batch);
            run_batch(exec.as_mut(), capacity, bucket, batch, &stats);
        }
        pending.clear();
    }
}

/// Execute one padded batch through the worker's executor and fan
/// results back out.
fn run_batch(
    exec: &mut dyn BatchExec,
    capacity: usize,
    bucket: usize,
    batch: Vec<Request>,
    stats: &Arc<Mutex<ServeStats>>,
) {
    let real = batch.len();
    let mut tokens = Vec::with_capacity(capacity * bucket);
    for r in &batch {
        tokens.extend(pad_to_bucket(&r.tokens, bucket));
    }
    // Pad phantom rows up to the executor's static batch.
    tokens.resize(capacity * bucket, crate::data::special::PAD);

    let result = exec.run(tokens, capacity, real, bucket);

    let mut st = stats.lock().unwrap();
    st.batch_sizes.push(real);
    match result {
        Ok(rows) => {
            for (r, row) in batch.into_iter().zip(rows) {
                let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                st.completed += 1;
                st.latencies_ms.push(latency_ms);
                r.resp
                    .send(Response { id: r.id, result: Ok(row), latency_ms, batch_size: real })
                    .ok();
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for r in batch {
                let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                st.errors += 1;
                r.resp
                    .send(Response {
                        id: r.id,
                        result: Err(msg.clone()),
                        latency_ms,
                        batch_size: real,
                    })
                    .ok();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{special, tasks::GlueGen, GlueTask};
    use crate::runtime::{artifacts_available, artifacts_dir};

    fn coordinator() -> Option<Coordinator> {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            return None;
        }
        let cfg = ServeConfig {
            method: "lln_diag".into(),
            queue_capacity: 64,
            max_batch: 8,
            batch_timeout_ms: 3,
            buckets: vec![128, 512],
            // These tests exist to exercise the PJRT path; a fallback
            // here would silently mask PJRT regressions.
            native_fallback: false,
            ..Default::default()
        };
        Some(Coordinator::start(cfg, &dir).unwrap())
    }

    /// A coordinator guaranteed to be on the native-backend path (the
    /// artifacts dir does not exist), exercising the full serving stack
    /// without PJRT.
    fn native_coordinator(method: &str, workers: usize) -> Coordinator {
        let cfg = ServeConfig {
            method: method.into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers,
            buckets: vec![32, 64],
            native_fallback: true,
            ..Default::default()
        };
        Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap()
    }

    #[test]
    fn native_fallback_serves_single_request() {
        let c = native_coordinator("lln_diag", 1);
        let resp = c.infer(vec![special::CLS; 20]).unwrap();
        let logits = resp.result.unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        c.shutdown();
    }

    #[test]
    fn native_fallback_batches_bursts() {
        let c = native_coordinator("lln", 1);
        let rxs: Vec<_> = (0..16)
            .map(|i| c.submit(vec![4 + (i as i32) % 7; 24]).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "{:?}", resp.result);
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.completed, 16);
        assert!(st.mean_batch_size() >= 1.0);
        assert!(st.p95_latency() >= st.p50_latency());
        drop(st);
        c.shutdown();
    }

    #[test]
    fn native_fallback_scales_workers_per_bucket() {
        let c = native_coordinator("softmax", 2);
        let rxs: Vec<_> = (0..12).map(|_| c.submit(vec![9i32; 50]).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        assert_eq!(c.stats().lock().unwrap().completed, 12);
        c.shutdown();
    }

    #[test]
    fn native_fallback_is_deterministic_per_request() {
        let c = native_coordinator("elu", 1);
        let a = c.infer(vec![11i32; 30]).unwrap().result.unwrap();
        let b = c.infer(vec![11i32; 30]).unwrap().result.unwrap();
        assert_eq!(a, b);
        c.shutdown();
    }

    #[test]
    fn native_fallback_still_rejects_over_length() {
        let c = native_coordinator("lln_diag", 1);
        let err = c.submit(vec![special::CLS; 1000]).unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
        c.shutdown();
    }

    #[test]
    fn serves_single_request() {
        let Some(c) = coordinator() else { return };
        let mut gen = GlueGen::new(GlueTask::Sst2, 512, 128, 1);
        let (tokens, _) = gen.example();
        let resp = c.infer(tokens).unwrap();
        let logits = resp.result.unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        c.shutdown();
    }

    #[test]
    fn serves_concurrent_burst_with_batching() {
        let Some(c) = coordinator() else { return };
        let mut gen = GlueGen::new(GlueTask::Qqp, 512, 128, 2);
        let rxs: Vec<_> = (0..24).map(|_| c.submit(gen.example().0).unwrap()).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "{:?}", resp.result);
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.completed, 24);
        assert!(st.mean_batch_size() > 1.0, "burst should batch: {}", st.mean_batch_size());
        drop(st);
        c.shutdown();
    }

    #[test]
    fn routes_long_sequences_to_big_bucket() {
        let Some(c) = coordinator() else { return };
        let tokens = vec![special::CLS; 300]; // > 128, <= 512
        let resp = c.infer(tokens).unwrap();
        assert!(resp.result.is_ok());
        c.shutdown();
    }

    #[test]
    fn rejects_over_length() {
        let Some(c) = coordinator() else { return };
        let err = c.submit(vec![special::CLS; 1000]).unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
        c.shutdown();
    }
}
