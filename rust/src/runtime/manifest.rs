//! Typed view of `artifacts/manifest.json` (produced by python aot.py).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One executable input or output.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "i32".
    pub dtype: String,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
    pub fn is_param(&self) -> bool {
        self.name.starts_with("p:")
    }
    pub fn is_opt_state(&self) -> bool {
        self.name.starts_with("m:") || self.name.starts_with("v:")
    }
}

/// One AOT-compiled executable.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: BTreeMap<String, String>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|x| x.name == name)
    }
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|x| x.name == name)
    }
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }
}

/// One exported model: config + parameter schema + initial weights file.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub tag: String,
    pub params_file: String,
    pub param_order: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub config: BTreeMap<String, String>,
}

impl ModelSpec {
    pub fn total_params(&self) -> usize {
        self.param_order
            .iter()
            .map(|k| self.param_shapes[k].iter().product::<usize>())
            .sum()
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub mm_a: f64,
    pub mm_b: f64,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelSpec>,
}

fn io_specs(v: &Json, what: &str) -> Result<Vec<IoSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("{what} not an array"))?
        .iter()
        .map(|x| {
            Ok(IoSpec {
                name: x.get("name").and_then(Json::as_str).context("io name")?.to_string(),
                shape: x
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("io shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                dtype: x.get("dtype").and_then(Json::as_str).context("io dtype")?.to_string(),
            })
        })
        .collect()
}

fn meta_map(v: Option<&Json>) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    if let Some(Json::Obj(m)) = v {
        for (k, x) in m {
            let s = match x {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                Json::Null => "null".into(),
                other => other.to_string_compact(),
            };
            out.insert(k.clone(), s);
        }
    }
    out
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mm_a = v.get("mm_a").and_then(Json::as_f64).context("mm_a")?;
        let mm_b = v.get("mm_b").and_then(Json::as_f64).context("mm_b")?;

        let mut artifacts = BTreeMap::new();
        for a in v.get("artifacts").and_then(Json::as_arr).context("artifacts")? {
            let name = a.get("name").and_then(Json::as_str).context("artifact name")?.to_string();
            let spec = ArtifactSpec {
                name: name.clone(),
                file: a.get("file").and_then(Json::as_str).context("file")?.to_string(),
                inputs: io_specs(a.get("inputs").context("inputs")?, "inputs")?,
                outputs: io_specs(a.get("outputs").context("outputs")?, "outputs")?,
                meta: meta_map(a.get("meta")),
            };
            if artifacts.insert(name.clone(), spec).is_some() {
                bail!("duplicate artifact {name}");
            }
        }

        let mut models = BTreeMap::new();
        if let Some(Json::Obj(m)) = v.get("models") {
            for (tag, spec) in m {
                let order: Vec<String> = spec
                    .get("param_order")
                    .and_then(Json::as_arr)
                    .context("param_order")?
                    .iter()
                    .map(|x| x.as_str().unwrap_or_default().to_string())
                    .collect();
                let mut shapes = BTreeMap::new();
                if let Some(Json::Obj(sh)) = spec.get("param_shapes") {
                    for (k, dims) in sh {
                        shapes.insert(
                            k.clone(),
                            dims.as_arr()
                                .context("shape dims")?
                                .iter()
                                .map(|d| d.as_usize().context("dim"))
                                .collect::<Result<_>>()?,
                        );
                    }
                }
                models.insert(
                    tag.clone(),
                    ModelSpec {
                        tag: tag.clone(),
                        params_file: spec
                            .get("params_file")
                            .and_then(Json::as_str)
                            .context("params_file")?
                            .to_string(),
                        param_order: order,
                        param_shapes: shapes,
                        config: meta_map(spec.get("config")),
                    },
                );
            }
        }
        Ok(Self { mm_a, mm_b, artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest ({} known)", self.artifacts.len()))
    }

    pub fn model(&self, tag: &str) -> Result<&ModelSpec> {
        self.models.get(tag).ok_or_else(|| anyhow!("model {tag:?} not in manifest"))
    }

    /// All artifacts whose meta.method equals the given method.
    pub fn artifacts_for_method(&self, method: &str) -> Vec<&ArtifactSpec> {
        self.artifacts
            .values()
            .filter(|a| a.meta.get("method").map(String::as_str) == Some(method))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "mm_a": 0.21, "mm_b": -1.08,
      "models": {
        "glue_lln": {
          "params_file": "params_glue_lln.bin",
          "param_order": ["cls.b", "cls.w"],
          "param_shapes": {"cls.b": [4], "cls.w": [128, 4]},
          "config": {"attn": "lln", "d_model": 128}
        }
      },
      "artifacts": [
        {"name": "attn_lln_n256", "file": "attn_lln_n256.hlo.txt",
         "inputs": [{"name": "q", "shape": [256, 64], "dtype": "f32"}],
         "outputs": [{"name": "out", "shape": [256, 64], "dtype": "f32"}],
         "meta": {"method": "lln", "n": 256}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!((m.mm_a - 0.21).abs() < 1e-12);
        let a = m.artifact("attn_lln_n256").unwrap();
        assert_eq!(a.inputs[0].shape, vec![256, 64]);
        assert_eq!(a.meta_usize("n"), Some(256));
        let model = m.model("glue_lln").unwrap();
        assert_eq!(model.total_params(), 4 + 128 * 4);
        assert_eq!(model.config.get("attn").unwrap(), "lln");
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn method_filter() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts_for_method("lln").len(), 1);
        assert_eq!(m.artifacts_for_method("softmax").len(), 0);
    }

    #[test]
    fn parses_real_manifest_when_present() {
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.artifacts.len() >= 50, "{}", m.artifacts.len());
            assert!(m.models.len() >= 10);
            // Train artifacts carry the state-symmetry property the
            // training driver relies on.
            let t = m.artifact("train_tinymlm_lln").unwrap();
            let n_in_params = t.inputs.iter().filter(|x| x.is_param()).count();
            let n_out_params = t.outputs.iter().filter(|x| x.is_param()).count();
            assert_eq!(n_in_params, n_out_params);
        }
    }
}
