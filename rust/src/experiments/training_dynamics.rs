//! Fig 1: temperature, entropy, and spectral gap of every layer's
//! attention matrix over the course of training.
//!
//! Two probe routes share the reporting:
//!
//! * **Artifact** — the probe executables (`probe_<method>`) execute
//!   the current parameters on a fixed batch and return the per-layer
//!   stochastic matrices + sigma stats;
//! * **Native** — when no artifacts directory exists (or `--native`),
//!   training runs through [`NativeStep`] (backprop through the native
//!   backends) and the probe reads each layer's `explicit_matrix`
//!   directly from the forward activations.

use anyhow::{anyhow, Result};

use super::maybe_write_csv;
use crate::analysis::{layer_dynamics, LayerDynamics};
use crate::cli::Args;
use crate::config::TrainConfig;
use crate::data::Corpus;
use crate::runtime::{artifacts_available, artifacts_dir, Engine, HostTensor};
use crate::tensor::Mat;
use crate::training::driver::TrainDriver;
use crate::training::native::{NativeShape, NativeStep, TrainStep};
use crate::util::print_table;

/// Render the per-layer metric tables shared by both probe routes.
fn print_dynamics_tables(checkpoints: &[(usize, Vec<LayerDynamics>)], n_layers: usize) {
    for metric in ["temperature", "entropy", "spectral gap"] {
        println!("\n-- {metric} per layer over training --");
        let mut rows = Vec::new();
        for l in 0..n_layers {
            let mut row = vec![format!("layer {l}")];
            for (_, dyns) in checkpoints {
                let d = &dyns[l];
                let v = match metric {
                    "temperature" => d.temperature,
                    "entropy" => d.entropy,
                    _ => d.spectral_gap,
                };
                row.push(format!("{v:.3}"));
            }
            rows.push(row);
        }
        let mut headers = vec!["".to_string()];
        headers.extend(checkpoints.iter().map(|(s, _)| format!("step {s}")));
        let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
        print_table(&hrefs, &rows);
    }
    println!("\npaper shape: temperature and entropy fall as training concentrates");
    println!("attention; mid layers concentrate hardest; the spectral gap separates");
    println!("biased from unbiased concentration (it can rise while entropy falls).");
}

pub fn run_fig1(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    if args.get_bool("native") || !artifacts_available(&dir) {
        return run_fig1_native(args);
    }
    let steps = args.get_usize("steps", 120)?;
    let probe_every = args.get_usize("probe-every", 30)?;
    let method = args.get_or("method", "softmax").to_string();
    let cfg = TrainConfig {
        lr: args.get_f64("lr", 5e-4)?,
        warmup: steps / 10,
        ..Default::default()
    };
    let mut engine = Engine::new(&dir)?;

    let train_artifact = format!("train_mlm_{method}");
    let probe_artifact = format!("probe_{method}");
    let probe_spec = engine.manifest().artifact(&probe_artifact)?.clone();
    let n_layers_nn: Vec<usize> = probe_spec.outputs[0].shape.clone(); // (L, N, N)
    let (n_layers, n) = (n_layers_nn[0], n_layers_nn[1]);

    println!("== Fig 1: attention dynamics during {method} MLM training ==");
    println!("   probing every {probe_every} steps; {n_layers} layers, N={n}\n");

    let mut driver = TrainDriver::new(&engine, &dir, &train_artifact)?;
    let mut corpus = Corpus::new(8192, 0);
    let probe_tokens: Vec<i32> = corpus.mlm_batch(2, n, 0.0).labels; // unmasked text

    let mut csv = Vec::new();
    let mut checkpoints: Vec<(usize, Vec<LayerDynamics>)> = Vec::new();

    #[allow(clippy::too_many_arguments)]
    fn probe(
        driver: &TrainDriver,
        engine: &mut Engine,
        probe_artifact: &str,
        probe_tokens: &[i32],
        n: usize,
        n_layers: usize,
        step: usize,
        csv: &mut Vec<String>,
    ) -> Result<Vec<LayerDynamics>> {
        // probe inputs: p:* + tokens
        let mut inputs = driver.params().to_literals()?;
        inputs.push(
            HostTensor::I32 { shape: vec![2, n], data: probe_tokens.to_vec() }.to_literal()?,
        );
        let outs = engine.execute_literals(probe_artifact, &inputs)?;
        let mats_flat = outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let stats = outs[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let mats: Vec<Mat> = (0..n_layers)
            .map(|l| Mat::from_vec(n, n, mats_flat[l * n * n..(l + 1) * n * n].to_vec()))
            .collect();
        let sigmas: Vec<(f64, f64)> = (0..n_layers)
            .map(|l| (stats[l * 4 + 2] as f64, stats[l * 4 + 3] as f64))
            .collect();
        let dyns = layer_dynamics(&mats, &sigmas);
        for d in &dyns {
            csv.push(format!(
                "{step},{},{:.4},{:.4},{:.4}",
                d.layer,
                d.temperature,
                d.entropy,
                d.spectral_gap
            ));
        }
        Ok(dyns)
    }

    checkpoints.push((
        0,
        probe(
            &driver,
            &mut engine,
            &probe_artifact,
            &probe_tokens,
            n,
            n_layers,
            0,
            &mut csv,
        )?,
    ));
    for step in 0..steps {
        let b = corpus.mlm_batch(8, n, 0.15);
        driver.step(
            &mut engine,
            cfg.lr_at(step),
            &[
                HostTensor::I32 { shape: vec![8, n], data: b.tokens },
                HostTensor::I32 { shape: vec![8, n], data: b.labels },
                HostTensor::F32 { shape: vec![8, n], data: b.weights },
            ],
        )?;
        if (step + 1) % probe_every == 0 || step + 1 == steps {
            eprintln!("   probe @ step {}", step + 1);
            checkpoints.push((
                step + 1,
                probe(
                    &driver,
                    &mut engine,
                    &probe_artifact,
                    &probe_tokens,
                    n,
                    n_layers,
                    step + 1,
                    &mut csv,
                )?,
            ));
        }
    }

    print_dynamics_tables(&checkpoints, n_layers);
    maybe_write_csv(args, "fig1", "step,layer,temperature,entropy,spectral_gap", &csv)?;
    Ok(())
}

/// Fig 1 without artifacts: train a [`NativeStep`] and probe each
/// layer's dense attention matrix from the live forward activations.
/// With `--heads > 1` the probe additionally reads every head's own
/// attention matrix ([`NativeStep::probe_heads`]) and reports per-head
/// entropy — the head-dilution view of fig. 1.
fn run_fig1_native(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps", 60)?;
    let probe_every = args.get_usize("probe-every", 20)?;
    let method_name = args.get_or("method", "softmax").to_string();
    let method = crate::attention::Method::parse(&method_name)
        .ok_or_else(|| anyhow!("unknown attention method {method_name:?}"))?;
    let cfg = TrainConfig {
        lr: args.get_f64("lr", 3e-3)?,
        warmup: steps / 10,
        ..Default::default()
    };
    let mut shape = NativeShape::for_size("tinymlm");
    shape.seed = args.get_usize("seed", 0)? as u64;
    shape.heads = args.get_usize("heads", shape.heads)?.max(1);
    let heads = shape.heads;
    let mut stepper = NativeStep::new(method, shape)?;
    let (b, n) = stepper.batch_shape();
    let n_layers = shape.layers;
    let mut corpus = Corpus::new(stepper.vocab(), shape.seed);
    let probe_tokens: Vec<i32> = corpus.mlm_batch(1, n, 0.0).labels; // unmasked text

    println!("== Fig 1 (native): attention dynamics during {method_name} MLM training ==");
    println!("   probing every {probe_every} steps; {n_layers} layers x {heads} heads, N={n}\n");

    let mut csv = Vec::new();
    let mut checkpoints: Vec<(usize, Vec<LayerDynamics>)> = Vec::new();
    // Per-checkpoint (step, (L, H) entropy grid) for the head table.
    let mut head_checkpoints: Vec<(usize, Vec<Vec<f64>>)> = Vec::new();
    fn probe(
        stepper: &NativeStep,
        probe_tokens: &[i32],
        step: usize,
        csv: &mut Vec<String>,
        head_checkpoints: &mut Vec<(usize, Vec<Vec<f64>>)>,
    ) -> Result<Vec<LayerDynamics>> {
        let probed = stepper.probe_layers(probe_tokens)?;
        let mats: Vec<Mat> = probed.iter().map(|(m, _)| m.clone()).collect();
        let sigmas: Vec<(f64, f64)> = probed.iter().map(|(_, s)| *s).collect();
        let dyns = layer_dynamics(&mats, &sigmas);
        for d in &dyns {
            csv.push(format!(
                "{step},{},{:.4},{:.4},{:.4}",
                d.layer,
                d.temperature,
                d.entropy,
                d.spectral_gap
            ));
        }
        if stepper.shape().heads > 1 {
            let grid: Vec<Vec<f64>> = stepper
                .probe_heads(probe_tokens)?
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|(m, _)| crate::stats::attention_entropy_nats(m))
                        .collect()
                })
                .collect();
            head_checkpoints.push((step, grid));
        }
        Ok(dyns)
    }

    checkpoints.push((
        0,
        probe(&stepper, &probe_tokens, 0, &mut csv, &mut head_checkpoints)?,
    ));
    for step in 0..steps {
        let batch = corpus.mlm_batch(b, n, 0.15);
        stepper.step(cfg.lr_at(step), &batch)?;
        if (step + 1) % probe_every == 0 || step + 1 == steps {
            eprintln!("   probe @ step {}", step + 1);
            checkpoints.push((
                step + 1,
                probe(&stepper, &probe_tokens, step + 1, &mut csv, &mut head_checkpoints)?,
            ));
        }
    }

    print_dynamics_tables(&checkpoints, n_layers);
    if !head_checkpoints.is_empty() {
        println!("\n-- per-head attention entropy [nats] over training --");
        let mut rows = Vec::new();
        for l in 0..n_layers {
            for h in 0..heads {
                let mut row = vec![format!("layer {l} head {h}")];
                for (_, grid) in &head_checkpoints {
                    row.push(format!("{:.3}", grid[l][h]));
                }
                rows.push(row);
            }
        }
        let mut headers = vec!["".to_string()];
        headers.extend(head_checkpoints.iter().map(|(s, _)| format!("step {s}")));
        let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
        print_table(&hrefs, &rows);
        println!("\nheads that stay near ln(N) are diluted (attend ~uniformly); spread");
        println!("between heads of one layer is the specialization signal.");
    }
    maybe_write_csv(args, "fig1", "step,layer,temperature,entropy,spectral_gap", &csv)?;
    Ok(())
}
