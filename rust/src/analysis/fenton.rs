//! Fenton (1960) log-normal-sum study (paper fig. 6 / Prop 4.1 proof).
//!
//! Validates the two regimes the proof leans on:
//!   moderate sigma^2:  var(log sum) ~ ln[(e^{s2} - 1)/d + 1]   (Fenton)
//!   broad sigma^2:     var(log sum) grows ~linearly in s2       (Romeo)

use crate::rng::Pcg64;
use crate::stats;

/// Fenton's moderate-regime prediction for the log-variance of a sum of
/// `d` iid zero-mean log-normals with log-variance `s2`.
pub fn fenton_sigma2(s2: f64, d: usize) -> f64 {
    (((s2.exp() - 1.0) / d as f64) + 1.0).ln()
}

/// Empirical var(log sum_d exp(N(0, s2))) over `trials` Monte-Carlo draws.
pub fn lognormal_sum_variance(s2: f64, d: usize, trials: usize, seed: u64) -> f64 {
    let sigma = s2.sqrt();
    let mut rng = Pcg64::seed(seed);
    let mut logs = Vec::with_capacity(trials);
    for _ in 0..trials {
        let mut sum = 0.0f64;
        for _ in 0..d {
            sum += (sigma * rng.gauss()).exp();
        }
        logs.push(sum.ln());
    }
    let mu = logs.iter().sum::<f64>() / logs.len() as f64;
    logs.iter().map(|&x| (x - mu) * (x - mu)).sum::<f64>() / logs.len() as f64
}

/// One row of the fig. 6 output.
#[derive(Clone, Copy, Debug)]
pub struct FentonPoint {
    pub s2: f64,
    pub measured: f64,
    pub fenton_theory: f64,
}

/// Sweep the moderate regime (fig. 6a).
pub fn moderate_sweep(d: usize, trials: usize, seed: u64) -> Vec<FentonPoint> {
    [0.2, 0.4, 0.6, 0.8, 1.0, 1.2]
        .iter()
        .map(|&s2| FentonPoint {
            s2,
            measured: lognormal_sum_variance(s2, d, trials, seed),
            fenton_theory: fenton_sigma2(s2, d),
        })
        .collect()
}

/// Sweep the broad regime (fig. 6b) — returns (s2, measured) pairs plus
/// the linear-fit slope/intercept/r^2 over them.
pub fn broad_sweep(d: usize, trials: usize, seed: u64) -> (Vec<(f64, f64)>, (f64, f64, f64)) {
    let s2s: Vec<f64> = (0..9).map(|i| 4.0 + 2.0 * i as f64).collect();
    let pts: Vec<(f64, f64)> = s2s
        .iter()
        .map(|&s2| (s2, lognormal_sum_variance(s2, d, trials, seed)))
        .collect();
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let fit = stats::linear_fit(&xs, &ys);
    (pts, fit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fenton_theory_matches_measurement_in_moderate_regime() {
        // Paper fig. 6a: dashed theory lines align with empirical points.
        for p in moderate_sweep(64, 4000, 1) {
            let rel = (p.measured - p.fenton_theory).abs() / p.fenton_theory.max(1e-9);
            assert!(rel < 0.25, "{p:?} rel={rel}");
        }
    }

    #[test]
    fn broad_regime_grows_linearly() {
        // Paper fig. 6b: linear growth with good r^2.
        let (_pts, (slope, _b, r2)) = broad_sweep(64, 3000, 2);
        assert!(slope > 0.0);
        assert!(r2 > 0.98, "r2={r2}");
    }

    #[test]
    fn sum_variance_shrinks_with_more_terms() {
        // Averaging effect: more log-normal terms concentrate the sum.
        let few = lognormal_sum_variance(1.0, 8, 4000, 3);
        let many = lognormal_sum_variance(1.0, 256, 4000, 3);
        assert!(many < few, "few={few} many={many}");
    }
}
