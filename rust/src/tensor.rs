//! Dense row-major f32 matrix — the numeric substrate for the native
//! attention baselines and the analysis instruments.
//!
//! Deliberately small: a 2-D owned matrix with the handful of BLAS-2/3
//! operations the paper's math needs.  The hot-path matmuls dispatch to
//! the register-blocked microkernels in [`micro`] (MR×NR output tiles,
//! LANES-wide independent accumulators the autovectorizer lifts to SIMD
//! width); the original scalar loops survive as the `*_ref` reference
//! implementations that the parity suites pin the blocked kernels
//! against.

use std::fmt;

/// Worker count for the parallel kernels: `LLN_THREADS` env override,
/// else the machine's available parallelism.  `0` passed to any `par_*`
/// entry point means "resolve via this function".
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LLN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested worker count: 0 means auto (the single source
/// of the 0-means-auto rule — config and kernels both consult this).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        default_threads()
    } else {
        requested
    }
}

/// Split `rows` into at most `threads` contiguous, non-empty,
/// near-equal `(start, len)` spans — the one row-partition rule every
/// `par_*` kernel uses.  Never emits an empty span: when
/// `rows < threads` the worker count clamps to `rows`, and the
/// remainder is spread one row at a time so no worker carries more than
/// one extra row (the former `div_ceil` chunking could hand the last
/// worker a sliver, or spawn fewer workers than the clamp allowed).
pub fn partition_rows(rows: usize, threads: usize) -> Vec<(usize, usize)> {
    if rows == 0 {
        return Vec::new();
    }
    let t = threads.max(1).min(rows);
    let base = rows / t;
    let extra = rows % t;
    let mut spans = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < extra);
        spans.push((start, len));
        start += len;
    }
    spans
}

/// Output-element count below which the `Mat::par_*` entry points take
/// the single-threaded kernel directly: a 64×64 output is the smallest
/// matrix where span scheduling pays for itself even on the persistent
/// pool (pinned by the `par_matmul_small`/`matmul_small` bench pair in
/// BENCH_kernels.json).  The fallback is bitwise-safe — the parallel
/// paths already match the serial kernels bitwise per row.
pub const PAR_MIN_ELEMS: usize = 4096;

/// Run `work(row0, len, chunk)` over the [`partition_rows`] spans of a
/// row-major buffer (`rows` rows of `row_len` values), one persistent
/// compute-pool task per span — the shared harness behind every
/// `par_*` kernel and the fused attention entry points.  `chunk` is the
/// span's disjoint `len * row_len` slice of `buf`; `row0` is its first
/// global row index.  `threads` is taken as already resolved; the span
/// count clamps to `rows`.  Partitioning is deterministic; which pool
/// worker executes a span is not — outputs never depend on it because
/// each span is written only by its owner.
pub fn par_row_spans(
    buf: &mut [f32],
    rows: usize,
    row_len: usize,
    threads: usize,
    work: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    debug_assert_eq!(buf.len(), rows * row_len);
    let spans = partition_rows(rows, threads);
    if spans.len() <= 1 {
        if let Some(&(row0, len)) = spans.first() {
            work(row0, len, buf);
        }
        return;
    }
    let work = &work;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(spans.len());
    let mut rest = buf;
    for (row0, len) in spans {
        let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(len * row_len);
        rest = tail;
        tasks.push(Box::new(move || work(row0, len, chunk)));
    }
    crate::util::compute_pool::scope(tasks);
}

/// Register-blocked microkernels shared by [`Mat`], the fused attention
/// kernels, and the block-diagonal softmax tiles.  Operands are raw
/// row-major slices with explicit shapes so callers can run them over
/// sub-ranges (K/V tiles, diagonal blocks) without copying.
///
/// The point of the blocking is to break the serial floating-point
/// dependency chain of a naive dot product: `LANES` independent
/// accumulators per output let the autovectorizer emit SIMD FMAs, and
/// the MR×NR output tiling reuses each loaded operand row across a
/// whole register block.  Per-output floating-point order is a function
/// of (k, LANES) alone — never of how rows are partitioned across
/// threads — which keeps the scalar and row-partitioned entry points
/// bitwise identical.
pub mod micro {
    /// Independent accumulator lanes per output scalar (8 f32 = one
    /// 256-bit vector register; narrower targets split the lanes).
    pub const LANES: usize = 8;
    /// Output rows per register block.
    pub const MR: usize = 4;
    /// Output columns (B rows) per register block in the A·Bᵀ kernel.
    pub const NR: usize = 4;
    /// k-panel depth of the ikj kernel (matches the pre-blocking KB).
    pub const KB: usize = 64;

    /// Fixed-order pairwise fold of one lane accumulator — the same
    /// reduction tree everywhere, so blocked and tail columns agree
    /// bitwise.
    #[inline(always)]
    fn fold_lanes(v: [f32; LANES]) -> f32 {
        ((v[0] + v[4]) + (v[2] + v[6])) + ((v[1] + v[5]) + (v[3] + v[7]))
    }

    /// Lane-blocked dot product (identical FP order to the NR-blocked
    /// kernel body in [`matmul_t_block`]).
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        debug_assert_eq!(k, b.len());
        let mut acc = [0.0f32; LANES];
        let mut kk = 0;
        while kk + LANES <= k {
            for l in 0..LANES {
                acc[l] += a[kk + l] * b[kk + l];
            }
            kk += LANES;
        }
        let mut tail = 0.0f32;
        while kk < k {
            tail += a[kk] * b[kk];
            kk += 1;
        }
        fold_lanes(acc) + tail
    }

    /// `out[m×n] = a[m×k] @ b[n×k]ᵀ` — the dot-product kernel behind
    /// [`Mat::matmul_t`](super::Mat::matmul_t), the fused attention
    /// score tiles, and the block-diagonal softmax tiles.  `out` is
    /// fully overwritten.
    pub fn matmul_t_block(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(out.len(), m * n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            let mut j = 0;
            while j + NR <= n {
                let b0 = &b[j * k..(j + 1) * k];
                let b1 = &b[(j + 1) * k..(j + 2) * k];
                let b2 = &b[(j + 2) * k..(j + 3) * k];
                let b3 = &b[(j + 3) * k..(j + 4) * k];
                let mut acc = [[0.0f32; LANES]; NR];
                let mut kk = 0;
                while kk + LANES <= k {
                    for l in 0..LANES {
                        let av = arow[kk + l];
                        acc[0][l] += av * b0[kk + l];
                        acc[1][l] += av * b1[kk + l];
                        acc[2][l] += av * b2[kk + l];
                        acc[3][l] += av * b3[kk + l];
                    }
                    kk += LANES;
                }
                let mut tail = [0.0f32; NR];
                while kk < k {
                    let av = arow[kk];
                    tail[0] += av * b0[kk];
                    tail[1] += av * b1[kk];
                    tail[2] += av * b2[kk];
                    tail[3] += av * b3[kk];
                    kk += 1;
                }
                for r in 0..NR {
                    orow[j + r] = fold_lanes(acc[r]) + tail[r];
                }
                j += NR;
            }
            while j < n {
                orow[j] = dot(arow, &b[j * k..(j + 1) * k]);
                j += 1;
            }
        }
    }

    /// `out[m×n] += a[m×k] @ b[k×n]` — the ikj kernel behind
    /// [`Mat::matmul`](super::Mat::matmul), with an MR-row register
    /// block so each streamed `b` row feeds MR output rows.  The caller
    /// zero-initializes `out`.
    pub fn matmul_block(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(out.len(), m * n);
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            let mut i = 0;
            while i + MR <= m {
                let rows = &mut out[i * n..(i + MR) * n];
                let (r0, rest) = rows.split_at_mut(n);
                let (r1, rest) = rest.split_at_mut(n);
                let (r2, r3) = rest.split_at_mut(n);
                for kk in kb..kend {
                    let a0 = a[i * k + kk];
                    let a1 = a[(i + 1) * k + kk];
                    let a2 = a[(i + 2) * k + kk];
                    let a3 = a[(i + 3) * k + kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (j, &bj) in brow.iter().enumerate() {
                        r0[j] += a0 * bj;
                        r1[j] += a1 * bj;
                        r2[j] += a2 * bj;
                        r3[j] += a3 * bj;
                    }
                }
                i += MR;
            }
            while i < m {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let av = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &bj) in orow.iter_mut().zip(brow) {
                        *o += av * bj;
                    }
                }
                i += 1;
            }
        }
    }

    // ------------------------------------------------------------------
    // Monomorphized head-dim instances
    // ------------------------------------------------------------------
    //
    // The generic kernels above take the reduction depth `k` at runtime:
    // the lane loop re-checks its trip count every `LANES` block and the
    // scalar tail survives as dead weight even when `k % LANES == 0`.
    // The `*_spec::<D>` instances below are the *same loops* with `k`
    // replaced by a const generic `D` (one instance per supported head
    // dim, D ∈ {32, 64, 128} — all multiples of LANES, so the compiler
    // proves the tail empty and fully unrolls the lane blocks).  The
    // statement order, and therefore the floating-point order, is
    // token-for-token the generic path's, so for equal inputs the
    // outputs are bitwise equal and the dispatch layer
    // ([`KernelDispatch`](super::KernelDispatch)) may pick either
    // freely.  Pinned by `spec_kernels_bitwise_match_generic` below and
    // the head-dim goldens in rust/tests/prop_kernels.rs.

    /// Lane-blocked dot with the depth fixed at `D` — bitwise [`dot`]
    /// for `k == D`.
    #[inline(always)]
    pub fn dot_spec<const D: usize>(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(D % LANES, 0);
        debug_assert_eq!(a.len(), D);
        debug_assert_eq!(b.len(), D);
        let mut acc = [0.0f32; LANES];
        let mut kk = 0;
        while kk + LANES <= D {
            for l in 0..LANES {
                acc[l] += a[kk + l] * b[kk + l];
            }
            kk += LANES;
        }
        // D % LANES == 0: the tail loop is provably empty, but the
        // `+ tail` stays so the FP expression matches [`dot`] exactly.
        let mut tail = 0.0f32;
        while kk < D {
            tail += a[kk] * b[kk];
            kk += 1;
        }
        fold_lanes(acc) + tail
    }

    /// [`matmul_t_block`] with the reduction depth fixed at `D` —
    /// bitwise the generic kernel for `k == D`.
    pub fn matmul_t_block_spec<const D: usize>(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        n: usize,
    ) {
        debug_assert_eq!(D % LANES, 0);
        debug_assert_eq!(a.len(), m * D);
        debug_assert_eq!(b.len(), n * D);
        debug_assert_eq!(out.len(), m * n);
        for i in 0..m {
            let arow = &a[i * D..(i + 1) * D];
            let orow = &mut out[i * n..(i + 1) * n];
            let mut j = 0;
            while j + NR <= n {
                let b0 = &b[j * D..(j + 1) * D];
                let b1 = &b[(j + 1) * D..(j + 2) * D];
                let b2 = &b[(j + 2) * D..(j + 3) * D];
                let b3 = &b[(j + 3) * D..(j + 4) * D];
                let mut acc = [[0.0f32; LANES]; NR];
                let mut kk = 0;
                while kk + LANES <= D {
                    for l in 0..LANES {
                        let av = arow[kk + l];
                        acc[0][l] += av * b0[kk + l];
                        acc[1][l] += av * b1[kk + l];
                        acc[2][l] += av * b2[kk + l];
                        acc[3][l] += av * b3[kk + l];
                    }
                    kk += LANES;
                }
                let mut tail = [0.0f32; NR];
                while kk < D {
                    let av = arow[kk];
                    tail[0] += av * b0[kk];
                    tail[1] += av * b1[kk];
                    tail[2] += av * b2[kk];
                    tail[3] += av * b3[kk];
                    kk += 1;
                }
                for r in 0..NR {
                    orow[j + r] = fold_lanes(acc[r]) + tail[r];
                }
                j += NR;
            }
            while j < n {
                orow[j] = dot_spec::<D>(arow, &b[j * D..(j + 1) * D]);
                j += 1;
            }
        }
    }

    /// [`matmul_block`] with the reduction depth fixed at `D` — bitwise
    /// the generic kernel for `k == D`.  The caller zero-initializes
    /// `out`.
    pub fn matmul_block_spec<const D: usize>(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * D);
        debug_assert_eq!(b.len(), D * n);
        debug_assert_eq!(out.len(), m * n);
        for kb in (0..D).step_by(KB) {
            let kend = (kb + KB).min(D);
            let mut i = 0;
            while i + MR <= m {
                let rows = &mut out[i * n..(i + MR) * n];
                let (r0, rest) = rows.split_at_mut(n);
                let (r1, rest) = rest.split_at_mut(n);
                let (r2, r3) = rest.split_at_mut(n);
                for kk in kb..kend {
                    let a0 = a[i * D + kk];
                    let a1 = a[(i + 1) * D + kk];
                    let a2 = a[(i + 2) * D + kk];
                    let a3 = a[(i + 3) * D + kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (j, &bj) in brow.iter().enumerate() {
                        r0[j] += a0 * bj;
                        r1[j] += a1 * bj;
                        r2[j] += a2 * bj;
                        r3[j] += a3 * bj;
                    }
                }
                i += MR;
            }
            while i < m {
                let arow = &a[i * D..(i + 1) * D];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let av = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &bj) in orow.iter_mut().zip(brow) {
                        *o += av * bj;
                    }
                }
                i += 1;
            }
        }
    }
}

/// Which head-dim instance of the [`micro`] kernels a call site runs.
///
/// `Auto` (the `[compute] head_dim = 0` default) looks the reduction
/// depth up per call; `D32`/`D64`/`D128` are resolved once at backend
/// construction through the dispatch table in `attention::backend`
/// (`resolve_kernel`); `Generic` forces the runtime-generic loops — the
/// bench baseline and the escape hatch for unspecialized dims.  Every
/// monomorphized instance is bitwise-identical to the generic path (see
/// the `micro::*_spec` docs), so dispatch is purely a perf choice: a
/// pinned instance that meets an off-config depth (e.g. Performer's
/// projected features) silently degrades to the generic kernel rather
/// than miscomputing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelDispatch {
    /// Per-call lookup: specialize whenever the depth matches.
    #[default]
    Auto,
    /// Runtime-generic loops only.
    Generic,
    /// Monomorphized for head dim 32.
    D32,
    /// Monomorphized for head dim 64.
    D64,
    /// Monomorphized for head dim 128.
    D128,
}

impl KernelDispatch {
    /// The instance specialized for reduction depth `d` (`Generic` when
    /// no monomorphized instance exists).
    pub fn for_dim(d: usize) -> Self {
        match d {
            32 => Self::D32,
            64 => Self::D64,
            128 => Self::D128,
            _ => Self::Generic,
        }
    }

    /// The head dim this instance is pinned to (`None` for
    /// `Auto`/`Generic`).
    pub fn specialized_dim(self) -> Option<usize> {
        match self {
            Self::D32 => Some(32),
            Self::D64 => Some(64),
            Self::D128 => Some(128),
            Self::Auto | Self::Generic => None,
        }
    }

    /// Resolve against a concrete depth: `Auto` picks the matching
    /// instance, a mismatched pinned instance falls back to `Generic`.
    #[inline(always)]
    fn resolve(self, k: usize) -> Self {
        match self {
            Self::Auto => Self::for_dim(k),
            other => match other.specialized_dim() {
                Some(d) if d != k => Self::Generic,
                _ => other,
            },
        }
    }

    /// [`micro::dot`] through the dispatch (`a`, `b` of equal length).
    #[inline(always)]
    pub fn dot(self, a: &[f32], b: &[f32]) -> f32 {
        match self.resolve(a.len()) {
            Self::D32 => micro::dot_spec::<32>(a, b),
            Self::D64 => micro::dot_spec::<64>(a, b),
            Self::D128 => micro::dot_spec::<128>(a, b),
            _ => micro::dot(a, b),
        }
    }

    /// [`micro::matmul_t_block`] through the dispatch.
    #[inline]
    pub fn matmul_t_block(
        self,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        match self.resolve(k) {
            Self::D32 => micro::matmul_t_block_spec::<32>(a, b, out, m, n),
            Self::D64 => micro::matmul_t_block_spec::<64>(a, b, out, m, n),
            Self::D128 => micro::matmul_t_block_spec::<128>(a, b, out, m, n),
            _ => micro::matmul_t_block(a, b, out, m, k, n),
        }
    }

    /// [`micro::matmul_block`] through the dispatch (the caller
    /// zero-initializes `out`).
    #[inline]
    pub fn matmul_block(
        self,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        match self.resolve(k) {
            Self::D32 => micro::matmul_block_spec::<32>(a, b, out, m, n),
            Self::D64 => micro::matmul_block_spec::<64>(a, b, out, m, n),
            Self::D128 => micro::matmul_block_spec::<128>(a, b, out, m, n),
            _ => micro::matmul_block(a, b, out, m, k, n),
        }
    }
}

/// Row-major dense matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Gaussian-filled matrix (mean 0, given std).
    pub fn gaussian(rows: usize, cols: usize, std: f32, rng: &mut crate::rng::Pcg64) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_gaussian(&mut m.data, 0.0, std);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// `self @ other` — register-blocked ikj matmul (see [`micro`]).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        KernelDispatch::Auto.matmul_block(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// Reference `self @ other`: the original cache-blocked scalar ikj
    /// loop, kept deliberately unoptimized so the parity suites can pin
    /// [`Mat::matmul`] (and the `par_*` entry points) against it.
    pub fn matmul_ref(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        const KB: usize = 64;
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        out
    }

    /// `self @ other` with the output rows partitioned across `threads`
    /// compute-pool tasks (0 = auto, see [`default_threads`]) via
    /// [`partition_rows`].  Each task runs the same register-blocked
    /// kernel as [`Mat::matmul`], in the same per-row floating-point
    /// order, so results are bitwise identical to the scalar path.
    /// Outputs below [`PAR_MIN_ELEMS`] skip the pool entirely.
    pub fn par_matmul(&self, other: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let t = resolve_threads(threads).min(m.max(1));
        if t <= 1 || m == 0 || n == 0 || m * n < PAR_MIN_ELEMS {
            return self.matmul(other);
        }
        let mut out = Mat::zeros(m, n);
        let a = self.data.as_slice();
        let b = other.data.as_slice();
        par_row_spans(&mut out.data, m, n, t, |row0, len, chunk| {
            KernelDispatch::Auto.matmul_block(&a[row0 * k..(row0 + len) * k], b, chunk, len, k, n);
        });
        out
    }

    /// `self @ other^T` without materializing the transpose —
    /// register-blocked dot kernel (see [`micro::matmul_t_block`]).
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        KernelDispatch::Auto.matmul_t_block(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// Reference `self @ other^T`: the original per-output scalar dot
    /// product (a serial FP dependency chain the autovectorizer cannot
    /// touch) — the parity anchor for [`Mat::matmul_t`].
    pub fn matmul_t_ref(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                orow[j] = acc;
            }
        }
        out
    }

    /// `self @ other^T` with output rows partitioned across `threads`
    /// compute-pool tasks (0 = auto) via [`partition_rows`].  Per-row FP
    /// order matches [`Mat::matmul_t`] exactly (lane structure is fixed
    /// by k alone), so results are bitwise identical to the scalar path.
    /// Outputs below [`PAR_MIN_ELEMS`] skip the pool entirely.
    pub fn par_matmul_t(&self, other: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let t = resolve_threads(threads).min(m.max(1));
        if t <= 1 || m == 0 || n == 0 || m * n < PAR_MIN_ELEMS {
            return self.matmul_t(other);
        }
        let mut out = Mat::zeros(m, n);
        let a = self.data.as_slice();
        let b = other.data.as_slice();
        par_row_spans(&mut out.data, m, n, t, |row0, len, chunk| {
            KernelDispatch::Auto.matmul_t_block(&a[row0 * k..(row0 + len) * k], b, chunk, len, k, n);
        });
        out
    }

    /// The PR-1 parallel `self @ other^T`: row-partitioned scalar dot
    /// products (per-row FP order matches [`Mat::matmul_t_ref`]
    /// bitwise).  Kept as the baseline the kernel perf trajectory
    /// (`lln bench` / BENCH_kernels.json) measures speedups against.
    pub fn par_matmul_t_ref(&self, other: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let t = resolve_threads(threads).min(m.max(1));
        if t <= 1 || m == 0 || n == 0 || m * n < PAR_MIN_ELEMS {
            return self.matmul_t_ref(other);
        }
        let mut out = Mat::zeros(m, n);
        let a = self.data.as_slice();
        let b = other.data.as_slice();
        par_row_spans(&mut out.data, m, n, t, |row0, len, chunk| {
            for i in 0..len {
                let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
                let orow = &mut chunk[i * n..(i + 1) * n];
                for j in 0..n {
                    let brow = &b[j * k..(j + 1) * k];
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        acc += arow[kk] * brow[kk];
                    }
                    orow[j] = acc;
                }
            }
        });
        out
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        let mut out = self.clone();
        out.map_inplace(f);
        out
    }

    pub fn scale(&self, s: f32) -> Mat {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        out
    }

    /// Row-wise softmax in place (numerically stable).
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for x in row.iter_mut() {
                *x = (*x - max).exp();
                sum += *x;
            }
            let inv = 1.0 / sum;
            for x in row.iter_mut() {
                *x *= inv;
            }
        }
    }

    /// Row-wise softmax with rows partitioned across `threads`
    /// compute-pool tasks (0 = auto) via [`partition_rows`].  Rows are
    /// independent, so results are bitwise identical to
    /// [`Mat::softmax_rows`].  Matrices below [`PAR_MIN_ELEMS`] skip
    /// the pool entirely.
    pub fn par_softmax_rows(&mut self, threads: usize) {
        let (m, n) = (self.rows, self.cols);
        let t = resolve_threads(threads).min(m.max(1));
        if t <= 1 || m == 0 || n == 0 || m * n < PAR_MIN_ELEMS {
            self.softmax_rows();
            return;
        }
        par_row_spans(&mut self.data, m, n, t, |_row0, _len, chunk| {
            for row in chunk.chunks_mut(n) {
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0.0f32;
                for x in row.iter_mut() {
                    *x = (*x - max).exp();
                    sum += *x;
                }
                let inv = 1.0 / sum;
                for x in row.iter_mut() {
                    *x *= inv;
                }
            }
        });
    }

    /// Normalize each row to sum 1 (entries assumed non-negative).
    pub fn normalize_rows(&mut self, eps: f32) {
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            let sum: f32 = row.iter().sum::<f32>() + eps;
            let inv = 1.0 / sum;
            for x in row.iter_mut() {
                *x *= inv;
            }
        }
    }

    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(i)) {
                *o += x;
            }
        }
        out
    }

    pub fn mean(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }

    pub fn variance(&self) -> f64 {
        let mu = self.mean();
        self.data.iter().map(|&x| (x as f64 - mu).powi(2)).sum::<f64>() / self.data.len() as f64
    }

    /// Matrix–vector product `self @ v`.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(&a, &b)| a * b).sum())
            .collect()
    }

    /// Transposed matrix–vector product `self^T @ v`.
    pub fn matvec_t(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, v.len());
        let mut out = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let vi = v[i];
            for (o, &x) in out.iter_mut().zip(self.row(i)) {
                *o += vi * x;
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Check every row sums to ~1 and entries are non-negative.
    pub fn is_stochastic(&self, tol: f32) -> bool {
        self.data.iter().all(|&x| x >= -tol)
            && self.row_sums().iter().all(|&s| (s - 1.0).abs() < tol)
    }
}

/// Vector helpers shared by linalg/stats.
pub mod vec_ops {
    pub fn dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }
    pub fn norm(a: &[f32]) -> f64 {
        dot(a, a).sqrt()
    }
    pub fn scale_inplace(a: &mut [f32], s: f32) {
        for x in a {
            *x *= s;
        }
    }
    pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }
    pub fn mean(a: &[f32]) -> f64 {
        a.iter().map(|&x| x as f64).sum::<f64>() / a.len() as f64
    }
    pub fn variance(a: &[f32]) -> f64 {
        let mu = mean(a);
        a.iter().map(|&x| (x as f64 - mu).powi(2)).sum::<f64>() / a.len() as f64
    }
    pub fn std(a: &[f32]) -> f64 {
        variance(a).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_matches_matmul_of_transpose() {
        let mut rng = Pcg64::seed(1);
        let a = Mat::gaussian(7, 5, 1.0, &mut rng);
        let b = Mat::gaussian(9, 5, 1.0, &mut rng);
        let via_t = a.matmul_t(&b);
        let explicit = a.matmul(&b.transpose());
        assert!(via_t.max_abs_diff(&explicit) < 1e-5);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Pcg64::seed(2);
        let a = Mat::gaussian(4, 6, 1.0, &mut rng);
        assert_eq!(a, a.transpose().transpose());
    }

    #[test]
    fn softmax_rows_stochastic() {
        let mut rng = Pcg64::seed(3);
        let mut a = Mat::gaussian(10, 16, 3.0, &mut rng);
        a.softmax_rows();
        assert!(a.is_stochastic(1e-5));
    }

    #[test]
    fn softmax_handles_large_scores() {
        let mut a = Mat::from_vec(1, 3, vec![1000.0, 999.0, -1000.0]);
        a.softmax_rows();
        assert!(a.data().iter().all(|x| x.is_finite()));
        assert!((a.row_sums()[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn matvec_consistency() {
        let mut rng = Pcg64::seed(4);
        let a = Mat::gaussian(5, 7, 1.0, &mut rng);
        let v: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let direct = a.matvec(&v);
        let via_mat = a.matmul(&Mat::from_vec(7, 1, v.clone()));
        for (i, &x) in direct.iter().enumerate() {
            assert!((x - via_mat.get(i, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_t_consistency() {
        let mut rng = Pcg64::seed(5);
        let a = Mat::gaussian(5, 7, 1.0, &mut rng);
        let v: Vec<f32> = (0..5).map(|i| i as f32 - 2.0).collect();
        let direct = a.matvec_t(&v);
        let explicit = a.transpose().matvec(&v);
        for (x, y) in direct.iter().zip(&explicit) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn mean_variance() {
        let a = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        assert!((a.mean() - 2.5).abs() < 1e-9);
        assert!((a.variance() - 1.25).abs() < 1e-9);
    }

    #[test]
    fn normalize_rows_sums_to_one() {
        let mut a = Mat::from_vec(2, 3, vec![1.0, 1.0, 2.0, 3.0, 0.0, 1.0]);
        a.normalize_rows(0.0);
        for s in a.row_sums() {
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = a.matmul(&b);
    }

    #[test]
    fn par_matmul_bitwise_matches_scalar() {
        let mut rng = Pcg64::seed(6);
        for (m, k, n) in [(1, 7, 5), (17, 33, 9), (64, 64, 64), (65, 3, 2)] {
            let a = Mat::gaussian(m, k, 1.0, &mut rng);
            let b = Mat::gaussian(k, n, 1.0, &mut rng);
            let serial = a.matmul(&b);
            for t in [1usize, 2, 3, 8, 0] {
                let par = a.par_matmul(&b, t);
                assert_eq!(serial.data(), par.data(), "m={m} k={k} n={n} t={t}");
            }
        }
    }

    #[test]
    fn par_matmul_t_bitwise_matches_scalar() {
        let mut rng = Pcg64::seed(7);
        for (m, k, n) in [(1, 5, 3), (19, 16, 31), (48, 64, 48)] {
            let a = Mat::gaussian(m, k, 1.0, &mut rng);
            let b = Mat::gaussian(n, k, 1.0, &mut rng);
            let serial = a.matmul_t(&b);
            for t in [1usize, 2, 5, 0] {
                let par = a.par_matmul_t(&b, t);
                assert_eq!(serial.data(), par.data(), "m={m} k={k} n={n} t={t}");
            }
        }
    }

    #[test]
    fn par_softmax_rows_bitwise_matches_scalar() {
        let mut rng = Pcg64::seed(8);
        for (m, n) in [(1, 4), (13, 29), (64, 64)] {
            let base = Mat::gaussian(m, n, 3.0, &mut rng);
            let mut serial = base.clone();
            serial.softmax_rows();
            for t in [1usize, 2, 7, 0] {
                let mut par = base.clone();
                par.par_softmax_rows(t);
                assert_eq!(serial.data(), par.data(), "m={m} n={n} t={t}");
            }
        }
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn partition_rows_spans_are_balanced_and_cover() {
        for rows in [0usize, 1, 2, 3, 7, 8, 9, 63, 64, 65, 1000] {
            for threads in [0usize, 1, 2, 3, 8, 17, 2000] {
                let spans = partition_rows(rows, threads);
                if rows == 0 {
                    assert!(spans.is_empty());
                    continue;
                }
                // At most `threads` (clamped) spans, none empty.
                assert!(spans.len() <= threads.max(1).min(rows));
                assert!(spans.iter().all(|&(_, len)| len > 0), "rows={rows} t={threads}");
                // Contiguous cover of 0..rows.
                let mut next = 0;
                for &(start, len) in &spans {
                    assert_eq!(start, next);
                    next += len;
                }
                assert_eq!(next, rows);
                // Balanced: max span exceeds min span by at most one row.
                let max = spans.iter().map(|&(_, l)| l).max().unwrap();
                let min = spans.iter().map(|&(_, l)| l).min().unwrap();
                assert!(max - min <= 1, "rows={rows} t={threads}: {min}..{max}");
            }
        }
    }

    #[test]
    fn par_kernels_handle_rows_fewer_than_threads() {
        // Regression for the empty-chunk edge: every worker must get a
        // non-empty span even when rows < threads.
        let mut rng = Pcg64::seed(20);
        for m in [1usize, 2, 3, 5] {
            let a = Mat::gaussian(m, 9, 1.0, &mut rng);
            let b = Mat::gaussian(9, 4, 1.0, &mut rng);
            assert_eq!(a.matmul(&b).data(), a.par_matmul(&b, 16).data(), "m={m}");
            let c = Mat::gaussian(7, 9, 1.0, &mut rng);
            assert_eq!(a.matmul_t(&c).data(), a.par_matmul_t(&c, 16).data(), "m={m}");
            let mut serial = a.clone();
            serial.softmax_rows();
            let mut par = a.clone();
            par.par_softmax_rows(16);
            assert_eq!(serial.data(), par.data(), "m={m}");
        }
    }

    #[test]
    fn blocked_matmul_matches_reference() {
        let mut rng = Pcg64::seed(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 64, 64), (65, 3, 2), (5, 130, 7)] {
            let a = Mat::gaussian(m, k, 1.0, &mut rng);
            let b = Mat::gaussian(k, n, 1.0, &mut rng);
            let err = a.matmul(&b).max_abs_diff(&a.matmul_ref(&b));
            assert!(err < 1e-4, "m={m} k={k} n={n}: {err}");
        }
    }

    #[test]
    fn blocked_matmul_t_matches_reference() {
        let mut rng = Pcg64::seed(22);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (19, 16, 31), (48, 64, 48), (7, 130, 9)] {
            let a = Mat::gaussian(m, k, 1.0, &mut rng);
            let b = Mat::gaussian(n, k, 1.0, &mut rng);
            let blocked = a.matmul_t(&b);
            let reference = a.matmul_t_ref(&b);
            let err = blocked.max_abs_diff(&reference);
            assert!(err < 1e-4, "m={m} k={k} n={n}: {err}");
            // The PR-1 parallel baseline stays bitwise-pinned to the
            // scalar reference it row-partitions.
            for t in [1usize, 3, 0] {
                assert_eq!(reference.data(), a.par_matmul_t_ref(&b, t).data(), "t={t}");
            }
        }
    }

    #[test]
    fn spec_kernels_bitwise_match_generic() {
        // Every monomorphized head-dim instance must be bitwise equal
        // to the runtime-generic kernel it replaces — the contract that
        // lets the dispatch layer pick instances freely.
        fn check_dim<const D: usize>(rng: &mut Pcg64) {
            for (m, n) in [(1usize, 1usize), (3, 5), (4, 4), (7, 13), (16, 33)] {
                let a = Mat::gaussian(m, D, 1.0, rng);
                let b = Mat::gaussian(n, D, 1.0, rng);
                assert_eq!(
                    micro::dot(a.row(0), b.row(0)).to_bits(),
                    micro::dot_spec::<D>(a.row(0), b.row(0)).to_bits(),
                    "dot d={D}"
                );
                let mut gen_out = vec![0.0f32; m * n];
                let mut spec_out = vec![0.0f32; m * n];
                micro::matmul_t_block(a.data(), b.data(), &mut gen_out, m, D, n);
                micro::matmul_t_block_spec::<D>(a.data(), b.data(), &mut spec_out, m, n);
                assert_eq!(gen_out, spec_out, "matmul_t_block d={D} m={m} n={n}");
                let c = Mat::gaussian(D, n, 1.0, rng);
                let mut gen_out = vec![0.0f32; m * n];
                let mut spec_out = vec![0.0f32; m * n];
                micro::matmul_block(a.data(), c.data(), &mut gen_out, m, D, n);
                micro::matmul_block_spec::<D>(a.data(), c.data(), &mut spec_out, m, n);
                assert_eq!(gen_out, spec_out, "matmul_block d={D} m={m} n={n}");
            }
        }
        let mut rng = Pcg64::seed(24);
        check_dim::<32>(&mut rng);
        check_dim::<64>(&mut rng);
        check_dim::<128>(&mut rng);
    }

    #[test]
    fn kernel_dispatch_resolution_and_fallback() {
        assert_eq!(KernelDispatch::for_dim(32), KernelDispatch::D32);
        assert_eq!(KernelDispatch::for_dim(64), KernelDispatch::D64);
        assert_eq!(KernelDispatch::for_dim(128), KernelDispatch::D128);
        assert_eq!(KernelDispatch::for_dim(48), KernelDispatch::Generic);
        assert_eq!(KernelDispatch::D64.specialized_dim(), Some(64));
        assert_eq!(KernelDispatch::Auto.specialized_dim(), None);
        // A pinned instance meeting an off-config depth degrades to the
        // generic kernel: same results, never a miscompute.
        let mut rng = Pcg64::seed(25);
        for k in [5usize, 32, 48, 64, 128] {
            let a = Mat::gaussian(6, k, 1.0, &mut rng);
            let b = Mat::gaussian(9, k, 1.0, &mut rng);
            let mut base = vec![0.0f32; 6 * 9];
            micro::matmul_t_block(a.data(), b.data(), &mut base, 6, k, 9);
            for kern in [
                KernelDispatch::Auto,
                KernelDispatch::Generic,
                KernelDispatch::D32,
                KernelDispatch::D64,
                KernelDispatch::D128,
            ] {
                let mut out = vec![0.0f32; 6 * 9];
                kern.matmul_t_block(a.data(), b.data(), &mut out, 6, k, 9);
                assert_eq!(base, out, "matmul_t k={k} kern={kern:?}");
                assert_eq!(
                    micro::dot(a.row(0), b.row(0)).to_bits(),
                    kern.dot(a.row(0), b.row(0)).to_bits(),
                    "dot k={k} kern={kern:?}"
                );
            }
        }
    }

    #[test]
    fn micro_dot_matches_f64_accumulation() {
        let mut rng = Pcg64::seed(23);
        for k in [1usize, 7, 8, 9, 16, 63, 64, 65, 200] {
            let a = Mat::gaussian(1, k, 1.0, &mut rng);
            let b = Mat::gaussian(1, k, 1.0, &mut rng);
            let exact: f64 = vec_ops::dot(a.row(0), b.row(0));
            let got = micro::dot(a.row(0), b.row(0)) as f64;
            assert!((got - exact).abs() < 1e-3 * (1.0 + exact.abs()), "k={k}: {got} vs {exact}");
        }
    }
}
