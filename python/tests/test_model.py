"""L2 model: shapes, attention-method dispatch, loss behavior, and the
probe outputs the Rust analysis layer consumes.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import train as T


def tiny_cfg(attn="softmax", **kw):
    return M.make_config("tiny", attn=attn, num_classes=4, **kw)


def make_inputs(cfg, batch=2, n=128, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, n)), jnp.int32)
    return tokens


@pytest.mark.parametrize("method", M.ATTENTION_METHODS)
def test_forward_shapes_all_methods(method):
    cfg = tiny_cfg(method)
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
    tokens = make_inputs(cfg)
    hidden, stats = M.forward(params, tokens, cfg)
    assert hidden.shape == (2, 128, cfg.d_model)
    assert len(stats) == cfg.n_layers
    assert bool(jnp.all(jnp.isfinite(hidden)))


@pytest.mark.parametrize("method", ["softmax", "lln", "lln_diag"])
def test_heads_and_losses(method):
    cfg = tiny_cfg(method)
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
    tokens = make_inputs(cfg)
    labels = tokens
    weights = jnp.ones_like(tokens, jnp.float32)
    loss, _ = M.mlm_loss(params, tokens, labels, weights, cfg)
    assert float(loss) > 0 and np.isfinite(float(loss))
    # Random init ~ uniform predictions: loss near log(V).
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0
    closs, _ = M.cls_loss(params, tokens, jnp.zeros((2,), jnp.int32), cfg)
    assert abs(float(closs) - np.log(cfg.num_classes)) < 0.5


def test_patch_mode_forward():
    cfg = tiny_cfg("lln_diag", max_len=64, diag_block=16)
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, patch_dim=48).items()}
    patches = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64, 48)), jnp.float32)
    hidden, _ = M.forward_patches(params, patches, cfg)
    assert hidden.shape == (2, 64, cfg.d_model)


def test_lln_stats_emitted():
    cfg = tiny_cfg("lln")
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
    _, stats = M.forward(params, make_inputs(cfg), cfg)
    tensor = M.stack_layer_stats(stats, cfg)
    assert tensor.shape == (cfg.n_layers, 4)
    alphas = np.asarray(tensor[:, 0])
    assert np.all(alphas > 0), "moment matching must produce positive alpha"


def test_fixed_alpha_beta_override():
    cfg = tiny_cfg("lln", fixed_alpha=2.0, fixed_beta=2.0)
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
    _, stats = M.forward(params, make_inputs(cfg), cfg)
    tensor = M.stack_layer_stats(stats, cfg)
    np.testing.assert_allclose(np.asarray(tensor[:, :2]), 2.0)


def test_param_order_is_deterministic():
    cfg = tiny_cfg()
    p1 = M.init_params(cfg, seed=0)
    p2 = M.init_params(cfg, seed=1)
    assert M.param_order(p1) == M.param_order(p2)
    assert M.param_order(p1) == sorted(p1.keys())


def test_probe_outputs_stochastic_matrices():
    for method in ("softmax", "lln"):
        cfg = tiny_cfg(method)
        params = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
        mats, stats = M.attention_probe(params, make_inputs(cfg), cfg)
        assert mats.shape == (cfg.n_layers, 128, 128)
        rows = np.asarray(jnp.sum(mats, axis=-1))
        np.testing.assert_allclose(rows, 1.0, atol=2e-3)
        assert stats.shape == (cfg.n_layers, 4)


def test_train_step_decreases_loss():
    cfg = tiny_cfg("lln_diag")
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
    m, v = T.init_opt_state(params)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 128)), jnp.int32)
    labels = tokens
    weights = jnp.ones((4, 128), jnp.float32)
    step = jax.jit(
        lambda p, m, v, t: T.train_step_mlm(
            p, m, v, t, jnp.float32(5e-3), tokens, labels, weights, cfg
        )
    )
    losses = []
    t = 1.0
    for _ in range(8):
        params, m, v, loss, gnorm, stats = step(params, m, v, jnp.float32(t))
        losses.append(float(loss))
        assert np.isfinite(float(gnorm))
        t += 1.0
    assert losses[-1] < losses[0] - 0.5, losses


def test_train_step_cls_runs_all_exported_methods():
    for method in ("softmax", "lln", "elu", "performer", "nystrom"):
        cfg = tiny_cfg(method)
        params = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
        m, v = T.init_opt_state(params)
        tokens = make_inputs(cfg)
        labels = jnp.asarray([0, 1], jnp.int32)
        out = T.train_step_cls(params, m, v, jnp.float32(1), jnp.float32(1e-3), tokens, labels, cfg)
        assert np.isfinite(float(out[3]))


def test_grad_norm_grows_with_alpha():
    """Fig 10b mechanism: larger fixed alpha/beta => larger gradients."""
    norms = {}
    for alpha in (1.0, 4.0):
        cfg = tiny_cfg("lln", fixed_alpha=alpha, fixed_beta=alpha)
        params = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
        m, v = T.init_opt_state(params)
        rng = np.random.default_rng(1)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 128)), jnp.int32)
        out = T.train_step_mlm(
            params, m, v, jnp.float32(1), jnp.float32(1e-3),
            tokens, tokens, jnp.ones((2, 128), jnp.float32), cfg,
        )
        norms[alpha] = float(out[4])
    assert norms[4.0] > norms[1.0]
