//! Backward kernels for the native training loop (ROADMAP: "fused
//! backward pass (recompute-based, flash-style)").
//!
//! Two kernel classes, mirroring the forward split:
//!
//! * **Fused softmax / quadratic backward** — FlashAttention-style
//!   recompute: the forward saves only the per-row online-softmax
//!   statistics (`row_max`, `row_sum`) and the output; the backward
//!   re-streams the K/V tiles at or below each query row (causal +
//!   `key_len` masks honored through [`AttnSpec::row_limit`], exactly
//!   like the fused forward) and rebuilds each probability tile from
//!   the saved statistics.  The n×n score matrix is never
//!   materialized: the working set is O(tile) per query row, so the
//!   O(n·tile) memory story of the forward survives training.
//!
//! * **Linear-class backward** — the reverse-sweep counterpart of
//!   [`linear_attention_causal`](super::linear_attention_causal)'s
//!   prefix-state recurrence: a forward sweep replays the
//!   `(Σ φ(k)vᵀ, Σ φ(k))` prefix state to produce `dφ(q)` rows and the
//!   per-row denominators, and a reverse sweep accumulates the
//!   *suffix* state `(Σ φ(q)·dnumᵀ, Σ dden·φ(q))` to produce `dφ(k)`
//!   and `dv` rows — O(m·dv) state, never an n×n buffer.  Feature-map
//!   chain rules ([`lln_feature_bwd`], [`elu_feature_bwd`],
//!   [`relu_feature_bwd`]) lift the φ-space gradients back to q/k —
//!   including `dα`/`dβ` for LLN's `exp(α·q)` / `exp(β·k)` maps, which
//!   is what lets the native trainer learn the paper's fig. 9
//!   alpha/beta trajectories.
//!
//! The dense references ([`softmax_attention_spec_bwd_dense`]) and the
//! finite-difference properties in `rust/tests/prop_kernels.rs` pin
//! every kernel here; [`super::backend`] exposes them through
//! `AttentionBackend::{forward_train, backward}`.

use super::kernels::{self, softmax_attention_matrix_spec};
use super::{AttnSpec, EXP_CLAMP};
use crate::tensor::Mat;

// ---------------------------------------------------------------------------
// Fused softmax: recompute forward + backward
// ---------------------------------------------------------------------------

/// Fused softmax forward that also returns the per-row online-softmax
/// statistics the recompute backward needs: `(out, row_max, row_sum)`.
/// Same masking, scale, and O(n·tile) streaming as
/// [`fused_softmax_attention_spec`](super::fused_softmax_attention_spec)
/// (values agree to streaming tolerance; this variant walks rows
/// serially so the statistics land in one pass).  Fully masked rows
/// (`row_limit == 0`) report `row_sum == 0` and a zero output row.
pub fn fused_softmax_attention_spec_fwd_train(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
) -> (Mat, Vec<f32>, Vec<f32>) {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut out = Mat::zeros(nq, dv);
    let mut row_max = vec![f32::NEG_INFINITY; nq];
    let mut row_sum = vec![0.0f32; nq];
    if nq == 0 || nk == 0 || dv == 0 {
        return (out, row_max, row_sum);
    }
    let scale = spec.resolve_scale(d);
    let tile = kernels::resolve_tile(tile).min(nk);
    let mut scores = vec![0.0f32; tile];
    let (kd, vd) = (k.data(), v.data());
    for i in 0..nq {
        let lim = spec.row_limit(i, nk);
        let qrow = q.row(i);
        let orow = out.row_mut(i);
        let mut m = f32::NEG_INFINITY;
        let mut l = 0.0f32;
        let mut t0 = 0;
        while t0 < lim {
            let tn = tile.min(lim - t0);
            let ktile = &kd[t0 * d..(t0 + tn) * d];
            crate::tensor::micro::matmul_t_block(qrow, ktile, &mut scores[..tn], 1, d, tn);
            let mut tile_max = f32::NEG_INFINITY;
            for s in scores[..tn].iter_mut() {
                *s *= scale;
                tile_max = tile_max.max(*s);
            }
            let m_new = m.max(tile_max);
            let correction = (m - m_new).exp();
            if correction != 1.0 {
                l *= correction;
                for a in orow.iter_mut() {
                    *a *= correction;
                }
            }
            let mut tile_sum = 0.0f32;
            for (j, &s) in scores[..tn].iter().enumerate() {
                let p = (s - m_new).exp();
                tile_sum += p;
                let vrow = &vd[(t0 + j) * dv..(t0 + j + 1) * dv];
                for (a, &vv) in orow.iter_mut().zip(vrow) {
                    *a += p * vv;
                }
            }
            l += tile_sum;
            m = m_new;
            t0 += tn;
        }
        if l > 0.0 {
            let inv = 1.0 / l;
            for a in orow.iter_mut() {
                *a *= inv;
            }
        } else {
            orow.fill(0.0);
        }
        row_max[i] = m;
        row_sum[i] = l;
    }
    (out, row_max, row_sum)
}

/// Flash-style recompute backward of the fused softmax forward.
///
/// Inputs are the forward operands plus what
/// [`fused_softmax_attention_spec_fwd_train`] saved (`out`, `row_max`,
/// `row_sum`) and the output cotangent `d_out`; returns `(dq, dk, dv)`.
/// Per query row the K/V tiles below its [`AttnSpec::row_limit`] are
/// re-streamed, each probability rebuilt as
/// `p_ij = exp(scale·q_i·k_j − m_i) / l_i`, and the standard softmax
/// VJP applied:
///
/// ```text
/// δ_i   = dO_i · O_i                        (row dot)
/// dS_ij = p_ij (dO_i · v_j − δ_i)
/// dq_i  = scale · Σ_j dS_ij k_j ;  dk_j += scale · dS_ij q_i
/// dv_j += p_ij dO_i
/// ```
///
/// Working set: one O(tile) score buffer — no n×n matrix at any
/// length.  Fully masked rows (`row_sum == 0`) contribute nothing.
#[allow(clippy::too_many_arguments)]
pub fn fused_softmax_attention_spec_bwd(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    row_max: &[f32],
    row_sum: &[f32],
    d_out: &Mat,
    tile: usize,
) -> (Mat, Mat, Mat) {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    assert_eq!(out.shape(), d_out.shape(), "out/d_out shape mismatch");
    assert_eq!(out.shape(), (q.rows(), v.cols()), "out shape mismatch");
    assert!(row_max.len() >= q.rows() && row_sum.len() >= q.rows(), "saved stats too short");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut dq = Mat::zeros(nq, d);
    let mut dk = Mat::zeros(nk, d);
    let mut dv_g = Mat::zeros(nk, dv);
    if nq == 0 || nk == 0 || dv == 0 {
        return (dq, dk, dv_g);
    }
    let scale = spec.resolve_scale(d);
    let tile = kernels::resolve_tile(tile).min(nk);
    let mut scores = vec![0.0f32; tile];
    let mut dqrow = vec![0.0f32; d];
    let kd = k.data();
    for i in 0..nq {
        let lim = spec.row_limit(i, nk);
        if lim == 0 || row_sum[i] <= 0.0 {
            continue;
        }
        let inv_l = 1.0 / row_sum[i];
        let m = row_max[i];
        let qrow = q.row(i);
        let dorow = d_out.row(i);
        // δ_i = dO_i · O_i = Σ_j p_ij (dO_i · v_j), accumulated in f64
        // so the subtraction below stays well-conditioned.
        let mut delta = 0.0f64;
        for (a, b) in dorow.iter().zip(out.row(i)) {
            delta += *a as f64 * *b as f64;
        }
        let delta = delta as f32;
        dqrow.fill(0.0);
        let mut t0 = 0;
        while t0 < lim {
            let tn = tile.min(lim - t0);
            let ktile = &kd[t0 * d..(t0 + tn) * d];
            crate::tensor::micro::matmul_t_block(qrow, ktile, &mut scores[..tn], 1, d, tn);
            for j in 0..tn {
                let kj = t0 + j;
                let p = (scores[j] * scale - m).exp() * inv_l;
                let vrow = v.row(kj);
                let mut dp = 0.0f32;
                for (a, b) in dorow.iter().zip(vrow) {
                    dp += a * b;
                }
                let ds = p * (dp - delta) * scale;
                let krow = k.row(kj);
                for (o, &x) in dqrow.iter_mut().zip(krow) {
                    *o += ds * x;
                }
                let dkrow = dk.row_mut(kj);
                for (o, &x) in dkrow.iter_mut().zip(qrow) {
                    *o += ds * x;
                }
                let dvrow = dv_g.row_mut(kj);
                for (o, &x) in dvrow.iter_mut().zip(dorow) {
                    *o += p * x;
                }
            }
            t0 += tn;
        }
        dq.row_mut(i).copy_from_slice(&dqrow);
    }
    (dq, dk, dv_g)
}

/// Dense reference backward of masked softmax attention: materializes
/// the row-stochastic matrix from
/// [`softmax_attention_matrix_spec`](super::softmax_attention_matrix_spec)
/// and applies the softmax VJP with full matrices.  O(n²) memory — the
/// parity anchor the fused recompute backward is property-tested
/// against, never a training path.
pub fn softmax_attention_spec_bwd_dense(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    d_out: &Mat,
) -> (Mat, Mat, Mat) {
    let p = softmax_attention_matrix_spec(q, k, spec);
    let dv = p.transpose().matmul(d_out);
    // ds_ij = p_ij (dp_ij − δ_i),  dp = dO Vᵀ,  δ_i = Σ_j p_ij dp_ij.
    let mut ds = d_out.matmul_t(v);
    for i in 0..p.rows() {
        let prow = p.row(i);
        let dsrow = ds.row_mut(i);
        let mut delta = 0.0f64;
        for (a, b) in prow.iter().zip(dsrow.iter()) {
            delta += *a as f64 * *b as f64;
        }
        let delta = delta as f32;
        for (o, &pv) in dsrow.iter_mut().zip(prow) {
            *o = pv * (*o - delta);
        }
    }
    let scale = spec.resolve_scale(q.cols());
    let dq = ds.matmul(k).scale(scale);
    let dk = ds.transpose().matmul(q).scale(scale);
    (dq, dk, dv)
}

// ---------------------------------------------------------------------------
// Linear class: reverse-sweep prefix-state backward
// ---------------------------------------------------------------------------

/// One query row's φ(q) gradient plus its `(1/den, dden)` pair, given
/// the prefix state `(S, z)` visible to that row:
///
/// ```text
/// den   = φq·z + ε          dnum = dO / den
/// dden  = −(O · dO) / den   dφq[f] = S[f,:]·dnum + dden·z[f]
/// ```
#[allow(clippy::too_many_arguments)]
fn row_linear_bwd_q(
    qrow: &[f32],
    dorow: &[f32],
    orow: &[f32],
    s_state: &[f32],
    z_state: &[f32],
    dv: usize,
    dqrow: &mut [f32],
    inv_den_out: &mut f32,
    dden_out: &mut f32,
) {
    let mut den = 0.0f32;
    for (&qf, &zf) in qrow.iter().zip(z_state) {
        den += qf * zf;
    }
    let inv = 1.0 / (den + kernels::EPS);
    let mut od = 0.0f32;
    for (a, b) in orow.iter().zip(dorow) {
        od += a * b;
    }
    let dden = -od * inv;
    for (f, dqf) in dqrow.iter_mut().enumerate() {
        let srow = &s_state[f * dv..(f + 1) * dv];
        let mut acc = 0.0f32;
        for (s, &go) in srow.iter().zip(dorow) {
            acc += s * go;
        }
        *dqf = acc * inv + dden * z_state[f];
    }
    *inv_den_out = inv;
    *dden_out = dden;
}

/// Fold one query row's cotangent into the reverse-suffix state:
/// `G[f,:] += φq[f] · dnum`, `h[f] += dden · φq[f]` with
/// `dnum = dO / den`.
fn accumulate_reverse_state(
    g_state: &mut [f32],
    h_state: &mut [f32],
    qrow: &[f32],
    dorow: &[f32],
    inv_den: f32,
    dden: f32,
    dv: usize,
) {
    for (f, &qf) in qrow.iter().enumerate() {
        h_state[f] += dden * qf;
        if qf != 0.0 {
            let dst = &mut g_state[f * dv..(f + 1) * dv];
            for (o, &go) in dst.iter_mut().zip(dorow) {
                *o += qf * go * inv_den;
            }
        }
    }
}

/// One live key row's `(dφk, dv)` from the suffix state `(G, h)` of
/// the queries that can see it: `dφk[f] = G[f,:]·v + h[f]`,
/// `dv += Σ_f φk[f]·G[f,:]`.
fn row_linear_bwd_k(
    krow: &[f32],
    vrow: &[f32],
    g_state: &[f32],
    h_state: &[f32],
    dv: usize,
    dkrow: &mut [f32],
    dvrow: &mut [f32],
) {
    for (f, dkf) in dkrow.iter_mut().enumerate() {
        let grow = &g_state[f * dv..(f + 1) * dv];
        let mut acc = 0.0f32;
        for (g, b) in grow.iter().zip(vrow) {
            acc += g * b;
        }
        *dkf = acc + h_state[f];
        let kf = krow[f];
        if kf != 0.0 {
            for (o, &g) in dvrow.iter_mut().zip(grow) {
                *o += kf * g;
            }
        }
    }
}

/// Backward of [`linear_attention_spec`](super::linear_attention_spec)
/// in feature space: given the lifted maps `φ(q)`, `φ(k)`, the values,
/// the saved forward output, and the cotangent `d_out`, returns
/// `(dφ(q), dφ(k), dv)`.
///
/// Causal specs run the reverse-sweep prefix-state recurrence (the
/// mirror of `linear_attention_causal`): a forward pass replays the
/// `(Σ φ(k)vᵀ, Σ φ(k))` prefix to emit each `dφ(q)` row and the
/// per-row denominators, then a reverse pass accumulates the suffix
/// state `(Σ φ(q)·dnumᵀ, Σ dden·φ(q))` — the state key row `j` needs
/// is exactly the queries `i ≥ j` — to emit `dφ(k)` / `dv` rows.
/// O(m·dv) state either way; no n×n buffer.  `key_len`-dead key rows
/// receive exact-zero gradients (they never entered the forward
/// state), and `spec.scale` is ignored exactly as the forward ignores
/// it.
pub fn linear_attention_spec_bwd(
    phi_q: &Mat,
    phi_k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    d_out: &Mat,
) -> (Mat, Mat, Mat) {
    assert_eq!(phi_q.cols(), phi_k.cols(), "feature dims differ");
    assert_eq!(phi_k.rows(), v.rows(), "key/value row mismatch");
    assert_eq!(out.shape(), (phi_q.rows(), v.cols()), "out shape mismatch");
    assert_eq!(out.shape(), d_out.shape(), "out/d_out shape mismatch");
    let (nq, m) = phi_q.shape();
    let nk = phi_k.rows();
    let dv = v.cols();
    let mut d_phi_q = Mat::zeros(nq, m);
    let mut d_phi_k = Mat::zeros(nk, m);
    let mut d_v = Mat::zeros(nk, dv);
    if nq == 0 || dv == 0 || m == 0 {
        return (d_phi_q, d_phi_k, d_v);
    }
    let kl = spec.key_limit(nk);
    let mut inv_den = vec![0.0f32; nq];
    let mut dden = vec![0.0f32; nq];

    if spec.causal {
        assert_eq!(nq, nk, "causal linear backward requires aligned q/k row counts");
        // Forward prefix sweep: dφq rows + per-row denominators.
        let mut s_state = vec![0.0f32; m * dv];
        let mut z_state = vec![0.0f32; m];
        for i in 0..nq {
            if i < kl {
                kernels::accumulate_state(&mut s_state, &mut z_state, phi_k.row(i), v.row(i), dv);
            }
            let (iv, dd) = (&mut inv_den[i], &mut dden[i]);
            row_linear_bwd_q(
                phi_q.row(i),
                d_out.row(i),
                out.row(i),
                &s_state,
                &z_state,
                dv,
                d_phi_q.row_mut(i),
                iv,
                dd,
            );
        }
        // Reverse suffix sweep: key row j reads the queries i >= j.
        let mut g_state = vec![0.0f32; m * dv];
        let mut h_state = vec![0.0f32; m];
        for i in (0..nq).rev() {
            accumulate_reverse_state(
                &mut g_state,
                &mut h_state,
                phi_q.row(i),
                d_out.row(i),
                inv_den[i],
                dden[i],
                dv,
            );
            if i < kl {
                row_linear_bwd_k(
                    phi_k.row(i),
                    v.row(i),
                    &g_state,
                    &h_state,
                    dv,
                    d_phi_k.row_mut(i),
                    d_v.row_mut(i),
                );
            }
        }
    } else {
        // Bidirectional: every query reads the same state over the
        // live key prefix, and every live key reads every query.
        let mut s_state = vec![0.0f32; m * dv];
        let mut z_state = vec![0.0f32; m];
        for j in 0..kl {
            kernels::accumulate_state(&mut s_state, &mut z_state, phi_k.row(j), v.row(j), dv);
        }
        let mut g_state = vec![0.0f32; m * dv];
        let mut h_state = vec![0.0f32; m];
        for i in 0..nq {
            let (iv, dd) = (&mut inv_den[i], &mut dden[i]);
            row_linear_bwd_q(
                phi_q.row(i),
                d_out.row(i),
                out.row(i),
                &s_state,
                &z_state,
                dv,
                d_phi_q.row_mut(i),
                iv,
                dd,
            );
            accumulate_reverse_state(
                &mut g_state,
                &mut h_state,
                phi_q.row(i),
                d_out.row(i),
                inv_den[i],
                dden[i],
                dv,
            );
        }
        for j in 0..kl {
            row_linear_bwd_k(
                phi_k.row(j),
                v.row(j),
                &g_state,
                &h_state,
                dv,
                d_phi_k.row_mut(j),
                d_v.row_mut(j),
            );
        }
    }
    (d_phi_q, d_phi_k, d_v)
}

// ---------------------------------------------------------------------------
// Feature-map chain rules (φ-space gradients -> q/k space)
// ---------------------------------------------------------------------------

/// Chain rule through LLN's clamped-exp feature map
/// `φ(x) = exp(clamp(s·x))`: returns `(dx, ds)` given the input `x`,
/// the forward features `φ`, their cotangent `dφ`, and the exponent
/// `s` (alpha for queries, beta for keys).  Inside the clamp,
/// `dφ/dx = s·φ` and `dφ/ds = x·φ`; at saturation the derivative is
/// exactly zero (the clamp is flat there), which also keeps the
/// trained exponents from being pushed by saturated features.
pub fn lln_feature_bwd(x: &Mat, phi: &Mat, d_phi: &Mat, s: f32) -> (Mat, f32) {
    assert_eq!(x.shape(), phi.shape(), "x/phi shape mismatch");
    assert_eq!(x.shape(), d_phi.shape(), "x/d_phi shape mismatch");
    let mut dx = Mat::zeros(x.rows(), x.cols());
    let mut dscale = 0.0f64;
    for ((o, &xv), (&pv, &dp)) in dx
        .data_mut()
        .iter_mut()
        .zip(x.data())
        .zip(phi.data().iter().zip(d_phi.data()))
    {
        if (s * xv).abs() < EXP_CLAMP {
            *o = s * pv * dp;
            dscale += (xv * pv * dp) as f64;
        }
    }
    (dx, dscale as f32)
}

/// Chain rule through the ELU feature map
/// `φ(x) = x + 1 (x > 0) | exp(x) (x ≤ 0)`:
/// `dφ/dx = 1 (x > 0) | exp(x) (x ≤ 0)` — continuous at 0.
pub fn elu_feature_bwd(x: &Mat, d_phi: &Mat) -> Mat {
    assert_eq!(x.shape(), d_phi.shape(), "x/d_phi shape mismatch");
    let mut dx = d_phi.clone();
    for (o, &xv) in dx.data_mut().iter_mut().zip(x.data()) {
        if xv <= 0.0 {
            *o *= xv.exp();
        }
    }
    dx
}

/// Chain rule through the ReLU feature map: pass where `x > 0`.
pub fn relu_feature_bwd(x: &Mat, d_phi: &Mat) -> Mat {
    assert_eq!(x.shape(), d_phi.shape(), "x/d_phi shape mismatch");
    let mut dx = d_phi.clone();
    for (o, &xv) in dx.data_mut().iter_mut().zip(x.data()) {
        if xv <= 0.0 {
            *o = 0.0;
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// Quadratic kernel: recompute forward + backward
// ---------------------------------------------------------------------------

/// Fused quadratic forward that also returns the per-row denominators
/// `Σ_j (q_i·k_j)²` (pre-ε) the backward needs.  Same masking and
/// streaming as
/// [`fused_quadratic_attention_spec`](super::fused_quadratic_attention_spec).
pub fn fused_quadratic_attention_spec_fwd_train(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
) -> (Mat, Vec<f32>) {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut out = Mat::zeros(nq, dv);
    let mut den = vec![0.0f32; nq];
    if nq == 0 || nk == 0 || dv == 0 {
        return (out, den);
    }
    let tile = kernels::resolve_tile(tile).min(nk);
    let mut scores = vec![0.0f32; tile];
    let (kd, vd) = (k.data(), v.data());
    for i in 0..nq {
        let lim = spec.row_limit(i, nk);
        let qrow = q.row(i);
        let orow = out.row_mut(i);
        let mut den_i = 0.0f32;
        let mut t0 = 0;
        while t0 < lim {
            let tn = tile.min(lim - t0);
            let ktile = &kd[t0 * d..(t0 + tn) * d];
            crate::tensor::micro::matmul_t_block(qrow, ktile, &mut scores[..tn], 1, d, tn);
            for (j, &s) in scores[..tn].iter().enumerate() {
                let w = s * s;
                den_i += w;
                let vrow = &vd[(t0 + j) * dv..(t0 + j + 1) * dv];
                for (o, &vv) in orow.iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
            t0 += tn;
        }
        let inv = 1.0 / (den_i + kernels::EPS);
        for o in orow.iter_mut() {
            *o *= inv;
        }
        den[i] = den_i;
    }
    (out, den)
}

/// Recompute backward of the fused quadratic-kernel forward: same
/// tile streaming as [`fused_softmax_attention_spec_bwd`] with the
/// κ(q,k) = (q·k)² weight VJP (`dw_ij = dO_i·v_j / denε − δ_i / denε`,
/// `ds_ij = 2 s_ij dw_ij`, `denε = den_i + ε`).  O(tile) working set.
#[allow(clippy::too_many_arguments)]
pub fn fused_quadratic_attention_spec_bwd(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    den: &[f32],
    d_out: &Mat,
    tile: usize,
) -> (Mat, Mat, Mat) {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    assert_eq!(out.shape(), d_out.shape(), "out/d_out shape mismatch");
    assert!(den.len() >= q.rows(), "saved denominators too short");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut dq = Mat::zeros(nq, d);
    let mut dk = Mat::zeros(nk, d);
    let mut dv_g = Mat::zeros(nk, dv);
    if nq == 0 || nk == 0 || dv == 0 {
        return (dq, dk, dv_g);
    }
    let tile = kernels::resolve_tile(tile).min(nk);
    let mut scores = vec![0.0f32; tile];
    let mut dqrow = vec![0.0f32; d];
    let kd = k.data();
    for i in 0..nq {
        let lim = spec.row_limit(i, nk);
        if lim == 0 {
            continue;
        }
        let inv = 1.0 / (den[i] + kernels::EPS);
        let qrow = q.row(i);
        let dorow = d_out.row(i);
        let mut delta = 0.0f64;
        for (a, b) in dorow.iter().zip(out.row(i)) {
            delta += *a as f64 * *b as f64;
        }
        // dden_i = −(O_i · dO_i) / denε — the normalizer's pullback.
        let dden = -(delta as f32) * inv;
        dqrow.fill(0.0);
        let mut t0 = 0;
        while t0 < lim {
            let tn = tile.min(lim - t0);
            let ktile = &kd[t0 * d..(t0 + tn) * d];
            crate::tensor::micro::matmul_t_block(qrow, ktile, &mut scores[..tn], 1, d, tn);
            for j in 0..tn {
                let kj = t0 + j;
                let s = scores[j];
                let vrow = v.row(kj);
                let mut dp = 0.0f32;
                for (a, b) in dorow.iter().zip(vrow) {
                    dp += a * b;
                }
                let dw = dp * inv + dden;
                let ds = 2.0 * s * dw;
                let w = s * s;
                let krow = k.row(kj);
                for (o, &x) in dqrow.iter_mut().zip(krow) {
                    *o += ds * x;
                }
                let dkrow = dk.row_mut(kj);
                for (o, &x) in dkrow.iter_mut().zip(qrow) {
                    *o += ds * x;
                }
                let dvrow = dv_g.row_mut(kj);
                for (o, &x) in dvrow.iter_mut().zip(dorow) {
                    *o += w * inv * x;
                }
            }
            t0 += tn;
        }
        dq.row_mut(i).copy_from_slice(&dqrow);
    }
    (dq, dk, dv_g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::kernels::{
        fused_quadratic_attention_spec, fused_softmax_attention_spec, lln_features,
    };
    use crate::rng::Pcg64;

    fn probe(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Pcg64::seed(seed);
        crate::attention::gaussian_qkv(n, d, 0.8, 0.8, &mut rng)
    }

    #[test]
    fn fwd_train_matches_fused_forward_under_specs() {
        let (q, k, v) = probe(48, 12, 1);
        for spec in [
            AttnSpec::FULL,
            AttnSpec::CAUSAL,
            AttnSpec::causal_padded(20),
            AttnSpec::padded(0),
            AttnSpec { scale: Some(0.2), ..AttnSpec::FULL },
        ] {
            for tile in [1usize, 7, 0, 200] {
                let fused = fused_softmax_attention_spec(&q, &k, &v, &spec, tile, 0, 1);
                let (out, m, l) = fused_softmax_attention_spec_fwd_train(&q, &k, &v, &spec, tile);
                let err = out.max_abs_diff(&fused);
                assert!(err < 1e-5, "{spec:?} tile={tile}: {err}");
                assert_eq!(m.len(), 48);
                assert_eq!(l.len(), 48);
            }
        }
    }

    #[test]
    fn fused_softmax_backward_matches_dense_reference() {
        let (q, k, v) = probe(40, 10, 2);
        let mut rng = Pcg64::seed(3);
        let d_out = Mat::gaussian(40, 10, 1.0, &mut rng);
        for spec in [AttnSpec::FULL, AttnSpec::CAUSAL, AttnSpec::causal_padded(17)] {
            for tile in [1usize, 9, 0] {
                let (out, m, l) = fused_softmax_attention_spec_fwd_train(&q, &k, &v, &spec, tile);
                let (dq, dk, dv) =
                    fused_softmax_attention_spec_bwd(&q, &k, &v, &spec, &out, &m, &l, &d_out, tile);
                let (dq2, dk2, dv2) = softmax_attention_spec_bwd_dense(&q, &k, &v, &spec, &d_out);
                assert!(dq.max_abs_diff(&dq2) < 1e-4, "{spec:?} tile={tile} dq");
                assert!(dk.max_abs_diff(&dk2) < 1e-4, "{spec:?} tile={tile} dk");
                assert!(dv.max_abs_diff(&dv2) < 1e-4, "{spec:?} tile={tile} dv");
            }
        }
    }

    #[test]
    fn quadratic_fwd_train_matches_fused_forward() {
        let (q, k, v) = probe(36, 8, 4);
        for spec in [AttnSpec::FULL, AttnSpec::CAUSAL, AttnSpec::padded(11)] {
            let fused = fused_quadratic_attention_spec(&q, &k, &v, &spec, 13, 0, 1);
            let (out, den) = fused_quadratic_attention_spec_fwd_train(&q, &k, &v, &spec, 13);
            assert!(out.max_abs_diff(&fused) < 1e-4, "{spec:?}");
            assert!(den.iter().all(|x| x.is_finite() && *x >= 0.0));
        }
    }

    #[test]
    fn linear_backward_zeroes_dead_key_rows() {
        let (q, k, v) = probe(32, 8, 5);
        let pq = lln_features(&q, 1.1);
        let pk = lln_features(&k, 1.1);
        let mut rng = Pcg64::seed(6);
        let d_out = Mat::gaussian(32, 8, 1.0, &mut rng);
        for spec in [AttnSpec::causal_padded(10), AttnSpec::padded(10)] {
            let out = crate::attention::linear_attention_spec(&pq, &pk, &v, &spec, 7, 1);
            let (dpq, dpk, dv) = linear_attention_spec_bwd(&pq, &pk, &v, &spec, &out, &d_out);
            assert_eq!(dpq.shape(), pq.shape());
            for j in 10..32 {
                assert!(dpk.row(j).iter().all(|&x| x == 0.0), "{spec:?}: dead dphi_k row {j}");
                assert!(dv.row(j).iter().all(|&x| x == 0.0), "{spec:?}: dead dv row {j}");
            }
        }
    }

    #[test]
    fn lln_feature_chain_rule_saturates_to_zero() {
        let x = Mat::from_vec(1, 3, vec![0.5, 40.0, -40.0]);
        let phi = lln_features(&x, 1.0);
        let d_phi = Mat::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        let (dx, ds) = lln_feature_bwd(&x, &phi, &d_phi, 1.0);
        // In-range entry: dφ/dx = φ.
        assert!((dx.get(0, 0) - phi.get(0, 0)).abs() < 1e-6);
        // Saturated entries: exactly zero.
        assert_eq!(dx.get(0, 1), 0.0);
        assert_eq!(dx.get(0, 2), 0.0);
        // dα only sees the live entry: x·φ·dφ = 0.5·e^0.5.
        assert!((ds - 0.5 * 0.5f32.exp()).abs() < 1e-5);
    }

    #[test]
    fn backward_kernels_handle_degenerate_shapes() {
        let q = Mat::zeros(0, 4);
        let k = Mat::zeros(3, 4);
        let v = Mat::zeros(3, 2);
        let out = Mat::zeros(0, 2);
        let (dq, dk, dv) = fused_softmax_attention_spec_bwd(
            &q,
            &k,
            &v,
            &AttnSpec::FULL,
            &out,
            &[],
            &[],
            &out,
            0,
        );
        assert_eq!(dq.shape(), (0, 4));
        assert_eq!(dk.shape(), (3, 4));
        assert_eq!(dv.shape(), (3, 2));
    }

    #[test]
    fn fused_backward_long_causal_runs_in_tile_memory() {
        // The acceptance smoke: a causal fused backward at n=4096 never
        // touches an n×n buffer (working set is O(tile) by
        // construction) — this would OOM/crawl if it materialized
        // 4096² scores.
        let n = 4096;
        let mut rng = Pcg64::seed(7);
        let q = Mat::gaussian(n, 4, 0.8, &mut rng);
        let k = Mat::gaussian(n, 4, 0.8, &mut rng);
        let v = Mat::gaussian(n, 2, 1.0, &mut rng);
        let d_out = Mat::gaussian(n, 2, 1.0, &mut rng);
        let spec = AttnSpec::CAUSAL;
        let (out, m, l) = fused_softmax_attention_spec_fwd_train(&q, &k, &v, &spec, 256);
        let (dq, dk, dv) =
            fused_softmax_attention_spec_bwd(&q, &k, &v, &spec, &out, &m, &l, &d_out, 256);
        assert!(dq.data().iter().all(|x| x.is_finite()));
        assert!(dk.data().iter().all(|x| x.is_finite()));
        assert!(dv.data().iter().all(|x| x.is_finite()));
        // Row 0's softmax is over a single key (p = 1 whatever q_0 is),
        // so its query gradient must vanish.
        assert!(dq.row(0).iter().all(|&x| x.abs() < 1e-5), "{:?}", dq.row(0));
    }
}
