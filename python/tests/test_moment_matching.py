"""Moment matching (paper App. A.7): properties of the (a, b) fit and the
alpha/beta derivation, plus the paper's own validation claims (fig. 5).
"""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import moment_matching as mm
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def ab():
    """Use the cached constants when artifacts exist (keeps tests fast)."""
    cache = os.path.join(ART, "mm_constants.json")
    if os.path.exists(cache):
        d = json.load(open(cache))
        return d["a"], d["b"]
    return mm.fit_broad_constants(seeds=(0, 1))


def test_fit_is_positive_slope(ab):
    a, b = ab
    assert a > 0, "broad-regime variance must grow with sigma-tilde^2"


def test_lln_log_variance_monotone_in_sigma():
    vals = [mm.measure_lln_log_variance(s2, seed=0) for s2 in (4.0, 8.0, 16.0, 24.0)]
    assert all(x < y for x, y in zip(vals, vals[1:]))


def test_sm_log_variance_matches_theory():
    """Prop 3.1 / fig 5a: var(log P_sm) ~= sigma_q^2 sigma_k^2 for Gaussians."""
    for sq, sk in [(1.0, 1.0), (1.2, 0.9), (1.5, 1.5)]:
        measured = mm.measure_sm_log_variance(sq, sk, n=512, d=64, seed=3)
        theory = (sq * sk) ** 2
        assert abs(measured - theory) / theory < 0.25, (sq, sk, measured, theory)


@settings(max_examples=10, deadline=None)
@given(st.floats(0.8, 1.6), st.floats(0.8, 1.6))
def test_matched_variance_within_band(ab, sq, sk):
    """After moment matching the LLN log-variance lands near the SA one.

    The (a, b) fit targets the broad regime (sigma^2_sm >~ 0.5); in the
    low-variance corner the linear model overshoots slightly, so small
    *absolute* error is accepted there (paper App. A.7 scopes matching
    to the broad case).
    """
    a, b = ab
    v_lln, v_sm, rel = mm.verify_matching(a, b, sq, sk, n=256, d=64, seed=11)
    assert rel < 0.35 or abs(v_lln - v_sm) < 0.25, (sq, sk, v_lln, v_sm)


def test_alpha_beta_in_paper_range(ab):
    """Fig 9: for unit-ish input stds the matched alpha/beta sit near 2-2.5."""
    a, b = ab
    al, be = mm.alpha_beta(jnp.float32(1.0), jnp.float32(1.0), a, b)
    assert 1.5 < float(al) < 3.0
    assert 1.5 < float(be) < 3.0


def test_alpha_beta_symmetric(ab):
    a, b = ab
    al, be = mm.alpha_beta(jnp.float32(1.3), jnp.float32(1.3), a, b)
    np.testing.assert_allclose(float(al), float(be), rtol=1e-6)


def test_alpha_scales_inversely_with_sigma_q(ab):
    """Eq. 10: alpha ~ 1/sigma_q at fixed product sigma_q*sigma_k."""
    a, b = ab
    al1, _ = mm.alpha_beta(jnp.float32(1.0), jnp.float32(1.44), a, b)
    al2, _ = mm.alpha_beta(jnp.float32(1.2), jnp.float32(1.2), a, b)
    # same sigma_q^2 sigma_k^2 => same sigma-tilde => alpha ratio = inverse sigma_q ratio
    np.testing.assert_allclose(float(al1) / float(al2), 1.2, rtol=1e-4)


def test_alpha_beta_guard_small_sigma(ab):
    """Degenerate stds must not produce NaN/inf (min_sigma2 guard)."""
    a, b = ab
    al, be = mm.alpha_beta(jnp.float32(1e-8), jnp.float32(1e-8), a, b)
    assert np.isfinite(float(al)) and np.isfinite(float(be))


def test_without_matching_variance_is_too_small():
    """Fig 5b's 'before' curve: alpha=beta=1 badly under-disperses."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1.2, (256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1.2, (256, 64)), jnp.float32)
    v_naive = float(mm.log_variance_of_attention(ref.lln_attention_matrix(q, k, 1.0, 1.0)))
    v_sm = float(mm.log_variance_of_attention(ref.softmax_attention_matrix(q, k)))
    assert v_naive < 0.25 * v_sm
