//! Pure batch-planning logic — separated from the threaded server so the
//! coordinator's core invariants are property-testable without PJRT.

/// A planned batch over request indices (into the arrival order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    /// Indices of requests in this batch, in arrival order.
    pub members: Vec<usize>,
    /// Executable batch capacity chosen (1 or max_batch today).
    pub capacity: usize,
}

/// Plan batches over a FIFO queue snapshot.
///
/// Invariants (property-tested below):
///   * every request appears in exactly one batch;
///   * arrival order is preserved within and across batches;
///   * no batch exceeds `max_batch`;
///   * capacity is the smallest available executable size >= |members|
///     (available sizes: 1 and `max_batch`).
pub fn plan_batches(n_requests: usize, max_batch: usize) -> Vec<BatchPlan> {
    assert!(max_batch >= 1);
    let mut out = Vec::new();
    let mut i = 0;
    while i < n_requests {
        let take = (n_requests - i).min(max_batch);
        let capacity = if take == 1 { 1 } else { max_batch };
        out.push(BatchPlan { members: (i..i + take).collect(), capacity });
        i += take;
    }
    out
}

/// Decide whether the batcher should fire now or keep waiting.
///
/// Fire when the queue can fill a batch, or when the oldest waiter has
/// exceeded the timeout (latency bound), or on shutdown drain.
pub fn should_fire(queued: usize, max_batch: usize, oldest_wait_ms: f64, timeout_ms: f64, draining: bool) -> bool {
    if queued == 0 {
        return false;
    }
    queued >= max_batch || oldest_wait_ms >= timeout_ms || draining
}

/// Projected queue wait for a newly admitted request, in milliseconds:
/// the batches already ahead of it (itself included) times the recent
/// mean batch latency for its class.  `0.0` when there is no latency
/// history yet — admission never rejects on a guess it cannot back.
///
/// Invariants (property-tested below): monotone non-decreasing in
/// `queued`; zero iff `batch_ms` is zero; an empty queue still pays
/// one batch (its own).
pub fn projected_wait_ms(queued: usize, max_batch: usize, batch_ms: f64) -> f64 {
    if !(batch_ms > 0.0) {
        return 0.0;
    }
    let batches_ahead = (queued + 1).div_ceil(max_batch.max(1));
    batches_ahead as f64 * batch_ms
}

/// Queue-side deadline shed decision: `true` when the item's deadline
/// (if any) has already passed at `now` — the worker replies
/// `DeadlineExceeded` instead of spending executor time on it.
pub fn deadline_expired(deadline: Option<std::time::Instant>, now: std::time::Instant) -> bool {
    deadline.is_some_and(|d| now >= d)
}

/// The per-bucket autoscaling policy: how many workers a bucket wants
/// for `queued` items of backlog — one worker per `max_batch` of queued
/// work, clamped to the `[min_workers, max_workers]` band.
///
/// Invariants (property-tested below): always inside the band, monotone
/// non-decreasing in `queued`, and exactly `min` on an empty queue.
pub fn desired_workers(
    queued: usize,
    max_batch: usize,
    min_workers: usize,
    max_workers: usize,
) -> usize {
    let min = min_workers.max(1);
    let max = max_workers.max(min);
    queued.div_ceil(max_batch.max(1)).clamp(min, max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, prop_assert};

    #[test]
    fn plan_batches_invariants() {
        check(256, |g| {
            let n = g.usize_in(0, 100);
            let max_batch = g.usize_in(1, 16);
            let plans = plan_batches(n, max_batch);
            // coverage: exactly once, in order
            let flat: Vec<usize> = plans.iter().flat_map(|p| p.members.clone()).collect();
            prop_assert(flat == (0..n).collect::<Vec<_>>(), format!("coverage broken: {flat:?}"))?;
            for p in &plans {
                prop_assert(!p.members.is_empty(), "empty batch")?;
                prop_assert(p.members.len() <= max_batch, "batch exceeds max")?;
                prop_assert(
                    p.capacity >= p.members.len(),
                    format!("capacity {} < members {}", p.capacity, p.members.len()),
                )?;
                prop_assert(
                    p.capacity == 1 || p.capacity == max_batch,
                    "capacity must be an available executable size",
                )?;
                if p.members.len() == 1 {
                    prop_assert(p.capacity == 1, "single request should ride the b1 executable")?;
                }
            }
            // all but the last batch are full
            for p in plans.iter().rev().skip(1) {
                prop_assert(p.members.len() == max_batch, "non-final batch not full")?;
            }
            Ok(())
        });
    }

    #[test]
    fn fire_logic() {
        assert!(!should_fire(0, 8, 1e9, 5.0, true), "never fire empty");
        assert!(should_fire(8, 8, 0.0, 5.0, false), "full batch fires");
        assert!(should_fire(3, 8, 6.0, 5.0, false), "timeout fires");
        assert!(!should_fire(3, 8, 1.0, 5.0, false), "partial+young waits");
        assert!(should_fire(1, 8, 0.0, 5.0, true), "drain flushes");
    }

    #[test]
    fn fire_is_monotone_in_queued_and_wait() {
        // Once the batcher decides to fire, more queued requests or a
        // longer-waiting head must never flip it back to waiting.
        check(512, |g| {
            let max_batch = g.usize_in(1, 16);
            let queued = g.usize_in(0, 32);
            let wait = g.f64_in(0.0, 20.0);
            let timeout = g.f64_in(0.0, 10.0);
            let draining = g.bool();
            prop_assert(
                !should_fire(0, max_batch, wait, timeout, draining),
                "must never fire an empty queue",
            )?;
            if should_fire(queued, max_batch, wait, timeout, draining) {
                prop_assert(
                    should_fire(queued + 1, max_batch, wait, timeout, draining),
                    format!("not monotone in queued at q={queued}"),
                )?;
                prop_assert(
                    should_fire(queued, max_batch, wait + 1.0, timeout, draining),
                    format!("not monotone in wait at w={wait}"),
                )?;
                prop_assert(
                    should_fire(queued, max_batch, wait, timeout, true),
                    "draining must only add firing reasons",
                )?;
            }
            // Boundary witnesses: a full batch always fires; a timed-out
            // head always fires.
            if queued > 0 {
                prop_assert(
                    should_fire(queued.max(max_batch), max_batch, 0.0, timeout, false),
                    "full batch must fire",
                )?;
                prop_assert(
                    should_fire(queued, max_batch, timeout, timeout, false),
                    "expired head must fire",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn desired_workers_stays_in_band_and_is_monotone() {
        check(512, |g| {
            let max_batch = g.usize_in(1, 16);
            let min = g.usize_in(1, 4);
            let max = min + g.usize_in(0, 6);
            let queued = g.usize_in(0, 128);
            let want = desired_workers(queued, max_batch, min, max);
            prop_assert(want >= min && want <= max, format!("{want} outside [{min}, {max}]"))?;
            prop_assert(
                desired_workers(queued + 1, max_batch, min, max) >= want,
                format!("not monotone at queued={queued}"),
            )?;
            prop_assert(
                desired_workers(0, max_batch, min, max) == min,
                "empty queue must idle at min",
            )?;
            // A backlog of w*max_batch wants at least min(w, max) workers.
            let w = g.usize_in(1, 8);
            prop_assert(
                desired_workers(w * max_batch, max_batch, min, max) >= w.clamp(min, max).min(max),
                format!("{w} full batches under-provisioned"),
            )?;
            Ok(())
        });
    }

    #[test]
    fn desired_workers_degenerate_band() {
        // min/max of 0 clamp to a sane single-worker band.
        assert_eq!(desired_workers(100, 8, 0, 0), 1);
        // max below min is lifted to min (config typo safety).
        assert_eq!(desired_workers(100, 8, 3, 1), 3);
        assert_eq!(desired_workers(0, 8, 2, 4), 2);
        assert_eq!(desired_workers(9, 8, 1, 4), 2);
        assert_eq!(desired_workers(1000, 8, 1, 4), 4);
    }

    #[test]
    fn projected_wait_properties() {
        check(512, |g| {
            let queued = g.usize_in(0, 256);
            let max_batch = g.usize_in(1, 16);
            let ms = g.f64_in(0.0, 50.0);
            let w = projected_wait_ms(queued, max_batch, ms);
            if ms == 0.0 {
                prop_assert(w == 0.0, "no history must project zero wait")?;
            } else {
                prop_assert(w >= ms, "even an empty queue pays its own batch")?;
                prop_assert(
                    projected_wait_ms(queued + 1, max_batch, ms) >= w,
                    format!("not monotone in queued at q={queued}"),
                )?;
                // A full extra batch of backlog adds exactly one batch time.
                let deeper = projected_wait_ms(queued + max_batch, max_batch, ms);
                prop_assert(
                    (deeper - w - ms).abs() < 1e-9,
                    format!("one extra batch of backlog must add one batch time ({w} -> {deeper})"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn projected_wait_degenerate_inputs() {
        // No history: never reject.
        assert_eq!(projected_wait_ms(100, 8, 0.0), 0.0);
        assert_eq!(projected_wait_ms(100, 8, f64::NAN), 0.0);
        // max_batch of 0 clamps to 1 instead of dividing by zero.
        assert_eq!(projected_wait_ms(2, 0, 10.0), 30.0);
        // Empty queue, one batch ahead (its own).
        assert_eq!(projected_wait_ms(0, 8, 4.0), 4.0);
    }

    #[test]
    fn deadline_expiry_decision() {
        let now = std::time::Instant::now();
        assert!(!deadline_expired(None, now), "no deadline never expires");
        assert!(!deadline_expired(Some(now + std::time::Duration::from_secs(5)), now));
        assert!(deadline_expired(Some(now), now), "deadline is inclusive");
        let later = now + std::time::Duration::from_millis(10);
        assert!(deadline_expired(Some(now), later));
    }

    #[test]
    fn plans_compose_with_fire_decision() {
        // Whatever the fire decision drains, planning must cover it:
        // firing `queued` requests yields ceil(queued / max_batch) plans.
        check(256, |g| {
            let queued = g.usize_in(1, 64);
            let max_batch = g.usize_in(1, 16);
            let plans = plan_batches(queued, max_batch);
            prop_assert(
                plans.len() == queued.div_ceil(max_batch),
                format!("{queued} reqs / max {max_batch} -> {} plans", plans.len()),
            )
        });
    }
}
