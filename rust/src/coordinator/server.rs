//! The threaded serving coordinator.
//!
//! Workers are generic over a [`BatchExec`] — either the PJRT engine
//! path (AOT artifacts) or the native [`AttentionBackend`] encoder
//! ([`super::native`]) when artifacts/PJRT are unavailable — so the
//! batching loop, stats, and backpressure behave identically on both.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use super::batcher::{plan_batches, should_fire};
use super::native::NativeEncoder;
use super::{pad_to_bucket, pick_bucket, Request, Response};
use crate::attention::Method;
use crate::config::ServeConfig;
use crate::runtime::{Engine, HostTensor, ParamStore};
use crate::util::pool::{Channel, SendError};

/// Rolling serving metrics (shared across workers).
#[derive(Default)]
pub struct ServeStats {
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub latencies_ms: Vec<f64>,
    pub batch_sizes: Vec<usize>,
}

impl ServeStats {
    pub fn p50_latency(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            crate::stats::percentile(&self.latencies_ms, 50.0)
        }
    }
    pub fn p95_latency(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            crate::stats::percentile(&self.latencies_ms, 95.0)
        }
    }
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }
}

/// The running coordinator: submit requests, read stats, shut down.
pub struct Coordinator {
    cfg: ServeConfig,
    queues: Vec<(usize, Channel<Request>)>, // (bucket_len, queue)
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<ServeStats>>,
    next_id: AtomicU64,
    draining: Arc<AtomicBool>,
    started_at: Instant,
}

impl Coordinator {
    /// Spawn `cfg.workers` workers per bucket.  Each worker owns its
    /// executor — a PJRT engine with the bucket's executables + resident
    /// params, or the native-backend encoder fallback — and all workers
    /// of a bucket drain the same MPMC queue.
    pub fn start(cfg: ServeConfig, artifacts: &std::path::Path) -> Result<Self> {
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let draining = Arc::new(AtomicBool::new(false));
        let mut queues = Vec::new();
        let mut workers = Vec::new();
        for &bucket in &cfg.buckets {
            let q: Channel<Request> = Channel::bounded(cfg.queue_capacity);
            queues.push((bucket, q.clone()));
            for w in 0..cfg.workers.max(1) {
                let cfgc = cfg.clone();
                let dir = artifacts.to_path_buf();
                let statsc = Arc::clone(&stats);
                let drainc = Arc::clone(&draining);
                let qc = q.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("lln-worker-n{bucket}-{w}"))
                        .spawn(move || {
                            if let Err(e) = worker_loop(cfgc, dir, bucket, qc, statsc, drainc) {
                                eprintln!("worker n{bucket}-{w} died: {e:#}");
                            }
                        })
                        .expect("spawn worker"),
                );
            }
        }
        Ok(Self {
            cfg,
            queues,
            workers,
            stats,
            next_id: AtomicU64::new(1),
            draining,
            started_at: Instant::now(),
        })
    }

    /// Submit a bidirectional request; returns the response receiver.
    /// Errors on over-length input or queue-full backpressure.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<mpsc::Receiver<Response>> {
        self.submit_with(tokens, false)
    }

    /// Submit a request with an explicit causal flag.  The request's
    /// live length rides along as its attention key mask: workers pad
    /// to the bucket but mask the padding out of attention, so buckets
    /// batch variable-length (and mixed causal/bidirectional) traffic
    /// instead of assuming square full attention.
    pub fn submit_with(&self, tokens: Vec<i32>, causal: bool) -> Result<mpsc::Receiver<Response>> {
        let bucket = pick_bucket(&self.cfg.buckets, tokens.len())
            .ok_or_else(|| anyhow!("sequence length {} exceeds all buckets", tokens.len()))?;
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            causal,
            enqueued_at: Instant::now(),
            resp: tx,
        };
        let queue = &self.queues.iter().find(|(b, _)| *b == bucket).unwrap().1;
        match queue.try_send(req) {
            Ok(()) => Ok(rx),
            Err(SendError::Full(_)) => {
                self.stats.lock().unwrap().rejected += 1;
                bail!("backpressure: bucket n{bucket} queue full")
            }
            Err(SendError::Closed(_)) => bail!("coordinator shutting down"),
        }
    }

    /// Submit and block for the result.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        let rx = self.submit(tokens)?;
        rx.recv().map_err(|_| anyhow!("worker dropped response"))
    }

    /// Submit with a causal flag and block for the result.
    pub fn infer_with(&self, tokens: Vec<i32>, causal: bool) -> Result<Response> {
        let rx = self.submit_with(tokens, causal)?;
        rx.recv().map_err(|_| anyhow!("worker dropped response"))
    }

    pub fn stats(&self) -> Arc<Mutex<ServeStats>> {
        Arc::clone(&self.stats)
    }

    pub fn uptime_secs(&self) -> f64 {
        self.started_at.elapsed().as_secs_f64()
    }

    /// Drain queues and join workers.
    pub fn shutdown(mut self) {
        self.draining.store(true, Ordering::SeqCst);
        for (_, q) in &self.queues {
            q.close();
        }
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

/// One member's attention shape inside a padded batch: its live token
/// count (the key mask) and its causal flag.  Built per request by
/// [`run_batch`] so a single bucket batch can mix variable-length and
/// mixed-mask traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReqSpec {
    pub key_len: usize,
    pub causal: bool,
}

/// One worker's batch executor: given the bucket-padded token buffer,
/// produce per-request logits rows.  The batching loop above is the
/// same for every implementation.
trait BatchExec {
    /// Executable batch capacity to plan for (PJRT batches are static;
    /// the native path accepts any size up to `max_batch`).
    fn plan_capacity(&self, members: usize, max_batch: usize) -> usize;

    /// Whether this executor can honor the causal mask.  [`run_batch`]
    /// rejects causal members *individually* (their co-batched
    /// bidirectional requests still run) when it cannot.
    fn supports_causal(&self) -> bool;

    /// `tokens` holds `capacity * bucket` ids (`real` live rows, the
    /// rest phantom padding); `specs` holds one [`ReqSpec`] per live
    /// row.  Returns `real` logit rows.
    fn run(
        &mut self,
        tokens: Vec<i32>,
        specs: &[ReqSpec],
        capacity: usize,
        real: usize,
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>>;
}

/// PJRT path: resident params + the bucket's b1/bN executables.
struct PjrtExec {
    engine: Engine,
    exe_b1: String,
    exe_bn: String,
    param_lits: Vec<Literal>,
    num_classes: usize,
}

impl PjrtExec {
    fn new(cfg: &ServeConfig, dir: &std::path::Path, bucket: usize) -> Result<Self> {
        let mut engine = Engine::new(dir)?;
        let exe_b1 = format!("serve_{}_b1_n{}", cfg.method, bucket);
        let exe_bn = format!("serve_{}_b{}_n{}", cfg.method, cfg.max_batch, bucket);
        engine.warmup(&[&exe_b1, &exe_bn])?;

        // Resident parameters: built once, reused for every call.
        let model_tag = engine.manifest().artifact(&exe_b1)?.meta.get("model").cloned()
            .ok_or_else(|| anyhow!("{exe_b1}: missing model meta"))?;
        let model = engine.manifest().model(&model_tag)?.clone();
        let params = ParamStore::load_initial(dir, &model)?;
        let param_lits: Vec<Literal> = params.to_literals()?;
        let num_classes: usize = {
            let spec = engine.manifest().artifact(&exe_b1)?;
            *spec.outputs[0].shape.last().unwrap_or(&4)
        };
        Ok(Self { engine, exe_b1, exe_bn, param_lits, num_classes })
    }
}

impl BatchExec for PjrtExec {
    fn plan_capacity(&self, members: usize, max_batch: usize) -> usize {
        if members == 1 {
            1
        } else {
            max_batch
        }
    }

    fn supports_causal(&self) -> bool {
        // The AOT executables are compiled as full bidirectional
        // attention over the padded bucket (key-length padding keeps
        // the historical attend-the-PAD-rows semantics): causal
        // members are rejected per request by `run_batch`.
        false
    }

    fn run(
        &mut self,
        tokens: Vec<i32>,
        specs: &[ReqSpec],
        capacity: usize,
        real: usize,
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        // Defensive: run_batch filters causal members out before this
        // executor sees them.
        if let Some(s) = specs.iter().find(|s| s.causal) {
            bail!(
                "causal request (key_len {}) reached the PJRT executor: AOT serve artifacts are \
                 full-attention only; serve causal traffic via the native backend path \
                 (`[serve] force_native = true`)",
                s.key_len
            );
        }
        let exe = if capacity == 1 { self.exe_b1.clone() } else { self.exe_bn.clone() };
        let tok_lit = HostTensor::I32 { shape: vec![capacity, bucket], data: tokens }.to_literal()?;
        let mut args: Vec<&Literal> = self.param_lits.iter().collect();
        args.push(&tok_lit);
        let outs = self.engine.execute_literals(&exe, &args)?;
        let logits = outs[0].to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        let nc = self.num_classes;
        Ok((0..real).map(|i| logits[i * nc..(i + 1) * nc].to_vec()).collect())
    }
}

/// Native path: the [`AttentionBackend`](crate::attention::AttentionBackend)
/// encoder — no artifacts, no PJRT, still the full serving pipeline.
struct NativeExec {
    encoder: NativeEncoder,
}

impl NativeExec {
    fn new(cfg: &ServeConfig, bucket: usize) -> Result<Self> {
        // A typo'd method must fail loudly, not silently serve lln_diag.
        let method = Method::parse(&cfg.method)
            .ok_or_else(|| anyhow!("unknown serving method {:?}", cfg.method))?;
        Ok(Self {
            encoder: NativeEncoder::new(
                method,
                super::native::NATIVE_D_MODEL,
                super::native::NATIVE_NUM_CLASSES,
                bucket,
                super::native::NATIVE_SEED,
                &cfg.compute,
            ),
        })
    }
}

impl BatchExec for NativeExec {
    fn plan_capacity(&self, members: usize, _max_batch: usize) -> usize {
        members
    }

    fn supports_causal(&self) -> bool {
        // Nystrom/Linformer structurally cannot be masked; their causal
        // requests must be rejected, not silently served bidirectional.
        self.encoder.method().supports_masking()
    }

    fn run(
        &mut self,
        tokens: Vec<i32>,
        specs: &[ReqSpec],
        _capacity: usize,
        real: usize,
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        Ok((0..real)
            .map(|i| {
                let spec = crate::attention::AttnSpec {
                    causal: specs[i].causal,
                    key_len: Some(specs[i].key_len),
                    scale: None,
                };
                self.encoder.infer_spec(&tokens[i * bucket..(i + 1) * bucket], &spec)
            })
            .collect())
    }
}

/// Per-bucket worker: owns its executor and loops batching until the
/// queue closes.
fn worker_loop(
    cfg: ServeConfig,
    dir: std::path::PathBuf,
    bucket: usize,
    queue: Channel<Request>,
    stats: Arc<Mutex<ServeStats>>,
    draining: Arc<AtomicBool>,
) -> Result<()> {
    let mut exec: Box<dyn BatchExec> = if cfg.force_native {
        // Causal serving and mask-sensitive traffic skip PJRT outright:
        // the AOT executables are full bidirectional attention.
        Box::new(NativeExec::new(&cfg, bucket)?)
    } else {
        match PjrtExec::new(&cfg, &dir, bucket) {
            Ok(e) => Box::new(e),
            Err(e) if cfg.native_fallback => {
                eprintln!(
                    "worker n{bucket}: PJRT path unavailable ({e:#}); serving via native {} \
                     backend (degraded: untrained weights)",
                    cfg.method
                );
                Box::new(NativeExec::new(&cfg, bucket)?)
            }
            Err(e) => return Err(e),
        }
    };

    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Top up the pending set.
        let drain = draining.load(Ordering::SeqCst);
        if pending.len() < cfg.max_batch {
            match queue.recv_timeout(Duration::from_millis(cfg.batch_timeout_ms.max(1))) {
                Ok(Some(req)) => {
                    pending.push(req);
                    // opportunistically grab whatever else is queued
                    pending.extend(queue.drain_up_to(cfg.max_batch - pending.len()));
                }
                Ok(None) => {}
                Err(_) if pending.is_empty() => return Ok(()), // closed + drained
                Err(_) => {}
            }
        }
        let oldest_ms = pending
            .first()
            .map(|r| r.enqueued_at.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        if !should_fire(pending.len(), cfg.max_batch, oldest_ms, cfg.batch_timeout_ms as f64, drain) {
            continue;
        }
        for plan in plan_batches(pending.len(), cfg.max_batch) {
            let batch: Vec<Request> = plan.members.iter().map(|_| pending.remove(0)).collect();
            let capacity = exec.plan_capacity(batch.len(), cfg.max_batch);
            run_batch(exec.as_mut(), capacity, bucket, batch, cfg.compute.causal, &stats);
        }
        pending.clear();
    }
}

/// Execute one padded batch through the worker's executor and fan
/// results back out.  `default_causal` (`[compute] causal`) is OR-ed
/// with each request's own flag; causal members an executor cannot
/// honor are rejected *individually* — their co-batched bidirectional
/// requests still run.
fn run_batch(
    exec: &mut dyn BatchExec,
    capacity: usize,
    bucket: usize,
    batch: Vec<Request>,
    default_causal: bool,
    stats: &Arc<Mutex<ServeStats>>,
) {
    let mut batch = batch;
    if !exec.supports_causal() {
        let mut kept = Vec::with_capacity(batch.len());
        for r in batch {
            if r.causal || default_causal {
                let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                stats.lock().unwrap().errors += 1;
                r.resp
                    .send(Response {
                        id: r.id,
                        result: Err(
                            "causal attention is not available on this worker's executor \
                             (AOT serve artifacts and the nystrom/linformer methods are \
                             full-attention only); serve a maskable method with `[serve] \
                             force_native = true`"
                                .into(),
                        ),
                        latency_ms,
                        batch_size: 0,
                    })
                    .ok();
            } else {
                kept.push(r);
            }
        }
        batch = kept;
        if batch.is_empty() {
            return;
        }
    }
    let real = batch.len();
    let mut tokens = Vec::with_capacity(capacity * bucket);
    // One attention spec per live row: the request's pre-padding length
    // becomes its key mask, its causal flag (or the worker-wide
    // default) rides along.
    let mut specs = Vec::with_capacity(real);
    for r in &batch {
        specs.push(ReqSpec {
            key_len: r.tokens.len().min(bucket),
            causal: r.causal || default_causal,
        });
        tokens.extend(pad_to_bucket(&r.tokens, bucket));
    }
    // Pad phantom rows up to the executor's static batch.
    tokens.resize(capacity * bucket, crate::data::special::PAD);

    let result = exec.run(tokens, &specs, capacity, real, bucket);

    let mut st = stats.lock().unwrap();
    st.batch_sizes.push(real);
    match result {
        Ok(rows) => {
            for (r, row) in batch.into_iter().zip(rows) {
                let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                st.completed += 1;
                st.latencies_ms.push(latency_ms);
                r.resp
                    .send(Response { id: r.id, result: Ok(row), latency_ms, batch_size: real })
                    .ok();
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for r in batch {
                let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                st.errors += 1;
                r.resp
                    .send(Response {
                        id: r.id,
                        result: Err(msg.clone()),
                        latency_ms,
                        batch_size: real,
                    })
                    .ok();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{special, tasks::GlueGen, GlueTask};
    use crate::runtime::{artifacts_available, artifacts_dir};

    fn coordinator() -> Option<Coordinator> {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            return None;
        }
        let cfg = ServeConfig {
            method: "lln_diag".into(),
            queue_capacity: 64,
            max_batch: 8,
            batch_timeout_ms: 3,
            buckets: vec![128, 512],
            // These tests exist to exercise the PJRT path; a fallback
            // here would silently mask PJRT regressions.
            native_fallback: false,
            ..Default::default()
        };
        Some(Coordinator::start(cfg, &dir).unwrap())
    }

    /// A coordinator guaranteed to be on the native-backend path (the
    /// artifacts dir does not exist), exercising the full serving stack
    /// without PJRT.
    fn native_coordinator(method: &str, workers: usize) -> Coordinator {
        let cfg = ServeConfig {
            method: method.into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers,
            buckets: vec![32, 64],
            native_fallback: true,
            ..Default::default()
        };
        Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap()
    }

    #[test]
    fn native_fallback_serves_single_request() {
        let c = native_coordinator("lln_diag", 1);
        let resp = c.infer(vec![special::CLS; 20]).unwrap();
        let logits = resp.result.unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        c.shutdown();
    }

    #[test]
    fn native_fallback_batches_bursts() {
        let c = native_coordinator("lln", 1);
        let rxs: Vec<_> = (0..16)
            .map(|i| c.submit(vec![4 + (i as i32) % 7; 24]).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "{:?}", resp.result);
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.completed, 16);
        assert!(st.mean_batch_size() >= 1.0);
        assert!(st.p95_latency() >= st.p50_latency());
        drop(st);
        c.shutdown();
    }

    #[test]
    fn native_fallback_scales_workers_per_bucket() {
        let c = native_coordinator("softmax", 2);
        let rxs: Vec<_> = (0..12).map(|_| c.submit(vec![9i32; 50]).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        assert_eq!(c.stats().lock().unwrap().completed, 12);
        c.shutdown();
    }

    #[test]
    fn native_fallback_is_deterministic_per_request() {
        let c = native_coordinator("elu", 1);
        let a = c.infer(vec![11i32; 30]).unwrap().result.unwrap();
        let b = c.infer(vec![11i32; 30]).unwrap().result.unwrap();
        assert_eq!(a, b);
        c.shutdown();
    }

    #[test]
    fn native_fallback_still_rejects_over_length() {
        let c = native_coordinator("lln_diag", 1);
        let err = c.submit(vec![special::CLS; 1000]).unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
        c.shutdown();
    }

    #[test]
    fn force_native_skips_pjrt_entirely() {
        // force_native must serve without ever probing the artifacts
        // dir (no native_fallback needed).
        let cfg = ServeConfig {
            method: "lln_diag".into(),
            force_native: true,
            native_fallback: false,
            buckets: vec![32],
            ..Default::default()
        };
        let c = Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap();
        let resp = c.infer_with(vec![special::CLS; 16], true).unwrap();
        assert!(resp.result.is_ok());
        c.shutdown();
    }

    #[test]
    fn native_fallback_serves_causal_requests() {
        let c = native_coordinator("lln", 1);
        let tokens: Vec<i32> = (0..30).map(|i| 4 + i % 9).collect();
        let causal = c.infer_with(tokens.clone(), true).unwrap().result.unwrap();
        let bidi = c.infer_with(tokens.clone(), false).unwrap().result.unwrap();
        assert_eq!(causal.len(), 4);
        assert!(causal.iter().all(|x| x.is_finite()));
        // The mask must actually change the served function...
        assert_ne!(causal, bidi);
        // ...deterministically.
        assert_eq!(causal, c.infer_with(tokens, true).unwrap().result.unwrap());
        c.shutdown();
    }

    #[test]
    fn unmaskable_method_rejects_causal_requests_individually() {
        // Nystrom cannot honor the causal mask: its causal members get
        // a per-request error while bidirectional members in the same
        // bucket still serve.
        let c = native_coordinator("nystrom", 1);
        let causal_rx = c.submit_with(vec![7i32; 32], true).unwrap();
        let bidi_rx = c.submit_with(vec![7i32; 32], false).unwrap();
        let causal = causal_rx.recv().unwrap();
        let bidi = bidi_rx.recv().unwrap();
        let err = causal.result.unwrap_err();
        assert!(err.contains("causal"), "unexpected error: {err}");
        assert!(bidi.result.is_ok(), "bidirectional co-request must still serve");
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.errors, 1);
        assert_eq!(st.completed, 1);
        drop(st);
        c.shutdown();
    }

    #[test]
    fn native_fallback_batches_mixed_causal_and_lengths() {
        // One bucket batch mixing causal/bidirectional members and
        // different live lengths: every member gets its own mask.
        let c = native_coordinator("softmax", 1);
        let rxs: Vec<_> = (0..12)
            .map(|i| {
                let len = 8 + (i % 3) * 7;
                c.submit_with(vec![5 + i as i32; len], i % 2 == 0).unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "{:?}", resp.result);
        }
        assert_eq!(c.stats().lock().unwrap().completed, 12);
        c.shutdown();
    }

    #[test]
    fn padding_is_masked_out_of_native_serving() {
        // The same live tokens served through different bucket sizes
        // (32-pad vs 64-pad) must produce near-identical logits now
        // that key_len masks the pad tail out of attention and pooling.
        let mk = |buckets: Vec<usize>| {
            let cfg = ServeConfig {
                method: "lln".into(),
                buckets,
                native_fallback: true,
                ..Default::default()
            };
            Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap()
        };
        let live: Vec<i32> = (0..20).map(|i| 4 + i % 11).collect();
        let c32 = mk(vec![32]);
        let small = c32.infer(live.clone()).unwrap().result.unwrap();
        c32.shutdown();
        let c64 = mk(vec![64]);
        let big = c64.infer(live).unwrap().result.unwrap();
        c64.shutdown();
        for (x, y) in small.iter().zip(&big) {
            assert!((x - y).abs() < 1e-4, "bucket choice leaked into logits: {small:?} vs {big:?}");
        }
    }

    #[test]
    fn serves_single_request() {
        let Some(c) = coordinator() else { return };
        let mut gen = GlueGen::new(GlueTask::Sst2, 512, 128, 1);
        let (tokens, _) = gen.example();
        let resp = c.infer(tokens).unwrap();
        let logits = resp.result.unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        c.shutdown();
    }

    #[test]
    fn serves_concurrent_burst_with_batching() {
        let Some(c) = coordinator() else { return };
        let mut gen = GlueGen::new(GlueTask::Qqp, 512, 128, 2);
        let rxs: Vec<_> = (0..24).map(|_| c.submit(gen.example().0).unwrap()).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "{:?}", resp.result);
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.completed, 24);
        assert!(st.mean_batch_size() > 1.0, "burst should batch: {}", st.mean_batch_size());
        drop(st);
        c.shutdown();
    }

    #[test]
    fn routes_long_sequences_to_big_bucket() {
        let Some(c) = coordinator() else { return };
        let tokens = vec![special::CLS; 300]; // > 128, <= 512
        let resp = c.infer(tokens).unwrap();
        assert!(resp.result.is_ok());
        c.shutdown();
    }

    #[test]
    fn rejects_over_length() {
        let Some(c) = coordinator() else { return };
        let err = c.submit(vec![special::CLS; 1000]).unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
        c.shutdown();
    }
}
