//! Bench: paper Table 2 — time per attention forward vs sequence length.
//!
//! Section 1 runs the native `AttentionBackend` registry (always
//! available); section 2 runs the AOT PJRT kernels when artifacts are
//! built.  `cargo bench --bench attention_scaling`.
//!
//! Since the fused O(n·tile) kernels landed, the exact (quadratic-time)
//! methods run honestly up to n=8192 — no n×n matrix is materialized —
//! so the long-sequence rows compare against real exact attention, not
//! a skipped cell.  `--tile` / `--unroll` (after `--`) forward the
//! `[compute]` fused-kernel knobs.

use lln::attention::{backend_for, AttnSpec, BackendParams, Method};
use lln::bench::{bench_arg_usize, run_attention_backend, Bench};
use lln::rng::Pcg64;
use lln::runtime::{artifacts_available, artifacts_dir, Engine, HostTensor};
use lln::tensor::default_threads;

fn main() {
    let d = 64usize;
    let tile = bench_arg_usize("tile").unwrap_or(0);
    let unroll = bench_arg_usize("unroll").unwrap_or(0);
    let mut b = Bench::new();

    println!(
        "== Table 2 bench (native backends, d={d}, {} worker threads, tile={tile}, unroll={unroll}) ==",
        default_threads()
    );
    for method in [Method::Softmax, Method::Lln, Method::LlnDiag, Method::Elu, Method::Nystrom] {
        for n in [256usize, 1024, 4096, 8192, 16384] {
            if !method.is_linear() && n > 8192 {
                println!(
                    "backend {} n={n:<24} --- (skipped: quadratic time; see `lln bench`)",
                    method.name()
                );
                continue;
            }
            let bk = backend_for(
                method,
                BackendParams { alpha: 2.2, beta: 2.2, tile, unroll, ..Default::default() },
            );
            let mean = run_attention_backend(&mut b, bk.as_ref(), n, d, n as u64, &AttnSpec::FULL);
            let gflops = bk.flops_model(n, d, &AttnSpec::FULL) / mean / 1e9;
            println!("    model: {:.1} GFLOP/s effective", gflops);
        }
    }

    // Decoder-side rows: the fused causal softmax (prefix tiles only)
    // and the causal prefix-state LLN, on the same probes.
    println!("\n== causal (decoder) forwards ==");
    for method in [Method::Softmax, Method::Lln] {
        for n in [1024usize, 4096, 8192] {
            let bk = backend_for(
                method,
                BackendParams { alpha: 2.2, beta: 2.2, tile, unroll, ..Default::default() },
            );
            let mean =
                run_attention_backend(&mut b, bk.as_ref(), n, d, n as u64, &AttnSpec::CAUSAL);
            let gflops = bk.flops_model(n, d, &AttnSpec::CAUSAL) / mean / 1e9;
            println!("    model: {:.1} GFLOP/s effective (causal)", gflops);
        }
    }

    let dir = artifacts_dir(None);
    if !artifacts_available(&dir) {
        println!("\nartifacts not built — skipping the PJRT (AOT kernel) section");
        return;
    }
    let mut engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            println!("\nPJRT engine unavailable ({e:#}) — skipping the AOT kernel section");
            return;
        }
    };
    let mut rng = Pcg64::seed(0);

    println!("\n== Table 2 bench: AOT attention kernels (PJRT CPU, d={d}) ==");
    for method in ["softmax", "lln", "lln_diag", "elu", "performer", "nystrom"] {
        for n in [256usize, 1024, 4096, 8192, 16384] {
            let name = format!("attn_{method}_n{n}");
            if engine.manifest().artifact(&name).is_err() {
                println!("{name:<40} --- (not exported: paper's OOM regime)");
                continue;
            }
            let mk = |rng: &mut Pcg64| HostTensor::F32 {
                shape: vec![n, d],
                data: (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            };
            let q = mk(&mut rng);
            let k = mk(&mut rng);
            let v = mk(&mut rng);
            let inputs: Vec<HostTensor> = if method.starts_with("lln") {
                vec![q, k, v, HostTensor::scalar_f32(2.2), HostTensor::scalar_f32(2.2)]
            } else {
                vec![q, k, v]
            };
            engine.execute(&name, &inputs).expect("warm"); // compile outside timing
            b.run(&name, n as f64, || engine.execute(&name, &inputs).unwrap());
        }
    }
}
