//! Rust-native moment matching (paper App. A.7) — mirrors
//! `python/compile/moment_matching.py`.
//!
//! The Python side fits (a, b) once at AOT time and bakes them into the
//! train-step HLO; this native implementation exists so the analysis
//! experiments (figs. 2/5/7) can sweep matching live, and so the fit
//! itself is covered by Rust tests against the same math.

use super::kernels::{lln_attention_matrix, softmax_attention_matrix};
use crate::rng::Pcg64;
use crate::stats;
use crate::tensor::Mat;

/// Fitted broad-regime model sigma^2_lln = a * s~^2 + b, plus derivation
/// of (alpha, beta) from live input stds (paper eq. 10).
#[derive(Clone, Copy, Debug)]
pub struct MomentMatcher {
    pub a: f64,
    pub b: f64,
}

impl MomentMatcher {
    /// Fit over the broad regime (see python module docstring for why
    /// the grid starts at s~^2 = 8 for d = 64).
    pub fn fit(n: usize, d: usize, seeds: &[u64]) -> Self {
        let grid: Vec<f64> = (0..11).map(|i| 8.0 + 2.0 * i as f64).collect();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &s2 in &grid {
            for &seed in seeds {
                xs.push(s2);
                ys.push(measure_lln_log_variance(s2, n, d, seed));
            }
        }
        let (a, b, _r2) = stats::linear_fit(&xs, &ys);
        Self { a, b }
    }

    /// Load the constants the AOT pipeline fitted (keeps Rust and the
    /// baked HLO consistent); `artifacts/mm_constants.json`.
    pub fn from_artifacts(dir: &std::path::Path) -> Option<Self> {
        let text = std::fs::read_to_string(dir.join("mm_constants.json")).ok()?;
        let v = crate::util::json::Json::parse(&text).ok()?;
        Some(Self { a: v.get("a")?.as_f64()?, b: v.get("b")?.as_f64()? })
    }

    /// Whether the fitted broad-regime line is usable by eq. 10: the
    /// slope must be positive (variance grows with s~²) and both
    /// constants finite.  A degenerate fit (possible on adversarial
    /// seeds / tiny probe budgets) would otherwise push a *negative*
    /// `s2_tilde` through the pre-clamp division and emit garbage
    /// alpha/beta.
    pub fn is_valid(&self) -> bool {
        self.a.is_finite() && self.b.is_finite() && self.a > 1e-9
    }

    /// Paper eq. 10.  A degenerate fit (see [`is_valid`](Self::is_valid))
    /// falls back to identity matching (`a = 1, b = 0`, i.e.
    /// `s~² = σq²σk²`) instead of dividing by a non-positive slope —
    /// the resulting exponents are then merely un-matched, never
    /// negative, non-finite, or clamped-to-epsilon garbage.
    pub fn alpha_beta(&self, sigma_q: f64, sigma_k: f64) -> (f32, f32) {
        let s2_sm = sigma_q * sigma_q * sigma_k * sigma_k;
        let s2_tilde = if self.is_valid() {
            ((s2_sm - self.b) / self.a).max(1e-4)
        } else {
            s2_sm.max(1e-4)
        };
        let s_tilde = s2_tilde.sqrt();
        let inv_sqrt2 = std::f64::consts::FRAC_1_SQRT_2;
        (
            (s_tilde * inv_sqrt2 / sigma_q.max(1e-6)) as f32,
            (s_tilde * inv_sqrt2 / sigma_k.max(1e-6)) as f32,
        )
    }
}

/// var(log P_lln) for Gaussian probes at a given s~^2 with alpha=beta=1.
pub fn measure_lln_log_variance(s2_tilde: f64, n: usize, d: usize, seed: u64) -> f64 {
    let sigma = (s2_tilde / 2.0).sqrt() as f32;
    let mut rng = Pcg64::seed(seed);
    let q = Mat::gaussian(n, d, sigma, &mut rng);
    let k = Mat::gaussian(n, d, sigma, &mut rng);
    stats::log_variance(&lln_attention_matrix(&q, &k, 1.0, 1.0), 1e-30)
}

/// var(log P_sm) for Gaussian probes (theory: sigma_q^2 sigma_k^2).
pub fn measure_sm_log_variance(sigma_q: f32, sigma_k: f32, n: usize, d: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::seed(seed);
    let q = Mat::gaussian(n, d, sigma_q, &mut rng);
    let k = Mat::gaussian(n, d, sigma_k, &mut rng);
    stats::log_variance(&softmax_attention_matrix(&q, &k), 1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fitted() -> MomentMatcher {
        // Prefer the AOT constants (fast, consistent with HLO); fall back
        // to a fresh small fit when artifacts are absent.
        MomentMatcher::from_artifacts(std::path::Path::new("artifacts"))
            .unwrap_or_else(|| MomentMatcher::fit(192, 64, &[0, 1]))
    }

    #[test]
    fn fit_slope_positive() {
        let mm = fitted();
        assert!(mm.a > 0.0, "{mm:?}");
    }

    #[test]
    fn sm_log_variance_matches_theory() {
        let v = measure_sm_log_variance(1.2, 1.2, 384, 64, 3);
        let theory = 1.2f64.powi(4); // (sigma_q * sigma_k)^2
        assert!((v - theory).abs() / theory < 0.25, "v={v} theory={theory}");
    }

    #[test]
    fn matched_alpha_beta_near_paper_range() {
        let mm = fitted();
        let (a, b) = mm.alpha_beta(1.0, 1.0);
        assert!(a > 1.5 && a < 3.0, "alpha {a}");
        assert!((a - b).abs() < 1e-5);
    }

    #[test]
    fn matching_aligns_log_variance() {
        let mm = fitted();
        let (alpha, beta) = mm.alpha_beta(1.2, 1.2);
        let mut rng = Pcg64::seed(21);
        let q = Mat::gaussian(256, 64, 1.2, &mut rng);
        let k = Mat::gaussian(256, 64, 1.2, &mut rng);
        let v_lln = stats::log_variance(&lln_attention_matrix(&q, &k, alpha, beta), 1e-30);
        let v_sm = stats::log_variance(&softmax_attention_matrix(&q, &k), 1e-30);
        let rel = (v_lln - v_sm).abs() / v_sm;
        assert!(rel < 0.35, "lln={v_lln} sm={v_sm} rel={rel}");
    }

    #[test]
    fn unmatched_variance_is_far_too_small() {
        let mut rng = Pcg64::seed(22);
        let q = Mat::gaussian(256, 64, 1.2, &mut rng);
        let k = Mat::gaussian(256, 64, 1.2, &mut rng);
        let naive = stats::log_variance(&lln_attention_matrix(&q, &k, 1.0, 1.0), 1e-30);
        let sm = stats::log_variance(&softmax_attention_matrix(&q, &k), 1e-30);
        assert!(naive < 0.25 * sm, "naive={naive} sm={sm}");
    }

    #[test]
    fn degenerate_fit_falls_back_to_identity_matching() {
        // Regression: a non-positive or non-finite slope used to flow
        // straight into `(s2_sm - b) / a`, yielding a negative (or
        // NaN) s2_tilde whose 1e-4 clamp produced near-zero alpha/beta
        // garbage.  Each degenerate matcher must now report invalid
        // and produce the identity-matched exponents instead.
        let identity = MomentMatcher { a: 1.0, b: 0.0 };
        assert!(identity.is_valid());
        let want = identity.alpha_beta(1.2, 1.2);
        for mm in [
            MomentMatcher { a: 0.0, b: 0.1 },
            MomentMatcher { a: -0.5, b: 0.1 },
            MomentMatcher { a: f64::NAN, b: 0.1 },
            MomentMatcher { a: 2.0, b: f64::INFINITY },
        ] {
            assert!(!mm.is_valid(), "{mm:?} must be flagged degenerate");
            let (a, b) = mm.alpha_beta(1.2, 1.2);
            assert!(a.is_finite() && b.is_finite(), "{mm:?}: non-finite exponents");
            assert!(a > 0.1 && b > 0.1, "{mm:?}: clamped-to-epsilon garbage ({a}, {b})");
            assert_eq!((a, b), want, "{mm:?}: must match the identity fallback");
        }
        // A healthy fit is untouched by the guard.
        let healthy = MomentMatcher { a: 2.0, b: 0.5 };
        assert!(healthy.is_valid());
        let (a, _) = healthy.alpha_beta(1.5, 1.5);
        let s2 = (1.5f64.powi(4) - 0.5) / 2.0;
        let expect = (s2.sqrt() * std::f64::consts::FRAC_1_SQRT_2 / 1.5) as f32;
        assert!((a - expect).abs() < 1e-6);
    }

    #[test]
    fn alpha_scales_inversely_with_sigma_q() {
        let mm = fitted();
        let (a1, _) = mm.alpha_beta(1.0, 1.44);
        let (a2, _) = mm.alpha_beta(1.2, 1.2);
        let ratio = a1 as f64 / a2 as f64;
        assert!((ratio - 1.2).abs() < 1e-3, "{ratio}");
    }
}
