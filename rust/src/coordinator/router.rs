//! Consistent-hash session router for the sharded coordinator front.
//!
//! Sessions are pinned to a shard for their lifetime (their decode
//! state lives in that shard's registry), so the router must be stable:
//! when the shard count grows from `n` to `n+1`, only the keys whose
//! ring arc the new shard claims may move — and every moved key lands
//! on the *new* shard.  A plain `key % n` would reshuffle nearly
//! everything.  Each shard contributes `replicas` virtual points to a
//! sorted ring; a key routes to the first point clockwise of its hash.

/// SplitMix64 finalizer: cheap, well-mixed 64-bit hash for ring points
/// and keys (session ids are sequential, so mixing matters).
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Immutable consistent-hash ring over `shards` shards.
pub struct HashRing {
    /// (point hash, shard) sorted by hash.
    points: Vec<(u64, usize)>,
    shards: usize,
}

/// Virtual points per shard; enough to keep the load split within a few
/// percent of uniform at single-digit shard counts.
pub const RING_REPLICAS: usize = 64;

impl HashRing {
    pub fn new(shards: usize) -> Self {
        Self::with_replicas(shards, RING_REPLICAS)
    }

    pub fn with_replicas(shards: usize, replicas: usize) -> Self {
        let shards = shards.max(1);
        let replicas = replicas.max(1);
        let mut points = Vec::with_capacity(shards * replicas);
        for s in 0..shards {
            for r in 0..replicas {
                points.push((mix(((s as u64) << 32) | r as u64), s));
            }
        }
        points.sort_unstable();
        Self { points, shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard owning `key`: first ring point clockwise of `mix(key)`.
    pub fn route(&self, key: u64) -> usize {
        let h = mix(key);
        let i = self.points.partition_point(|&(p, _)| p < h);
        self.points[if i == self.points.len() { 0 } else { i }].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_routes_everything_to_zero() {
        let ring = HashRing::new(1);
        for k in 0..1000u64 {
            assert_eq!(ring.route(k), 0);
        }
    }

    #[test]
    fn load_is_roughly_uniform() {
        let ring = HashRing::new(4);
        let mut counts = [0usize; 4];
        for k in 0..40_000u64 {
            counts[ring.route(k)] += 1;
        }
        for &c in &counts {
            // Within 30% of the uniform 10k per shard.
            assert!((7_000..=13_000).contains(&c), "skewed shard load: {counts:?}");
        }
    }

    #[test]
    fn growing_the_ring_only_remaps_onto_the_new_shard() {
        // The consistency property the session registry depends on:
        // adding shard n never moves a key between two old shards.
        for n in 1..6usize {
            let old = HashRing::new(n);
            let new = HashRing::new(n + 1);
            let mut moved = 0usize;
            for k in 0..20_000u64 {
                let (a, b) = (old.route(k), new.route(k));
                if a != b {
                    assert_eq!(b, n, "key {k} remapped {a}->{b}, not to the new shard {n}");
                    moved += 1;
                }
            }
            // The new shard claims roughly 1/(n+1) of the keyspace.
            let expect = 20_000 / (n + 1);
            assert!(
                moved < 2 * expect,
                "shard growth {n}->{} moved {moved} keys (expected ~{expect})",
                n + 1
            );
            assert!(moved > 0, "the new shard must claim some keys");
        }
    }

    #[test]
    fn routing_is_deterministic() {
        let a = HashRing::new(3);
        let b = HashRing::new(3);
        for k in 0..512u64 {
            assert_eq!(a.route(k), b.route(k));
        }
    }
}
