//! Bounded MPMC channel + fixed-size thread pool (tokio substitute).
//!
//! The coordinator's admission queue and worker pool are built on these.
//! The channel is a mutex+condvar ring buffer: bounded (backpressure by
//! blocking or failing fast), FIFO, multi-producer multi-consumer.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Error returned by sends on a closed or full channel.
#[derive(Debug, PartialEq, Eq)]
pub enum SendError<T> {
    /// Channel closed — value returned to caller.
    Closed(T),
    /// try_send on a full channel — value returned to caller.
    Full(T),
}

/// Error returned by receives on a closed-and-drained channel.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded FIFO channel handle (clone freely; all clones share state).
pub struct Channel<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Channel<T> {
    pub fn bounded(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            inner: Arc::new(Inner {
                queue: Mutex::new(State {
                    items: VecDeque::with_capacity(capacity),
                    closed: false,
                }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                capacity,
            }),
        }
    }

    /// Blocking send; waits while full.  Errors if closed.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError::Closed(item));
            }
            if st.items.len() < self.inner.capacity {
                st.items.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; `Full` signals backpressure to the caller
    /// (the router surfaces this as 429-style rejection).
    pub fn try_send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.closed {
            return Err(SendError::Closed(item));
        }
        if st.items.len() >= self.inner.capacity {
            return Err(SendError::Full(item));
        }
        st.items.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; `Err` only when closed AND drained.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Ok(item);
            }
            if st.closed {
                return Err(RecvError);
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with timeout; `Ok(None)` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Option<T>, RecvError> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Ok(Some(item));
            }
            if st.closed {
                return Err(RecvError);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self.inner.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if res.timed_out() && st.items.is_empty() {
                if st.closed {
                    return Err(RecvError);
                }
                return Ok(None);
            }
        }
    }

    /// Steal up to `max` items from the queue *front*, but only while
    /// `pred` holds (work-stealing fill path).  Stops at the first
    /// non-matching item, so the remaining queue keeps its exact order
    /// — a thief configured with `pred = !is_session_work` can never
    /// reorder or migrate session-pinned steps.
    pub fn steal_up_to(&self, max: usize, mut pred: impl FnMut(&T) -> bool) -> Vec<T> {
        let mut st = self.inner.queue.lock().unwrap();
        let mut out = Vec::new();
        while out.len() < max {
            match st.items.front() {
                Some(item) if pred(item) => out.push(st.items.pop_front().unwrap()),
                _ => break,
            }
        }
        if !out.is_empty() {
            self.inner.not_full.notify_all();
        }
        out
    }

    /// Drain up to `max` items without blocking (batcher fill path).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut st = self.inner.queue.lock().unwrap();
        let take = st.items.len().min(max);
        let out: Vec<T> = st.items.drain(..take).collect();
        if !out.is_empty() {
            self.inner.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Close: senders fail, receivers drain then fail.
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

/// Fixed-size thread pool executing boxed jobs.
pub struct ThreadPool {
    tx: Channel<Job>,
    handles: Vec<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> Self {
        assert!(threads > 0);
        let tx: Channel<Job> = Channel::bounded(threads * 64);
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = tx.clone();
            let sd = Arc::clone(&shutdown);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || {
                        while !sd.load(Ordering::Relaxed) {
                            match rx.recv() {
                                Ok(job) => job(),
                                Err(RecvError) => break,
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { tx, handles, shutdown }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.send(Box::new(job)).ok();
    }

    /// Graceful shutdown: drain queued jobs, then join workers.
    pub fn shutdown(mut self) {
        self.tx.close();
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.tx.close();
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

/// Run a closure over a range in parallel chunks using scoped threads
/// (simple data-parallel helper for the native analysis paths).
pub fn parallel_for_chunks(total: usize, num_threads: usize, f: impl Fn(usize, usize) + Sync) {
    if total == 0 {
        return;
    }
    let threads = num_threads.max(1).min(total);
    let chunk = total.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let f = &f;
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(total);
            if lo < hi {
                scope.spawn(move || f(lo, hi));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn fifo_order_single_thread() {
        let ch = Channel::bounded(8);
        for i in 0..5 {
            ch.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(ch.recv().unwrap(), i);
        }
    }

    #[test]
    fn try_send_full_backpressure() {
        let ch = Channel::bounded(2);
        ch.try_send(1).unwrap();
        ch.try_send(2).unwrap();
        assert_eq!(ch.try_send(3), Err(SendError::Full(3)));
        ch.recv().unwrap();
        ch.try_send(3).unwrap();
    }

    #[test]
    fn close_drains_then_errors() {
        let ch = Channel::bounded(4);
        ch.send(10).unwrap();
        ch.close();
        assert_eq!(ch.send(11), Err(SendError::Closed(11)));
        assert_eq!(ch.recv(), Ok(10));
        assert_eq!(ch.recv(), Err(RecvError));
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let ch: Channel<usize> = Channel::bounded(16);
        let n_items = 4000usize;
        let seen = Arc::new(Mutex::new(vec![0u8; n_items]));
        std::thread::scope(|s| {
            for p in 0..4 {
                let tx = ch.clone();
                s.spawn(move || {
                    for i in (p..n_items).step_by(4) {
                        tx.send(i).unwrap();
                    }
                });
            }
            let done = Arc::new(AtomicUsize::new(0));
            for _ in 0..3 {
                let rx = ch.clone();
                let seen = Arc::clone(&seen);
                let done = Arc::clone(&done);
                s.spawn(move || loop {
                    match rx.recv_timeout(Duration::from_millis(200)).unwrap_or(None) {
                        Some(i) => {
                            seen.lock().unwrap()[i] += 1;
                            done.fetch_add(1, Ordering::Relaxed);
                        }
                        None => {
                            if done.load(Ordering::Relaxed) >= n_items {
                                break;
                            }
                        }
                    }
                });
            }
        });
        let seen = seen.lock().unwrap();
        assert!(seen.iter().all(|&c| c == 1), "loss or duplication detected");
    }

    #[test]
    fn drain_up_to_takes_prefix() {
        let ch = Channel::bounded(8);
        for i in 0..6 {
            ch.send(i).unwrap();
        }
        assert_eq!(ch.drain_up_to(4), vec![0, 1, 2, 3]);
        assert_eq!(ch.len(), 2);
    }

    #[test]
    fn steal_up_to_stops_at_first_non_matching_item() {
        let ch = Channel::bounded(8);
        // 0,1 stealable; 2 is "session work" (odd sentinel: >= 100).
        for i in [0, 1, 102, 3] {
            ch.send(i).unwrap();
        }
        assert_eq!(ch.steal_up_to(8, |&x| x < 100), vec![0, 1]);
        // The blocked prefix stays put in order — even stealable items
        // behind it are not reordered past the session item.
        assert_eq!(ch.len(), 2);
        assert_eq!(ch.recv().unwrap(), 102);
        assert_eq!(ch.recv().unwrap(), 3);
        assert!(ch.steal_up_to(0, |_| true).is_empty());
    }

    #[test]
    fn recv_timeout_times_out() {
        let ch: Channel<u32> = Channel::bounded(1);
        let got = ch.recv_timeout(Duration::from_millis(20)).unwrap();
        assert_eq!(got, None);
    }

    #[test]
    fn thread_pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4, "test");
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.shutdown();
        }
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits = Arc::new(Mutex::new(vec![0u8; 103]));
        parallel_for_chunks(103, 5, |lo, hi| {
            let mut h = hits.lock().unwrap();
            for i in lo..hi {
                h[i] += 1;
            }
        });
        assert!(hits.lock().unwrap().iter().all(|&c| c == 1));
    }
}
