"""L2 — AOT-able train/eval steps: Adam fully inside the jitted graph.

The Rust training driver owns three flat buffer sets (params, adam_m,
adam_v) in the canonical `model.param_order` order, plus two scalars
(t — the Adam step count, lr — from the Rust-side schedule).  One call
to the exported executable advances everything by one step and returns
the new state, the loss, the global gradient norm (the paper's FP16
loss-scale telemetry proxy, figs. 8b/10b) and the per-layer
[alpha, beta, sigma_q, sigma_k] stats tensor (fig. 9).

Keeping the optimizer inside the graph means the hot path is exactly one
PJRT execute per step, with all state device-resident (`execute_b`).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import model as M

ADAM_B1 = 0.9
ADAM_B2 = 0.98
ADAM_EPS = 1e-6
WEIGHT_DECAY = 0.01


def adam_update(params, grads, m, v, t, lr):
    """One decoupled-weight-decay Adam step over flat dicts."""
    b1t = 1.0 - jnp.power(ADAM_B1, t)
    b2t = 1.0 - jnp.power(ADAM_B2, t)
    new_p, new_m, new_v = {}, {}, {}
    for key in params:
        g = grads[key]
        mk = ADAM_B1 * m[key] + (1.0 - ADAM_B1) * g
        vk = ADAM_B2 * v[key] + (1.0 - ADAM_B2) * jnp.square(g)
        update = (mk / b1t) / (jnp.sqrt(vk / b2t) + ADAM_EPS)
        new_p[key] = params[key] - lr * (update + WEIGHT_DECAY * params[key])
        new_m[key] = mk
        new_v[key] = vk
    return new_p, new_m, new_v


def global_grad_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads.values()))


def _finish(params, m, v, t, lr, loss, grads, stats, cfg):
    gnorm = global_grad_norm(grads)
    new_p, new_m, new_v = adam_update(params, grads, m, v, t, lr)
    return new_p, new_m, new_v, loss, gnorm, M.stack_layer_stats(stats, cfg)


def train_step_mlm(params, m, v, t, lr, tokens, labels, weights, cfg: M.ModelConfig):
    """tokens/labels (B,N) i32, weights (B,N) f32 -> new state + telemetry."""
    (loss, stats), grads = jax.value_and_grad(
        lambda p: M.mlm_loss(p, tokens, labels, weights, cfg), has_aux=True
    )(params)
    return _finish(params, m, v, t, lr, loss, grads, stats, cfg)


def train_step_cls(params, m, v, t, lr, tokens, labels, cfg: M.ModelConfig):
    (loss, (stats, _logits)), grads = jax.value_and_grad(
        lambda p: M.cls_loss(p, tokens, labels, cfg), has_aux=True
    )(params)
    return _finish(params, m, v, t, lr, loss, grads, stats, cfg)


def train_step_vit(params, m, v, t, lr, patches, labels, cfg: M.ModelConfig):
    (loss, (stats, _logits)), grads = jax.value_and_grad(
        lambda p: M.vit_loss(p, patches, labels, cfg), has_aux=True
    )(params)
    return _finish(params, m, v, t, lr, loss, grads, stats, cfg)


# --- Eval-side functions (forward only) ------------------------------------

def eval_mlm(params, tokens, labels, weights, cfg: M.ModelConfig):
    loss, _ = M.mlm_loss(params, tokens, labels, weights, cfg)
    return (loss,)


def eval_cls(params, tokens, cfg: M.ModelConfig):
    hidden, _ = M.forward(params, tokens, cfg)
    return (M.cls_logits(params, hidden),)


def eval_vit(params, patches, cfg: M.ModelConfig):
    hidden, _ = M.forward_patches(params, patches, cfg)
    return (M.cls_logits(params, hidden),)


def init_opt_state(params: Dict) -> tuple[Dict, Dict]:
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return zeros, {k: jnp.zeros_like(v) for k, v in params.items()}
