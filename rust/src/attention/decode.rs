//! Incremental decode state: stateful O(1)-per-token causal attention.
//!
//! A batch causal forward pays the whole prefix for every row; a decode
//! session carries the prefix *state* across calls so token `t` costs
//! only its own step.  Two state shapes cover every maskable method:
//!
//! * [`KvCache`] — the appended K/V rows (Softmax / Quadratic /
//!   BlockDiag).  Per-token step cost grows with the prefix (O(t·d)),
//!   state is 2·t·d floats.
//! * [`PrefixState`] — the running `Σ φ(k) vᵀ` / `Σ φ(k)` recurrence of
//!   the linear class (LLN / ELU / ReLU / Performer).  Per-token step
//!   cost and state are O(m·dv) — *independent of the prefix length*,
//!   the paper's constant-state decode story.
//!
//! [`DecodeState`] wraps both (plus the LLN+Diag hybrid) behind the
//! [`AttentionBackend::begin_decode`](super::AttentionBackend::begin_decode)
//! / [`decode_step`](super::AttentionBackend::decode_step) entry points.
//!
//! `PrefixState` replicates the *chunk-carry* structure of
//! [`linear_attention_causal`](super::kernels::linear_attention_causal)
//! — completed chunks fold into a carry, the live chunk accumulates on
//! top — so stepping a session token-by-token is **bitwise identical**
//! to the batch kernel's rows for the same `chunk` parameter (the
//! property suite pins this).

use super::kernels::accumulate_state_dispatch;
use crate::lowp::{Precision, RowStore};
use crate::tensor::KernelDispatch;

const EPS: f32 = 1e-6;

/// Appended K/V rows — the decode state of the exact quadratic-cost
/// methods.  Rows append; the incremental fused-softmax / quadratic /
/// block-diagonal step kernels stream them back per token.  Methods
/// that only ever re-read a bounded suffix (BlockDiag's diagonal tile)
/// call [`start_new_window`](Self::start_new_window) at tile
/// boundaries, which evicts the dead prefix and keeps the resident
/// state O(window) instead of O(t).
///
/// Rows are *stored* at the configured [`Precision`] (the
/// `[compute] precision` knob): each pushed row is encoded on append —
/// per-row scale/zero-point for int8, plain bf16/f16 words otherwise —
/// and the step kernels read a maintained f32 decode of the live window
/// (the gather scratch; [`state_bytes`](Self::state_bytes) counts only
/// the stored payload, mirroring the paged cache's accounting).  At
/// `Precision::F32` the store IS the f32 buffer — zero-copy and bitwise
/// identical to the pre-precision cache.
pub struct KvCache {
    d: usize,
    dv: usize,
    /// Total tokens ever appended (the session length).
    len: usize,
    /// Tokens evicted from the front; the buffers hold rows
    /// `base..len`.
    base: usize,
    k: RowStore,
    v: RowStore,
    /// f32 decode of the resident window (empty at `Precision::F32`,
    /// where the store itself is read zero-copy).
    k_dec: Vec<f32>,
    v_dec: Vec<f32>,
}

impl KvCache {
    pub fn new(d: usize, dv: usize) -> Self {
        Self::with_precision(d, dv, Precision::F32)
    }

    /// A cache whose K/V rows are stored at `prec` (encoded on push,
    /// decoded for the step kernels; arithmetic stays f32).
    pub fn with_precision(d: usize, dv: usize, prec: Precision) -> Self {
        Self {
            d,
            dv,
            len: 0,
            base: 0,
            k: RowStore::new(prec, d),
            v: RowStore::new(prec, dv),
            k_dec: Vec::new(),
            v_dec: Vec::new(),
        }
    }

    /// The storage precision of the K/V rows.
    pub fn precision(&self) -> Precision {
        self.k.precision()
    }

    /// Appended token count (total, including evicted rows).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Key head dim.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Value dim.
    pub fn dv(&self) -> usize {
        self.dv
    }

    /// Rows currently resident (the live window).
    pub fn window_len(&self) -> usize {
        self.len - self.base
    }

    /// Append one token's key/value rows.  The rows are encoded through
    /// the storage precision; what the step kernels later read is the
    /// *decoded* values, so quantization error is applied exactly once
    /// per row, at append time (a pure function of the row — the
    /// determinism the paged recompute-on-miss path relies on).
    pub fn push(&mut self, krow: &[f32], vrow: &[f32]) {
        assert_eq!(krow.len(), self.d, "key row dim mismatch");
        assert_eq!(vrow.len(), self.dv, "value row dim mismatch");
        self.k.push_row(krow);
        self.v.push_row(vrow);
        if self.k.as_f32().is_none() {
            // Low-precision store: extend the f32 window decode with
            // just the new row (O(1)/token).
            let mut tmp = Vec::with_capacity(self.d.max(self.dv));
            self.k.decode_range_into(self.k.rows() - 1, self.k.rows(), &mut tmp);
            self.k_dec.extend_from_slice(&tmp);
            self.v.decode_range_into(self.v.rows() - 1, self.v.rows(), &mut tmp);
            self.v_dec.extend_from_slice(&tmp);
        }
        self.len += 1;
    }

    /// Evict every resident row (they will never be read again): the
    /// next pushes start a fresh window.  Buffer capacity is retained,
    /// so a windowed cache settles at O(window) memory with no realloc
    /// churn.
    pub fn start_new_window(&mut self) {
        self.k.clear();
        self.v.clear();
        self.k_dec.clear();
        self.v_dec.clear();
        self.base = self.len;
    }

    /// The resident key rows as f32, row-major (`window_len() * d` —
    /// rows `base..len` of the sequence).  Zero-copy at
    /// `Precision::F32`; the maintained window decode otherwise.
    pub fn keys(&self) -> &[f32] {
        self.k.as_f32().unwrap_or(&self.k_dec)
    }

    /// The resident value rows as f32, row-major (`window_len() * dv`).
    pub fn values(&self) -> &[f32] {
        self.v.as_f32().unwrap_or(&self.v_dec)
    }

    /// Resident *stored* state bytes: the encoded K/V payload (plus the
    /// int8 per-row scale/zero tables) — linear in the decoded length
    /// for the full-prefix methods, bounded by the window for
    /// BlockDiag.  This is what the serving admission math budgets; the
    /// f32 window decode is gather scratch, same as the paged cache's
    /// gather buffers.
    pub fn state_bytes(&self) -> usize {
        self.k.stored_bytes() + self.v.stored_bytes()
    }
}

/// The linear-class running prefix state
///
/// ```text
///   S_t = Σ_{j <= t} φ(k_j) v_jᵀ   (m × dv),   z_t = Σ_{j <= t} φ(k_j)
/// ```
///
/// held in the same chunk-carry structure as the batch kernel
/// [`linear_attention_causal`](super::kernels::linear_attention_causal):
/// `carry` is the element-wise sum of completed chunk partials, `part`
/// the live chunk's partial (accumulated from zero), and `state` the
/// carry with the live chunk's rows replayed on top — exactly phase 2 /
/// phase 3 of the batch kernel, so N [`push`](Self::push) +
/// [`read`](Self::read) calls reproduce the batch rows bitwise for the
/// same `chunk`.
pub struct PrefixState {
    m: usize,
    dv: usize,
    chunk: usize,
    len: usize,
    /// Microkernel instance for the per-token state fold, resolved at
    /// backend construction (bitwise-identical across instances).
    kern: KernelDispatch,
    carry_kv: Vec<f32>,
    carry_z: Vec<f32>,
    part_kv: Vec<f32>,
    part_z: Vec<f32>,
    state_kv: Vec<f32>,
    state_z: Vec<f32>,
}

impl PrefixState {
    /// `m` feature dim, `dv` value dim, `chunk` the carry granularity
    /// (0 = the batch kernel's default of 128).
    pub fn new(m: usize, dv: usize, chunk: usize) -> Self {
        Self::with_kernel(m, dv, chunk, KernelDispatch::Auto)
    }

    /// [`PrefixState::new`] with an explicit [`KernelDispatch`] for the
    /// per-token `Σ φ(k)vᵀ` fold (backends pass their
    /// construction-resolved instance; outputs are bitwise identical
    /// for every dispatch value).
    pub fn with_kernel(m: usize, dv: usize, chunk: usize, kern: KernelDispatch) -> Self {
        let chunk = if chunk == 0 { 128 } else { chunk };
        Self {
            m,
            dv,
            chunk,
            len: 0,
            kern,
            carry_kv: vec![0.0; m * dv],
            carry_z: vec![0.0; m],
            part_kv: vec![0.0; m * dv],
            part_z: vec![0.0; m],
            state_kv: vec![0.0; m * dv],
            state_z: vec![0.0; m],
        }
    }

    /// Appended token count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Feature dim.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Value dim.
    pub fn dv(&self) -> usize {
        self.dv
    }

    /// Fold one token's `φ(k)` / value rows into the running state.
    pub fn push(&mut self, phi_k: &[f32], vrow: &[f32]) {
        assert_eq!(phi_k.len(), self.m, "feature row dim mismatch");
        assert_eq!(vrow.len(), self.dv, "value row dim mismatch");
        if self.len > 0 && self.len % self.chunk == 0 {
            // Chunk boundary — the batch kernel's phase 2: the finished
            // chunk's partial folds into the carry element-wise, and the
            // new chunk replays from a fresh copy of that carry.
            for (c, p) in self.carry_kv.iter_mut().zip(&self.part_kv) {
                *c += *p;
            }
            for (c, p) in self.carry_z.iter_mut().zip(&self.part_z) {
                *c += *p;
            }
            self.part_kv.fill(0.0);
            self.part_z.fill(0.0);
            self.state_kv.copy_from_slice(&self.carry_kv);
            self.state_z.copy_from_slice(&self.carry_z);
        }
        accumulate_state_dispatch(self.kern, &mut self.part_kv, &mut self.part_z, phi_k, vrow, self.dv);
        accumulate_state_dispatch(self.kern, &mut self.state_kv, &mut self.state_z, phi_k, vrow, self.dv);
        self.len += 1;
    }

    /// Read the current token's output: `φ(q)ᵀ S / (φ(q)·z + ε)` — the
    /// batch kernel's phase-3 read-back, in the same FP order.
    pub fn read(&self, phi_q: &[f32]) -> Vec<f32> {
        assert_eq!(phi_q.len(), self.m, "query feature row dim mismatch");
        let mut out = vec![0.0f32; self.dv];
        let mut den = 0.0f32;
        for (f, &qf) in phi_q.iter().enumerate() {
            den += qf * self.state_z[f];
            if qf != 0.0 {
                let krow = &self.state_kv[f * self.dv..(f + 1) * self.dv];
                for (o, &kvv) in out.iter_mut().zip(krow) {
                    *o += qf * kvv;
                }
            }
        }
        let inv = 1.0 / (den + EPS);
        for o in out.iter_mut() {
            *o *= inv;
        }
        out
    }

    /// Resident state bytes — constant in the decoded length (the
    /// O(m·dv) story): three (kv, z) buffers.
    pub fn state_bytes(&self) -> usize {
        3 * (self.m * self.dv + self.m) * std::mem::size_of::<f32>()
    }
}

/// One decode session's attention state, per method class.  Built by
/// [`AttentionBackend::begin_decode`](super::AttentionBackend::begin_decode)
/// and advanced one token at a time by
/// [`AttentionBackend::decode_step`](super::AttentionBackend::decode_step).
pub enum DecodeState {
    /// Appended K/V rows (Softmax / Quadratic / BlockDiag).
    Cache(KvCache),
    /// Running `Σ φ(k)vᵀ` / `Σ φ(k)` prefix state (LLN / ELU / ReLU /
    /// Performer).
    Prefix(PrefixState),
    /// LLN+Diag: prefix state for the long-range half plus a K/V cache
    /// for the diagonal-tile softmax half.
    Hybrid { prefix: PrefixState, cache: KvCache },
    /// [`KvCache`] semantics over pool-backed fixed-size pages: rows
    /// may be evicted under memory pressure and recomputed on the next
    /// step (see [`super::paged`]).
    Paged(super::paged::PagedKvCache),
}

impl DecodeState {
    /// Tokens decoded so far.
    pub fn len(&self) -> usize {
        match self {
            DecodeState::Cache(c) => c.len(),
            DecodeState::Prefix(p) => p.len(),
            DecodeState::Hybrid { prefix, .. } => prefix.len(),
            DecodeState::Paged(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident state bytes: O(t·d) for the cache class, O(m·dv)
    /// constant for the prefix class (see docs/CONFIG.md for the
    /// per-method formulas).
    pub fn state_bytes(&self) -> usize {
        match self {
            DecodeState::Cache(c) => c.state_bytes(),
            DecodeState::Prefix(p) => p.state_bytes(),
            DecodeState::Hybrid { prefix, cache } => prefix.state_bytes() + cache.state_bytes(),
            DecodeState::Paged(c) => c.state_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_cache_appends_rows() {
        let mut c = KvCache::new(3, 2);
        assert!(c.is_empty());
        c.push(&[1.0, 2.0, 3.0], &[4.0, 5.0]);
        c.push(&[6.0, 7.0, 8.0], &[9.0, 10.0]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.keys(), &[1.0, 2.0, 3.0, 6.0, 7.0, 8.0]);
        assert_eq!(c.values(), &[4.0, 5.0, 9.0, 10.0]);
        assert_eq!(c.state_bytes(), (6 + 4) * 4);
    }

    #[test]
    fn kv_cache_low_precision_stores_fewer_bytes_and_bounded_error() {
        let d = 8;
        let dv = 8;
        let mut rng = crate::rng::Pcg64::seed(77);
        let k = crate::tensor::Mat::gaussian(12, d, 1.0, &mut rng);
        let v = crate::tensor::Mat::gaussian(12, dv, 1.0, &mut rng);
        let mut f32c = KvCache::new(d, dv);
        for i in 0..12 {
            f32c.push(k.row(i), v.row(i));
        }
        for (prec, tol, shrink) in [
            (Precision::Bf16, 1.0 / 128.0, 2),
            (Precision::F16, 1.0 / 1024.0, 2),
            (Precision::Int8Kv, 0.05, 2),
        ] {
            let mut c = KvCache::with_precision(d, dv, prec);
            for i in 0..12 {
                c.push(k.row(i), v.row(i));
            }
            assert_eq!(c.precision(), prec);
            assert_eq!(c.len(), 12);
            assert!(
                c.state_bytes() * shrink <= f32c.state_bytes(),
                "{prec:?}: {} vs f32 {}",
                c.state_bytes(),
                f32c.state_bytes()
            );
            for (&x, &y) in f32c.keys().iter().zip(c.keys()) {
                assert!((x - y).abs() <= tol * x.abs().max(2.0), "{prec:?} key: {x} vs {y}");
            }
            for (&x, &y) in f32c.values().iter().zip(c.values()) {
                assert!((x - y).abs() <= tol * x.abs().max(2.0), "{prec:?} value: {x} vs {y}");
            }
            // Window eviction clears the decode scratch too.
            c.start_new_window();
            assert_eq!(c.window_len(), 0);
            assert!(c.keys().is_empty() && c.values().is_empty());
            assert_eq!(c.state_bytes(), 0);
            c.push(k.row(0), v.row(0));
            assert_eq!(c.window_len(), 1);
            assert_eq!(c.keys().len(), d);
        }
    }

    #[test]
    fn prefix_state_is_constant_size() {
        let mut p = PrefixState::new(4, 3, 2);
        let bytes0 = p.state_bytes();
        for i in 0..9 {
            let f = i as f32;
            p.push(&[0.1 + f, 0.2, 0.3, 0.4], &[1.0, f, -f]);
        }
        assert_eq!(p.len(), 9);
        assert_eq!(p.state_bytes(), bytes0, "prefix state must not grow with length");
        let out = p.read(&[1.0, 0.0, 0.5, 0.0]);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefix_state_matches_direct_sum() {
        // Irrespective of the chunk carry structure, the state read must
        // equal the naive Σ φ(k)vᵀ / Σ φ(k) attention to f32 tolerance.
        let m = 5;
        let dv = 4;
        let n = 23;
        let mut rng = crate::rng::Pcg64::seed(9);
        let phi_k = crate::tensor::Mat::gaussian(n, m, 0.5, &mut rng).map(|x| x.abs());
        let v = crate::tensor::Mat::gaussian(n, dv, 1.0, &mut rng);
        let phi_q = crate::tensor::Mat::gaussian(1, m, 0.5, &mut rng).map(|x| x.abs());
        for chunk in [1usize, 3, 7, 0] {
            let mut st = PrefixState::new(m, dv, chunk);
            for i in 0..n {
                st.push(phi_k.row(i), v.row(i));
            }
            let got = st.read(phi_q.row(0));
            // Naive reference.
            let mut num = vec![0.0f64; dv];
            let mut den = 0.0f64;
            for i in 0..n {
                let w: f64 = phi_q
                    .row(0)
                    .iter()
                    .zip(phi_k.row(i))
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                den += w;
                for (o, &vv) in num.iter_mut().zip(v.row(i)) {
                    *o += w * vv as f64;
                }
            }
            for (g, want) in got.iter().zip(num.iter().map(|x| x / (den + EPS as f64))) {
                assert!((*g as f64 - want).abs() < 1e-4, "chunk={chunk}: {g} vs {want}");
            }
        }
    }
}
