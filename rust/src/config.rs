//! Configuration system: a TOML-subset parser + the typed configs for the
//! launcher (serde+toml substitute).
//!
//! Supported TOML subset: `[section]` and `[section.sub]` headers,
//! `key = value` with string/int/float/bool/array values, `#` comments.
//! Env-var overrides use `LLN_<SECTION>_<KEY>=value`.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}
impl std::error::Error for ConfigError {}

/// A TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat `section.key -> value` table.
#[derive(Clone, Debug, Default)]
pub struct ConfigTable {
    pub entries: BTreeMap<String, Value>,
}

impl ConfigTable {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(ConfigError(format!("line {}: malformed section header", lineno + 1)));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(ConfigError(format!("line {}: empty section name", lineno + 1)));
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| ConfigError(format!("line {}: expected key = value", lineno + 1)))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ConfigError(format!("line {}: empty key", lineno + 1)));
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            entries.insert(full, parse_value(val.trim(), lineno + 1)?);
        }
        Ok(Self { entries })
    }

    pub fn load(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("reading {}: {e}", path.display())))?;
        let mut t = Self::parse(&text)?;
        t.apply_env_overrides();
        Ok(t)
    }

    /// `LLN_TRAIN_STEPS=500` overrides `train.steps`.
    pub fn apply_env_overrides(&mut self) {
        for (k, v) in std::env::vars() {
            if let Some(rest) = k.strip_prefix("LLN_") {
                let key = rest.to_lowercase().replacen('_', ".", 1);
                if self.entries.contains_key(&key) {
                    if let Ok(val) = parse_value(&v, 0) {
                        self.entries.insert(key, val);
                    }
                }
            }
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_i64).map(|x| x as usize).unwrap_or(default)
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value, ConfigError> {
    let s = s.trim();
    if s.is_empty() {
        return Err(ConfigError(format!("line {lineno}: empty value")));
    }
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part, lineno)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // Bare word: accept as string (common for method names).
    Ok(Value::Str(s.to_string()))
}

// ---------------------------------------------------------------------------
// Typed launcher configs
// ---------------------------------------------------------------------------

/// Training-run configuration (the `lln train` launcher).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifact: String,
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    pub out_dir: String,
    /// Batch size override for the native trainer (0 = the model
    /// size's default; the artifact path always uses the AOT batch).
    pub batch: usize,
    /// Sequence length override for the native trainer (0 = default).
    pub seqlen: usize,
    /// Force the native backprop trainer even when AOT artifacts
    /// exist (`lln train --native`).  With no artifacts directory the
    /// native path is picked automatically regardless of this flag.
    pub native: bool,
    /// Attention heads for the native trainer (0 = the model size's
    /// default; must divide d_model).  The artifact path ignores this
    /// — its head count is baked into the AOT graph.
    pub heads: usize,
    /// Gradient-checkpointing segments for the native trainer
    /// (0/1 = off).  Loss and gradients are bitwise-identical to the
    /// unsegmented run; peak tape memory shrinks to the largest
    /// segment.
    pub checkpoint_segments: usize,
    /// Data-parallel sequence shards on the compute pool for the
    /// native trainer (0 = serial).  Fixed-order all-reduce keeps
    /// results bitwise across shard and worker counts.
    pub data_parallel: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            artifact: "train_tinymlm_lln_diag".into(),
            steps: 200,
            lr: 5e-4,
            warmup: 20,
            seed: 0,
            log_every: 10,
            eval_every: 50,
            out_dir: "runs".into(),
            batch: 0,
            seqlen: 0,
            native: false,
            heads: 0,
            checkpoint_segments: 0,
            data_parallel: 0,
        }
    }
}

impl TrainConfig {
    pub fn from_table(t: &ConfigTable) -> Self {
        let d = Self::default();
        Self {
            artifact: t.str_or("train.artifact", &d.artifact),
            steps: t.usize_or("train.steps", d.steps),
            lr: t.f64_or("train.lr", d.lr),
            warmup: t.usize_or("train.warmup", d.warmup),
            seed: t.usize_or("train.seed", d.seed as usize) as u64,
            log_every: t.usize_or("train.log_every", d.log_every),
            eval_every: t.usize_or("train.eval_every", d.eval_every),
            out_dir: t.str_or("train.out_dir", &d.out_dir),
            batch: t.usize_or("train.batch", d.batch),
            seqlen: t.usize_or("train.seqlen", d.seqlen),
            native: t.bool_or("train.native", d.native),
            heads: t.usize_or("train.heads", d.heads),
            checkpoint_segments: t.usize_or("train.checkpoint_segments", d.checkpoint_segments),
            data_parallel: t.usize_or("train.data_parallel", d.data_parallel),
        }
    }

    /// Linear warmup then inverse-sqrt decay (the RoBERTa schedule shape).
    pub fn lr_at(&self, step: usize) -> f64 {
        if self.warmup > 0 && step < self.warmup {
            self.lr * (step + 1) as f64 / self.warmup as f64
        } else {
            self.lr * ((self.warmup.max(1) as f64) / (step + 1) as f64).sqrt()
        }
    }
}

/// Serving configuration (the `lln serve` coordinator).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub method: String,
    pub queue_capacity: usize,
    pub max_batch: usize,
    pub batch_timeout_ms: u64,
    /// Worker threads per sequence-length bucket, all draining the
    /// bucket's shared MPMC queue.  Each PJRT worker owns its own
    /// engine AND its own resident parameter copy — the xla wrappers
    /// are thread-confined, so literals cannot be shared across
    /// workers — which is why the default stays 1: scaling this up
    /// multiplies resident-parameter memory per bucket.  With the
    /// autoscaling band unset this is the *fixed* count (the
    /// historical behavior); with `min_workers`/`max_workers` set it
    /// is only the `min_workers` fallback.
    pub workers: usize,
    /// Autoscaling floor: workers each bucket always keeps alive.
    /// `0` = use `workers` (the historical fixed count).
    pub min_workers: usize,
    /// Autoscaling ceiling: the per-bucket scaler spawns extra workers
    /// from queue depth (one per `max_batch` of backlog — see
    /// [`desired_workers`](crate::coordinator::desired_workers)) up to
    /// this; idle extras retire back down to the floor.  `0` = no
    /// autoscaling (the band collapses to the floor).
    pub max_workers: usize,
    pub buckets: Vec<usize>,
    /// Opt-in: when PJRT artifacts are unavailable, serve through the
    /// native [`AttentionBackend`](crate::attention::AttentionBackend)
    /// encoder (untrained weights — a degraded pipeline exerciser, not
    /// the model) instead of failing the worker.  Off by default so a
    /// misconfigured artifacts path fails loudly in production.
    pub native_fallback: bool,
    /// Skip PJRT entirely and serve through the native backend encoder
    /// even when artifacts exist.  The AOT executables are compiled as
    /// full bidirectional attention, so causal serving (`lln serve
    /// --causal`, `[compute] causal`) needs this path.
    pub force_native: bool,
    /// Coordinator shards: each shard owns its own per-bucket queues,
    /// worker pools, and session registries; sessions pin to a shard
    /// via the consistent-hash router, prefill goes to the
    /// least-loaded shard, and idle workers steal prefill (never
    /// session steps) from sibling shards' same-bucket queues.  `1` =
    /// the historical single-front coordinator.
    pub shards: usize,
    /// Page budget for the paged KV cache backing softmax / quadratic
    /// / blockdiag decode sessions: total pages the pool may hold
    /// (`bytes <= page_pool_pages * (page_tokens * (d + dv) * kv_bytes
    /// + 2 * page_tokens * quant_overhead)` where `kv_bytes` /
    /// `quant_overhead` follow `[compute] precision` — 4/0 at f32,
    /// 2/0 at bf16 or f16, 1/8 at int8-kv; see docs/CONFIG.md).
    /// `0` = unpaged sessions (each grows its own `KvCache`).
    pub page_pool_pages: usize,
    /// Tokens per KV page.
    pub page_tokens: usize,
    /// Refill LRU-evicted pages from the session's token history on
    /// its next step (deterministic re-embedding — bitwise identical).
    /// When off, a session that lost a page fails its next step.
    pub recompute_on_miss: bool,
    /// Live decode-session slot budget; opening past it evicts the
    /// oldest-idle session (a session holds its slot while stepping).
    /// `0` = unlimited.
    pub max_sessions: usize,
    /// Admission token budgets per payload class, in tokens/second
    /// with a one-second burst capacity (`0` = unlimited).  Decode
    /// steps are exempt: a live session already holds its slot.
    pub short_tokens_per_s: f64,
    pub long_tokens_per_s: f64,
    /// Session opens per second (each open costs 1).
    pub opens_per_s: f64,
    /// Default per-request deadline in milliseconds (`0` = none).
    /// Requests past their deadline are shed queue-side with a
    /// `DeadlineExceeded` terminal response instead of wasting executor
    /// time, and admission rejects a request outright when the
    /// projected queue wait already exceeds its deadline.  Decode
    /// steps are exempt (a live session already holds its slot).
    pub default_deadline_ms: u64,
    /// Max coordinator-side retries for failed *prefill* batches
    /// (`0` = no retry).  Decode steps are never retried: a failed
    /// step poisons its session rather than silently re-executing.
    pub retry_max: u32,
    /// Base backoff between prefill retries in milliseconds; grows
    /// exponentially per attempt with deterministic jitter (see
    /// [`backoff_ms`](crate::faults::backoff_ms)).
    pub retry_backoff_ms: u64,
    /// Shed new session opens when PagePool churn — pages evicted +
    /// recomputed per decode step since the last open — exceeds this
    /// ratio (`0.0` = never shed).  Protects live-session p99 from
    /// thrash before it protects new traffic.
    pub thrash_shed_ratio: f64,
    /// Seeded fault-injection schedule (`[faults]` section /
    /// `lln serve --chaos-seed`).  All-off by default.
    pub faults: FaultsConfig,
    /// Kernel-compute knobs forwarded to the native backends.
    pub compute: ComputeConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            method: "lln_diag".into(),
            queue_capacity: 256,
            max_batch: 8,
            batch_timeout_ms: 5,
            workers: 1,
            min_workers: 0,
            max_workers: 0,
            buckets: vec![128, 512],
            native_fallback: false,
            force_native: false,
            shards: 1,
            page_pool_pages: 0,
            page_tokens: 16,
            recompute_on_miss: true,
            max_sessions: 0,
            short_tokens_per_s: 0.0,
            long_tokens_per_s: 0.0,
            opens_per_s: 0.0,
            default_deadline_ms: 0,
            retry_max: 0,
            retry_backoff_ms: 5,
            thrash_shed_ratio: 0.0,
            faults: FaultsConfig::default(),
            compute: ComputeConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn from_table(t: &ConfigTable) -> Self {
        let d = Self::default();
        let buckets = match t.get("serve.buckets") {
            Some(Value::Array(xs)) => xs.iter().filter_map(|v| v.as_i64()).map(|x| x as usize).collect(),
            _ => d.buckets.clone(),
        };
        Self {
            method: t.str_or("serve.method", &d.method),
            queue_capacity: t.usize_or("serve.queue_capacity", d.queue_capacity),
            max_batch: t.usize_or("serve.max_batch", d.max_batch),
            batch_timeout_ms: t.usize_or("serve.batch_timeout_ms", d.batch_timeout_ms as usize) as u64,
            workers: t.usize_or("serve.workers", d.workers),
            min_workers: t.usize_or("serve.min_workers", d.min_workers),
            max_workers: t.usize_or("serve.max_workers", d.max_workers),
            buckets,
            native_fallback: t.bool_or("serve.native_fallback", d.native_fallback),
            force_native: t.bool_or("serve.force_native", d.force_native),
            shards: t.usize_or("serve.shards", d.shards),
            page_pool_pages: t.usize_or("serve.page_pool_pages", d.page_pool_pages),
            page_tokens: t.usize_or("serve.page_tokens", d.page_tokens),
            recompute_on_miss: t.bool_or("serve.recompute_on_miss", d.recompute_on_miss),
            max_sessions: t.usize_or("serve.max_sessions", d.max_sessions),
            short_tokens_per_s: t.f64_or("serve.short_tokens_per_s", d.short_tokens_per_s),
            long_tokens_per_s: t.f64_or("serve.long_tokens_per_s", d.long_tokens_per_s),
            opens_per_s: t.f64_or("serve.opens_per_s", d.opens_per_s),
            default_deadline_ms: t.usize_or("serve.default_deadline_ms", d.default_deadline_ms as usize) as u64,
            retry_max: t.usize_or("serve.retry_max", d.retry_max as usize) as u32,
            retry_backoff_ms: t.usize_or("serve.retry_backoff_ms", d.retry_backoff_ms as usize) as u64,
            thrash_shed_ratio: t.f64_or("serve.thrash_shed_ratio", d.thrash_shed_ratio),
            faults: FaultsConfig::from_table(t),
            compute: ComputeConfig::from_table(t),
        }
    }

    /// The resolved per-bucket autoscaling band `(min, max)`:
    /// `min_workers` falls back to the historical `workers` count, and
    /// the ceiling is never below the floor.  `min == max` means a
    /// fixed worker pool (no scaler thread).
    pub fn worker_band(&self) -> (usize, usize) {
        let min = if self.min_workers == 0 { self.workers.max(1) } else { self.min_workers };
        (min, self.max_workers.max(min))
    }
}

/// Seeded fault-injection schedule (`[faults]` section): every knob is
/// a deterministic arrival-count trigger — see
/// [`FaultPoint`](crate::faults::FaultPoint) for the
/// `start` / `every` / `limit` semantics (`start == 0` disables a
/// fault; `every == 0` fires only at `start`; `limit == 0` is
/// unlimited).  All-off by default: production serving never pays for
/// the harness.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Seed recorded for reproducibility (`lln serve --chaos-seed`);
    /// purely informational once the schedule below is derived.
    pub seed: u64,
    /// Panic the Nth executor call (batch run / decode begin / step).
    pub exec_panic_start: u64,
    pub exec_panic_every: u64,
    pub exec_panic_limit: u64,
    /// Delay a worker `delay_ms` before processing the Nth item.
    pub delay_start: u64,
    pub delay_every: u64,
    pub delay_limit: u64,
    pub delay_ms: u64,
    /// Fail the Nth fresh PagePool page acquisition.
    pub page_fail_start: u64,
    pub page_fail_every: u64,
    pub page_fail_limit: u64,
    /// Kill the worker that picks up the Nth item.
    pub kill_worker_start: u64,
    pub kill_worker_every: u64,
    pub kill_worker_limit: u64,
    /// Condemn this shard's worker pool (`-1` = off) once the global
    /// worker-item counter reaches `kill_shard_at`.
    pub kill_shard: i64,
    pub kill_shard_at: u64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        Self {
            seed: 0,
            exec_panic_start: 0,
            exec_panic_every: 0,
            exec_panic_limit: 0,
            delay_start: 0,
            delay_every: 0,
            delay_limit: 0,
            delay_ms: 10,
            page_fail_start: 0,
            page_fail_every: 0,
            page_fail_limit: 0,
            kill_worker_start: 0,
            kill_worker_every: 0,
            kill_worker_limit: 0,
            kill_shard: -1,
            kill_shard_at: 0,
        }
    }
}

impl FaultsConfig {
    /// Any fault armed?  (`FaultPlan::from_config` returns `None`
    /// otherwise, so fault-free serving takes no new locks or counters.)
    pub fn enabled(&self) -> bool {
        self.exec_panic_start > 0
            || self.delay_start > 0
            || self.page_fail_start > 0
            || self.kill_worker_start > 0
            || self.kill_shard >= 0
    }

    pub fn from_table(t: &ConfigTable) -> Self {
        let d = Self::default();
        let u = |key: &str, dv: u64| t.usize_or(key, dv as usize) as u64;
        Self {
            seed: u("faults.seed", d.seed),
            exec_panic_start: u("faults.exec_panic_start", d.exec_panic_start),
            exec_panic_every: u("faults.exec_panic_every", d.exec_panic_every),
            exec_panic_limit: u("faults.exec_panic_limit", d.exec_panic_limit),
            delay_start: u("faults.delay_start", d.delay_start),
            delay_every: u("faults.delay_every", d.delay_every),
            delay_limit: u("faults.delay_limit", d.delay_limit),
            delay_ms: u("faults.delay_ms", d.delay_ms),
            page_fail_start: u("faults.page_fail_start", d.page_fail_start),
            page_fail_every: u("faults.page_fail_every", d.page_fail_every),
            page_fail_limit: u("faults.page_fail_limit", d.page_fail_limit),
            kill_worker_start: u("faults.kill_worker_start", d.kill_worker_start),
            kill_worker_every: u("faults.kill_worker_every", d.kill_worker_every),
            kill_worker_limit: u("faults.kill_worker_limit", d.kill_worker_limit),
            kill_shard: t.get("faults.kill_shard").and_then(Value::as_i64).unwrap_or(d.kill_shard),
            kill_shard_at: u("faults.kill_shard_at", d.kill_shard_at),
        }
    }

    /// Derive a full chaos schedule from one seed (`lln serve
    /// --chaos-seed`): a short burst of executor panics, a couple of
    /// slow-worker delays, one single-worker kill (the supervisor must
    /// respawn it), and — with more than one shard — one whole-shard
    /// kill partway through the run.  Deterministic in `(seed, shards)`.
    pub fn chaos(seed: u64, shards: usize) -> Self {
        let mix = crate::faults::splitmix;
        let h = |salt: u64| mix(seed ^ mix(salt));
        Self {
            seed,
            // First panic within calls 4..=11, then every 5..=9 calls, 3 total.
            exec_panic_start: 4 + h(1) % 8,
            exec_panic_every: 5 + h(2) % 5,
            exec_panic_limit: 3,
            // Two slow-downs of 15..=30 ms starting within items 3..=8.
            delay_start: 3 + h(3) % 6,
            delay_every: 7 + h(4) % 6,
            delay_limit: 2,
            delay_ms: 15 + h(5) % 16,
            page_fail_start: 0,
            page_fail_every: 0,
            page_fail_limit: 0,
            // One worker dies within items 6..=13; the supervisor respawns.
            kill_worker_start: 6 + h(6) % 8,
            kill_worker_every: 0,
            kill_worker_limit: 1,
            // With >1 shard, condemn one whole shard within items 20..=35.
            kill_shard: if shards > 1 { (h(7) % shards as u64) as i64 } else { -1 },
            kill_shard_at: 20 + h(8) % 16,
        }
    }
}

/// Native compute-kernel configuration: worker-thread count and blocking
/// for the parallel tensor kernels and the streaming linear-attention
/// formulation (see `attention::BackendParams::from_compute`).
#[derive(Clone, Copy, Debug)]
pub struct ComputeConfig {
    /// Scoped-worker count for `Mat::par_*` and streamed attention
    /// (0 = auto: `LLN_THREADS` env or available parallelism).
    pub threads: usize,
    /// Worker-thread count for the persistent compute pool that runs
    /// every `par_*` kernel and the pooled training backward (0 =
    /// auto: available parallelism).  Read once at first kernel use;
    /// later edits need a restart.  See docs/CONFIG.md §[compute].
    pub pool_threads: usize,
    /// Diagonal tile size for BlockDiag / LLN+Diag.
    pub block: usize,
    /// Streaming work-partition granularity: key/value rows are split
    /// across workers in multiples of this (0 = auto).
    pub chunk: usize,
    /// K/V tile rows for the fused O(n·tile) exact-attention kernels
    /// (0 = auto).  See docs/CONFIG.md §[compute].
    pub tile: usize,
    /// Query rows per register block in the fused kernels (0 = auto).
    pub unroll: usize,
    /// Route exact (Softmax / Quadratic) forwards through the fused
    /// streaming kernels instead of materializing the n×n score matrix.
    pub fused: bool,
    /// Serve causal (autoregressive) attention by default: native
    /// workers run every request under the causal mask unless the
    /// request says otherwise.  Requests can also opt in per-call via
    /// [`Coordinator::submit_with`](crate::coordinator::Coordinator::submit_with).
    pub causal: bool,
    /// Declared attention head dim, used to pin the monomorphized
    /// microkernel instance at backend construction (0 = resolve per
    /// call from the operand width).  32 / 64 / 128 hit the specialized
    /// fully-unrolled kernels; any other nonzero value pins the generic
    /// fallback.  See docs/CONFIG.md §[compute].
    pub head_dim: usize,
    /// K/V storage precision for decode caches, paged pools, and
    /// at-rest attention operands: `f32` (default; bitwise identical to
    /// a build without the precision layer), `bf16`, `f16`, or
    /// `int8-kv` (per-row affine quantization).  Arithmetic always
    /// accumulates in f32.
    pub precision: crate::lowp::Precision,
}

impl Default for ComputeConfig {
    fn default() -> Self {
        Self {
            threads: 0,
            pool_threads: 0,
            block: 64,
            chunk: 0,
            tile: 0,
            unroll: 0,
            fused: true,
            causal: false,
            head_dim: 0,
            precision: crate::lowp::Precision::F32,
        }
    }
}

impl ComputeConfig {
    pub fn from_table(t: &ConfigTable) -> Self {
        let d = Self::default();
        Self {
            threads: t.usize_or("compute.threads", d.threads),
            pool_threads: t.usize_or("compute.pool_threads", d.pool_threads),
            block: t.usize_or("compute.block", d.block),
            chunk: t.usize_or("compute.chunk", d.chunk),
            tile: t.usize_or("compute.tile", d.tile),
            unroll: t.usize_or("compute.unroll", d.unroll),
            fused: t.bool_or("compute.fused", d.fused),
            causal: t.bool_or("compute.causal", d.causal),
            head_dim: t.usize_or("compute.head_dim", d.head_dim),
            precision: crate::lowp::Precision::parse(&t.str_or("compute.precision", "f32"))
                .unwrap_or_default(),
        }
    }

    /// The worker count the kernels will actually use (delegates to the
    /// kernels' own resolution rule so the two can never disagree).
    pub fn resolved_threads(&self) -> usize {
        crate::tensor::resolve_threads(self.threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
[train]
steps = 500
lr = 0.0003          # inline comment
artifact = "train_mlm_lln"
verbose = true

[serve]
buckets = [128, 512]
method = lln_diag
"#;

    #[test]
    fn parses_sections_and_types() {
        let t = ConfigTable::parse(SAMPLE).unwrap();
        assert_eq!(t.usize_or("train.steps", 0), 500);
        assert!((t.f64_or("train.lr", 0.0) - 3e-4).abs() < 1e-12);
        assert_eq!(t.str_or("train.artifact", ""), "train_mlm_lln");
        assert!(t.bool_or("train.verbose", false));
        assert_eq!(t.str_or("serve.method", ""), "lln_diag");
        match t.get("serve.buckets").unwrap() {
            Value::Array(xs) => assert_eq!(xs.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn train_config_native_knobs_parse() {
        // Defaults: artifact path, auto batch/seqlen, no heads/ckpt/dp
        // overrides.
        let d = TrainConfig::default();
        assert!(!d.native);
        assert_eq!((d.batch, d.seqlen), (0, 0));
        assert_eq!((d.heads, d.checkpoint_segments, d.data_parallel), (0, 0, 0));
        let t = ConfigTable::parse(
            "[train]\nnative = true\nbatch = 2\nseqlen = 32\nheads = 4\n\
             checkpoint_segments = 2\ndata_parallel = 2",
        )
        .unwrap();
        let tc = TrainConfig::from_table(&t);
        assert!(tc.native);
        assert_eq!((tc.batch, tc.seqlen), (2, 32));
        assert_eq!((tc.heads, tc.checkpoint_segments, tc.data_parallel), (4, 2, 2));
    }

    #[test]
    fn typed_configs_from_table() {
        let t = ConfigTable::parse(SAMPLE).unwrap();
        let tc = TrainConfig::from_table(&t);
        assert_eq!(tc.steps, 500);
        let sc = ServeConfig::from_table(&t);
        assert_eq!(sc.buckets, vec![128, 512]);
        assert!(!sc.native_fallback, "native fallback must be opt-in");
        let t2 = ConfigTable::parse("[serve]\nnative_fallback = true").unwrap();
        assert!(ServeConfig::from_table(&t2).native_fallback);
    }

    #[test]
    fn compute_config_defaults_and_overrides() {
        let t =
            ConfigTable::parse("[compute]\nthreads = 3\nblock = 32\npool_threads = 2").unwrap();
        let cc = ComputeConfig::from_table(&t);
        assert_eq!(cc.threads, 3);
        assert_eq!(cc.block, 32);
        assert_eq!(cc.chunk, 0);
        assert_eq!(cc.pool_threads, 2);
        // Pool size defaults to auto (available parallelism).
        assert_eq!(ComputeConfig::default().pool_threads, 0);
        assert_eq!(cc.resolved_threads(), 3);
        // Fused-kernel knobs default to auto/on.
        assert_eq!(cc.tile, 0);
        assert_eq!(cc.unroll, 0);
        assert!(cc.fused, "fused exact kernels must be the default");
        let auto = ComputeConfig::default();
        assert!(auto.resolved_threads() >= 1);
        // The serve config forwards the [compute] section to workers.
        let sc = ServeConfig::from_table(&t);
        assert_eq!(sc.compute.threads, 3);
        assert_eq!(sc.compute.block, 32);
    }

    #[test]
    fn compute_config_fused_knobs_parse() {
        let t = ConfigTable::parse("[compute]\ntile = 256\nunroll = 2\nfused = false").unwrap();
        let cc = ComputeConfig::from_table(&t);
        assert_eq!(cc.tile, 256);
        assert_eq!(cc.unroll, 2);
        assert!(!cc.fused);
        // And they ride along into the serve config's compute section.
        let sc = ServeConfig::from_table(&t);
        assert_eq!(sc.compute.tile, 256);
        assert!(!sc.compute.fused);
    }

    #[test]
    fn compute_config_head_dim_and_precision_parse() {
        use crate::lowp::Precision;
        // Defaults: auto head dim, full-width storage.
        let d = ComputeConfig::default();
        assert_eq!(d.head_dim, 0);
        assert_eq!(d.precision, Precision::F32);
        let t = ConfigTable::parse("[compute]\nhead_dim = 64\nprecision = \"int8-kv\"").unwrap();
        let cc = ComputeConfig::from_table(&t);
        assert_eq!(cc.head_dim, 64);
        assert_eq!(cc.precision, Precision::Int8Kv);
        // Aliases and the serve-config ride-along.
        let t2 = ConfigTable::parse("[compute]\nprecision = \"bfloat16\"").unwrap();
        assert_eq!(ServeConfig::from_table(&t2).compute.precision, Precision::Bf16);
        // Unknown spellings fall back to the f32 escape hatch rather
        // than killing the launcher.
        let t3 = ConfigTable::parse("[compute]\nprecision = \"int4\"").unwrap();
        assert_eq!(ComputeConfig::from_table(&t3).precision, Precision::F32);
    }

    #[test]
    fn compute_config_causal_knob_parses() {
        // Bidirectional by default (the pre-causal behavior).
        assert!(!ComputeConfig::default().causal);
        let t = ConfigTable::parse("[compute]\ncausal = true").unwrap();
        let cc = ComputeConfig::from_table(&t);
        assert!(cc.causal);
        // And it reaches serving workers through the serve config.
        let sc = ServeConfig::from_table(&t);
        assert!(sc.compute.causal);
    }

    #[test]
    fn serve_force_native_knob_parses() {
        assert!(!ServeConfig::default().force_native);
        let t = ConfigTable::parse("[serve]\nforce_native = true").unwrap();
        assert!(ServeConfig::from_table(&t).force_native);
    }

    #[test]
    fn serve_worker_band_resolution() {
        // Defaults: fixed single worker (the historical behavior).
        assert_eq!(ServeConfig::default().worker_band(), (1, 1));
        // Legacy `workers` count stays the fixed pool when no band set.
        let legacy = ServeConfig { workers: 3, ..Default::default() };
        assert_eq!(legacy.worker_band(), (3, 3));
        // Explicit band parses and resolves.
        let t = ConfigTable::parse("[serve]\nmin_workers = 2\nmax_workers = 6").unwrap();
        let sc = ServeConfig::from_table(&t);
        assert_eq!((sc.min_workers, sc.max_workers), (2, 6));
        assert_eq!(sc.worker_band(), (2, 6));
        // Ceiling never below the floor.
        let inverted = ServeConfig { min_workers: 4, max_workers: 2, ..Default::default() };
        assert_eq!(inverted.worker_band(), (4, 4));
        // max_workers alone scales up from the `workers` floor.
        let up = ServeConfig { workers: 1, max_workers: 4, ..Default::default() };
        assert_eq!(up.worker_band(), (1, 4));
    }

    #[test]
    fn serve_resilience_knobs_parse() {
        let d = ServeConfig::default();
        assert_eq!(d.default_deadline_ms, 0, "deadlines must be opt-in");
        assert_eq!(d.retry_max, 0, "retry must be opt-in");
        assert_eq!(d.thrash_shed_ratio, 0.0, "thrash shedding must be opt-in");
        let t = ConfigTable::parse(
            "[serve]\ndefault_deadline_ms = 250\nretry_max = 2\nretry_backoff_ms = 8\nthrash_shed_ratio = 1.5",
        )
        .unwrap();
        let sc = ServeConfig::from_table(&t);
        assert_eq!(sc.default_deadline_ms, 250);
        assert_eq!(sc.retry_max, 2);
        assert_eq!(sc.retry_backoff_ms, 8);
        assert!((sc.thrash_shed_ratio - 1.5).abs() < 1e-12);
    }

    #[test]
    fn faults_section_parses_and_defaults_off() {
        let off = FaultsConfig::default();
        assert!(!off.enabled(), "all-off faults must not arm the harness");
        assert!(!ServeConfig::default().faults.enabled());
        let t = ConfigTable::parse(
            "[faults]\nexec_panic_start = 3\nexec_panic_every = 5\nexec_panic_limit = 2\nkill_shard = 1\nkill_shard_at = 10\ndelay_start = 4\ndelay_ms = 20",
        )
        .unwrap();
        let fc = FaultsConfig::from_table(&t);
        assert!(fc.enabled());
        assert_eq!((fc.exec_panic_start, fc.exec_panic_every, fc.exec_panic_limit), (3, 5, 2));
        assert_eq!((fc.kill_shard, fc.kill_shard_at), (1, 10));
        assert_eq!((fc.delay_start, fc.delay_ms), (4, 20));
        // And the section rides into the serve config.
        let sc = ServeConfig::from_table(&t);
        assert!(sc.faults.enabled());
        assert_eq!(sc.faults.kill_shard, 1);
    }

    #[test]
    fn lr_schedule_shape() {
        let tc = TrainConfig { warmup: 10, lr: 1.0, ..Default::default() };
        assert!(tc.lr_at(0) < tc.lr_at(9));
        assert!((tc.lr_at(9) - 1.0).abs() < 1e-9);
        assert!(tc.lr_at(40) < tc.lr_at(10));
    }

    #[test]
    fn malformed_rejected() {
        assert!(ConfigTable::parse("[unclosed").is_err());
        assert!(ConfigTable::parse("keywithoutvalue").is_err());
        assert!(ConfigTable::parse("[s]\n= 3").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = ConfigTable::parse("[a]\nx = \"v#1\"").unwrap();
        assert_eq!(t.str_or("a.x", ""), "v#1");
    }
}
