//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The crates.io mirror is unavailable in this build image, so the repo
//! vendors the slice of `anyhow` it actually uses: [`Error`], [`Result`],
//! the [`anyhow!`]/[`bail!`] macros, and the [`Context`] extension trait
//! for `Result` and `Option`.  Context is recorded by prefixing the
//! message (`"outer: inner"`), which keeps `{e}` / `{e:#}` renderings and
//! substring-based test assertions behaving like upstream for this
//! codebase's usage.

use std::fmt;

/// Dynamic error: a message chain.  Mirrors `anyhow::Error` closely
/// enough for this repo: it does NOT implement `std::error::Error`
/// (exactly like upstream), which is what allows the blanket
/// `From<E: std::error::Error>` conversion below to coexist with the
/// reflexive `From<Error> for Error` used by `?`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }

    /// Construct from anything displayable (parity with `Error::msg`).
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }

    /// Wrap with an outer context message.
    pub fn context(self, c: impl fmt::Display) -> Self {
        Self { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// `anyhow::Result`: error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures (implemented for `Result` and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(format!("{context}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::new(context.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

/// Build an [`Error`] from a message or format string — the upstream
/// macro's arm structure: a lone literal formats (keeping inline
/// captures), a lone non-literal expression is taken as a displayable
/// message (`anyhow!(err_string)`), and a format string with arguments
/// formats.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::new(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::new(format!($fmt, $($arg)*))
    };
}

/// Early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_fail() -> Result<String> {
        let e = std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(e)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!format!("{err}").is_empty());
    }

    #[test]
    fn context_chains_prefix() {
        let base: Result<(), Error> = Err(crate::anyhow!("inner {}", 7));
        let err = base.context("outer").unwrap_err();
        assert_eq!(format!("{err}"), "outer: inner 7");
        assert_eq!(format!("{err:#}"), "outer: inner 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing key").unwrap_err();
        assert!(format!("{err}").contains("missing key"));
    }

    #[test]
    fn bail_macro_returns() {
        fn f(x: bool) -> Result<u32> {
            if x {
                crate::bail!("nope: {x}");
            }
            Ok(1)
        }
        assert!(f(false).is_ok());
        assert!(format!("{}", f(true).unwrap_err()).contains("nope"));
    }
}
