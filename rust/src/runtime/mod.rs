//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! Layering (see /opt/xla-example/load_hlo):
//!   `HloModuleProto::from_text_file` -> `XlaComputation::from_proto`
//!   -> `PjRtClient::compile` -> `execute` / `execute_b`.
//!
//! The `xla` crate's wrappers are raw-pointer types without Send/Sync,
//! so an [`Engine`] is **thread-confined**: every coordinator worker and
//! the training driver construct their own engine (compilation results
//! are cached per engine).  Cross-thread traffic moves plain `Vec<f32>`
//! / `Vec<i32>` tensors, never PJRT handles.

pub mod engine;
pub mod manifest;
pub mod params;

pub use engine::{Engine, HostTensor};
pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelSpec};
pub use params::ParamStore;

use std::path::{Path, PathBuf};

/// Resolve the artifacts directory: explicit flag > $LLN_ARTIFACTS > ./artifacts.
pub fn artifacts_dir(explicit: Option<&str>) -> PathBuf {
    if let Some(p) = explicit {
        return PathBuf::from(p);
    }
    if let Ok(p) = std::env::var("LLN_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(crate::ARTIFACTS_DIR)
}

/// True if artifacts have been built (integration tests skip otherwise).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists()
}
