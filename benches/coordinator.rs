//! Bench: coordinator substrate hot paths (no PJRT) + the end-to-end
//! serving loop when artifacts exist.

use std::time::Duration;

use lln::bench::Bench;
use lln::config::ServeConfig;
use lln::coordinator::{batcher::plan_batches, Coordinator};
use lln::data::tasks::{GlueGen, GlueTask};
use lln::runtime::{artifacts_available, artifacts_dir};
use lln::util::pool::Channel;

fn main() {
    let mut b = Bench::new();

    println!("== coordinator substrates ==");
    b.run("plan_batches(1000, 8)", 1000.0, || plan_batches(1000, 8));
    let ch: Channel<u64> = Channel::bounded(1024);
    b.run("channel send+recv x1000", 1000.0, || {
        for i in 0..1000u64 {
            ch.send(i).unwrap();
        }
        for _ in 0..1000 {
            ch.recv().unwrap();
        }
    });
    b.run("channel drain_up_to(64) x1000", 1000.0, || {
        for i in 0..1000u64 {
            ch.send(i).unwrap();
        }
        let mut got = 0;
        while got < 1000 {
            got += ch.drain_up_to(64).len();
        }
    });

    let dir = artifacts_dir(None);
    if !artifacts_available(&dir) {
        println!("artifacts not built — skipping end-to-end serving bench");
        return;
    }
    println!("\n== end-to-end serving (lln_diag encoder) ==");
    let coord = Coordinator::start(ServeConfig::default(), &dir).expect("coordinator");
    coord.infer(vec![lln::data::special::CLS; 64]).unwrap(); // warm n128
    let mut gen = GlueGen::new(GlueTask::Sst2, 4096, 120, 1);
    b.run("serve 32-request burst (n=128)", 32.0, || {
        let rxs: Vec<_> = (0..32).map(|_| coord.submit(gen.example().0).unwrap()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
    });
    coord.shutdown();
}
