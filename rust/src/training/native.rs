//! Native training loop: backprop through the native attention
//! backends, no AOT artifacts anywhere (ROADMAP: "native training
//! loop").
//!
//! Three pieces:
//!
//! * [`Tape`] — a minimal reverse-mode autograd tape over [`Mat`] ops:
//!   each op records its parents and a backward closure (capturing the
//!   saved activations it needs), and [`Tape::backward`] walks the
//!   nodes in reverse creation order accumulating cotangents.  The op
//!   set is exactly what the MLM model needs: embedding lookup,
//!   matmul, bias, ReLU, layernorm, attention (through
//!   [`AttentionBackend::forward_train`] /
//!   [`AttentionBackend::backward`] — the fused recompute kernels, so
//!   the O(n·tile) memory story survives the backward), and the
//!   weighted MLM cross-entropy.
//!
//! * [`TrainStep`] — one optimizer step behind a uniform interface,
//!   with two implementations: [`ArtifactStep`] (today's AOT
//!   [`TrainDriver`] path) and [`NativeStep`] (a RoBERTa-lite MLM
//!   encoder trained natively with the tape + [`Adam`]).  The fig. 8 /
//!   fig. 1 harnesses pick [`NativeStep`] automatically when no
//!   artifacts directory exists (`lln train --native` forces it).
//!
//! * [`NativeStep`] emits the same [`StepTelemetry`] the AOT driver
//!   does — loss, grad-norm, per-layer `[alpha, beta, sigma_q,
//!   sigma_k]` plus per-head entropy rows — and, for LLN, *learns*
//!   alpha/beta through the `dα`/`dβ` hooks of the backward kernels
//!   (the paper's fig. 9 trajectories, without baked moment-matching
//!   constants).  The encoder is multi-head (each head attends over
//!   its own column band, outputs concatenate before `wo`), supports
//!   gradient checkpointing (segmented recompute, bitwise-identical
//!   gradients, smaller peak tape), and data-parallel sequence
//!   sharding on the persistent compute pool (fixed-order all-reduce,
//!   bitwise across worker counts).

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::attention::{backend_for, AttentionBackend, AttnSpec, BackendParams, Method};
use crate::data::MlmBatch;
use crate::rng::Pcg64;
use crate::runtime::{Engine, HostTensor};
use crate::tensor::{vec_ops, Mat};
use crate::training::driver::{StepTelemetry, TrainDriver};

// ---------------------------------------------------------------------------
// Tape
// ---------------------------------------------------------------------------

/// Backward closure of one tape node: output cotangent in, one
/// gradient per parent out (same order as the recorded parents).
type BackFn = Box<dyn Fn(&Mat) -> Vec<Mat>>;

/// Minimal reverse-mode autograd tape over [`Mat`] ops.  Node ids are
/// creation-ordered, so parents always precede children and one
/// reverse walk is a valid topological order.  Leaves keep their
/// accumulated gradients; intermediate cotangents are dropped as soon
/// as they are consumed.
///
/// Ops clone the operand matrices they need into their backward
/// closures (rather than re-reading `vals` by parent id at backward
/// time) — a deliberate simplicity-over-memory trade: the closures
/// stay self-contained `Fn(&Mat) -> Vec<Mat>` values, at the cost of
/// roughly doubling the held activation memory for the life of one
/// step.  At the shapes this trainer serves (tiny/small MLM models,
/// low-MB activations) that is noise; revisit if the native trainer
/// ever grows to models where activation memory dominates.
#[derive(Default)]
pub struct Tape {
    vals: Vec<Mat>,
    parents: Vec<Vec<usize>>,
    backs: Vec<Option<BackFn>>,
}

impl Tape {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of recorded nodes.
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// A leaf node (parameter or constant input).
    pub fn leaf(&mut self, v: Mat) -> usize {
        self.vals.push(v);
        self.parents.push(Vec::new());
        self.backs.push(None);
        self.vals.len() - 1
    }

    fn push(&mut self, v: Mat, parents: Vec<usize>, back: BackFn) -> usize {
        self.vals.push(v);
        self.parents.push(parents);
        self.backs.push(Some(back));
        self.vals.len() - 1
    }

    /// Forward value of a node.
    pub fn val(&self, id: usize) -> &Mat {
        &self.vals[id]
    }

    /// `a @ b`.
    pub fn matmul(&mut self, a: usize, b: usize) -> usize {
        let av = self.vals[a].clone();
        let bv = self.vals[b].clone();
        let out = av.matmul(&bv);
        self.push(
            out,
            vec![a, b],
            Box::new(move |d| vec![d.matmul_t(&bv), av.transpose().matmul(d)]),
        )
    }

    /// Elementwise `a + b` (same shape).
    pub fn add(&mut self, a: usize, b: usize) -> usize {
        let out = self.vals[a].add(&self.vals[b]);
        self.push(out, vec![a, b], Box::new(|d: &Mat| vec![d.clone(), d.clone()]))
    }

    /// Add a `1×n` bias row to every row of `x`.
    pub fn add_bias(&mut self, x: usize, b: usize) -> usize {
        let bv = self.vals[b].clone();
        assert_eq!(bv.rows(), 1, "bias must be a row vector");
        assert_eq!(bv.cols(), self.vals[x].cols(), "bias width mismatch");
        let mut out = self.vals[x].clone();
        for r in 0..out.rows() {
            for (o, &bb) in out.row_mut(r).iter_mut().zip(bv.row(0)) {
                *o += bb;
            }
        }
        let cols = bv.cols();
        self.push(
            out,
            vec![x, b],
            Box::new(move |d| {
                let mut db = Mat::zeros(1, cols);
                for r in 0..d.rows() {
                    for (o, &g) in db.data_mut().iter_mut().zip(d.row(r)) {
                        *o += g;
                    }
                }
                vec![d.clone(), db]
            }),
        )
    }

    /// Elementwise ReLU.
    pub fn relu(&mut self, x: usize) -> usize {
        let xv = self.vals[x].clone();
        let out = xv.map(|v| v.max(0.0));
        self.push(
            out,
            vec![x],
            Box::new(move |d| {
                let mut dx = d.clone();
                for (o, &v) in dx.data_mut().iter_mut().zip(xv.data()) {
                    if v <= 0.0 {
                        *o = 0.0;
                    }
                }
                vec![dx]
            }),
        )
    }

    /// Row-wise layer normalization with learned `1×n` gain/shift.
    pub fn layernorm(&mut self, x: usize, gamma: usize, beta: usize) -> usize {
        const LN_EPS: f32 = 1e-5;
        let xv = self.vals[x].clone();
        let gv = self.vals[gamma].clone();
        let bv = self.vals[beta].clone();
        let (rows, cols) = xv.shape();
        assert_eq!(gv.shape(), (1, cols), "layernorm gain shape");
        assert_eq!(bv.shape(), (1, cols), "layernorm shift shape");
        let mut out = Mat::zeros(rows, cols);
        let mut xhat = Mat::zeros(rows, cols);
        let mut inv_std = vec![0.0f32; rows];
        for r in 0..rows {
            let row = xv.row(r);
            let mu = row.iter().sum::<f32>() / cols as f32;
            let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
            let istd = 1.0 / (var + LN_EPS).sqrt();
            inv_std[r] = istd;
            let xh = xhat.row_mut(r);
            let orow = out.row_mut(r);
            for j in 0..cols {
                let h = (row[j] - mu) * istd;
                xh[j] = h;
                orow[j] = h * gv.get(0, j) + bv.get(0, j);
            }
        }
        self.push(
            out,
            vec![x, gamma, beta],
            Box::new(move |d| {
                let mut dx = Mat::zeros(rows, cols);
                let mut dg = Mat::zeros(1, cols);
                let mut db = Mat::zeros(1, cols);
                for r in 0..rows {
                    let dorow = d.row(r);
                    let xh = xhat.row(r);
                    {
                        let dgrow = dg.data_mut();
                        for j in 0..cols {
                            dgrow[j] += dorow[j] * xh[j];
                        }
                    }
                    {
                        let dbrow = db.data_mut();
                        for j in 0..cols {
                            dbrow[j] += dorow[j];
                        }
                    }
                    // dx̂ = d ∘ γ;  dx = (dx̂ − mean(dx̂) − x̂·mean(dx̂∘x̂))/σ
                    let mut mean_dxh = 0.0f32;
                    let mut mean_dxh_xh = 0.0f32;
                    for j in 0..cols {
                        let dxh = dorow[j] * gv.get(0, j);
                        mean_dxh += dxh;
                        mean_dxh_xh += dxh * xh[j];
                    }
                    mean_dxh /= cols as f32;
                    mean_dxh_xh /= cols as f32;
                    let istd = inv_std[r];
                    let dxrow = dx.row_mut(r);
                    for j in 0..cols {
                        let dxh = dorow[j] * gv.get(0, j);
                        dxrow[j] = (dxh - mean_dxh - xh[j] * mean_dxh_xh) * istd;
                    }
                }
                vec![dx, dg, db]
            }),
        )
    }

    /// Embedding lookup: row `r` of the output is
    /// `table[tokens[r]] + pos[r % n]` — token + learned positional
    /// embedding for `tokens.len() / n` packed sequences of length
    /// `n`.  Backward scatter-adds into both tables.
    pub fn embed(&mut self, table: usize, pos: usize, tokens: &[i32], n: usize) -> usize {
        let tv = self.vals[table].clone();
        let pv = self.vals[pos].clone();
        let d = tv.cols();
        assert_eq!(pv.cols(), d, "token/positional embedding width mismatch");
        assert!(n >= 1 && tokens.len() % n == 0, "token count must pack whole sequences");
        let rows = tokens.len();
        let vrows = tv.rows();
        let prows = pv.rows();
        let toks: Vec<usize> =
            tokens.iter().map(|&t| (t.max(0) as usize).min(vrows.saturating_sub(1))).collect();
        let mut out = Mat::zeros(rows, d);
        for (r, &t) in toks.iter().enumerate() {
            let prow = (r % n) % prows.max(1);
            for ((o, &a), &b) in out.row_mut(r).iter_mut().zip(tv.row(t)).zip(pv.row(prow)) {
                *o = a + b;
            }
        }
        self.push(
            out,
            vec![table, pos],
            Box::new(move |dout| {
                let mut dt = Mat::zeros(vrows, d);
                let mut dp = Mat::zeros(prows, d);
                for (r, &t) in toks.iter().enumerate() {
                    let dorow = dout.row(r);
                    for (o, &g) in dt.row_mut(t).iter_mut().zip(dorow) {
                        *o += g;
                    }
                    let prow = (r % n) % prows.max(1);
                    for (o, &g) in dp.row_mut(prow).iter_mut().zip(dorow) {
                        *o += g;
                    }
                }
                vec![dt, dp]
            }),
        )
    }

    /// Multi-head attention over `seqs` packed sequences (rows split
    /// evenly) and `heads` column bands (the `d_model / heads` head
    /// width), each `(sequence, head)` slice routed through the
    /// backend's fused [`forward_train`](AttentionBackend::forward_train)
    /// / [`backward`](AttentionBackend::backward) — `alpha` / `beta`
    /// are `1×1` tape nodes so LLN's exponents receive gradients
    /// (shared across heads, summed in fixed `(seq, head)` order on the
    /// way back).  `heads == 1` is bitwise the old single-head op.
    /// `Err` when the method has no native backward.
    #[allow(clippy::too_many_arguments)]
    pub fn attention(
        &mut self,
        q: usize,
        k: usize,
        v: usize,
        alpha: usize,
        beta: usize,
        method: Method,
        base: BackendParams,
        seqs: usize,
        heads: usize,
    ) -> Result<usize, String> {
        let qv = self.vals[q].clone();
        let kv = self.vals[k].clone();
        let vv = self.vals[v].clone();
        let rows = qv.rows();
        assert!(seqs >= 1 && rows % seqs == 0, "rows must pack whole sequences");
        let n = rows / seqs;
        let d = qv.cols();
        let dvc = vv.cols();
        assert!(
            heads >= 1 && d % heads == 0 && dvc % heads == 0,
            "head count must divide the q/k and v widths"
        );
        let (dh, dvh) = (d / heads, dvc / heads);
        let a_val = self.vals[alpha].get(0, 0);
        let b_val = self.vals[beta].get(0, 0);
        let backend: Arc<dyn AttentionBackend> =
            Arc::from(backend_for(method, BackendParams { alpha: a_val, beta: b_val, ..base }));
        let spec = AttnSpec::FULL;
        let mut out = Mat::zeros(rows, dvc);
        let mut caches = Vec::with_capacity(seqs * heads);
        for s in 0..seqs {
            for h in 0..heads {
                let qb = slice_block(&qv, s * n, n, h * dh, dh);
                let kb = slice_block(&kv, s * n, n, h * dh, dh);
                let vb = slice_block(&vv, s * n, n, h * dvh, dvh);
                let (ob, cache) = backend.forward_train(&qb, &kb, &vb, &spec)?;
                copy_block(&mut out, s * n, h * dvh, &ob);
                caches.push(cache);
            }
        }
        Ok(self.push(
            out,
            vec![q, k, v, alpha, beta],
            Box::new(move |dout| {
                let mut dq = Mat::zeros(rows, d);
                let mut dk = Mat::zeros(rows, d);
                let mut dvm = Mat::zeros(rows, dvc);
                let mut da = 0.0f32;
                let mut db = 0.0f32;
                for s in 0..seqs {
                    for h in 0..heads {
                        let qb = slice_block(&qv, s * n, n, h * dh, dh);
                        let kb = slice_block(&kv, s * n, n, h * dh, dh);
                        let vb = slice_block(&vv, s * n, n, h * dvh, dvh);
                        let dob = slice_block(dout, s * n, n, h * dvh, dvh);
                        let g = backend
                            .backward(&qb, &kb, &vb, &spec, &caches[s * heads + h], &dob)
                            .expect("native attention backward (forward_train succeeded)");
                        copy_block(&mut dq, s * n, h * dh, &g.dq);
                        copy_block(&mut dk, s * n, h * dh, &g.dk);
                        copy_block(&mut dvm, s * n, h * dvh, &g.dv);
                        da += g.dalpha;
                        db += g.dbeta;
                    }
                }
                vec![
                    dq,
                    dk,
                    dvm,
                    Mat::from_vec(1, 1, vec![da]),
                    Mat::from_vec(1, 1, vec![db]),
                ]
            }),
        ))
    }

    /// Weighted MLM cross-entropy over row logits: a `1×1` loss node,
    /// `loss = Σ_r w_r · (−log softmax(logits_r)[label_r]) / Σ_r w_r`
    /// (f64 accumulation).
    pub fn mlm_loss(&mut self, logits: usize, labels: &[i32], weights: &[f32]) -> usize {
        let lv = &self.vals[logits];
        let (rows, classes) = lv.shape();
        assert_eq!(labels.len(), rows, "label count mismatch");
        assert_eq!(weights.len(), rows, "weight count mismatch");
        assert!(classes >= 1, "no classes");
        let mut probs = lv.clone();
        probs.softmax_rows();
        let wsum = weights.iter().map(|&w| w as f64).sum::<f64>().max(1e-12);
        let labs: Vec<usize> =
            labels.iter().map(|&l| (l.max(0) as usize).min(classes - 1)).collect();
        let mut loss = 0.0f64;
        for (r, &lab) in labs.iter().enumerate() {
            let w = weights[r] as f64;
            if w == 0.0 {
                continue;
            }
            loss -= w * (probs.get(r, lab).max(1e-12) as f64).ln();
        }
        loss /= wsum;
        let out = Mat::from_vec(1, 1, vec![loss as f32]);
        let w: Vec<f32> = weights.to_vec();
        self.push(
            out,
            vec![logits],
            Box::new(move |dout| {
                let g = dout.get(0, 0);
                let mut dl = probs.clone();
                for (r, &lab) in labs.iter().enumerate() {
                    let row = dl.row_mut(r);
                    if w[r] == 0.0 {
                        row.fill(0.0);
                        continue;
                    }
                    row[lab] -= 1.0;
                    let scale = g * w[r] / wsum as f32;
                    for x in row.iter_mut() {
                        *x *= scale;
                    }
                }
                vec![dl]
            }),
        )
    }

    /// Reverse-mode sweep from `root` (typically the `1×1` loss).
    /// Returns one gradient slot per node; leaf slots keep their
    /// accumulated gradients, interior slots are drained as they are
    /// consumed (`None`).  Nodes the root does not depend on stay
    /// `None`.
    pub fn backward(&self, root: usize) -> Vec<Option<Mat>> {
        let (r, c) = self.vals[root].shape();
        self.backward_with(root, Mat::from_vec(r, c, vec![1.0; r * c]))
    }

    /// [`backward`](Tape::backward) with an explicit root cotangent —
    /// the seam gradient checkpointing and data-parallel loss scaling
    /// thread through (a segment's output cotangent, or the per-shard
    /// loss weight).  `backward` is exactly `backward_with(root, ones)`.
    pub fn backward_with(&self, root: usize, seed: Mat) -> Vec<Option<Mat>> {
        let mut grads: Vec<Option<Mat>> = (0..self.vals.len()).map(|_| None).collect();
        assert_eq!(seed.shape(), self.vals[root].shape(), "root cotangent shape mismatch");
        grads[root] = Some(seed);
        for id in (0..=root).rev() {
            let Some(back) = self.backs[id].as_ref() else { continue };
            let Some(g) = grads[id].take() else { continue };
            let pgrads = back(&g);
            debug_assert_eq!(pgrads.len(), self.parents[id].len());
            for (&p, pg) in self.parents[id].iter().zip(pgrads) {
                match grads[p].as_mut() {
                    Some(acc) => {
                        for (a, &x) in acc.data_mut().iter_mut().zip(pg.data()) {
                            *a += x;
                        }
                    }
                    None => grads[p] = Some(pg),
                }
            }
        }
        grads
    }

    /// Bytes held by this tape's stored activations (every node value,
    /// f32) — the peak-memory counter gradient checkpointing reports
    /// against: a checkpointed step's peak is the largest *segment*
    /// tape, not the whole-network tape.
    pub fn val_bytes(&self) -> usize {
        self.vals.iter().map(|m| m.data().len() * std::mem::size_of::<f32>()).sum()
    }
}

/// Copy an `rlen × clen` block of `m` starting at `(r0, c0)` into an
/// owned [`Mat`] — the per-(sequence, head) view the attention op hands
/// the backend.  Full-width blocks (`c0 == 0`, `clen == cols`) are the
/// old per-sequence row slice.
fn slice_block(m: &Mat, r0: usize, rlen: usize, c0: usize, clen: usize) -> Mat {
    let cols = m.cols();
    let mut out = Mat::zeros(rlen, clen);
    for r in 0..rlen {
        let base = (r0 + r) * cols + c0;
        out.row_mut(r).copy_from_slice(&m.data()[base..base + clen]);
    }
    out
}

/// Scatter `src` back into `dst` at block origin `(r0, c0)` — the
/// head-concatenation half of [`slice_block`].
fn copy_block(dst: &mut Mat, r0: usize, c0: usize, src: &Mat) {
    let cols = dst.cols();
    let (rlen, clen) = src.shape();
    for r in 0..rlen {
        let base = (r0 + r) * cols + c0;
        dst.data_mut()[base..base + clen].copy_from_slice(src.row(r));
    }
}

// ---------------------------------------------------------------------------
// Adam
// ---------------------------------------------------------------------------

/// Standard Adam with f64 bias correction, one moment pair per
/// parameter tensor — the native counterpart of the optimizer baked
/// into the AOT train step.
pub struct Adam {
    m: Vec<Mat>,
    v: Vec<Mat>,
    t: usize,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Adam {
    pub fn new(params: &[Mat]) -> Self {
        let zeros = |p: &Mat| Mat::zeros(p.rows(), p.cols());
        Self {
            m: params.iter().map(zeros).collect(),
            v: params.iter().map(zeros).collect(),
            t: 0,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    pub fn step_count(&self) -> usize {
        self.t
    }

    pub fn step(&mut self, params: &mut [Mat], grads: &[Mat], lr: f64) {
        assert_eq!(params.len(), grads.len(), "param/grad arity mismatch");
        self.t += 1;
        let (b1, b2) = (self.beta1, self.beta2);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for ((p, g), (m, v)) in
            params.iter_mut().zip(grads).zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            for ((pv, &gv), (mv, vv)) in p
                .data_mut()
                .iter_mut()
                .zip(g.data())
                .zip(m.data_mut().iter_mut().zip(v.data_mut().iter_mut()))
            {
                let g64 = gv as f64;
                let m64 = b1 * (*mv as f64) + (1.0 - b1) * g64;
                let v64 = b2 * (*vv as f64) + (1.0 - b2) * g64 * g64;
                *mv = m64 as f32;
                *vv = v64 as f32;
                *pv -= (lr * (m64 / bc1) / ((v64 / bc2).sqrt() + self.eps)) as f32;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TrainStep: one optimizer step behind a uniform interface
// ---------------------------------------------------------------------------

/// One MLM optimizer step — the seam between the fig. 8 / fig. 1
/// harnesses and *how* the step executes (AOT artifact vs native
/// backprop).  Both implementations speak [`StepTelemetry`].
pub trait TrainStep {
    /// Human-readable backend tag (`artifact:…` / `native:…`).
    fn name(&self) -> String;
    /// `(batch, seqlen)` the step consumes.
    fn batch_shape(&self) -> (usize, usize);
    /// Vocabulary size the corpus should generate.
    fn vocab(&self) -> usize;
    /// One optimizer step on an MLM batch.
    fn step(&mut self, lr: f64, batch: &MlmBatch) -> Result<StepTelemetry>;
    /// Forward-only loss on a held-out batch.
    fn eval_loss(&mut self, batch: &MlmBatch) -> Result<f32>;
}

/// [`TrainStep`] over today's AOT path: a PJRT [`Engine`] plus the
/// [`TrainDriver`] that steps a `train_*` executable.
pub struct ArtifactStep {
    engine: Engine,
    driver: TrainDriver,
    batch: usize,
    seqlen: usize,
    vocab: usize,
}

impl ArtifactStep {
    pub fn new(dir: &Path, artifact: &str) -> Result<Self> {
        let engine = Engine::new(dir)?;
        let spec = engine.manifest().artifact(artifact)?.clone();
        let batch = spec.meta_usize("batch").unwrap_or(8);
        let seqlen = spec.meta_usize("seqlen").unwrap_or(128);
        let model_tag = spec.meta.get("model").cloned().unwrap_or_default();
        let vocab = engine
            .manifest()
            .model(&model_tag)?
            .config
            .get("vocab_size")
            .and_then(|s| s.parse().ok())
            .unwrap_or(8192);
        let driver = TrainDriver::new(&engine, dir, artifact)?;
        Ok(Self { engine, driver, batch, seqlen, vocab })
    }

    fn data_tensors(&self, batch: &MlmBatch) -> [HostTensor; 3] {
        let (b, n) = (self.batch, self.seqlen);
        [
            HostTensor::I32 { shape: vec![b, n], data: batch.tokens.clone() },
            HostTensor::I32 { shape: vec![b, n], data: batch.labels.clone() },
            HostTensor::F32 { shape: vec![b, n], data: batch.weights.clone() },
        ]
    }
}

impl TrainStep for ArtifactStep {
    fn name(&self) -> String {
        format!("artifact:{}", self.driver.artifact)
    }
    fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seqlen)
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn step(&mut self, lr: f64, batch: &MlmBatch) -> Result<StepTelemetry> {
        let data = self.data_tensors(batch);
        self.driver.step(&mut self.engine, lr, &data)
    }
    fn eval_loss(&mut self, batch: &MlmBatch) -> Result<f32> {
        let data = self.data_tensors(batch);
        let outs = self.driver.eval(&mut self.engine, &data)?;
        outs[0].first_f32()
    }
}

// ---------------------------------------------------------------------------
// NativeStep: the RoBERTa-lite MLM encoder trained natively
// ---------------------------------------------------------------------------

/// Model + batch dimensions of the native MLM trainer.
#[derive(Clone, Copy, Debug)]
pub struct NativeShape {
    pub batch: usize,
    pub seqlen: usize,
    pub d_model: usize,
    /// Attention heads per layer; must divide `d_model`.  Each head
    /// attends over its own `d_model / heads` column band and the
    /// outputs concatenate before the `wo` projection.
    pub heads: usize,
    pub layers: usize,
    pub ff: usize,
    pub vocab: usize,
    pub seed: u64,
}

impl NativeShape {
    /// Dimensions matching the AOT size tags: `"mlm"` is the small
    /// fig. 8 model shape (multi-head, like the models the paper
    /// actually measures), anything else the tiny CI/test shape.
    pub fn for_size(size: &str) -> Self {
        if size == "mlm" {
            Self {
                batch: 8,
                seqlen: 128,
                d_model: 64,
                heads: 4,
                layers: 4,
                ff: 128,
                vocab: 8192,
                seed: 0,
            }
        } else {
            Self {
                batch: 4,
                seqlen: 64,
                d_model: 32,
                heads: 1,
                layers: 2,
                ff: 64,
                vocab: 1024,
                seed: 0,
            }
        }
    }
}

/// Per-layer parameter indices into [`NativeStep::params`].
struct LayerIdx {
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ln1_g: usize,
    ln1_b: usize,
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
    ln2_g: usize,
    ln2_b: usize,
    alpha: usize,
    beta: usize,
}

/// Parameter indices of the whole model.
struct ParamIdx {
    tok: usize,
    pos: usize,
    layers: Vec<LayerIdx>,
    wout: usize,
    bout: usize,
}

/// Node handles a forward pass exposes to telemetry/probing.
struct ForwardRefs {
    loss: usize,
    /// Vocab-logits node (`batch·seqlen × vocab`) — the classification
    /// readout LRA/GLUE's native degraded mode reads.
    logits: usize,
    /// Per layer: the (q, k) projection nodes.
    layer_qk: Vec<(usize, usize)>,
}

/// Node handles of one gradient-checkpointing segment's tape.
struct SegmentRefs {
    /// Leaf id of the boundary input activation — `None` for segment 0,
    /// which embeds tokens instead.
    x_in: Option<usize>,
    /// Output activation node (the next segment's boundary input).
    x_out: usize,
    /// `(global layer index, (q, k))` for the layers this segment owns.
    layer_qk: Vec<(usize, (usize, usize))>,
    /// Loss node — only on the last segment, which runs the vocab head.
    loss: Option<usize>,
}

/// Balanced contiguous `[lo, hi)` ranges: `total % parts` leading parts
/// take one extra item.  Used for both checkpoint layer segments and
/// data-parallel sequence shards.
fn balanced_ranges(total: usize, parts: usize) -> Vec<(usize, usize)> {
    let base = total / parts;
    let extra = total % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for j in 0..parts {
        let hi = lo + base + usize::from(j < extra);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// One backward pass over a token slice: loss, per-parameter gradients
/// (creation order, zeros where a parameter is unused), the telemetry
/// stats, and the largest tape held live along the way.
struct SliceRun {
    loss: f32,
    grads: Vec<Mat>,
    layer_stats: Vec<[f32; 4]>,
    head_stats: Vec<Vec<[f32; 3]>>,
    peak_bytes: usize,
}

/// [`TrainStep`] over the native backends: a multi-head RoBERTa-lite
/// MLM encoder (embed + per-layer [attention → residual → layernorm →
/// ReLU MLP → residual → layernorm] + vocab head) whose attention runs
/// through [`AttentionBackend::forward_train`] / `backward` — the
/// fused recompute kernels, one call per `(sequence, head)` slice —
/// and whose LLN alpha/beta are *learned* parameters shared across a
/// layer's heads.  Gradient checkpointing
/// ([`set_checkpoint_segments`](NativeStep::set_checkpoint_segments))
/// and data-parallel sharding
/// ([`set_data_parallel`](NativeStep::set_data_parallel)) compose and
/// both preserve the step's determinism contract.
pub struct NativeStep {
    method: Method,
    shape: NativeShape,
    base: BackendParams,
    params: Vec<Mat>,
    idx: ParamIdx,
    adam: Adam,
    steps_done: usize,
    /// `> 1`: recompute the layer stack in this many segments.
    checkpoint_segments: usize,
    /// `> 0`: shard sequences over the compute pool; fixed-order
    /// all-reduce keeps results bitwise across worker counts.
    data_parallel: usize,
}

impl NativeStep {
    /// Build a fresh model.  `Err` for methods without a native
    /// backward (Nystrom/Linformer, whose token mixing has no
    /// recompute-light cache) — train those through artifacts instead.
    pub fn new(method: Method, shape: NativeShape) -> Result<Self> {
        if matches!(method, Method::Nystrom | Method::Linformer) {
            bail!(
                "{} attention has no native backward pass; train it through AOT artifacts, or \
                 pick one of softmax/lln/lln_diag/elu/relu/quadratic/performer/blockdiag",
                method.name()
            );
        }
        assert!(shape.batch >= 1 && shape.seqlen >= 1 && shape.layers >= 1);
        assert!(shape.vocab > crate::data::special::FIRST_CONTENT as usize);
        assert!(
            shape.heads >= 1 && shape.d_model % shape.heads == 0,
            "head count must divide d_model"
        );
        let mut rng = Pcg64::new(shape.seed, 0x7A1e);
        let (d, f, v) = (shape.d_model, shape.ff, shape.vocab);
        let std = 0.02f32;
        let mut params: Vec<Mat> = Vec::new();
        let push = |params: &mut Vec<Mat>, m: Mat| -> usize {
            params.push(m);
            params.len() - 1
        };
        let tok = push(&mut params, Mat::gaussian(v, d, std, &mut rng));
        let pos = push(&mut params, Mat::gaussian(shape.seqlen, d, std, &mut rng));
        let mut layers = Vec::with_capacity(shape.layers);
        // LLN starts near the paper's trained equilibrium (fig. 9);
        // the exponents are then learned via dα/dβ.
        let alpha0 = if matches!(method, Method::Lln | Method::LlnDiag) {
            2.0
        } else {
            1.0
        };
        for _ in 0..shape.layers {
            layers.push(LayerIdx {
                wq: push(&mut params, Mat::gaussian(d, d, std, &mut rng)),
                wk: push(&mut params, Mat::gaussian(d, d, std, &mut rng)),
                wv: push(&mut params, Mat::gaussian(d, d, std, &mut rng)),
                wo: push(&mut params, Mat::gaussian(d, d, std, &mut rng)),
                ln1_g: push(&mut params, Mat::from_vec(1, d, vec![1.0; d])),
                ln1_b: push(&mut params, Mat::zeros(1, d)),
                w1: push(&mut params, Mat::gaussian(d, f, std, &mut rng)),
                b1: push(&mut params, Mat::zeros(1, f)),
                w2: push(&mut params, Mat::gaussian(f, d, std, &mut rng)),
                b2: push(&mut params, Mat::zeros(1, d)),
                ln2_g: push(&mut params, Mat::from_vec(1, d, vec![1.0; d])),
                ln2_b: push(&mut params, Mat::zeros(1, d)),
                alpha: push(&mut params, Mat::from_vec(1, 1, vec![alpha0])),
                beta: push(&mut params, Mat::from_vec(1, 1, vec![alpha0])),
            });
        }
        let wout = push(&mut params, Mat::gaussian(d, v, std, &mut rng));
        let bout = push(&mut params, Mat::zeros(1, v));
        let adam = Adam::new(&params);
        let mut base = BackendParams::default();
        if matches!(method, Method::BlockDiag | Method::LlnDiag) && shape.seqlen % base.block != 0
        {
            // The block-diagonal tile must divide the per-head sequence
            // length; fall back to the largest divisor within the
            // default tile budget.
            let mut b = base.block.min(shape.seqlen);
            while shape.seqlen % b != 0 {
                b -= 1;
            }
            base.block = b;
        }
        Ok(Self {
            method,
            shape,
            base,
            params,
            idx: ParamIdx { tok, pos, layers, wout, bout },
            adam,
            steps_done: 0,
            checkpoint_segments: 0,
            data_parallel: 0,
        })
    }

    /// Gradient checkpointing: recompute the layer stack in `segments`
    /// pieces (`<= 1` disables).  Loss and gradients stay bitwise
    /// identical to the monolithic tape — every parameter's gradient
    /// comes from exactly one segment whose op sequence matches the
    /// monolithic tape's — while peak activation memory drops from the
    /// whole-network tape to the largest segment tape.
    pub fn set_checkpoint_segments(&mut self, segments: usize) {
        self.checkpoint_segments = segments;
    }

    /// Data-parallel sharding on the persistent compute pool (`0`
    /// keeps the serial single-tape step).  Sequences are dealt to
    /// `shards` contiguous micro-batches; the gradient all-reduce runs
    /// in fixed sequence-then-parameter order, so results are bitwise
    /// across both shard and pool-worker counts.
    pub fn set_data_parallel(&mut self, shards: usize) {
        self.data_parallel = shards;
    }

    /// The model/batch dimensions this step was built with.
    pub fn shape(&self) -> &NativeShape {
        &self.shape
    }

    /// One encoder layer on the tape: multi-head attention → residual
    /// → layernorm → ReLU MLP → residual → layernorm.  Returns the
    /// output activation node and the `(q, k)` projection nodes the
    /// telemetry probes read.
    fn layer_forward(
        &self,
        tape: &mut Tape,
        x: usize,
        li: usize,
        batch: usize,
    ) -> Result<(usize, (usize, usize))> {
        let l = &self.idx.layers[li];
        let qn = tape.matmul(x, l.wq);
        let kn = tape.matmul(x, l.wk);
        let vn = tape.matmul(x, l.wv);
        let att = tape
            .attention(
                qn,
                kn,
                vn,
                l.alpha,
                l.beta,
                self.method,
                self.base,
                batch,
                self.shape.heads,
            )
            .map_err(|e| anyhow!(e))?;
        let proj = tape.matmul(att, l.wo);
        let res1 = tape.add(x, proj);
        let x1 = tape.layernorm(res1, l.ln1_g, l.ln1_b);
        let h1m = tape.matmul(x1, l.w1);
        let h1b = tape.add_bias(h1m, l.b1);
        let h1 = tape.relu(h1b);
        let h2m = tape.matmul(h1, l.w2);
        let h2 = tape.add_bias(h2m, l.b2);
        let res2 = tape.add(x1, h2);
        let out = tape.layernorm(res2, l.ln2_g, l.ln2_b);
        Ok((out, (qn, kn)))
    }

    /// Build the forward tape for one packed `(batch, seqlen)` token
    /// buffer.  Leaves the parameters at node ids `0..params.len()`
    /// (creation order), so [`Tape::backward`]'s leaf grads map back
    /// to parameters by index.
    fn forward(
        &self,
        tape: &mut Tape,
        tokens: &[i32],
        labels: &[i32],
        weights: &[f32],
        batch: usize,
    ) -> Result<ForwardRefs> {
        let n = self.shape.seqlen;
        if tokens.len() != batch * n {
            bail!(
                "native {}: {} tokens, expected {}x{}",
                self.method.name(),
                tokens.len(),
                batch,
                n
            );
        }
        for p in &self.params {
            tape.leaf(p.clone());
        }
        let mut x = tape.embed(self.idx.tok, self.idx.pos, tokens, n);
        let mut layer_qk = Vec::with_capacity(self.idx.layers.len());
        for li in 0..self.idx.layers.len() {
            let (out, qk) = self.layer_forward(tape, x, li, batch)?;
            x = out;
            layer_qk.push(qk);
        }
        let lg = tape.matmul(x, self.idx.wout);
        let logits = tape.add_bias(lg, self.idx.bout);
        let loss = tape.mlm_loss(logits, labels, weights);
        Ok(ForwardRefs { loss, logits, layer_qk })
    }

    /// Build the tape for one checkpoint segment: parameters leafed at
    /// ids `0..params.len()` (same as [`forward`](Self::forward)), then
    /// either the token embedding (segment 0) or a boundary-activation
    /// leaf, then layers `[lo, hi)`, then — on the last segment — the
    /// vocab head and loss.  Because the op sequence inside a segment
    /// matches the corresponding stretch of the monolithic tape
    /// exactly, recomputation is bitwise.
    #[allow(clippy::too_many_arguments)]
    fn segment_forward(
        &self,
        tape: &mut Tape,
        (lo, hi): (usize, usize),
        boundary: Option<&Mat>,
        tokens: &[i32],
        labels: &[i32],
        weights: &[f32],
        batch: usize,
        with_head: bool,
    ) -> Result<SegmentRefs> {
        for p in &self.params {
            tape.leaf(p.clone());
        }
        let (x_in, mut x) = match boundary {
            None => (None, tape.embed(self.idx.tok, self.idx.pos, tokens, self.shape.seqlen)),
            Some(b) => {
                let id = tape.leaf(b.clone());
                (Some(id), id)
            }
        };
        let mut layer_qk = Vec::with_capacity(hi - lo);
        for li in lo..hi {
            let (out, qk) = self.layer_forward(tape, x, li, batch)?;
            x = out;
            layer_qk.push((li, qk));
        }
        let loss = if with_head {
            let lg = tape.matmul(x, self.idx.wout);
            let logits = tape.add_bias(lg, self.idx.bout);
            Some(tape.mlm_loss(logits, labels, weights))
        } else {
            None
        };
        Ok(SegmentRefs { x_in, x_out: x, layer_qk, loss })
    }

    /// One layer's `[alpha, beta, sigma_q, sigma_k]` — the fig. 9
    /// telemetry row (alpha/beta are 0 for methods without LLN
    /// exponents, matching the AOT driver's convention).
    fn layer_stat_at(&self, tape: &Tape, li: usize, (qn, kn): (usize, usize)) -> [f32; 4] {
        let l = &self.idx.layers[li];
        let sq = vec_ops::std(tape.val(qn).data()) as f32;
        let sk = vec_ops::std(tape.val(kn).data()) as f32;
        if matches!(self.method, Method::Lln | Method::LlnDiag) {
            [self.params[l.alpha].get(0, 0), self.params[l.beta].get(0, 0), sq, sk]
        } else {
            [0.0, 0.0, sq, sk]
        }
    }

    /// Per-layer `[alpha, beta, sigma_q, sigma_k]` from a built tape.
    fn layer_stats(&self, tape: &Tape, refs: &ForwardRefs) -> Vec<[f32; 4]> {
        refs.layer_qk
            .iter()
            .enumerate()
            .map(|(li, &qk)| self.layer_stat_at(tape, li, qk))
            .collect()
    }

    /// The backend this step probes dense matrices through, with one
    /// layer's *current* alpha/beta.
    fn probe_backend(&self, li: usize) -> Box<dyn AttentionBackend> {
        let l = &self.idx.layers[li];
        backend_for(
            self.method,
            BackendParams {
                alpha: self.params[l.alpha].get(0, 0),
                beta: self.params[l.beta].get(0, 0),
                ..self.base
            },
        )
    }

    /// One layer's per-head `[entropy_nats, sigma_q, sigma_k]`, probed
    /// on the batch's first sequence through the backend's dense
    /// matrix — the dilution diagnostic from "The Devil in Linear
    /// Transformer": per-head attention entropy creeping toward
    /// `ln(seqlen)` means that head's attention is diluting.  Entropy
    /// is NaN for backends without a dense matrix.
    fn head_stat_at(&self, tape: &Tape, li: usize, (qn, kn): (usize, usize)) -> Vec<[f32; 3]> {
        let n = self.shape.seqlen;
        let heads = self.shape.heads;
        let dh = self.shape.d_model / heads;
        let qv = tape.val(qn);
        let kv = tape.val(kn);
        let backend = self.probe_backend(li);
        (0..heads)
            .map(|h| {
                let qh = slice_block(qv, 0, n, h * dh, dh);
                let kh = slice_block(kv, 0, n, h * dh, dh);
                let ent = backend
                    .explicit_matrix(&qh, &kh, &AttnSpec::FULL)
                    .map(|p| crate::stats::attention_entropy_nats(&p) as f32)
                    .unwrap_or(f32::NAN);
                [ent, vec_ops::std(qh.data()) as f32, vec_ops::std(kh.data()) as f32]
            })
            .collect()
    }

    /// Per-layer, per-head telemetry rows from a built tape.
    fn head_stats(&self, tape: &Tape, refs: &ForwardRefs) -> Vec<Vec<[f32; 3]>> {
        refs.layer_qk
            .iter()
            .enumerate()
            .map(|(li, &qk)| self.head_stat_at(tape, li, qk))
            .collect()
    }

    /// Per-layer `(attention matrix, (sigma_q, sigma_k))` for a single
    /// probe sequence of `seqlen` tokens — the native fig. 1 probe
    /// (dense matrices come from the backend's `explicit_matrix` with
    /// the layer's *current* alpha/beta).
    pub fn probe_layers(&self, tokens: &[i32]) -> Result<Vec<(Mat, (f64, f64))>> {
        let n = self.shape.seqlen;
        if tokens.len() != n {
            bail!("probe wants one sequence of {n} tokens, got {}", tokens.len());
        }
        let mut tape = Tape::new();
        let weights = vec![0.0f32; n];
        let refs = self.forward(&mut tape, tokens, tokens, &weights, 1)?;
        let mut out = Vec::with_capacity(self.idx.layers.len());
        for (li, &(qn, kn)) in refs.layer_qk.iter().enumerate() {
            let q = tape.val(qn);
            let k = tape.val(kn);
            let p = self
                .probe_backend(li)
                .explicit_matrix(q, k, &AttnSpec::FULL)
                .ok_or_else(|| anyhow!("{} has no dense matrix to probe", self.method.name()))?;
            out.push((p, (vec_ops::std(q.data()), vec_ops::std(k.data()))));
        }
        Ok(out)
    }

    /// Per-layer, per-head `(attention matrix, (sigma_q, sigma_k))`
    /// for a single probe sequence — the multi-head fig. 1 probe.
    /// With `heads == 1` this is [`probe_layers`](Self::probe_layers)
    /// wrapped in one-element rows.
    pub fn probe_heads(&self, tokens: &[i32]) -> Result<Vec<Vec<(Mat, (f64, f64))>>> {
        let n = self.shape.seqlen;
        if tokens.len() != n {
            bail!("probe wants one sequence of {n} tokens, got {}", tokens.len());
        }
        let heads = self.shape.heads;
        let dh = self.shape.d_model / heads;
        let mut tape = Tape::new();
        let weights = vec![0.0f32; n];
        let refs = self.forward(&mut tape, tokens, tokens, &weights, 1)?;
        let mut out = Vec::with_capacity(self.idx.layers.len());
        for (li, &(qn, kn)) in refs.layer_qk.iter().enumerate() {
            let backend = self.probe_backend(li);
            let mut per_head = Vec::with_capacity(heads);
            for h in 0..heads {
                let qh = slice_block(tape.val(qn), 0, n, h * dh, dh);
                let kh = slice_block(tape.val(kn), 0, n, h * dh, dh);
                let p = backend
                    .explicit_matrix(&qh, &kh, &AttnSpec::FULL)
                    .ok_or_else(|| anyhow!("{} has no dense matrix to probe", self.method.name()))?;
                per_head.push((p, (vec_ops::std(qh.data()), vec_ops::std(kh.data()))));
            }
            out.push(per_head);
        }
        Ok(out)
    }

    /// Forward-only vocab logits for a packed `(batch, seqlen)` token
    /// buffer (row `s·seqlen + p` holds position `p` of sequence `s`)
    /// — the readout the native LRA/GLUE degraded mode classifies
    /// with.
    pub fn eval_logits(&self, tokens: &[i32], batch: usize) -> Result<Mat> {
        let rows = batch * self.shape.seqlen;
        let labels = vec![0i32; rows];
        let weights = vec![0.0f32; rows];
        let mut tape = Tape::new();
        let refs = self.forward(&mut tape, tokens, &labels, &weights, batch)?;
        Ok(tape.val(refs.logits).clone())
    }

    /// Collect leaf gradients into dense per-parameter mats (creation
    /// order; zeros where the root did not depend on the parameter).
    fn collect_grads(&self, grads: &mut [Option<Mat>]) -> Vec<Mat> {
        self.params
            .iter()
            .enumerate()
            .map(|(i, p)| grads[i].take().unwrap_or_else(|| Mat::zeros(p.rows(), p.cols())))
            .collect()
    }

    /// Loss + gradients for one token slice, seeded with `seed` as the
    /// root cotangent (1.0 for a whole-batch step; a shard's loss
    /// weight under data parallelism).  Dispatches to the monolithic
    /// single-tape path or the gradient-checkpointed multi-tape path;
    /// both produce bitwise-identical results.
    fn run_slice(
        &self,
        tokens: &[i32],
        labels: &[i32],
        weights: &[f32],
        batch: usize,
        seed: f32,
        want_stats: bool,
    ) -> Result<SliceRun> {
        let nseg = self.checkpoint_segments.min(self.shape.layers);
        if nseg > 1 {
            return self.run_checkpointed(tokens, labels, weights, batch, seed, want_stats, nseg);
        }
        let mut tape = Tape::new();
        let refs = self.forward(&mut tape, tokens, labels, weights, batch)?;
        let loss = tape.val(refs.loss).get(0, 0);
        let peak_bytes = tape.val_bytes();
        let (layer_stats, head_stats) = if want_stats {
            (self.layer_stats(&tape, &refs), self.head_stats(&tape, &refs))
        } else {
            (Vec::new(), Vec::new())
        };
        let mut grads = tape.backward_with(refs.loss, Mat::from_vec(1, 1, vec![seed]));
        let grads = self.collect_grads(&mut grads);
        Ok(SliceRun { loss, grads, layer_stats, head_stats, peak_bytes })
    }

    /// The gradient-checkpointed slice run: phase 1 walks the segments
    /// forward, stashing each boundary activation; phase 2 walks them
    /// in reverse, rebuilding each segment's tape (recompute) and
    /// chaining the boundary cotangent backwards.  Every parameter
    /// belongs to exactly one segment whose op sequence matches the
    /// monolithic tape's stretch, so loss and gradients are bitwise
    /// identical to the unsegmented run; only the peak live tape
    /// shrinks.
    #[allow(clippy::too_many_arguments)]
    fn run_checkpointed(
        &self,
        tokens: &[i32],
        labels: &[i32],
        weights: &[f32],
        batch: usize,
        seed: f32,
        want_stats: bool,
        nseg: usize,
    ) -> Result<SliceRun> {
        let nlayers = self.shape.layers;
        let bounds = balanced_ranges(nlayers, nseg);
        let mut peak_bytes = 0usize;
        // Phase 1: forward, stashing each later segment's input.
        let mut boundaries: Vec<Mat> = Vec::with_capacity(nseg - 1);
        for j in 0..nseg - 1 {
            let mut tape = Tape::new();
            let prev = if j == 0 {
                None
            } else {
                Some(&boundaries[j - 1])
            };
            let seg = self.segment_forward(
                &mut tape,
                bounds[j],
                prev,
                tokens,
                labels,
                weights,
                batch,
                false,
            )?;
            peak_bytes = peak_bytes.max(tape.val_bytes());
            boundaries.push(tape.val(seg.x_out).clone());
        }
        // Phase 2: reverse sweep with recompute.
        let mut loss = 0.0f32;
        let mut layer_stats = vec![[0.0f32; 4]; if want_stats { nlayers } else { 0 }];
        let mut head_stats = vec![Vec::new(); if want_stats { nlayers } else { 0 }];
        let mut gmats: Vec<Option<Mat>> = (0..self.params.len()).map(|_| None).collect();
        let mut cot: Option<Mat> = None;
        for j in (0..nseg).rev() {
            let mut tape = Tape::new();
            let prev = if j == 0 {
                None
            } else {
                Some(&boundaries[j - 1])
            };
            let last = j == nseg - 1;
            let seg = self.segment_forward(
                &mut tape,
                bounds[j],
                prev,
                tokens,
                labels,
                weights,
                batch,
                last,
            )?;
            peak_bytes = peak_bytes.max(tape.val_bytes());
            if want_stats {
                for &(li, qk) in &seg.layer_qk {
                    layer_stats[li] = self.layer_stat_at(&tape, li, qk);
                    head_stats[li] = self.head_stat_at(&tape, li, qk);
                }
            }
            let mut grads = if let Some(ln) = seg.loss {
                loss = tape.val(ln).get(0, 0);
                tape.backward_with(ln, Mat::from_vec(1, 1, vec![seed]))
            } else {
                tape.backward_with(seg.x_out, cot.take().expect("boundary cotangent"))
            };
            if j > 0 {
                let xid = seg.x_in.expect("segment > 0 reads a boundary leaf");
                cot = Some(grads[xid].take().expect("boundary leaf gradient"));
            }
            for (slot, g) in gmats.iter_mut().zip(grads.iter_mut().take(self.params.len())) {
                let Some(g) = g.take() else { continue };
                match slot.as_mut() {
                    Some(acc) => {
                        for (a, &x) in acc.data_mut().iter_mut().zip(g.data()) {
                            *a += x;
                        }
                    }
                    None => *slot = Some(g),
                }
            }
        }
        let grads = self
            .params
            .iter()
            .zip(gmats)
            .map(|(p, g)| g.unwrap_or_else(|| Mat::zeros(p.rows(), p.cols())))
            .collect();
        Ok(SliceRun { loss, grads, layer_stats, head_stats, peak_bytes })
    }

    /// The data-parallel step body: deal the batch's sequences to
    /// `data_parallel` contiguous shards, run each shard's per-sequence
    /// slices on the persistent compute pool, then all-reduce in fixed
    /// sequence-then-parameter order.  Each sequence's math is
    /// self-contained (its loss is seeded with `wsum_seq / wsum_total`,
    /// reproducing the whole-batch MLM normalization), so the result
    /// is bitwise no matter how many shards or pool workers ran it.
    fn run_data_parallel(&self, batch: &MlmBatch) -> Result<SliceRun> {
        let (b, n) = (batch.batch, self.shape.seqlen);
        let wsum_tot = batch.weights.iter().map(|&w| w as f64).sum::<f64>().max(1e-12);
        let shards = self.data_parallel.min(b).max(1);
        let mut slots: Vec<Option<Result<SliceRun>>> = (0..b).map(|_| None).collect();
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(shards);
            let mut rest = slots.as_mut_slice();
            for &(lo, hi) in &balanced_ranges(b, shards) {
                let (win, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
                rest = tail;
                let toks = &batch.tokens[lo * n..hi * n];
                let labs = &batch.labels[lo * n..hi * n];
                let wts = &batch.weights[lo * n..hi * n];
                tasks.push(Box::new(move || {
                    for (i, slot) in win.iter_mut().enumerate() {
                        let wr = &wts[i * n..(i + 1) * n];
                        let wsum_s: f64 = wr.iter().map(|&w| w as f64).sum();
                        let seed = (wsum_s / wsum_tot) as f32;
                        *slot = Some(self.run_slice(
                            &toks[i * n..(i + 1) * n],
                            &labs[i * n..(i + 1) * n],
                            wr,
                            1,
                            seed,
                            lo + i == 0,
                        ));
                    }
                }));
            }
            crate::util::compute_pool::scope(tasks);
        }
        // Fixed-order all-reduce: sequence order, then parameter
        // order.  The reduction sees the same addend sequence however
        // the shards were scheduled.
        let mut agg: Option<SliceRun> = None;
        let mut loss_acc = 0.0f64;
        for (s, slot) in slots.into_iter().enumerate() {
            let run = slot.expect("data-parallel shard ran")?;
            let wsum_s: f64 =
                batch.weights[s * n..(s + 1) * n].iter().map(|&w| w as f64).sum();
            loss_acc += (wsum_s / wsum_tot) * run.loss as f64;
            match agg.as_mut() {
                None => agg = Some(run),
                Some(a) => {
                    for (ag, g) in a.grads.iter_mut().zip(&run.grads) {
                        for (x, &y) in ag.data_mut().iter_mut().zip(g.data()) {
                            *x += y;
                        }
                    }
                    a.peak_bytes = a.peak_bytes.max(run.peak_bytes);
                }
            }
        }
        let mut agg = agg.expect("batch holds at least one sequence");
        agg.loss = loss_acc as f32;
        Ok(agg)
    }
}

impl TrainStep for NativeStep {
    fn name(&self) -> String {
        format!(
            "native:{} (L={} d={} h={} ff={} vocab={})",
            self.method.name(),
            self.shape.layers,
            self.shape.d_model,
            self.shape.heads,
            self.shape.ff,
            self.shape.vocab
        )
    }
    fn batch_shape(&self) -> (usize, usize) {
        (self.shape.batch, self.shape.seqlen)
    }
    fn vocab(&self) -> usize {
        self.shape.vocab
    }

    fn step(&mut self, lr: f64, batch: &MlmBatch) -> Result<StepTelemetry> {
        let run = if self.data_parallel > 0 {
            self.run_data_parallel(batch)?
        } else {
            self.run_slice(
                &batch.tokens,
                &batch.labels,
                &batch.weights,
                batch.batch,
                1.0,
                true,
            )?
        };
        if !run.loss.is_finite() {
            bail!("native {}: non-finite loss at step {}", self.method.name(), self.steps_done + 1);
        }
        let mut gnorm2 = 0.0f64;
        for g in &run.grads {
            gnorm2 += g.data().iter().map(|&x| x as f64 * x as f64).sum::<f64>();
        }
        self.adam.step(&mut self.params, &run.grads, lr);
        self.steps_done += 1;
        Ok(StepTelemetry {
            step: self.steps_done,
            loss: run.loss,
            grad_norm: gnorm2.sqrt() as f32,
            layer_stats: run.layer_stats,
            head_stats: run.head_stats,
            peak_bytes: run.peak_bytes,
        })
    }

    fn eval_loss(&mut self, batch: &MlmBatch) -> Result<f32> {
        let mut tape = Tape::new();
        let refs =
            self.forward(&mut tape, &batch.tokens, &batch.labels, &batch.weights, batch.batch)?;
        Ok(tape.val(refs.loss).get(0, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;

    fn tiny_shape() -> NativeShape {
        NativeShape {
            batch: 2,
            seqlen: 32,
            d_model: 16,
            heads: 1,
            layers: 1,
            ff: 32,
            vocab: 256,
            seed: 3,
        }
    }

    /// Finite-difference check of one tape op pipeline: perturb a leaf
    /// coordinate, compare the loss delta against the tape gradient.
    fn tape_fd_check(build: impl Fn(&mut Tape, &[Mat]) -> usize, leaves: Vec<Mat>, tol: f32) {
        let mut tape = Tape::new();
        for l in &leaves {
            tape.leaf(l.clone());
        }
        let loss = build(&mut tape, &leaves);
        assert_eq!(tape.val(loss).shape(), (1, 1));
        let grads = tape.backward(loss);
        let h = 1e-2f32;
        for (li, leaf) in leaves.iter().enumerate() {
            let g = grads[li].as_ref().expect("leaf grad");
            // Spot-check a few coordinates per leaf.
            for ci in 0..leaf.data().len().min(3) {
                let fd = {
                    let run = |delta: f32| {
                        let mut tape2 = Tape::new();
                        for (j, l) in leaves.iter().enumerate() {
                            let mut m = l.clone();
                            if j == li {
                                m.data_mut()[ci] += delta;
                            }
                            tape2.leaf(m);
                        }
                        let id = build(&mut tape2, &leaves);
                        tape2.val(id).get(0, 0)
                    };
                    (run(h) - run(-h)) / (2.0 * h)
                };
                let got = g.data()[ci];
                assert!(
                    (got - fd).abs() <= tol * (1.0 + fd.abs()),
                    "leaf {li} coord {ci}: tape {got} vs fd {fd}"
                );
            }
        }
    }

    #[test]
    fn tape_matmul_layernorm_chain_matches_finite_differences() {
        let mut rng = Pcg64::seed(11);
        let a = Mat::gaussian(3, 4, 0.7, &mut rng);
        let b = Mat::gaussian(4, 4, 0.7, &mut rng);
        let g = Mat::from_vec(1, 4, vec![1.1, 0.9, 1.0, 1.2]);
        let s = Mat::zeros(1, 4);
        tape_fd_check(
            |tape, _| {
                // leaves: a, b, g, s (ids 0..4).  Smooth ops only — a
                // ReLU kink near zero would poison the central
                // differences; relu is covered by the training tests.
                let m = tape.matmul(0, 1);
                let ln = tape.layernorm(m, 2, 3);
                let bias = tape.add_bias(ln, 3);
                // Reduce to a scalar via mlm_loss over 3 "classes"-wide rows.
                tape.mlm_loss(bias, &[0, 1, 2], &[1.0, 0.5, 1.0])
            },
            vec![a, b, g, s],
            5e-2,
        );
    }

    #[test]
    fn tape_embed_scatter_accumulates() {
        let mut tape = Tape::new();
        let table = tape.leaf(Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        let pos = tape.leaf(Mat::zeros(2, 2));
        let x = tape.embed(table, pos, &[1, 1, 2, 1], 2);
        assert_eq!(tape.val(x).row(0), &[3.0, 4.0]);
        // Scalarize: sum everything via a weighted loss surrogate —
        // use mlm_loss with uniform labels for a quick backward.
        let loss = tape.mlm_loss(x, &[0, 0, 0, 0], &[1.0; 4]);
        let grads = tape.backward(loss);
        let dt = grads[table].as_ref().unwrap();
        // Token 1 appears 3x, token 2 once, token 0 never.
        assert!(dt.row(0).iter().all(|&v| v == 0.0));
        assert!(dt.row(1).iter().any(|&v| v != 0.0));
        assert!(dt.row(2).iter().any(|&v| v != 0.0));
        let dp = grads[pos].as_ref().unwrap();
        assert_eq!(dp.shape(), (2, 2));
    }

    #[test]
    fn native_training_reduces_loss_for_softmax_and_lln() {
        for method in [Method::Softmax, Method::Lln] {
            let mut step = NativeStep::new(method, tiny_shape()).unwrap();
            let (b, n) = step.batch_shape();
            let mut corpus = Corpus::new(step.vocab(), 5);
            let mut first = None;
            let mut last = 0.0f32;
            for _ in 0..12 {
                let batch = corpus.mlm_batch(b, n, 0.15);
                let out = step.step(2e-2, &batch).unwrap();
                assert!(out.loss.is_finite() && out.grad_norm.is_finite(), "{method:?}");
                assert!(out.grad_norm > 0.0, "{method:?}: zero grad norm");
                if first.is_none() {
                    first = Some(out.loss);
                }
                last = out.loss;
            }
            let first = first.unwrap();
            assert!(
                last < first - 0.05,
                "{method:?}: loss should drop: first={first} last={last}"
            );
        }
    }

    #[test]
    fn lln_alpha_beta_are_learned() {
        let mut step = NativeStep::new(Method::Lln, tiny_shape()).unwrap();
        let (b, n) = step.batch_shape();
        let mut corpus = Corpus::new(step.vocab(), 9);
        let init = step.params[step.idx.layers[0].alpha].get(0, 0);
        let mut tel = None;
        for _ in 0..8 {
            let batch = corpus.mlm_batch(b, n, 0.15);
            tel = Some(step.step(5e-2, &batch).unwrap());
        }
        let now = step.params[step.idx.layers[0].alpha].get(0, 0);
        assert!(now != init, "alpha never moved: {init} -> {now}");
        let tel = tel.unwrap();
        assert_eq!(tel.layer_stats.len(), 1);
        assert!(tel.layer_stats[0][0] > 0.0, "telemetry must carry alpha");
        assert!(tel.layer_stats[0][2] > 0.0, "telemetry must carry sigma_q");
    }

    #[test]
    fn eval_loss_is_deterministic_and_step_count_advances() {
        let mut step = NativeStep::new(Method::Softmax, tiny_shape()).unwrap();
        let (b, n) = step.batch_shape();
        let mut corpus = Corpus::new(step.vocab(), 6);
        let batch = corpus.mlm_batch(b, n, 0.15);
        let a = step.eval_loss(&batch).unwrap();
        let b2 = step.eval_loss(&batch).unwrap();
        assert_eq!(a, b2);
        step.step(1e-3, &batch).unwrap();
        let c = step.eval_loss(&batch).unwrap();
        assert_ne!(a, c, "a step must change the model");
    }

    #[test]
    fn native_step_rejects_untrainable_methods() {
        for m in [Method::Nystrom, Method::Linformer] {
            let err = NativeStep::new(m, tiny_shape()).unwrap_err();
            assert!(format!("{err}").contains("backward"), "{m:?}");
        }
    }

    #[test]
    fn every_trainable_method_steps_natively() {
        // The full backward matrix: every non-Nystrom/Linformer method
        // builds, steps, and produces finite telemetry — including the
        // three that used to be artifact-only (lln_diag, performer,
        // blockdiag).
        for m in [
            Method::Softmax,
            Method::Lln,
            Method::LlnDiag,
            Method::Elu,
            Method::Relu,
            Method::Quadratic,
            Method::Performer,
            Method::BlockDiag,
        ] {
            let mut step = NativeStep::new(m, tiny_shape()).unwrap();
            let (b, n) = step.batch_shape();
            let mut corpus = Corpus::new(step.vocab(), 13);
            let batch = corpus.mlm_batch(b, n, 0.15);
            let out = step.step(1e-2, &batch).unwrap();
            assert!(out.loss.is_finite() && out.grad_norm > 0.0, "{m:?}");
            assert!(out.peak_bytes > 0, "{m:?}: peak tape bytes missing");
        }
    }

    #[test]
    fn multi_head_attention_matches_finite_differences() {
        // Tape-level check of the multi-head op: 2 heads over d=4
        // (per-head width 2), softmax per head, scalarized through the
        // MLM loss.
        let mut rng = Pcg64::seed(21);
        let q = Mat::gaussian(6, 4, 0.6, &mut rng);
        let k = Mat::gaussian(6, 4, 0.6, &mut rng);
        let v = Mat::gaussian(6, 4, 0.6, &mut rng);
        let a = Mat::from_vec(1, 1, vec![1.0]);
        let b = Mat::from_vec(1, 1, vec![1.0]);
        tape_fd_check(
            |tape, _| {
                let att = tape
                    .attention(0, 1, 2, 3, 4, Method::Softmax, BackendParams::default(), 1, 2)
                    .unwrap();
                tape.mlm_loss(att, &[0, 1, 2, 3, 0, 1], &[1.0, 0.5, 1.0, 0.25, 1.0, 0.5])
            },
            vec![q, k, v, a, b],
            5e-2,
        );
    }

    #[test]
    fn multi_head_training_reduces_loss_and_reports_heads() {
        let mut shape = tiny_shape();
        shape.heads = 4;
        let mut step = NativeStep::new(Method::Lln, shape).unwrap();
        let (b, n) = step.batch_shape();
        let mut corpus = Corpus::new(step.vocab(), 17);
        let mut first = None;
        let mut tel = None;
        for _ in 0..12 {
            let batch = corpus.mlm_batch(b, n, 0.15);
            let out = step.step(2e-2, &batch).unwrap();
            if first.is_none() {
                first = Some(out.loss);
            }
            tel = Some(out);
        }
        let (first, tel) = (first.unwrap(), tel.unwrap());
        assert!(tel.loss < first - 0.05, "multi-head loss should drop: {first} -> {}", tel.loss);
        assert_eq!(tel.head_stats.len(), 1, "one layer of head telemetry");
        assert_eq!(tel.head_stats[0].len(), 4, "one row per head");
        let ln_n = (n as f32).ln();
        for hs in &tel.head_stats[0] {
            assert!(hs[0].is_finite() && hs[0] > 0.0 && hs[0] <= ln_n + 1e-3, "entropy {hs:?}");
            assert!(hs[1] > 0.0 && hs[2] > 0.0, "per-head sigma {hs:?}");
        }
        // Per-head probe: one dense stochastic matrix per (layer, head).
        let tokens = corpus.mlm_batch(1, n, 0.0).labels;
        let probed = step.probe_heads(&tokens).unwrap();
        assert_eq!(probed.len(), 1);
        assert_eq!(probed[0].len(), 4);
        for (p, (sq, sk)) in &probed[0] {
            assert_eq!(p.shape(), (n, n));
            assert!(p.is_stochastic(1e-3));
            assert!(*sq > 0.0 && *sk > 0.0);
        }
    }

    #[test]
    fn checkpointing_and_data_parallelism_are_bitwise() {
        // One deep-ish shape; five configurations that must agree
        // bit-for-bit: serial monolithic vs checkpointed, and
        // data-parallel at 1/2/4 shards with and without
        // checkpointing.  (The pool's fixed-order all-reduce makes
        // shard/worker count invisible; checkpointed segments replay
        // the exact monolithic op sequence per parameter.)
        let shape = NativeShape {
            batch: 4,
            seqlen: 32,
            d_model: 16,
            heads: 2,
            layers: 2,
            ff: 32,
            vocab: 256,
            seed: 5,
        };
        let configs: [(usize, usize); 5] = [(0, 2), (1, 0), (2, 0), (4, 0), (2, 2)];
        let mut steps: Vec<NativeStep> = configs
            .iter()
            .map(|&(dp, ckpt)| {
                let mut s = NativeStep::new(Method::Lln, shape).unwrap();
                s.set_data_parallel(dp);
                s.set_checkpoint_segments(ckpt);
                s
            })
            .collect();
        // The serial-monolithic reference only agrees with the others
        // when the batch is a single sequence (the data-parallel loss
        // is reduced per sequence); per-slice bitwise parity of the
        // checkpointed path is what config (0, 2) pins against it.
        let mut reference = NativeStep::new(Method::Lln, shape).unwrap();
        let mut corpus = Corpus::new(reference.vocab(), 23);
        for _ in 0..3 {
            let batch = corpus.mlm_batch(shape.batch, shape.seqlen, 0.15);
            let base = reference.step(1e-2, &batch).unwrap();
            let ckpt_tel = steps[0].step(1e-2, &batch).unwrap();
            // Checkpointed-vs-monolithic: bitwise loss, grad norm, and
            // parameters, with a strictly smaller peak tape.
            assert_eq!(base.loss.to_bits(), ckpt_tel.loss.to_bits(), "ckpt loss drifted");
            assert_eq!(
                base.grad_norm.to_bits(),
                ckpt_tel.grad_norm.to_bits(),
                "ckpt grad_norm drifted"
            );
            assert!(
                ckpt_tel.peak_bytes < base.peak_bytes,
                "checkpointing must shrink the peak tape: {} !< {}",
                ckpt_tel.peak_bytes,
                base.peak_bytes
            );
            for (p, q) in reference.params.iter().zip(&steps[0].params) {
                assert_eq!(p.data(), q.data(), "ckpt params drifted");
            }
            // Data-parallel shard counts 1/2/4 (and ckpt on top): all
            // bitwise identical to each other.
            let tels: Vec<StepTelemetry> =
                steps[1..].iter_mut().map(|s| s.step(1e-2, &batch).unwrap()).collect();
            for (i, t) in tels.iter().enumerate().skip(1) {
                assert_eq!(tels[0].loss.to_bits(), t.loss.to_bits(), "dp loss config {i}");
                assert_eq!(
                    tels[0].grad_norm.to_bits(),
                    t.grad_norm.to_bits(),
                    "dp grad_norm config {i}"
                );
            }
            for i in 2..configs.len() {
                for (p, q) in steps[1].params.iter().zip(&steps[i].params) {
                    assert_eq!(p.data(), q.data(), "dp params drifted (config {i})");
                }
            }
        }
    }

    #[test]
    fn probe_layers_returns_stochastic_matrices() {
        let step = NativeStep::new(Method::Softmax, tiny_shape()).unwrap();
        let mut corpus = Corpus::new(step.vocab(), 7);
        let tokens = corpus.mlm_batch(1, 32, 0.0).labels;
        let probed = step.probe_layers(&tokens).unwrap();
        assert_eq!(probed.len(), 1);
        let (p, (sq, sk)) = &probed[0];
        assert_eq!(p.shape(), (32, 32));
        assert!(p.is_stochastic(1e-3));
        assert!(*sq > 0.0 && *sk > 0.0);
    }
}
