//! Serving experiment: drive the coordinator with an open-loop request
//! stream and report throughput / latency / batching efficiency —
//! the deployment-side payoff of linear attention (long-sequence
//! batches SA could not schedule at the same cost).

use std::time::{Duration, Instant};

use anyhow::Result;

use super::maybe_write_csv;
use crate::cli::Args;
use crate::config::{ConfigTable, ServeConfig};
use crate::coordinator::Coordinator;
use crate::data::tasks::{GlueGen, GlueTask};
use crate::rng::Pcg64;
use crate::runtime::{artifacts_available, artifacts_dir};
use crate::util::print_table;

pub fn run_serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let requests = args.get_usize("requests", 200)?;
    let methods = args.get_list("methods", "softmax,lln_diag");
    let rate = args.get_f64("rate", 200.0)?; // requests/second offered
    let long_frac = args.get_f64("long-frac", 0.3)?;
    // Causal (decoder-mask) traffic: --causal masks every request,
    // --causal-frac mixes a fraction into the stream.
    let causal_all = args.get_bool("causal");
    let causal_frac =
        if causal_all { 1.0 } else { args.get_f64("causal-frac", 0.0)?.clamp(0.0, 1.0) };
    // Streaming decode sessions: --sessions opens N concurrent
    // token-by-token sessions per method and streams --decode-tokens
    // through each, co-batched with the prefill traffic's buckets.
    let sessions = args.get_usize("sessions", 0)?;
    let decode_tokens = args.get_usize("decode-tokens", 48)?.max(1);

    println!(
        "== Serving: coordinator throughput/latency ({requests} reqs, {rate}/s offered, {:.0}% long, {:.0}% causal) ==\n",
        long_frac * 100.0,
        causal_frac * 100.0
    );
    // --config wires the [serve] / [compute] sections (queue, batching,
    // workers-per-bucket, kernel threads) into the coordinator.
    let base_cfg = match args.get("config") {
        Some(path) => ServeConfig::from_table(&ConfigTable::load(std::path::Path::new(path))?),
        None => ServeConfig::default(),
    };
    // Experiment harness (not production serving): explicitly opt into
    // the native-backend encoder when AOT artifacts are absent so the
    // coordinator pipeline is still measurable.  Causal traffic forces
    // the native path outright (`force_native`) — the AOT serve
    // executables are compiled as full bidirectional attention.
    let native = base_cfg.native_fallback || !artifacts_available(&dir);
    let force_native = base_cfg.force_native || causal_frac > 0.0 || sessions > 0;
    if !artifacts_available(&dir) {
        println!("(artifacts absent: serving via the native AttentionBackend encoder)\n");
    } else if force_native {
        println!("(causal/decode traffic requested: serving via the native AttentionBackend encoder)\n");
    }
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for method in &methods {
        let cfg = ServeConfig {
            method: method.clone(),
            native_fallback: native,
            force_native,
            ..base_cfg.clone()
        };
        let coord = Coordinator::start(cfg, &dir)?;
        // Warm both buckets (compile once) before timing.
        coord.infer(vec![crate::data::special::CLS; 64])?;
        coord.infer(vec![crate::data::special::CLS; 300])?;

        let mut gen_short = GlueGen::new(GlueTask::Sst2, 512, 120, 1);
        let mut gen_long = GlueGen::new(GlueTask::Qnli, 512, 480, 2);
        let mut rng = Pcg64::seed(3);
        let interval = Duration::from_secs_f64(1.0 / rate);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(requests);
        let mut rejected = 0usize;
        for i in 0..requests {
            let tokens = if rng.f64() < long_frac {
                gen_long.example().0
            } else {
                gen_short.example().0
            };
            let causal = causal_frac > 0.0 && rng.f64() < causal_frac;
            match coord.submit_with(tokens, causal) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
            // Open-loop pacing.
            let target = t0 + interval * (i as u32 + 1);
            if let Some(sleep) = target.checked_duration_since(Instant::now()) {
                std::thread::sleep(sleep);
            }
        }
        let mut latencies = Vec::with_capacity(rxs.len());
        for rx in rxs {
            let resp = rx.recv()?;
            latencies.push(resp.latency_ms);
        }
        let wall = t0.elapsed().as_secs_f64();
        // Snapshot the prefill-phase stats before any decode-session
        // traffic lands: the shared latency buffer would otherwise mix
        // sub-millisecond decode-step latencies into the prefill
        // percentiles.
        let (prefill_completed, p50, p95, mean_batch) = {
            let stats_arc = coord.stats();
            let st = stats_arc.lock().unwrap();
            (st.completed, st.p50_latency(), st.p95_latency(), st.mean_batch_size())
        };

        // Streaming decode sessions, co-batched through the same
        // coordinator: open N sessions, pipeline decode_tokens through
        // each, and drain the streams (tokens arrive as they decode).
        let decode_cell = if sessions == 0 {
            "-".to_string()
        } else if !crate::attention::Method::parse(method)
            .map(|m| m.supports_masking())
            .unwrap_or(false)
        {
            "n/a".to_string()
        } else {
            let d0 = Instant::now();
            let mut handles = Vec::new();
            let mut streams = Vec::new();
            for s in 0..sessions {
                let mut session = coord.open_session(decode_tokens)?;
                let toks: Vec<i32> =
                    (0..decode_tokens).map(|i| 4 + ((s * 31 + i) % 97) as i32).collect();
                streams.push(session.stream(&toks)?);
                handles.push(session);
            }
            let mut streamed = 0usize;
            for rx in &streams {
                for _ in 0..decode_tokens {
                    if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
                        streamed += 1;
                    }
                }
            }
            for s in handles {
                s.close();
            }
            let tok_s = streamed as f64 / d0.elapsed().as_secs_f64();
            format!("{tok_s:.0}")
        };

        let throughput = prefill_completed as f64 / wall;
        rows.push(vec![
            method.to_string(),
            format!("{throughput:.1}"),
            format!("{p50:.1}"),
            format!("{p95:.1}"),
            format!("{mean_batch:.2}"),
            format!("{rejected}"),
            decode_cell.clone(),
        ]);
        csv.push(format!("{method},{throughput},{p50},{p95},{mean_batch},{rejected},{decode_cell}"));
        coord.shutdown();
    }
    print_table(
        &[
            "method",
            "throughput [req/s]",
            "p50 [ms]",
            "p95 [ms]",
            "mean batch",
            "rejected",
            "decode [tok/s]",
        ],
        &rows,
    );
    println!("\nshape: lln_diag sustains long-sequence traffic at lower p95 than");
    println!("softmax (quadratic N=512 forwards dominate SA's tail).");
    maybe_write_csv(args, "serve", "method,throughput,p50,p95,mean_batch,rejected,decode_tok_s", &csv)?;
    Ok(())
}
