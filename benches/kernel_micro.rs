//! Bench: native Rust attention kernels (the analysis hot path) across
//! methods and sequence lengths — tracks the §Perf L3-native numbers.

use lln::attention as att;
use lln::bench::Bench;
use lln::rng::Pcg64;
use lln::tensor::Mat;

fn main() {
    let d = 64usize;
    let mut rng = Pcg64::seed(1);
    let mut b = Bench::new();

    println!("== native attention kernels (d={d}) ==");
    for n in [256usize, 1024, 4096] {
        let q = Mat::gaussian(n, d, 1.0, &mut rng);
        let k = Mat::gaussian(n, d, 1.0, &mut rng);
        let v = Mat::gaussian(n, d, 1.0, &mut rng);
        b.run(&format!("native softmax n={n}"), n as f64, || att::softmax_attention(&q, &k, &v));
        b.run(&format!("native lln n={n}"), n as f64, || att::lln_attention(&q, &k, &v, 2.2, 2.2));
        b.run(&format!("native lln_diag n={n}"), n as f64, || {
            att::lln_diag_attention(&q, &k, &v, 2.2, 2.2, 64)
        });
        b.run(&format!("native elu n={n}"), n as f64, || att::elu_attention(&q, &k, &v));
        if n <= 1024 {
            b.run(&format!("native nystrom n={n}"), n as f64, || {
                att::nystrom_attention(&q, &k, &v, 32)
            });
        }
    }

    println!("\n== analysis instruments (N x N stochastic matrices) ==");
    for n in [128usize, 256] {
        let q = Mat::gaussian(n, d, 1.0, &mut rng);
        let k = Mat::gaussian(n, d, 1.0, &mut rng);
        let p = att::softmax_attention_matrix(&q, &k);
        b.run(&format!("entropy n={n}"), 1.0, || lln::stats::attention_entropy(&p));
        b.run(&format!("spectral_gap n={n}"), 1.0, || lln::linalg::spectral_gap(&p, 400, 1e-8));
        b.run(&format!("log_variance n={n}"), 1.0, || lln::stats::log_variance(&p, 1e-30));
    }
}
