//! Bench: paper Table 2 — time per attention forward vs sequence length,
//! through the AOT PJRT kernels.  `cargo bench --bench attention_scaling`.

use lln::bench::Bench;
use lln::rng::Pcg64;
use lln::runtime::{artifacts_available, artifacts_dir, Engine, HostTensor};

fn main() {
    let dir = artifacts_dir(None);
    if !artifacts_available(&dir) {
        println!("artifacts not built — run `make artifacts` first; skipping");
        return;
    }
    let mut engine = Engine::new(&dir).expect("engine");
    let mut rng = Pcg64::seed(0);
    let d = 64usize;
    let mut b = Bench::new();

    println!("== Table 2 bench: AOT attention kernels (PJRT CPU, d={d}) ==");
    for method in ["softmax", "lln", "lln_diag", "elu", "performer", "nystrom"] {
        for n in [256usize, 1024, 4096, 8192, 16384] {
            let name = format!("attn_{method}_n{n}");
            if engine.manifest().artifact(&name).is_err() {
                println!("{name:<40} --- (not exported: paper's OOM regime)");
                continue;
            }
            let mk = |rng: &mut Pcg64| HostTensor::F32 {
                shape: vec![n, d],
                data: (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            };
            let q = mk(&mut rng);
            let k = mk(&mut rng);
            let v = mk(&mut rng);
            let inputs: Vec<HostTensor> = if method.starts_with("lln") {
                vec![q, k, v, HostTensor::scalar_f32(2.2), HostTensor::scalar_f32(2.2)]
            } else {
                vec![q, k, v]
            };
            engine.execute(&name, &inputs).expect("warm"); // compile outside timing
            b.run(&name, n as f64, || engine.execute(&name, &inputs).unwrap());
        }
    }
}
