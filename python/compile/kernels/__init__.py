"""L1 — Pallas kernels for the paper's compute hot-spots.

`ref` holds the pure-jnp oracles every kernel is validated against
(pytest + hypothesis in python/tests/).
"""

from . import ref  # noqa: F401
from .blockdiag import blockdiag_attention_pallas, lln_diag_attention_pallas  # noqa: F401
from .flash_softmax import softmax_attention_pallas  # noqa: F401
from .linear_attn import (  # noqa: F401
    elu_attention_pallas,
    linear_attention_pallas,
    lln_attention_pallas,
)
