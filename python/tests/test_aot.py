"""AOT manifest integrity: what Rust will rely on must hold.

These tests run against artifacts/ when present (CI path: `make test`
builds artifacts first); they skip gracefully otherwise.
"""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built (run `make artifacts`)"
)


@pytest.fixture(scope="module")
def manifest():
    return json.load(open(MANIFEST))


def test_every_artifact_file_exists(manifest):
    for a in manifest["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["name"]
        assert os.path.getsize(path) > 100, a["name"]


def test_hlo_text_parses_as_hlo_module(manifest):
    """Spot-check the interchange format: must be HLO text, not proto."""
    a = manifest["artifacts"][0]
    head = open(os.path.join(ART, a["file"])).read(200)
    assert head.startswith("HloModule"), head[:50]


def test_param_binaries_match_schema(manifest):
    for tag, m in manifest["models"].items():
        path = os.path.join(ART, m["params_file"])
        assert os.path.exists(path), tag
        expect = sum(int(np.prod(m["param_shapes"][k])) for k in m["param_order"]) * 4
        assert os.path.getsize(path) == expect, (tag, os.path.getsize(path), expect)


def test_param_order_is_sorted(manifest):
    for tag, m in manifest["models"].items():
        assert m["param_order"] == sorted(m["param_order"]), tag


def test_train_artifacts_have_state_in_out_symmetry(manifest):
    """Train steps must output exactly the params/m/v they take in."""
    for a in manifest["artifacts"]:
        if not a["name"].startswith("train_"):
            continue
        in_state = [x["name"] for x in a["inputs"] if x["name"][:2] in ("p:", "m:", "v:")]
        out_state = [x["name"] for x in a["outputs"] if x["name"][:2] in ("p:", "m:", "v:")]
        assert in_state == out_state, a["name"]
        in_shapes = {x["name"]: x["shape"] for x in a["inputs"]}
        for x in a["outputs"]:
            if x["name"] in in_shapes:
                assert x["shape"] == in_shapes[x["name"]], (a["name"], x["name"])


def test_train_artifacts_emit_telemetry(manifest):
    for a in manifest["artifacts"]:
        if not a["name"].startswith("train_"):
            continue
        out_names = [x["name"] for x in a["outputs"]]
        for needed in ("loss", "grad_norm", "layer_stats"):
            assert needed in out_names, (a["name"], needed)


def test_micro_kernels_cover_scaling_grid(manifest):
    names = {a["name"] for a in manifest["artifacts"]}
    for n in (256, 1024, 4096, 8192, 16384):
        assert f"attn_lln_n{n}" in names
        assert f"attn_lln_diag_n{n}" in names
    for n in (256, 1024, 4096):
        assert f"attn_softmax_n{n}" in names
    # The paper's OOM analog: no quadratic softmax beyond 4096.
    assert "attn_softmax_n8192" not in names


def test_mm_constants_recorded(manifest):
    assert manifest["mm_a"] > 0
    assert np.isfinite(manifest["mm_b"])


def test_dtypes_are_expected(manifest):
    for a in manifest["artifacts"]:
        for x in a["inputs"] + a["outputs"]:
            assert x["dtype"] in ("f32", "i32"), (a["name"], x)
        tok = [x for x in a["inputs"] if x["name"] == "tokens"]
        if tok:
            assert tok[0]["dtype"] == "i32"
