//! Serving demo: start the coordinator (router + dynamic batcher +
//! workers) with LLN+Diag encoders and drive mixed-length traffic —
//! including causal (decoder-mask) requests when serving through the
//! native backend path.  Requests are padded up to their bucket, and
//! each request's live length rides along as its attention key mask,
//! so batches mix variable-length and mixed-mask traffic.
//!
//!     cargo run --release --example serve -- [requests]          # native
//!     make artifacts && cargo run --release --example serve -- 120
//!
//! With artifacts present the PJRT executables serve full bidirectional
//! attention (causal traffic is a native-path feature).

use anyhow::Result;

use lln::config::ServeConfig;
use lln::coordinator::Coordinator;
use lln::data::tasks::{GlueGen, GlueTask};
use lln::rng::Pcg64;
use lln::runtime::{artifacts_available, artifacts_dir};

fn main() -> Result<()> {
    let requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let dir = artifacts_dir(None);
    let native = !artifacts_available(&dir);
    let cfg = ServeConfig { native_fallback: native, ..ServeConfig::default() };
    println!(
        "starting coordinator: method={} buckets={:?} max_batch={} queue={} ({})",
        cfg.method,
        cfg.buckets,
        cfg.max_batch,
        cfg.queue_capacity,
        if native { "native backends" } else { "PJRT artifacts" }
    );
    // Causal decode-style traffic only makes sense on the native path:
    // the AOT executables are compiled as full bidirectional attention.
    let causal_frac = if native { 0.25 } else { 0.0 };
    let coord = Coordinator::start(cfg, &dir)?;
    // Warm both buckets (first call compiles the executables).
    coord.infer(vec![lln::data::special::CLS; 64])?;
    coord.infer(vec![lln::data::special::CLS; 300])?;
    println!(
        "warmed up; sending {requests} requests (70% short / 30% long, {:.0}% causal)...",
        causal_frac * 100.0
    );

    let mut short = GlueGen::new(GlueTask::Sst2, 512, 120, 1);
    let mut long = GlueGen::new(GlueTask::Qnli, 512, 480, 2);
    let mut rng = Pcg64::seed(0);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|_| {
            let tokens = if rng.f64() < 0.3 { long.example().0 } else { short.example().0 };
            let causal = rng.f64() < causal_frac;
            coord.submit_with(tokens, causal)
        })
        .collect::<Result<_>>()?;
    let mut ok = 0usize;
    for rx in rxs {
        let resp = rx.recv()?;
        if resp.result.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats_arc = coord.stats();
    let st = stats_arc.lock().unwrap();
    println!("\ncompleted {ok}/{requests} in {wall:.2}s  ({:.1} req/s)", ok as f64 / wall);
    println!(
        "latency p50 {:.1} ms  p95 {:.1} ms   mean batch {:.2}   rejected {}",
        st.p50_latency(),
        st.p95_latency(),
        st.mean_batch_size(),
        st.rejected
    );
    drop(st);

    // Streaming decode sessions (native path only: the AOT executables
    // are batch-prefill): two concurrent sessions pipeline tokens
    // through the same bucket queues and read logits back as each token
    // decodes — amortized O(1)/token state for the linear methods.
    if native {
        let d0 = std::time::Instant::now();
        let per_session = 32usize;
        let mut sessions = Vec::new();
        let mut streams = Vec::new();
        for s in 0..2 {
            let mut session = coord.open_session(per_session)?;
            let tokens: Vec<i32> = (0..per_session).map(|i| 4 + ((7 * s + i) % 19) as i32).collect();
            streams.push(session.stream(&tokens)?);
            sessions.push(session);
        }
        let mut streamed = 0usize;
        for rx in &streams {
            for _ in 0..per_session {
                if rx.recv()?.result.is_ok() {
                    streamed += 1;
                }
            }
        }
        for s in sessions {
            s.close();
        }
        println!(
            "decode sessions: streamed {streamed} tokens across 2 sessions in {:.1} ms",
            d0.elapsed().as_secs_f64() * 1e3
        );
    }
    coord.shutdown();
    println!("serve demo OK");
    Ok(())
}
