"""L2 — RoBERTa-lite encoder in JAX with pluggable attention.

Pure-functional: parameters are a flat `dict[str, jnp.ndarray]` with a
canonical (sorted-key) ordering that the AOT manifest records and the
Rust runtime reproduces.  The encoder body calls the differentiable
Pallas kernels from `kernels.autodiff` for the methods the paper
implements at kernel level (softmax / lln / lln_diag / elu / blockdiag);
the comparison baselines (performer / nystrom / linformer) use the jnp
references — they are baselines, not the contribution.

For `attn = "lln"` / `"lln_diag"`, alpha and beta are derived *inside
the graph* from live per-layer query/key standard deviations via the
moment-matching constants (a, b) baked into the config — this is what
makes fig. 9 (alpha/beta evolving during training) reproducible with
Python off the hot path.

The same encoder body serves:
  * token mode   (MLM pretraining, GLUE-like classification, LRA-lite)
  * patch mode   (`forward_patches` — ViT-lite for Table 3)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import autodiff as att
from .kernels import ref
from . import moment_matching as mm

ATTENTION_METHODS = (
    "softmax",      # Pallas flash baseline
    "lln",          # Pallas, paper eq. 8 + moment matching
    "lln_diag",     # Pallas, paper sec. 4.2
    "elu",          # Pallas, Katharopoulos et al.
    "blockdiag",    # Pallas, diagonal-only SA
    "performer",    # jnp baseline (kernel class)
    "nystrom",      # jnp baseline (low-rank class)
    "linformer",    # jnp baseline (projection class)
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + attention configuration (baked into HLO)."""

    vocab_size: int = 8192
    d_model: int = 256
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 1024
    max_len: int = 512
    num_classes: int = 4
    attn: str = "softmax"
    # LLN moment-matching constants (fit offline by moment_matching.py).
    mm_a: float = 0.21
    mm_b: float = -1.08
    # Fixed alpha/beta override (fig. 10 ablation); None = moment matching.
    fixed_alpha: float | None = None
    fixed_beta: float | None = None
    diag_block: int = 64
    performer_features: int = 64
    nystrom_landmarks: int = 32
    linformer_k: int = 64
    # Pallas block sizes for the chunked kernels.
    block_q: int = 128
    block_k: int = 128

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Named presets; "base" mirrors RoBERTa-base for config-completeness
# (not AOT-exported by default — compile time).
PRESETS: Dict[str, dict] = {
    "tiny": dict(vocab_size=512, d_model=64, n_heads=2, n_layers=2, d_ff=128, max_len=128),
    "small": dict(vocab_size=8192, d_model=256, n_heads=4, n_layers=4, d_ff=1024, max_len=512),
    "medium": dict(vocab_size=16384, d_model=512, n_heads=8, n_layers=8, d_ff=2048, max_len=512),
    "base": dict(vocab_size=32768, d_model=768, n_heads=12, n_layers=12, d_ff=3072, max_len=512),
}


def make_config(size: str = "small", **overrides) -> ModelConfig:
    kw = dict(PRESETS[size])
    kw.update(overrides)
    return ModelConfig(**kw)


# ---------------------------------------------------------------------------
# Parameter initialization (canonical flat dict)
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0, patch_dim: int | None = None) -> Dict[str, np.ndarray]:
    """Initialize all parameters as numpy arrays keyed by canonical names.

    patch_dim: when set, adds the ViT patch-embedding matrix (token table
    stays — unused in patch mode but keeps one param schema per config).
    """
    rng = np.random.default_rng(seed)
    d, dff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    std = 0.02

    def norm(*shape):
        return rng.normal(0.0, std, size=shape).astype(np.float32)

    p: Dict[str, np.ndarray] = {
        "emb.tok": norm(v, d),
        "emb.pos": _sinusoidal(cfg.max_len, d),
        "final_ln.g": np.ones(d, np.float32),
        "final_ln.b": np.zeros(d, np.float32),
        "mlm.bias": np.zeros(v, np.float32),
        "cls.w": norm(d, cfg.num_classes),
        "cls.b": np.zeros(cfg.num_classes, np.float32),
    }
    if patch_dim is not None:
        p["emb.patch"] = norm(patch_dim, d)
    for i in range(cfg.n_layers):
        pre = f"layer_{i:02d}."
        p[pre + "ln1.g"] = np.ones(d, np.float32)
        p[pre + "ln1.b"] = np.zeros(d, np.float32)
        p[pre + "wq"] = norm(d, d)
        p[pre + "bq"] = np.zeros(d, np.float32)
        p[pre + "wk"] = norm(d, d)
        p[pre + "bk"] = np.zeros(d, np.float32)
        p[pre + "wv"] = norm(d, d)
        p[pre + "bv"] = np.zeros(d, np.float32)
        p[pre + "wo"] = norm(d, d)
        p[pre + "bo"] = np.zeros(d, np.float32)
        p[pre + "ln2.g"] = np.ones(d, np.float32)
        p[pre + "ln2.b"] = np.zeros(d, np.float32)
        p[pre + "w1"] = norm(d, dff)
        p[pre + "b1"] = np.zeros(dff, np.float32)
        p[pre + "w2"] = norm(dff, d)
        p[pre + "b2"] = np.zeros(d, np.float32)
        if cfg.attn == "performer":
            # Fixed (non-trainable by convention, but stored) random projection.
            p[pre + "performer_proj"] = rng.normal(
                0.0, 1.0, size=(cfg.d_head, cfg.performer_features)
            ).astype(np.float32)
        if cfg.attn == "linformer":
            p[pre + "linformer_e"] = norm(cfg.max_len, cfg.linformer_k)
            p[pre + "linformer_f"] = norm(cfg.max_len, cfg.linformer_k)
    return p


def _sinusoidal(n: int, d: int, scale: float = 0.05) -> np.ndarray:
    """Sinusoidal position table scaled to the token-embedding init scale.

    Unit-amplitude sinusoids would dominate std-0.02 token embeddings by
    ~50x, drowning content in position and stalling classification
    training (verified empirically: SST2-like accuracy 0.56 -> 0.97 after
    rescaling).  The table is a trainable parameter either way.
    """
    pos = np.arange(n)[:, None]
    i = np.arange(d)[None, :]
    angle = pos / np.power(10000.0, (2 * (i // 2)) / d)
    out = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
    return (scale * out).astype(np.float32)


def param_order(params: Dict[str, np.ndarray]) -> List[str]:
    """The canonical flattening order shared with the Rust runtime."""
    return sorted(params.keys())


# ---------------------------------------------------------------------------
# Encoder forward
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _split_heads(x, n_heads):
    b, n, d = x.shape
    return x.reshape(b, n, n_heads, d // n_heads).transpose(0, 2, 1, 3)  # (B,H,N,dh)


def _merge_heads(x):
    b, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def _lln_alpha_beta(q, k, cfg: ModelConfig):
    """Per-layer alpha/beta from live stats (or the fixed override)."""
    if cfg.fixed_alpha is not None:
        return jnp.float32(cfg.fixed_alpha), jnp.float32(cfg.fixed_beta)
    sigma_q = jnp.std(q) + 1e-6
    sigma_k = jnp.std(k) + 1e-6
    return mm.alpha_beta(sigma_q, sigma_k, cfg.mm_a, cfg.mm_b)


def _attention(q, k, v, cfg: ModelConfig, layer_params, prefix):
    """Dispatch one layer's multi-head attention.  q/k/v: (B, H, N, dh).

    Returns (context (B,H,N,dh), stats dict of scalars for probes).
    """
    bq, bk = cfg.block_q, cfg.block_k
    stats = {}

    def over_heads(fn):
        return jax.vmap(jax.vmap(fn))(q, k, v)

    if cfg.attn == "softmax":
        ctx = over_heads(lambda a, b, c: att.softmax_attention(a, b, c, bq, bk))
    elif cfg.attn in ("lln", "lln_diag"):
        alpha, beta = _lln_alpha_beta(q, k, cfg)
        stats["alpha"] = alpha
        stats["beta"] = beta
        stats["sigma_q"] = jnp.std(q)
        stats["sigma_k"] = jnp.std(k)
        if cfg.attn == "lln":
            fn = lambda a, b, c: att.lln_attention(a, b, c, alpha, beta, block_q=bq, block_k=bk)
        else:
            fn = lambda a, b, c: att.lln_diag_attention(
                a, b, c, alpha, beta, cfg.diag_block, block_q=bq, block_k=bk
            )
        ctx = over_heads(fn)
    elif cfg.attn == "elu":
        ctx = over_heads(lambda a, b, c: att.elu_attention(a, b, c, block_q=bq, block_k=bk))
    elif cfg.attn == "blockdiag":
        ctx = over_heads(lambda a, b, c: att.blockdiag_attention(a, b, c, cfg.diag_block))
    elif cfg.attn == "performer":
        proj = layer_params[prefix + "performer_proj"]
        ctx = over_heads(lambda a, b, c: ref.performer_attention(a, b, c, proj))
    elif cfg.attn == "nystrom":
        ctx = over_heads(lambda a, b, c: ref.nystrom_attention(a, b, c, cfg.nystrom_landmarks))
    elif cfg.attn == "linformer":
        n = q.shape[2]
        e = layer_params[prefix + "linformer_e"][:n]
        f = layer_params[prefix + "linformer_f"][:n]

        def linformer_head(qh, kh, vh):
            kp = e.T @ kh  # (k, dh)
            vp = f.T @ vh
            return ref.softmax_attention(qh, kp, vp)

        ctx = over_heads(linformer_head)
    else:
        raise ValueError(f"unknown attention {cfg.attn!r}")
    return ctx, stats


def encode(params, h, cfg: ModelConfig):
    """Shared encoder body on pre-embedded inputs h: (B, N, D).

    Returns (hidden, per-layer stats list).
    """
    all_stats = []
    for i in range(cfg.n_layers):
        pre = f"layer_{i:02d}."
        x = _layer_norm(h, params[pre + "ln1.g"], params[pre + "ln1.b"])
        q = _split_heads(x @ params[pre + "wq"] + params[pre + "bq"], cfg.n_heads)
        k = _split_heads(x @ params[pre + "wk"] + params[pre + "bk"], cfg.n_heads)
        v = _split_heads(x @ params[pre + "wv"] + params[pre + "bv"], cfg.n_heads)
        ctx, stats = _attention(q, k, v, cfg, params, pre)
        h = h + _merge_heads(ctx) @ params[pre + "wo"] + params[pre + "bo"]
        y = _layer_norm(h, params[pre + "ln2.g"], params[pre + "ln2.b"])
        h = h + jax.nn.gelu(y @ params[pre + "w1"] + params[pre + "b1"]) @ params[pre + "w2"] + params[pre + "b2"]
        all_stats.append(stats)
    h = _layer_norm(h, params["final_ln.g"], params["final_ln.b"])
    return h, all_stats


def embed_tokens(params, tokens, cfg: ModelConfig):
    n = tokens.shape[1]
    return params["emb.tok"][tokens] + params["emb.pos"][:n][None, :, :]


def forward(params, tokens, cfg: ModelConfig):
    """Token mode: tokens (B, N) int32 -> (hidden (B,N,D), stats)."""
    return encode(params, embed_tokens(params, tokens, cfg), cfg)


def forward_patches(params, patches, cfg: ModelConfig):
    """Patch mode (ViT-lite): patches (B, P, patch_dim) f32."""
    n = patches.shape[1]
    h = patches @ params["emb.patch"] + params["emb.pos"][:n][None, :, :]
    return encode(params, h, cfg)


# ---------------------------------------------------------------------------
# Heads and losses
# ---------------------------------------------------------------------------

def mlm_logits(params, hidden):
    """Tied-embedding MLM head: (B, N, D) -> (B, N, V)."""
    return hidden @ params["emb.tok"].T + params["mlm.bias"]


def cls_logits(params, hidden):
    """Mean-pooled classification head: (B, N, D) -> (B, C)."""
    pooled = jnp.mean(hidden, axis=1)
    return pooled @ params["cls.w"] + params["cls.b"]


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def mlm_loss(params, tokens, labels, weights, cfg: ModelConfig):
    """Masked-LM loss.  tokens already contain [MASK]; weights select positions."""
    hidden, stats = forward(params, tokens, cfg)
    per_tok = _xent(mlm_logits(params, hidden), labels)
    loss = jnp.sum(per_tok * weights) / jnp.maximum(jnp.sum(weights), 1.0)
    return loss, stats


def cls_loss(params, tokens, labels, cfg: ModelConfig):
    hidden, stats = forward(params, tokens, cfg)
    logits = cls_logits(params, hidden)
    return jnp.mean(_xent(logits, labels)), (stats, logits)


def vit_loss(params, patches, labels, cfg: ModelConfig):
    hidden, stats = forward_patches(params, patches, cfg)
    logits = cls_logits(params, hidden)
    return jnp.mean(_xent(logits, labels)), (stats, logits)


def stack_layer_stats(all_stats, cfg: ModelConfig):
    """(L, 4) tensor of [alpha, beta, sigma_q, sigma_k] per layer (zeros if n/a)."""
    rows = []
    for s in all_stats:
        rows.append(
            jnp.stack(
                [
                    s.get("alpha", jnp.float32(0.0)),
                    s.get("beta", jnp.float32(0.0)),
                    s.get("sigma_q", jnp.float32(0.0)),
                    s.get("sigma_k", jnp.float32(0.0)),
                ]
            )
        )
    return jnp.stack(rows)


# ---------------------------------------------------------------------------
# Analysis probe (fig. 1): per-layer attention matrices + input stats
# ---------------------------------------------------------------------------

def attention_probe(params, tokens, cfg: ModelConfig):
    """Returns (P (L, N, N): head-0 attention of batch element 0 per layer,
    layer_stats (L, 4)).

    For LLN methods P is the explicit LLN stochastic matrix (eq. 9) so the
    entropy/spectral-gap instruments measure the *actual* mechanism.
    """
    h = embed_tokens(params, tokens, cfg)
    mats = []
    all_stats = []
    for i in range(cfg.n_layers):
        pre = f"layer_{i:02d}."
        x = _layer_norm(h, params[pre + "ln1.g"], params[pre + "ln1.b"])
        q = _split_heads(x @ params[pre + "wq"] + params[pre + "bq"], cfg.n_heads)
        k = _split_heads(x @ params[pre + "wk"] + params[pre + "bk"], cfg.n_heads)
        v = _split_heads(x @ params[pre + "wv"] + params[pre + "bv"], cfg.n_heads)
        q0, k0 = q[0, 0], k[0, 0]
        if cfg.attn in ("lln", "lln_diag"):
            alpha, beta = _lln_alpha_beta(q, k, cfg)
            mats.append(ref.lln_attention_matrix(q0, k0, alpha, beta))
        else:
            mats.append(ref.softmax_attention_matrix(q0, k0))
        ctx, stats = _attention(q, k, v, cfg, params, pre)
        all_stats.append(stats)
        h = h + _merge_heads(ctx) @ params[pre + "wo"] + params[pre + "bo"]
        y = _layer_norm(h, params[pre + "ln2.g"], params[pre + "ln2.b"])
        h = h + jax.nn.gelu(y @ params[pre + "w1"] + params[pre + "b1"]) @ params[pre + "w2"] + params[pre + "b2"]
    return jnp.stack(mats), stack_layer_stats(all_stats, cfg)
