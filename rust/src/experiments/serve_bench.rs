//! Serving experiment: drive the coordinator with an open-loop request
//! stream and report throughput / batching efficiency plus **per-class
//! latency percentiles** — prefill-short / prefill-long / decode-step /
//! session-open each get their own p50/p90/p99 instead of one smeared
//! mixed distribution (a sub-millisecond decode step and a 512-token
//! prefill do not belong in the same histogram).
//!
//! `--slo-p99 <ms>` turns the report into a gate: any class with
//! traffic whose p99 exceeds the bound fails the run (CI's SLO smoke).

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::maybe_write_csv;
use crate::cli::Args;
use crate::config::{ConfigTable, FaultsConfig, ServeConfig};
use crate::coordinator::{Coordinator, DecodeSession, HashRing, PayloadClass};
use crate::data::tasks::{GlueGen, GlueTask};
use crate::rng::Pcg64;
use crate::runtime::{artifacts_available, artifacts_dir};
use crate::util::print_table;

pub fn run_serve(args: &Args) -> Result<()> {
    // `--chaos-seed N` flips the bench into the deterministic chaos
    // soak: same coordinator, but under a seeded fault plan, and the
    // report is the resilience contract instead of latency percentiles.
    let chaos_seed = args.get_usize("chaos-seed", 0)? as u64;
    if chaos_seed > 0 {
        return run_chaos(args, chaos_seed);
    }
    let dir = artifacts_dir(args.get("artifacts"));
    let requests = args.get_usize("requests", 200)?;
    let methods = args.get_list("methods", "softmax,lln_diag");
    let rate = args.get_f64("rate", 200.0)?; // requests/second offered
    let long_frac = args.get_f64("long-frac", 0.3)?;
    // Causal (decoder-mask) traffic: --causal masks every request,
    // --causal-frac mixes a fraction into the stream.
    let causal_all = args.get_bool("causal");
    let causal_frac = if causal_all {
        1.0
    } else {
        args.get_f64("causal-frac", 0.0)?.clamp(0.0, 1.0)
    };
    // Streaming decode sessions: --sessions opens N concurrent
    // token-by-token sessions per method and streams --decode-tokens
    // through each, co-batched with the prefill traffic's buckets.
    let sessions = args.get_usize("sessions", 0)?;
    let decode_tokens = args.get_usize("decode-tokens", 48)?.max(1);
    // Sharded front override (0 = take the [serve] config's value).
    let shards = args.get_usize("shards", 0)?;
    // SLO gate: 0 disables; otherwise every trafficked class's p99 [ms]
    // must stay under the bound or the run exits nonzero.
    let slo_p99 = args.get_f64("slo-p99", 0.0)?;

    println!(
        "== Serving: coordinator throughput/latency ({requests} reqs, {rate}/s offered, {:.0}% long, {:.0}% causal) ==\n",
        long_frac * 100.0,
        causal_frac * 100.0
    );
    // --config wires the [serve] / [compute] sections (queue, batching,
    // workers-per-bucket, shards, page pool, admission) into the
    // coordinator.
    let mut base_cfg = match args.get("config") {
        Some(path) => ServeConfig::from_table(&ConfigTable::load(std::path::Path::new(path))?),
        None => ServeConfig::default(),
    };
    if shards > 0 {
        base_cfg.shards = shards;
    }
    // Experiment harness (not production serving): explicitly opt into
    // the native-backend encoder when AOT artifacts are absent so the
    // coordinator pipeline is still measurable.  Causal traffic forces
    // the native path outright (`force_native`) — the AOT serve
    // executables are compiled as full bidirectional attention.
    let native = base_cfg.native_fallback || !artifacts_available(&dir);
    let force_native = base_cfg.force_native || causal_frac > 0.0 || sessions > 0;
    if !artifacts_available(&dir) {
        println!("(artifacts absent: serving via the native AttentionBackend encoder)\n");
    } else if force_native {
        println!(
            "(causal/decode traffic requested: serving via the native AttentionBackend encoder)\n"
        );
    }
    let mut class_rows = Vec::new();
    let mut summary_rows = Vec::new();
    let mut csv = Vec::new();
    let mut outcome_csv = Vec::new();
    let mut slo_violations: Vec<String> = Vec::new();
    for method in &methods {
        let cfg = ServeConfig {
            method: method.clone(),
            native_fallback: native,
            force_native,
            ..base_cfg.clone()
        };
        let coord = Coordinator::start(cfg, &dir)?;
        // Warm both buckets (compile once), then zero the stats so the
        // warmup's cold latencies don't pollute the percentiles.
        coord.infer(vec![crate::data::special::CLS; 64])?;
        coord.infer(vec![crate::data::special::CLS; 300])?;
        coord.stats().lock().unwrap().reset();

        let mut gen_short = GlueGen::new(GlueTask::Sst2, 512, 120, 1);
        let mut gen_long = GlueGen::new(GlueTask::Qnli, 512, 480, 2);
        let mut rng = Pcg64::seed(3);
        let interval = Duration::from_secs_f64(1.0 / rate);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(requests);
        let mut rejected = 0usize;
        for i in 0..requests {
            let tokens = if rng.f64() < long_frac {
                gen_long.example().0
            } else {
                gen_short.example().0
            };
            let causal = causal_frac > 0.0 && rng.f64() < causal_frac;
            match coord.submit_with(tokens, causal) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
            // Open-loop pacing.
            let target = t0 + interval * (i as u32 + 1);
            if let Some(sleep) = target.checked_duration_since(Instant::now()) {
                std::thread::sleep(sleep);
            }
        }
        // Every admitted request ends in exactly one terminal response;
        // tally the outcome classes instead of assuming Ok — shed load
        // (queue-side rejections, expired deadlines) and failures are
        // their own columns, not silently folded into throughput.
        let (mut ok, mut deadline_dropped, mut failed) = (0u64, 0u64, 0u64);
        for rx in rxs {
            match rx.recv() {
                Err(_) => failed += 1, // dropped without a terminal reply
                Ok(resp) => match &resp.result {
                    Ok(_) => ok += 1,
                    Err(e) => match e.kind() {
                        "rejected" => rejected += 1,
                        "deadline-exceeded" => deadline_dropped += 1,
                        _ => failed += 1,
                    },
                },
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        // Streaming decode sessions, co-batched through the same
        // coordinator: open N sessions, pipeline decode_tokens through
        // each, and drain the streams (tokens arrive as they decode).
        // Their latencies land in the decode-step / session-open class
        // windows — the prefill percentiles stay untouched.
        let decode_cell = if sessions == 0 {
            "-".to_string()
        } else if !crate::attention::Method::parse(method)
            .map(|m| m.supports_masking())
            .unwrap_or(false)
        {
            "n/a".to_string()
        } else {
            let d0 = Instant::now();
            let mut handles = Vec::new();
            let mut streams = Vec::new();
            for s in 0..sessions {
                let mut session = coord.open_session(decode_tokens)?;
                let toks: Vec<i32> =
                    (0..decode_tokens).map(|i| 4 + ((s * 31 + i) % 97) as i32).collect();
                streams.push(session.stream(&toks)?);
                handles.push(session);
            }
            let mut streamed = 0usize;
            for rx in &streams {
                for _ in 0..decode_tokens {
                    if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
                        streamed += 1;
                    }
                }
            }
            for s in handles {
                s.close();
            }
            let tok_s = streamed as f64 / d0.elapsed().as_secs_f64();
            format!("{tok_s:.0}")
        };

        let stats_arc = coord.stats();
        let st = stats_arc.lock().unwrap();
        let mut prefill_completed = 0u64;
        for class in PayloadClass::ALL {
            let w = st.class(class);
            if matches!(class, PayloadClass::PrefillShort | PayloadClass::PrefillLong) {
                prefill_completed += w.completed;
            }
            if w.completed == 0 {
                continue;
            }
            let (p50, p90, p99) =
                (w.percentile(50.0), w.percentile(90.0), w.percentile(99.0));
            class_rows.push(vec![
                method.to_string(),
                class.name().to_string(),
                format!("{}", w.completed),
                format!("{p50:.2}"),
                format!("{p90:.2}"),
                format!("{p99:.2}"),
            ]);
            csv.push(format!("{method},{},{},{p50},{p90},{p99}", class.name(), w.completed));
            if slo_p99 > 0.0 && p99 > slo_p99 {
                slo_violations.push(format!(
                    "{method}/{}: p99 {p99:.2} ms > SLO {slo_p99:.2} ms",
                    class.name()
                ));
            }
        }
        let pages_cell = match coord.page_pool() {
            Some(_) => format!("{}/{}", st.pages_evicted, st.pages_recomputed),
            None => "-".to_string(),
        };
        let throughput = prefill_completed as f64 / wall;
        summary_rows.push(vec![
            method.to_string(),
            format!("{throughput:.1}"),
            format!("{:.2}", st.mean_batch_size()),
            format!("{ok}"),
            format!("{rejected}"),
            format!("{deadline_dropped}"),
            format!("{failed}"),
            format!("{}", st.steals),
            decode_cell,
            pages_cell,
        ]);
        outcome_csv.push(format!("{method},{ok},{rejected},{deadline_dropped},{failed}"));
        drop(st);
        coord.shutdown();
    }
    print_table(
        &["method", "class", "count", "p50 [ms]", "p90 [ms]", "p99 [ms]"],
        &class_rows,
    );
    println!();
    print_table(
        &[
            "method",
            "throughput [req/s]",
            "mean batch",
            "ok",
            "rejected",
            "deadline_dropped",
            "failed",
            "steals",
            "decode [tok/s]",
            "pages evict/recomp",
        ],
        &summary_rows,
    );
    println!("\nshape: lln_diag sustains long-sequence traffic at lower prefill-long p99");
    println!("than softmax (quadratic N=512 forwards dominate SA's tail), and decode");
    println!("steps hold a distribution of their own instead of hiding the prefill tail.");
    maybe_write_csv(args, "serve", "method,class,count,p50,p90,p99", &csv)?;
    maybe_write_csv(
        args,
        "serve_outcomes",
        "method,ok,rejected,deadline_dropped,failed",
        &outcome_csv,
    )?;
    if !slo_violations.is_empty() {
        bail!("SLO violated:\n  {}", slo_violations.join("\n  "));
    }
    if slo_p99 > 0.0 {
        println!("\nSLO check passed: every trafficked class p99 <= {slo_p99:.1} ms");
    }
    Ok(())
}

/// `--chaos-seed N`: deterministic chaos soak (CI's chaos smoke).
///
/// Drives a sharded native front under the seeded fault plan from
/// [`FaultsConfig::chaos`] — executor panics, worker delays, a worker
/// kill, and one whole-shard condemnation — and verifies the resilience
/// contract end to end:
///
///   * every submitted request gets exactly one terminal response
///     (none lost, none duplicated);
///   * the supervisor respawns killed workers back to the floor;
///   * sessions stranded on the condemned shard fail over, and their
///     post-failover logits are bitwise identical to an unfaulted
///     single-shard replay of the same tokens;
///   * the condemned shard leaves the routing ring.
///
/// Any violation exits nonzero.
fn run_chaos(args: &Args, seed: u64) -> Result<()> {
    let shards = args.get_usize("shards", 0)?.max(2);
    let requests = args.get_usize("requests", 48)?.max(24);
    let sessions = args.get_usize("sessions", 2)?.clamp(1, 8);
    let decode_tokens = args.get_usize("decode-tokens", 24)?.clamp(16, 48);
    let method = "softmax";

    let mut faults = FaultsConfig::chaos(seed, shards);
    // Sessions are opened first (ids 1..=sessions): pin the shard kill
    // onto session 1's home so failover is exercised on every seed.
    faults.kill_shard = HashRing::new(shards).route(1) as i64;
    println!(
        "== Chaos soak: seed {seed}, {shards} shards, {requests} prefills, \
         {sessions} sessions x {decode_tokens} tokens =="
    );
    println!("   plan: {faults:?}\n");

    let cfg = ServeConfig {
        method: method.into(),
        queue_capacity: 64,
        max_batch: 4,
        batch_timeout_ms: 3,
        workers: 1,
        buckets: vec![32, 64],
        native_fallback: true,
        force_native: true,
        shards,
        retry_max: 2,
        retry_backoff_ms: 1,
        faults,
        ..ServeConfig::default()
    };
    let dir = artifacts_dir(args.get("artifacts"));
    let coord = Coordinator::start(cfg.clone(), &dir)?;

    let tok = |s: usize, i: usize| 4 + ((s * 31 + i) % 97) as i32;
    let mut sess: Vec<DecodeSession> = Vec::new();
    for _ in 0..sessions {
        sess.push(coord.open_session(decode_tokens)?);
    }
    let mut got: Vec<Vec<Vec<f32>>> = vec![Vec::new(); sessions];
    let (mut ok, mut rejected, mut deadline_dropped, mut failed) = (0u64, 0u64, 0u64, 0u64);
    let (mut lost, mut duplicated, mut restores) = (0u64, 0u64, 0u64);

    let rounds = requests.max(decode_tokens);
    for round in 0..rounds {
        if round < requests {
            match coord.submit(vec![4 + (round % 13) as i32; 16]) {
                Err(_) => rejected += 1,
                Ok(rx) => match rx.recv_timeout(Duration::from_secs(30)) {
                    Err(_) => lost += 1,
                    Ok(resp) => {
                        match &resp.result {
                            Ok(_) => ok += 1,
                            Err(e) => match e.kind() {
                                "rejected" => rejected += 1,
                                "deadline-exceeded" => deadline_dropped += 1,
                                _ => failed += 1,
                            },
                        }
                        if rx.try_recv().is_ok() {
                            duplicated += 1;
                        }
                    }
                },
            }
        }
        if round < decode_tokens {
            for (s, session) in sess.iter_mut().enumerate() {
                let t = tok(s, round);
                // A failed step means the session's shard died (or its
                // state is poisoned): fail over and resubmit the same
                // token against the restored fresh-lineage state.
                let logits = match session.step(t) {
                    Ok(l) => l,
                    Err(_) => {
                        coord.restore_session(session)?;
                        restores += 1;
                        session.step(t)?
                    }
                };
                got[s].push(logits);
            }
        }
    }

    let dead = coord.dead_shards();
    let stats_arc = coord.stats();
    let st = stats_arc.lock().unwrap();
    let (worker_restarts, injected, stat_restored, retries) =
        (st.worker_restarts, st.faults_injected, st.sessions_restored, st.retries);
    drop(st);
    for s in sess.drain(..) {
        s.close();
    }
    coord.shutdown();

    // Bitwise ground truth: an unfaulted single-shard front fed the
    // same per-session token sequences.
    let ref_cfg = ServeConfig {
        shards: 1,
        retry_max: 0,
        faults: FaultsConfig::default(),
        ..cfg
    };
    let refc = Coordinator::start(ref_cfg, &dir)?;
    let mut divergences = 0u64;
    for (s, rows) in got.iter().enumerate() {
        let mut rs = refc.open_session(decode_tokens)?;
        for (i, row) in rows.iter().enumerate() {
            let want = rs.step(tok(s, i))?;
            if *row != want {
                divergences += 1;
                eprintln!("session {s} step {i}: logits diverged from the unfaulted replay");
            }
        }
        rs.close();
    }
    refc.shutdown();

    print_table(
        &["ok", "rejected", "deadline_dropped", "failed", "lost", "duplicated"],
        &[vec![
            format!("{ok}"),
            format!("{rejected}"),
            format!("{deadline_dropped}"),
            format!("{failed}"),
            format!("{lost}"),
            format!("{duplicated}"),
        ]],
    );
    println!(
        "\nfaults injected: {injected}  retries: {retries}  worker restarts: {worker_restarts}  \
         session failovers: {restores} (stats: {stat_restored})  dead shards: {dead:?}"
    );
    maybe_write_csv(
        args,
        "serve_chaos",
        "seed,ok,rejected,deadline_dropped,failed,lost,duplicated,worker_restarts,failovers",
        &[format!(
            "{seed},{ok},{rejected},{deadline_dropped},{failed},{lost},{duplicated},\
             {worker_restarts},{restores}"
        )],
    )?;

    let mut violations: Vec<String> = Vec::new();
    if lost > 0 {
        violations.push(format!("{lost} request(s) lost without a terminal response"));
    }
    if duplicated > 0 {
        violations.push(format!("{duplicated} duplicated response(s)"));
    }
    if worker_restarts == 0 {
        violations.push("no worker restart observed under a plan that kills one".into());
    }
    if restores == 0 {
        violations.push("no session failover observed under a pinned shard kill".into());
    }
    if divergences > 0 {
        violations.push(format!(
            "{divergences} step(s) diverged bitwise from the unfaulted replay"
        ));
    }
    if dead.is_empty() {
        violations.push("the condemned shard never left the ring".into());
    }
    if !violations.is_empty() {
        bail!("chaos contract violated:\n  {}", violations.join("\n  "));
    }
    println!(
        "\nchaos contract held: every request got exactly one terminal response, the \
         supervisor held the worker floor, and failover restored sessions bit-exactly."
    );
    Ok(())
}
