//! The attention kernels themselves: outputs (O(N) formulations where the
//! method allows) and explicit stochastic matrices (for analysis).
//!
//! Numerics mirror python/compile/kernels/ref.py exactly: same clamping,
//! same eps, same landmark/feature constructions — integration tests
//! assert closeness against the PJRT-executed artifacts.

use super::{AttnSpec, EXP_CLAMP};
use crate::rng::Pcg64;
use crate::tensor::{KernelDispatch, Mat};

pub(crate) const EPS: f32 = 1e-6;

#[inline]
pub(crate) fn clamped_exp(x: f32) -> f32 {
    x.clamp(-EXP_CLAMP, EXP_CLAMP).exp()
}

// ---------------------------------------------------------------------------
// Softmax attention (paper eq. 1)
// ---------------------------------------------------------------------------

/// Full softmax attention output; O(N^2) time and memory.
pub fn softmax_attention(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    softmax_attention_matrix(q, k).matmul(v)
}

/// The stochastic matrix P^(SM) (paper eq. 6).
pub fn softmax_attention_matrix(q: &Mat, k: &Mat) -> Mat {
    let d = q.cols();
    let mut scores = q.matmul_t(k);
    let scale = 1.0 / (d as f32).sqrt();
    scores.map_inplace(|x| x * scale);
    scores.softmax_rows();
    scores
}

/// Stable softmax over the first `lim` entries of one score row (scaled
/// in place); entries at/past `lim` become exact zeros, and a fully
/// masked row (`lim == 0`) carries no mass at all.  The single masked
/// softmax used by the dense reference matrix, the materialized backend
/// route, and the block-diagonal tiles — keep them numerically
/// identical by construction.
pub(crate) fn masked_softmax_row(row: &mut [f32], lim: usize, scale: f32) {
    if lim == 0 {
        row.fill(0.0);
        return;
    }
    let mut m = f32::NEG_INFINITY;
    for s in row[..lim].iter_mut() {
        *s *= scale;
        m = m.max(*s);
    }
    let mut sum = 0.0f32;
    for s in row[..lim].iter_mut() {
        *s = (*s - m).exp();
        sum += *s;
    }
    let inv = 1.0 / sum;
    for s in row[..lim].iter_mut() {
        *s *= inv;
    }
    row[lim..].fill(0.0);
}

/// Apply [`masked_softmax_row`] to every row of a dense score matrix
/// under a spec (row `i`'s limit is `spec.row_limit(i, nk)`).
pub(crate) fn masked_softmax_rows(p: &mut Mat, nk: usize, spec: &AttnSpec, scale: f32) {
    for i in 0..p.rows() {
        let lim = spec.row_limit(i, nk);
        masked_softmax_row(p.row_mut(i), lim, scale);
    }
}

/// [`masked_softmax_rows`] with rows partitioned across `threads`
/// scoped workers (0 = auto) — rows are independent, so results are
/// bitwise identical to the serial version (the masked counterpart of
/// [`Mat::par_softmax_rows`]).
pub(crate) fn par_masked_softmax_rows(
    p: &mut Mat,
    nk: usize,
    spec: &AttnSpec,
    scale: f32,
    threads: usize,
) {
    let (m, cols) = p.shape();
    let t = crate::tensor::resolve_threads(threads).min(m.max(1));
    if t <= 1 || m == 0 || cols == 0 {
        masked_softmax_rows(p, nk, spec, scale);
        return;
    }
    crate::tensor::par_row_spans(p.data_mut(), m, cols, t, |row0, _len, chunk| {
        for (r, row) in chunk.chunks_mut(cols).enumerate() {
            let lim = spec.row_limit(row0 + r, nk);
            masked_softmax_row(row, lim, scale);
        }
    });
}

/// Masked softmax attention matrix under an [`AttnSpec`]: the dense
/// *reference* formulation of causal / padded softmax attention that the
/// fused streaming kernel is property-tested against.  Masked entries
/// are exact zeros; a row whose every key is masked (`key_len == 0`)
/// carries no mass at all and stays all-zero.
pub fn softmax_attention_matrix_spec(q: &Mat, k: &Mat, spec: &AttnSpec) -> Mat {
    if spec.is_full() && spec.scale.is_none() {
        // Bitwise-identical to the historical unmasked route.
        return softmax_attention_matrix(q, k);
    }
    let d = q.cols();
    let nk = k.rows();
    let mut p = q.matmul_t(k);
    masked_softmax_rows(&mut p, nk, spec, spec.resolve_scale(d));
    p
}

// ---------------------------------------------------------------------------
// Fused tiled exact attention (flash-style streaming softmax)
// ---------------------------------------------------------------------------

/// Default K/V tile rows for the fused kernels: 128 rows of d=64 f32
/// keys + values ≈ 64 KiB, hot in L2 while a query block streams over
/// them.
pub const DEFAULT_FUSED_TILE: usize = 128;
/// Default query rows per register block in the fused kernels (matches
/// [`crate::tensor::micro::MR`]).
pub const DEFAULT_FUSED_UNROLL: usize = 4;
/// Cap on the query-row register block — beyond this the per-worker
/// score buffer stops paying for itself.
pub const MAX_FUSED_UNROLL: usize = 8;

pub(crate) fn resolve_tile(tile: usize) -> usize {
    if tile == 0 {
        DEFAULT_FUSED_TILE
    } else {
        tile
    }
}

fn resolve_unroll(unroll: usize) -> usize {
    if unroll == 0 {
        DEFAULT_FUSED_UNROLL
    } else {
        unroll.min(MAX_FUSED_UNROLL)
    }
}

/// Run `work(row0, len, chunk)` over contiguous query-row spans of a
/// row-major output buffer, one compute-pool task per span — like
/// [`par_row_spans`](crate::tensor::par_row_spans), but when the spec
/// is causal the spans are cut on cumulative *live pairs* instead of
/// row counts: causal work is triangular, so an even row split would
/// leave the last worker ~2x the mean work and cap the parallel
/// speedup near half the thread count.
fn par_query_spans(
    buf: &mut [f32],
    nq: usize,
    nk: usize,
    row_len: usize,
    threads: usize,
    spec: &AttnSpec,
    work: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    if !spec.causal {
        // Rectangular masks: every row costs the same, even rows are
        // already balanced.
        crate::tensor::par_row_spans(buf, nq, row_len, threads, work);
        return;
    }
    let spans = balanced_causal_spans(nq, nk, spec, threads);
    if spans.len() <= 1 {
        if let Some(&(row0, len)) = spans.first() {
            work(row0, len, buf);
        }
        return;
    }
    let work = &work;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(spans.len());
    let mut rest = buf;
    for (row0, len) in spans {
        let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(len * row_len);
        rest = tail;
        tasks.push(Box::new(move || work(row0, len, chunk)));
    }
    crate::util::compute_pool::scope(tasks);
}

/// Contiguous spans of `nq` query rows with roughly equal cumulative
/// live-pair work under a causal spec (at most `threads` spans, never
/// empty, covering every row in order).  Shared with the backward
/// kernels in [`super::grad`], whose causal dq/row-stat phases have the
/// same triangular cost profile.
pub(crate) fn balanced_causal_spans(
    nq: usize,
    nk: usize,
    spec: &AttnSpec,
    threads: usize,
) -> Vec<(usize, usize)> {
    let t = threads.max(1).min(nq.max(1));
    if t <= 1 || nq == 0 {
        return if nq == 0 { Vec::new() } else { vec![(0, nq)] };
    }
    // Charge at least 1 per row so fully masked rows still spread.
    let total: f64 = (0..nq).map(|i| spec.row_limit(i, nk).max(1) as f64).sum();
    let mut spans = Vec::with_capacity(t);
    let mut start = 0usize;
    let mut acc = 0.0f64;
    for i in 0..nq {
        acc += spec.row_limit(i, nk).max(1) as f64;
        let cuts_done = spans.len() + 1;
        if cuts_done < t && acc >= total * cuts_done as f64 / t as f64 {
            spans.push((start, i + 1 - start));
            start = i + 1;
        }
    }
    if start < nq {
        spans.push((start, nq - start));
    }
    spans
}

/// Fused tiled softmax attention — exact (up to f32 summation order)
/// softmax attention in O(n·tile) working memory: the n×n score matrix
/// is never materialized.
///
/// Query rows are split across `threads` scoped workers (0 = auto) via
/// [`partition_rows`](crate::tensor::partition_rows); each worker walks
/// its rows in `unroll`-row register blocks (0 = auto) and streams K/V
/// in `tile`-row tiles (0 = auto), maintaining the online-softmax
/// (running row-max m, running row-sum l, value accumulator) recurrence
/// per query row:
///
///   m' = max(m, max_j s_j);  c = exp(m - m');
///   l' = c·l + Σ_j exp(s_j - m');  acc' = c·acc + Σ_j exp(s_j - m')·v_j
///
/// Score tiles come from the register-blocked
/// [`micro::matmul_t_block`](crate::tensor::micro::matmul_t_block)
/// kernel, so this is also substantially faster than the materialized
/// `par_matmul_t` + `par_softmax_rows` + `par_matmul` pipeline it
/// replaces.  Any `tile` ≥ 1 is legal, including tiles larger than the
/// key count and tiles that do not divide it.
pub fn fused_softmax_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    tile: usize,
    unroll: usize,
    threads: usize,
) -> Mat {
    fused_softmax_attention_spec(q, k, v, &AttnSpec::FULL, tile, unroll, threads)
}

/// [`fused_softmax_attention`] under an [`AttnSpec`]: the fused causal /
/// masked streaming-softmax variant.  The online row-max/row-sum
/// recurrence runs over only the K/V tiles at or below each query row
/// (plus the live prefix of `key_len`-padded keys), including partial
/// diagonal tiles, so a causal forward does ~half the dense score work
/// and the working set stays O(n·tile) — no n×n buffer at any length.
/// With [`AttnSpec::FULL`] this is bitwise identical to the unmasked
/// kernel.  Rows whose every key is masked (`key_len == 0`) produce
/// zero output rows, matching [`softmax_attention_matrix_spec`].
pub fn fused_softmax_attention_spec(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
    unroll: usize,
    threads: usize,
) -> Mat {
    fused_softmax_attention_dispatch(q, k, v, spec, tile, unroll, threads, KernelDispatch::Auto)
}

/// [`fused_softmax_attention_spec`] with an explicit [`KernelDispatch`]:
/// the score tiles run the monomorphized head-dim microkernel the
/// backend resolved at construction (bitwise-identical to the generic
/// path — the spec instances are exact statement-for-statement copies,
/// see `tensor::micro`).  A pinned instance whose `D` does not match
/// `q.cols()` silently falls back to the generic kernel.
#[allow(clippy::too_many_arguments)]
pub fn fused_softmax_attention_dispatch(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
    unroll: usize,
    threads: usize,
    kern: KernelDispatch,
) -> Mat {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut out = Mat::zeros(nq, dv);
    if nq == 0 || nk == 0 || dv == 0 {
        return out;
    }
    let scale = spec.resolve_scale(d);
    let tile = resolve_tile(tile).min(nk);
    let ur = resolve_unroll(unroll);
    let t = crate::tensor::resolve_threads(threads).min(nq);
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    if t <= 1 {
        // Same serial short-circuit as the other `par_*` entry points:
        // no worker spawn when one span would do.
        fused_softmax_rows(
            qd, kd, vd, out.data_mut(), 0, nq, d, nk, dv, scale, tile, ur, spec, kern,
        );
        return out;
    }
    par_query_spans(out.data_mut(), nq, nk, dv, t, spec, |row0, len, chunk| {
        fused_softmax_rows(qd, kd, vd, chunk, row0, len, d, nk, dv, scale, tile, ur, spec, kern);
    });
    out
}

/// One worker's query-row span of [`fused_softmax_attention_spec`].
#[allow(clippy::too_many_arguments)]
fn fused_softmax_rows(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    out: &mut [f32],
    row0: usize,
    rows: usize,
    d: usize,
    nk: usize,
    dv: usize,
    scale: f32,
    tile: usize,
    ur: usize,
    spec: &AttnSpec,
    kern: KernelDispatch,
) {
    // Per-worker scratch: O(ur·(tile + dv)) — independent of n.
    let mut scores = vec![0.0f32; ur * tile];
    let mut acc = vec![0.0f32; ur * dv];
    let mut row_max = vec![f32::NEG_INFINITY; ur];
    let mut row_sum = vec![0.0f32; ur];
    let mut i = 0;
    while i < rows {
        let ib = ur.min(rows - i);
        acc[..ib * dv].fill(0.0);
        row_max[..ib].fill(f32::NEG_INFINITY);
        row_sum[..ib].fill(0.0);
        let qrows = &q[(row0 + i) * d..(row0 + i + ib) * d];
        // Stream only the tiles some row of this register block can
        // see: row limits are monotone in the row index, so the last
        // row's limit bounds the whole block's key span.
        let span = spec.row_limit(row0 + i + ib - 1, nk);
        let mut t0 = 0;
        while t0 < span {
            let tn = tile.min(span - t0);
            let ktile = &k[t0 * d..(t0 + tn) * d];
            kern.matmul_t_block(qrows, ktile, &mut scores[..ib * tn], ib, d, tn);
            for r in 0..ib {
                // Keys this row may use within the tile — `live < tn`
                // is exactly the partial diagonal tile of the causal
                // mask.
                let live = spec.row_limit(row0 + i + r, nk).saturating_sub(t0).min(tn);
                if live == 0 {
                    continue;
                }
                let srow = &mut scores[r * tn..r * tn + live];
                let mut tile_max = f32::NEG_INFINITY;
                for s in srow.iter_mut() {
                    *s *= scale;
                    tile_max = tile_max.max(*s);
                }
                let m_new = row_max[r].max(tile_max);
                // First tile: row_max is -inf, m_new is finite (scores
                // of finite inputs are finite), so the correction
                // exp(-inf) = 0 cleanly re-zeroes the empty state.
                let correction = (row_max[r] - m_new).exp();
                let arow = &mut acc[r * dv..(r + 1) * dv];
                if correction != 1.0 {
                    row_sum[r] *= correction;
                    for a in arow.iter_mut() {
                        *a *= correction;
                    }
                }
                let mut tile_sum = 0.0f32;
                for (j, &s) in srow.iter().enumerate() {
                    let p = (s - m_new).exp();
                    tile_sum += p;
                    let vrow = &v[(t0 + j) * dv..(t0 + j + 1) * dv];
                    for (a, &vv) in arow.iter_mut().zip(vrow) {
                        *a += p * vv;
                    }
                }
                row_sum[r] += tile_sum;
                row_max[r] = m_new;
            }
            t0 += tn;
        }
        for r in 0..ib {
            let orow = &mut out[(i + r) * dv..(i + r + 1) * dv];
            if row_sum[r] == 0.0 {
                // Every key masked (key_len == 0): no mass, zero row —
                // same as the dense masked reference.
                orow.fill(0.0);
                continue;
            }
            // row_sum >= exp(m - m) = 1: no eps needed, exactly like
            // the dense softmax.
            let inv = 1.0 / row_sum[r];
            for (o, &a) in orow.iter_mut().zip(&acc[r * dv..(r + 1) * dv]) {
                *o = a * inv;
            }
        }
        i += ib;
    }
}

/// Fused tiled quadratic-kernel attention: same K/V streaming as
/// [`fused_softmax_attention`] but with κ(q,k) = (q·k)² weights, which
/// need no online max — just numerator/denominator accumulators.
/// Matches [`quadratic_attention_matrix`]` @ v` (same EPS in the
/// denominator) up to f32 summation order, in O(n·tile) memory.
pub fn fused_quadratic_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    tile: usize,
    unroll: usize,
    threads: usize,
) -> Mat {
    fused_quadratic_attention_spec(q, k, v, &AttnSpec::FULL, tile, unroll, threads)
}

/// [`fused_quadratic_attention`] under an [`AttnSpec`]: causal / padded
/// masking with the same prefix-tile streaming as the fused softmax
/// kernel (the (q·k)² weights need no online max, so masking is just a
/// per-row live-key bound).  Matches
/// [`quadratic_attention_matrix_spec`]` @ v` in O(n·tile) memory.
pub fn fused_quadratic_attention_spec(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
    unroll: usize,
    threads: usize,
) -> Mat {
    fused_quadratic_attention_dispatch(q, k, v, spec, tile, unroll, threads, KernelDispatch::Auto)
}

/// [`fused_quadratic_attention_spec`] with an explicit
/// [`KernelDispatch`] for the score microkernel (see
/// [`fused_softmax_attention_dispatch`]).
#[allow(clippy::too_many_arguments)]
pub fn fused_quadratic_attention_dispatch(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
    unroll: usize,
    threads: usize,
    kern: KernelDispatch,
) -> Mat {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut out = Mat::zeros(nq, dv);
    if nq == 0 || nk == 0 || dv == 0 {
        return out;
    }
    let tile = resolve_tile(tile).min(nk);
    let ur = resolve_unroll(unroll);
    let t = crate::tensor::resolve_threads(threads).min(nq);
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    if t <= 1 {
        fused_quadratic_rows(qd, kd, vd, out.data_mut(), 0, nq, d, nk, dv, tile, ur, spec, kern);
        return out;
    }
    par_query_spans(out.data_mut(), nq, nk, dv, t, spec, |row0, len, chunk| {
        fused_quadratic_rows(qd, kd, vd, chunk, row0, len, d, nk, dv, tile, ur, spec, kern);
    });
    out
}

/// One worker's query-row span of [`fused_quadratic_attention_spec`].
#[allow(clippy::too_many_arguments)]
fn fused_quadratic_rows(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    out: &mut [f32],
    row0: usize,
    rows: usize,
    d: usize,
    nk: usize,
    dv: usize,
    tile: usize,
    ur: usize,
    spec: &AttnSpec,
    kern: KernelDispatch,
) {
    let mut scores = vec![0.0f32; ur * tile];
    let mut num = vec![0.0f32; ur * dv];
    let mut den = vec![0.0f32; ur];
    let mut i = 0;
    while i < rows {
        let ib = ur.min(rows - i);
        num[..ib * dv].fill(0.0);
        den[..ib].fill(0.0);
        let qrows = &q[(row0 + i) * d..(row0 + i + ib) * d];
        let span = spec.row_limit(row0 + i + ib - 1, nk);
        let mut t0 = 0;
        while t0 < span {
            let tn = tile.min(span - t0);
            let ktile = &k[t0 * d..(t0 + tn) * d];
            kern.matmul_t_block(qrows, ktile, &mut scores[..ib * tn], ib, d, tn);
            for r in 0..ib {
                let live = spec.row_limit(row0 + i + r, nk).saturating_sub(t0).min(tn);
                let srow = &scores[r * tn..r * tn + live];
                let nrow = &mut num[r * dv..(r + 1) * dv];
                let mut tile_den = 0.0f32;
                for (j, &s) in srow.iter().enumerate() {
                    let w = s * s;
                    tile_den += w;
                    let vrow = &v[(t0 + j) * dv..(t0 + j + 1) * dv];
                    for (o, &vv) in nrow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
                den[r] += tile_den;
            }
            t0 += tn;
        }
        for r in 0..ib {
            let inv = 1.0 / (den[r] + EPS);
            let orow = &mut out[(i + r) * dv..(i + r + 1) * dv];
            for (o, &x) in orow.iter_mut().zip(&num[r * dv..(r + 1) * dv]) {
                *o = x * inv;
            }
        }
        i += ib;
    }
}

// ---------------------------------------------------------------------------
// Incremental decode steps (stateful O(1)-per-token causal attention)
// ---------------------------------------------------------------------------

/// One incremental fused-softmax decode step: softmax attention of a
/// single query row over the `len` cached key/value rows, streamed in
/// `tile`-row tiles with the same online row-max/row-sum recurrence
/// (and the same [`micro::matmul_t_block`](crate::tensor::micro) score
/// microkernel) as [`fused_softmax_attention_spec`] — this IS the
/// causal forward's row `len - 1` when the cache holds keys `0..len`,
/// computed against the cache instead of re-streaming the prefix per
/// token.  O(len·d) time, O(tile + dv) scratch.
pub fn fused_softmax_decode_step(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    len: usize,
    d: usize,
    dv: usize,
    scale: f32,
    tile: usize,
) -> Vec<f32> {
    fused_softmax_decode_step_dispatch(q, keys, values, len, d, dv, scale, tile, KernelDispatch::Auto)
}

/// [`fused_softmax_decode_step`] with an explicit [`KernelDispatch`]
/// for the score microkernel — the per-token serving hot path, where
/// the backend's construction-time dispatch table pays off most (one
/// `q · K_tileᵀ` microkernel call per tile per token).
#[allow(clippy::too_many_arguments)]
pub fn fused_softmax_decode_step_dispatch(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    len: usize,
    d: usize,
    dv: usize,
    scale: f32,
    tile: usize,
    kern: KernelDispatch,
) -> Vec<f32> {
    assert_eq!(q.len(), d, "query row dim mismatch");
    assert!(keys.len() >= len * d && values.len() >= len * dv, "cache shorter than len");
    let mut out = vec![0.0f32; dv];
    if len == 0 || dv == 0 {
        return out;
    }
    let tile = resolve_tile(tile).min(len);
    let mut scores = vec![0.0f32; tile];
    let mut row_max = f32::NEG_INFINITY;
    let mut row_sum = 0.0f32;
    let mut t0 = 0;
    while t0 < len {
        let tn = tile.min(len - t0);
        let ktile = &keys[t0 * d..(t0 + tn) * d];
        kern.matmul_t_block(q, ktile, &mut scores[..tn], 1, d, tn);
        let mut tile_max = f32::NEG_INFINITY;
        for s in scores[..tn].iter_mut() {
            *s *= scale;
            tile_max = tile_max.max(*s);
        }
        let m_new = row_max.max(tile_max);
        let correction = (row_max - m_new).exp();
        if correction != 1.0 {
            row_sum *= correction;
            for a in out.iter_mut() {
                *a *= correction;
            }
        }
        let mut tile_sum = 0.0f32;
        for (j, &s) in scores[..tn].iter().enumerate() {
            let p = (s - m_new).exp();
            tile_sum += p;
            let vrow = &values[(t0 + j) * dv..(t0 + j + 1) * dv];
            for (a, &vv) in out.iter_mut().zip(vrow) {
                *a += p * vv;
            }
        }
        row_sum += tile_sum;
        row_max = m_new;
        t0 += tn;
    }
    // len >= 1 puts the max score's exp(0) = 1 in the sum: no eps.
    let inv = 1.0 / row_sum;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

/// One incremental quadratic-kernel decode step: κ(q,k) = (q·k)²
/// weights over the cached rows with the same numerator/denominator
/// accumulation (and EPS) as [`fused_quadratic_attention_spec`].
pub fn fused_quadratic_decode_step(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    len: usize,
    d: usize,
    dv: usize,
    tile: usize,
) -> Vec<f32> {
    fused_quadratic_decode_step_dispatch(q, keys, values, len, d, dv, tile, KernelDispatch::Auto)
}

/// [`fused_quadratic_decode_step`] with an explicit [`KernelDispatch`]
/// for the score microkernel.
#[allow(clippy::too_many_arguments)]
pub fn fused_quadratic_decode_step_dispatch(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    len: usize,
    d: usize,
    dv: usize,
    tile: usize,
    kern: KernelDispatch,
) -> Vec<f32> {
    assert_eq!(q.len(), d, "query row dim mismatch");
    assert!(keys.len() >= len * d && values.len() >= len * dv, "cache shorter than len");
    let mut num = vec![0.0f32; dv];
    if len == 0 || dv == 0 {
        return num;
    }
    let tile = resolve_tile(tile).min(len);
    let mut scores = vec![0.0f32; tile];
    let mut den = 0.0f32;
    let mut t0 = 0;
    while t0 < len {
        let tn = tile.min(len - t0);
        let ktile = &keys[t0 * d..(t0 + tn) * d];
        kern.matmul_t_block(q, ktile, &mut scores[..tn], 1, d, tn);
        for (j, &s) in scores[..tn].iter().enumerate() {
            let w = s * s;
            den += w;
            let vrow = &values[(t0 + j) * dv..(t0 + j + 1) * dv];
            for (o, &vv) in num.iter_mut().zip(vrow) {
                *o += w * vv;
            }
        }
        t0 += tn;
    }
    let inv = 1.0 / (den + EPS);
    for o in num.iter_mut() {
        *o *= inv;
    }
    num
}

/// One block-diagonal decode step: the new token (global index
/// `len - 1`) attends its own diagonal `block`-tile's causal prefix —
/// cached keys `[tile_start, len)` where `tile_start = ((len-1)/block)
/// * block` — through the same [`masked_softmax_row`] the batch tiles
/// use.  O(block·d) per token regardless of the decoded length.
pub fn blockdiag_decode_step(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    len: usize,
    d: usize,
    dv: usize,
    scale: f32,
    block: usize,
) -> Vec<f32> {
    blockdiag_decode_step_dispatch(q, keys, values, len, d, dv, scale, block, KernelDispatch::Auto)
}

/// [`blockdiag_decode_step`] with an explicit [`KernelDispatch`] for
/// the score microkernel.
#[allow(clippy::too_many_arguments)]
pub fn blockdiag_decode_step_dispatch(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    len: usize,
    d: usize,
    dv: usize,
    scale: f32,
    block: usize,
    kern: KernelDispatch,
) -> Vec<f32> {
    assert_eq!(q.len(), d, "query row dim mismatch");
    assert!(keys.len() >= len * d && values.len() >= len * dv, "cache shorter than len");
    let mut out = vec![0.0f32; dv];
    if len == 0 || dv == 0 {
        return out;
    }
    let b0 = ((len - 1) / block.max(1)) * block.max(1);
    let span = len - b0;
    let mut scores = vec![0.0f32; span];
    let ktile = &keys[b0 * d..(b0 + span) * d];
    kern.matmul_t_block(q, ktile, &mut scores, 1, d, span);
    masked_softmax_row(&mut scores, span, scale);
    for (j, &p) in scores.iter().enumerate() {
        let vrow = &values[(b0 + j) * dv..(b0 + j + 1) * dv];
        for (o, &vv) in out.iter_mut().zip(vrow) {
            *o += p * vv;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Generic linearized attention (paper eq. 4)
// ---------------------------------------------------------------------------

/// O(N m d) linear attention from explicit feature maps.
pub fn linear_attention(phi_q: &Mat, phi_k: &Mat, v: &Mat) -> Mat {
    let kv = phi_k.transpose().matmul(v); // (m, dv)
    let z = phi_k.col_sums(); // (m,)
    let num = phi_q.matmul(&kv); // (n, dv)
    let den = phi_q.matvec(&z); // (n,)
    let mut out = num;
    for i in 0..out.rows() {
        let inv = 1.0 / (den[i] + EPS);
        for x in out.row_mut(i) {
            *x *= inv;
        }
    }
    out
}

/// Explicit N x N stochastic matrix of a linearized attention.
pub fn linear_attention_matrix(phi_q: &Mat, phi_k: &Mat) -> Mat {
    let mut p = phi_q.matmul_t(phi_k);
    p.normalize_rows(EPS);
    p
}

/// Masked linearized attention matrix under an [`AttnSpec`]: the dense
/// *reference* formulation of causal / padded linear attention — masked
/// entries are zeroed before row normalization, so each row is a
/// distribution over only its live keys.  This is what the O(N)
/// prefix-state kernel ([`linear_attention_causal`]) is property-tested
/// against.
pub fn linear_attention_matrix_spec(phi_q: &Mat, phi_k: &Mat, spec: &AttnSpec) -> Mat {
    if spec.is_full() {
        return linear_attention_matrix(phi_q, phi_k);
    }
    let nq = phi_q.rows();
    let nk = phi_k.rows();
    let mut p = phi_q.matmul_t(phi_k);
    for i in 0..nq {
        let lim = spec.row_limit(i, nk);
        p.row_mut(i)[lim..].fill(0.0);
    }
    p.normalize_rows(EPS);
    p
}

/// Linearized attention under an [`AttnSpec`] — the backend dispatch
/// point for the whole linear class (LLN, ELU, ReLU, Performer):
///
/// * full          -> [`linear_attention_streamed`] (unchanged);
/// * `key_len`     -> streamed over only the live key/value prefix
///                    (a row bound, no copy — the serving hot path);
/// * `causal`      -> [`linear_attention_causal`], the O(N)
///                    prefix-state recurrence.
///
/// `spec.scale` is ignored: linearized kernels have no score
/// temperature (the feature maps already fix the kernel).
pub fn linear_attention_spec(
    phi_q: &Mat,
    phi_k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    chunk: usize,
    threads: usize,
) -> Mat {
    linear_attention_spec_dispatch(phi_q, phi_k, v, spec, chunk, threads, KernelDispatch::Auto)
}

/// [`linear_attention_spec`] with an explicit [`KernelDispatch`] for
/// the causal prefix-state route (the streamed full/padded routes keep
/// their own chunk-parallel folds).
#[allow(clippy::too_many_arguments)]
pub fn linear_attention_spec_dispatch(
    phi_q: &Mat,
    phi_k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    chunk: usize,
    threads: usize,
    kern: KernelDispatch,
) -> Mat {
    if spec.causal {
        return linear_attention_causal_dispatch(
            phi_q,
            phi_k,
            v,
            spec.key_len,
            chunk,
            threads,
            kern,
        );
    }
    linear_attention_streamed_prefix(
        phi_q,
        phi_k,
        v,
        spec.key_limit(phi_k.rows()),
        chunk,
        threads,
    )
}

/// Causal O(N) *prefix-state* linearized attention: every query row i
/// reads the running state
///
///   S_i = Σ_{j <= i} φ(k_j) v_jᵀ   (m × dv),   z_i = Σ_{j <= i} φ(k_j)
///
/// and emits  out_i = φ(q_i)ᵀ S_i / (φ(q_i)·z_i + eps)  — attention
/// over the past in O(1) state per token instead of O(i) keys (the
/// recurrence decoders run token-by-token; here it is evaluated for all
/// rows in one pass).
///
/// Chunked + multi-threaded with per-chunk state carry: key rows are
/// cut into `chunk`-row chunks whose (S, z) partials are accumulated in
/// parallel, a serial pass turns them into exclusive prefix carries,
/// and each chunk then replays its own rows on top of its carry — also
/// in parallel.  Summation order per chunk is fixed, so results do not
/// depend on the worker count.  `key_len` keys at/past the limit are
/// treated as dead (contribute no state), which is how padded causal
/// serving batches decode.  Requires aligned q/k row counts (the causal
/// mask is over matching indices).
pub fn linear_attention_causal(
    phi_q: &Mat,
    phi_k: &Mat,
    v: &Mat,
    key_len: Option<usize>,
    chunk: usize,
    threads: usize,
) -> Mat {
    linear_attention_causal_dispatch(phi_q, phi_k, v, key_len, chunk, threads, KernelDispatch::Auto)
}

/// [`linear_attention_causal`] with an explicit [`KernelDispatch`]: the
/// per-row state folds (phases 1 and 3) run the monomorphized
/// fixed-`dv` fold when the value dimension matches a specialized
/// instance (bitwise-identical to the generic fold — see
/// [`accumulate_state_dispatch`]).
#[allow(clippy::too_many_arguments)]
pub fn linear_attention_causal_dispatch(
    phi_q: &Mat,
    phi_k: &Mat,
    v: &Mat,
    key_len: Option<usize>,
    chunk: usize,
    threads: usize,
    kern: KernelDispatch,
) -> Mat {
    assert_eq!(phi_q.cols(), phi_k.cols(), "feature dims differ");
    assert_eq!(phi_k.rows(), v.rows(), "key/value row mismatch");
    assert_eq!(
        phi_q.rows(),
        phi_k.rows(),
        "causal linear attention requires aligned q/k row counts"
    );
    let (n, m) = phi_q.shape();
    let dv = v.cols();
    let mut out = Mat::zeros(n, dv);
    if n == 0 || dv == 0 || m == 0 {
        // m == 0: no features — every numerator is 0 and every
        // denominator is EPS, i.e. an all-zero output (same as the
        // dense masked route).
        return out;
    }
    let kl = key_len.unwrap_or(n).min(n);
    let chunk = if chunk == 0 { 128 } else { chunk };
    let threads = crate::tensor::resolve_threads(threads);
    let n_chunks = n.div_ceil(chunk);
    let groups = threads.max(1).min(n_chunks);
    let chunks_per = n_chunks.div_ceil(groups);

    // Phase 1: per-chunk (Σ φ(k) vᵀ, Σ φ(k)) partials over live key
    // rows, accumulated in parallel chunk groups.
    let mut kv_part = vec![0.0f32; n_chunks * m * dv];
    let mut z_part = vec![0.0f32; n_chunks * m];
    {
        let kv_groups = kv_part.chunks_mut(chunks_per * m * dv);
        let z_groups = z_part.chunks_mut(chunks_per * m);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = kv_groups
            .zip(z_groups)
            .enumerate()
            .map(|(gi, (kv_g, z_g))| {
                Box::new(move || {
                    let per_chunk = kv_g.chunks_mut(m * dv).zip(z_g.chunks_mut(m));
                    for (ci, (kv_c, z_c)) in per_chunk.enumerate() {
                        let c = gi * chunks_per + ci;
                        let lo = c * chunk;
                        let hi = ((c + 1) * chunk).min(n).min(kl);
                        for i in lo..hi.max(lo) {
                            accumulate_state_dispatch(kern, kv_c, z_c, phi_k.row(i), v.row(i), dv);
                        }
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        crate::util::compute_pool::scope(tasks);
    }

    // Phase 2 (serial): exclusive prefix over the chunk partials — the
    // state each chunk starts from.
    let mut carry_kv = vec![0.0f32; n_chunks * m * dv];
    let mut carry_z = vec![0.0f32; n_chunks * m];
    for c in 1..n_chunks {
        let (prev_kv, cur_kv) = carry_kv.split_at_mut(c * m * dv);
        let prev_kv = &prev_kv[(c - 1) * m * dv..];
        let part_kv = &kv_part[(c - 1) * m * dv..c * m * dv];
        for ((o, &a), &b) in cur_kv[..m * dv].iter_mut().zip(prev_kv).zip(part_kv) {
            *o = a + b;
        }
        let (prev_z, cur_z) = carry_z.split_at_mut(c * m);
        let prev_z = &prev_z[(c - 1) * m..];
        let part_z = &z_part[(c - 1) * m..c * m];
        for ((o, &a), &b) in cur_z[..m].iter_mut().zip(prev_z).zip(part_z) {
            *o = a + b;
        }
    }

    // Phase 3: each chunk replays its rows on its carry, in parallel.
    let carry_kv = carry_kv.as_slice();
    let carry_z = carry_z.as_slice();
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .data_mut()
        .chunks_mut(chunks_per * chunk * dv)
        .enumerate()
        .map(|(gi, out_g)| {
            Box::new(move || {
                let mut state_kv = vec![0.0f32; m * dv];
                let mut state_z = vec![0.0f32; m];
                for (ci, out_c) in out_g.chunks_mut(chunk * dv).enumerate() {
                    let c = gi * chunks_per + ci;
                    state_kv.copy_from_slice(&carry_kv[c * m * dv..(c + 1) * m * dv]);
                    state_z.copy_from_slice(&carry_z[c * m..(c + 1) * m]);
                    let lo = c * chunk;
                    for (ri, orow) in out_c.chunks_mut(dv).enumerate() {
                        let i = lo + ri;
                        if i < kl {
                            accumulate_state_dispatch(
                                kern,
                                &mut state_kv,
                                &mut state_z,
                                phi_k.row(i),
                                v.row(i),
                                dv,
                            );
                        }
                        let qrow = phi_q.row(i);
                        let mut den = 0.0f32;
                        for (f, &qf) in qrow.iter().enumerate() {
                            den += qf * state_z[f];
                            if qf != 0.0 {
                                let krow = &state_kv[f * dv..(f + 1) * dv];
                                for (o, &kvv) in orow.iter_mut().zip(krow) {
                                    *o += qf * kvv;
                                }
                            }
                        }
                        let inv = 1.0 / (den + EPS);
                        for o in orow.iter_mut() {
                            *o *= inv;
                        }
                    }
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::util::compute_pool::scope(tasks);
    out
}

/// Fold one key/value row into a running (Σ φ(k) vᵀ, Σ φ(k)) state —
/// shared by both phases of [`linear_attention_causal`] *and* by the
/// decode-session [`PrefixState`](super::decode::PrefixState) so their
/// per-chunk summation orders are identical (the bitwise
/// decode-vs-batch parity depends on this).
#[inline]
pub(crate) fn accumulate_state(kv: &mut [f32], z: &mut [f32], krow: &[f32], vrow: &[f32], dv: usize) {
    for (f, &kf) in krow.iter().enumerate() {
        z[f] += kf;
        if kf != 0.0 {
            let dst = &mut kv[f * dv..(f + 1) * dv];
            for (o, &vv) in dst.iter_mut().zip(vrow) {
                *o += kf * vv;
            }
        }
    }
}

/// [`accumulate_state`] monomorphized per value dimension: the inner
/// `dv`-length fused multiply-add becomes a const-length loop the
/// autovectorizer fully unrolls.  The body is a statement-for-statement
/// copy of the generic fold (same iteration order, same `kf != 0.0`
/// skip), so outputs are bitwise identical — pinned by
/// `accumulate_state_dispatch_is_bitwise` below and the head-dim
/// goldens in rust/tests/prop_kernels.rs.
#[inline]
fn accumulate_state_spec<const DV: usize>(kv: &mut [f32], z: &mut [f32], krow: &[f32], vrow: &[f32]) {
    for (f, &kf) in krow.iter().enumerate() {
        z[f] += kf;
        if kf != 0.0 {
            let dst = &mut kv[f * DV..(f + 1) * DV];
            for (o, &vv) in dst.iter_mut().zip(vrow) {
                *o += kf * vv;
            }
        }
    }
}

/// Dispatch one state fold through the resolved microkernel instance:
/// `Auto` picks the monomorphized fold when `dv` matches a specialized
/// dimension, a pinned instance applies only when its `D == dv`, and
/// everything else takes the generic fold.  Bitwise-identical across
/// all dispatch values.
#[inline]
pub(crate) fn accumulate_state_dispatch(
    kern: KernelDispatch,
    kv: &mut [f32],
    z: &mut [f32],
    krow: &[f32],
    vrow: &[f32],
    dv: usize,
) {
    match (kern, dv) {
        (KernelDispatch::Auto | KernelDispatch::D32, 32) => {
            accumulate_state_spec::<32>(kv, z, krow, vrow)
        }
        (KernelDispatch::Auto | KernelDispatch::D64, 64) => {
            accumulate_state_spec::<64>(kv, z, krow, vrow)
        }
        (KernelDispatch::Auto | KernelDispatch::D128, 128) => {
            accumulate_state_spec::<128>(kv, z, krow, vrow)
        }
        _ => accumulate_state(kv, z, krow, vrow, dv),
    }
}

/// Chunked O(N) *streaming* formulation of linearized attention — the
/// backend hot path.  The (m, dv) KV state and the (m,) normalizer are
/// accumulated exactly once over key/value row-chunks (never
/// materialized per query row), with per-thread partials merged at the
/// chunk barrier; query rows then read the shared state back in
/// parallel.  Matches [`linear_attention`] up to f32 summation order.
///
/// `chunk` is the thread work-partition granularity: key/value rows are
/// handed to workers in multiples of `chunk` (0 = 128).  It does not
/// change memory use or per-partition summation order — only how the
/// row range splits across workers.  `threads` is the scoped-worker
/// count (0 = auto).
pub fn linear_attention_streamed(
    phi_q: &Mat,
    phi_k: &Mat,
    v: &Mat,
    chunk: usize,
    threads: usize,
) -> Mat {
    linear_attention_streamed_prefix(phi_q, phi_k, v, phi_k.rows(), chunk, threads)
}

/// [`linear_attention_streamed`] restricted to the first `live`
/// key/value rows — the zero-copy form of a right-padding key mask
/// (rows at/past `live` simply never enter the state accumulation).
/// `live >= phi_k.rows()` is the unmasked kernel.
pub(crate) fn linear_attention_streamed_prefix(
    phi_q: &Mat,
    phi_k: &Mat,
    v: &Mat,
    live: usize,
    chunk: usize,
    threads: usize,
) -> Mat {
    assert_eq!(phi_q.cols(), phi_k.cols(), "feature dims differ");
    assert_eq!(phi_k.rows(), v.rows(), "key/value row mismatch");
    let (nq, m) = phi_q.shape();
    let nk = phi_k.rows().min(live);
    let dv = v.cols();
    let chunk = if chunk == 0 { 128 } else { chunk };
    let threads = if threads == 0 { crate::tensor::default_threads() } else { threads };
    let mut out = Mat::zeros(nq, dv);
    if nq == 0 || dv == 0 {
        return out;
    }

    // Phase 1: stream key/value chunks into per-thread (kv, z) partials.
    let n_chunks = nk.div_ceil(chunk).max(1);
    let t1 = threads.max(1).min(n_chunks);
    let chunks_per = n_chunks.div_ceil(t1);
    let mut kv = vec![0.0f32; m * dv];
    let mut z = vec![0.0f32; m];
    // Live worker ranges, in index order: partials are pre-allocated
    // per range and merged serially in that same order, so the
    // summation order is a function of (nk, chunk, threads) alone —
    // never of pool scheduling.
    let ranges: Vec<(usize, usize)> = (0..t1)
        .map(|ti| (ti * chunks_per * chunk, ((ti + 1) * chunks_per * chunk).min(nk)))
        .filter(|&(lo, hi)| lo < hi)
        .collect();
    let mut partials: Vec<(Vec<f32>, Vec<f32>)> =
        ranges.iter().map(|_| (vec![0.0f32; m * dv], vec![0.0f32; m])).collect();
    {
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = partials
            .iter_mut()
            .zip(&ranges)
            .map(|(part, &(lo, hi))| {
                Box::new(move || {
                    let (kv_p, z_p) = part;
                    for i in lo..hi {
                        let krow = phi_k.row(i);
                        let vrow = v.row(i);
                        for (f, &kf) in krow.iter().enumerate() {
                            z_p[f] += kf;
                            if kf != 0.0 {
                                let dst = &mut kv_p[f * dv..(f + 1) * dv];
                                for (o, &vv) in dst.iter_mut().zip(vrow) {
                                    *o += kf * vv;
                                }
                            }
                        }
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        crate::util::compute_pool::scope(tasks);
    }
    for (kv_p, z_p) in partials {
        for (a, b) in kv.iter_mut().zip(&kv_p) {
            *a += b;
        }
        for (a, b) in z.iter_mut().zip(&z_p) {
            *a += b;
        }
    }

    // Phase 2: query rows read the shared state back, in parallel.
    let t2 = threads.max(1).min(nq);
    let rows_per = nq.div_ceil(t2);
    let kv_ref = kv.as_slice();
    let z_ref = z.as_slice();
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .data_mut()
        .chunks_mut(rows_per * dv)
        .enumerate()
        .map(|(ti, chunk_rows)| {
            let row0 = ti * rows_per;
            Box::new(move || {
                let rows_here = chunk_rows.len() / dv;
                for i in 0..rows_here {
                    let qrow = phi_q.row(row0 + i);
                    let orow = &mut chunk_rows[i * dv..(i + 1) * dv];
                    let mut den = 0.0f32;
                    for (f, &qf) in qrow.iter().enumerate() {
                        den += qf * z_ref[f];
                        if qf != 0.0 {
                            let krow = &kv_ref[f * dv..(f + 1) * dv];
                            for (o, &kvv) in orow.iter_mut().zip(krow) {
                                *o += qf * kvv;
                            }
                        }
                    }
                    let inv = 1.0 / (den + EPS);
                    for o in orow.iter_mut() {
                        *o *= inv;
                    }
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::util::compute_pool::scope(tasks);
    out
}

// ---------------------------------------------------------------------------
// LLN attention (paper eq. 8-9)
// ---------------------------------------------------------------------------

pub fn lln_features(x: &Mat, scale: f32) -> Mat {
    x.map(|v| clamped_exp(scale * v))
}

pub fn lln_attention(q: &Mat, k: &Mat, v: &Mat, alpha: f32, beta: f32) -> Mat {
    linear_attention(&lln_features(q, alpha), &lln_features(k, beta), v)
}

pub fn lln_attention_matrix(q: &Mat, k: &Mat, alpha: f32, beta: f32) -> Mat {
    linear_attention_matrix(&lln_features(q, alpha), &lln_features(k, beta))
}

/// Streaming-chunked LLN forward (the [`super::backend`] hot path).
pub fn lln_attention_streamed(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    alpha: f32,
    beta: f32,
    chunk: usize,
    threads: usize,
) -> Mat {
    linear_attention_streamed(&lln_features(q, alpha), &lln_features(k, beta), v, chunk, threads)
}

// ---------------------------------------------------------------------------
// ELU / ReLU / quadratic kernels
// ---------------------------------------------------------------------------

pub fn elu_features(x: &Mat) -> Mat {
    x.map(|v| if v > 0.0 { v + 1.0 } else { v.exp() })
}

pub fn elu_attention(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    linear_attention(&elu_features(q), &elu_features(k), v)
}

pub fn elu_attention_matrix(q: &Mat, k: &Mat) -> Mat {
    linear_attention_matrix(&elu_features(q), &elu_features(k))
}

pub fn relu_attention_matrix(q: &Mat, k: &Mat) -> Mat {
    let f = |m: &Mat| m.map(|v| v.max(0.0));
    linear_attention_matrix(&f(q), &f(k))
}

/// kappa(q, k) = (q . k)^2 — the fig. 2 "quadratic kernel" comparator.
pub fn quadratic_attention_matrix(q: &Mat, k: &Mat) -> Mat {
    let mut p = q.matmul_t(k);
    p.map_inplace(|x| x * x);
    p.normalize_rows(EPS);
    p
}

/// Masked quadratic-kernel matrix under an [`AttnSpec`] (dense
/// reference for [`fused_quadratic_attention_spec`]); masked entries
/// are zeroed before row normalization.
pub fn quadratic_attention_matrix_spec(q: &Mat, k: &Mat, spec: &AttnSpec) -> Mat {
    if spec.is_full() {
        return quadratic_attention_matrix(q, k);
    }
    let nq = q.rows();
    let nk = k.rows();
    let mut p = q.matmul_t(k);
    p.map_inplace(|x| x * x);
    for i in 0..nq {
        let lim = spec.row_limit(i, nk);
        p.row_mut(i)[lim..].fill(0.0);
    }
    p.normalize_rows(EPS);
    p
}

// ---------------------------------------------------------------------------
// Performer (FAVOR+ positive features)
// ---------------------------------------------------------------------------

/// Deterministic Gaussian projection for Performer features.
pub fn performer_projection(d: usize, m: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed(seed);
    Mat::gaussian(d, m, 1.0, &mut rng)
}

pub fn performer_features(x: &Mat, proj: &Mat) -> Mat {
    let d = x.cols();
    let m = proj.cols();
    let scale = 1.0 / (m as f32).sqrt();
    let dscale = 1.0 / (d as f32).powf(0.25);
    let xs = x.scale(dscale);
    let u = xs.matmul(proj); // (n, m)
    let mut out = Mat::zeros(x.rows(), m);
    for i in 0..x.rows() {
        let sq: f32 = xs.row(i).iter().map(|&a| a * a).sum::<f32>() * 0.5;
        for j in 0..m {
            out.set(i, j, scale * clamped_exp(u.get(i, j) - sq));
        }
    }
    out
}

pub fn performer_attention(q: &Mat, k: &Mat, v: &Mat, proj: &Mat) -> Mat {
    linear_attention(&performer_features(q, proj), &performer_features(k, proj), v)
}

pub fn performer_attention_matrix(q: &Mat, k: &Mat, proj: &Mat) -> Mat {
    linear_attention_matrix(&performer_features(q, proj), &performer_features(k, proj))
}

// ---------------------------------------------------------------------------
// Nystromformer (segment-mean landmarks + Newton-Schulz pinv)
// ---------------------------------------------------------------------------

fn segment_means(x: &Mat, m: usize) -> Mat {
    let n = x.rows();
    let seg = n / m;
    let mut out = Mat::zeros(m, x.cols());
    for s in 0..m {
        for i in s * seg..(s + 1) * seg {
            for (o, &val) in out.row_mut(s).iter_mut().zip(x.row(i)) {
                *o += val;
            }
        }
        let inv = 1.0 / seg as f32;
        for o in out.row_mut(s) {
            *o *= inv;
        }
    }
    out
}

fn softmax_scores(a: &Mat, b: &Mat, scale: f32) -> Mat {
    let mut s = a.matmul_t(b);
    s.map_inplace(|x| x * scale);
    s.softmax_rows();
    s
}

/// Newton–Schulz iterative pseudo-inverse (matches ref.py, 12 iters).
pub fn newton_schulz_pinv(a: &Mat, iters: usize) -> Mat {
    let n = a.rows();
    let max_col: f32 = (0..n)
        .map(|j| (0..n).map(|i| a.get(i, j).abs()).sum::<f32>())
        .fold(0.0, f32::max);
    let max_row: f32 = (0..n).map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>()).fold(0.0, f32::max);
    let mut z = a.transpose().scale(1.0 / (max_col * max_row).max(1e-12));
    let ident = Mat::eye(n);
    for _ in 0..iters {
        let az = a.matmul(&z);
        // z <- z (13 I - az (15 I - az (7 I - az))) / 4
        let t1 = ident.scale(7.0).sub(&az);
        let t2 = ident.scale(15.0).sub(&az.matmul(&t1));
        let t3 = ident.scale(13.0).sub(&az.matmul(&t2));
        z = z.matmul(&t3).scale(0.25);
    }
    z
}

pub fn nystrom_attention(q: &Mat, k: &Mat, v: &Mat, landmarks: usize) -> Mat {
    let n = q.rows();
    let m = landmarks.min(n);
    assert!(n % m == 0, "N must divide landmark count");
    let d = q.cols();
    let scale = 1.0 / (d as f32).sqrt();
    let q_l = segment_means(q, m);
    let k_l = segment_means(k, m);
    let f = softmax_scores(q, &k_l, scale); // (n, m)
    let a = softmax_scores(&q_l, &k_l, scale); // (m, m)
    let b = softmax_scores(&q_l, k, scale); // (m, n)
    f.matmul(&newton_schulz_pinv(&a, 12).matmul(&b.matmul(v)))
}

// ---------------------------------------------------------------------------
// Block-diagonal + LLN+Diag (paper sec. 4.2)
// ---------------------------------------------------------------------------

/// One diagonal tile's row-stochastic softmax scores: the shared kernel
/// of [`blockdiag_attention`], [`par_blockdiag_attention`], and
/// [`blockdiag_attention_matrix`] (keep them numerically identical).
/// Scores come from the register-blocked
/// [`micro::matmul_t_block`](crate::tensor::micro::matmul_t_block) over
/// the tile's contiguous row range — the same microkernel the fused
/// softmax path uses — so the LLN+Diag score path shares the SIMD
/// kernels too.  The [`AttnSpec`] mask applies *inside* the tile:
/// global row `b0 + i` keeps the tile keys below its row limit, so a
/// causal BlockDiag tile is lower-triangular and tiles past `key_len`
/// go fully dead (zero rows).
fn softmax_tile(q: &Mat, k: &Mat, b0: usize, block: usize, scale: f32, spec: &AttnSpec) -> Mat {
    let d = q.cols();
    let nk = k.rows();
    let mut s = Mat::zeros(block, block);
    let qrows = &q.data()[b0 * d..(b0 + block) * d];
    let krows = &k.data()[b0 * d..(b0 + block) * d];
    // `Auto` resolves per call: batch tiles pick up the monomorphized
    // head-dim instance whenever `d` matches one (bitwise-identical
    // either way, so no dispatch handle needs to thread through the
    // blockdiag entry points).
    KernelDispatch::Auto.matmul_t_block(qrows, krows, s.data_mut(), block, d, block);
    if spec.is_full() && spec.scale.is_none() {
        // Bitwise-identical to the historical unmasked tile.
        s.map_inplace(|x| x * scale);
        s.softmax_rows();
        return s;
    }
    for i in 0..block {
        // Keys of this tile (global j = b0 + c) below row b0+i's limit.
        let lim = spec.row_limit(b0 + i, nk).saturating_sub(b0).min(block);
        masked_softmax_row(s.row_mut(i), lim, scale);
    }
    s
}

pub fn blockdiag_attention(q: &Mat, k: &Mat, v: &Mat, block: usize) -> Mat {
    blockdiag_attention_spec(q, k, v, block, &AttnSpec::FULL)
}

/// [`blockdiag_attention`] under an [`AttnSpec`] (causal tiles are
/// lower-triangular; tiles past `key_len` emit zero rows).
pub fn blockdiag_attention_spec(q: &Mat, k: &Mat, v: &Mat, block: usize, spec: &AttnSpec) -> Mat {
    let (n, d) = q.shape();
    assert!(n % block == 0, "N must divide block size");
    let scale = spec.resolve_scale(d);
    let mut out = Mat::zeros(n, v.cols());
    for b0 in (0..n).step_by(block) {
        let s = softmax_tile(q, k, b0, block, scale, spec);
        for i in 0..block {
            for j in 0..block {
                let p = s.get(i, j);
                for t in 0..v.cols() {
                    let cur = out.get(b0 + i, t);
                    out.set(b0 + i, t, cur + p * v.get(b0 + j, t));
                }
            }
        }
    }
    out
}

/// Block-diagonal attention with the independent diagonal tiles
/// partitioned across `threads` scoped workers (0 = auto).
pub fn par_blockdiag_attention(q: &Mat, k: &Mat, v: &Mat, block: usize, threads: usize) -> Mat {
    par_blockdiag_attention_spec(q, k, v, block, threads, &AttnSpec::FULL)
}

/// [`par_blockdiag_attention`] under an [`AttnSpec`].
pub fn par_blockdiag_attention_spec(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    block: usize,
    threads: usize,
    spec: &AttnSpec,
) -> Mat {
    let (n, d) = q.shape();
    assert!(n % block == 0, "N must divide block size");
    let dv = v.cols();
    let tiles = n / block;
    let threads = if threads == 0 { crate::tensor::default_threads() } else { threads };
    let t = threads.max(1).min(tiles.max(1));
    if t <= 1 || n == 0 || dv == 0 {
        return blockdiag_attention_spec(q, k, v, block, spec);
    }
    let scale = spec.resolve_scale(d);
    let tiles_per = tiles.div_ceil(t);
    let mut out = Mat::zeros(n, dv);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .data_mut()
        .chunks_mut(tiles_per * block * dv)
        .enumerate()
        .map(|(gi, group)| {
            let tile0 = gi * tiles_per;
            Box::new(move || {
                let tiles_here = group.len() / (block * dv);
                for ti in 0..tiles_here {
                    let b0 = (tile0 + ti) * block;
                    let s = softmax_tile(q, k, b0, block, scale, spec);
                    let rows = &mut group[ti * block * dv..(ti + 1) * block * dv];
                    for i in 0..block {
                        let orow = &mut rows[i * dv..(i + 1) * dv];
                        for j in 0..block {
                            let p = s.get(i, j);
                            for (o, &vv) in orow.iter_mut().zip(v.row(b0 + j)) {
                                *o += p * vv;
                            }
                        }
                    }
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::util::compute_pool::scope(tasks);
    out
}

/// Dense N x N stochastic matrix of block-diagonal attention: softmax
/// tiles on the diagonal, exact zeros elsewhere.  Row-stochastic by
/// construction, which gives BlockDiag (and LLN+Diag) an explicit-matrix
/// route for the parity and analysis suites.
pub fn blockdiag_attention_matrix(q: &Mat, k: &Mat, block: usize) -> Mat {
    blockdiag_attention_matrix_spec(q, k, block, &AttnSpec::FULL)
}

/// [`blockdiag_attention_matrix`] under an [`AttnSpec`].
pub fn blockdiag_attention_matrix_spec(q: &Mat, k: &Mat, block: usize, spec: &AttnSpec) -> Mat {
    let (n, d) = q.shape();
    assert!(n % block == 0, "N must divide block size");
    let scale = spec.resolve_scale(d);
    let mut p = Mat::zeros(n, n);
    for b0 in (0..n).step_by(block) {
        let s = softmax_tile(q, k, b0, block, scale, spec);
        for i in 0..block {
            for j in 0..block {
                p.set(b0 + i, b0 + j, s.get(i, j));
            }
        }
    }
    p
}

pub fn lln_diag_attention(q: &Mat, k: &Mat, v: &Mat, alpha: f32, beta: f32, block: usize) -> Mat {
    let long = lln_attention(q, k, v, alpha, beta);
    let short = blockdiag_attention(q, k, v, block);
    let mut out = long;
    for (o, s) in out.data_mut().iter_mut().zip(short.data()) {
        *o = 0.5 * (*o + s);
    }
    out
}

// ---------------------------------------------------------------------------
// Linformer (projection baseline)
// ---------------------------------------------------------------------------

pub fn linformer_attention(q: &Mat, k: &Mat, v: &Mat, e: &Mat, f: &Mat) -> Mat {
    // e, f: (n, kproj); project keys/values along the sequence axis.
    let kp = e.transpose().matmul(k); // (kproj, d)
    let vp = f.transpose().matmul(v); // (kproj, dv)
    softmax_attention(q, &kp, &vp)
}

/// Dispatch: stochastic matrix for any method (fig. 2 sweeps).  Routed
/// through the [`super::backend`] registry so analysis callers and the
/// serving/bench hot paths share one dispatch point.
pub fn attention_matrix(method: super::Method, q: &Mat, k: &Mat, alpha: f32, beta: f32) -> Mat {
    attention_matrix_spec(method, q, k, alpha, beta, &AttnSpec::FULL)
}

/// [`attention_matrix`] under an [`AttnSpec`] (causal / padded sweeps).
pub fn attention_matrix_spec(
    method: super::Method,
    q: &Mat,
    k: &Mat,
    alpha: f32,
    beta: f32,
    spec: &AttnSpec,
) -> Mat {
    let params = super::backend::BackendParams { alpha, beta, ..Default::default() };
    super::backend::backend_for(method, params)
        .explicit_matrix(q, k, spec)
        .unwrap_or_else(|| panic!("no dense stochastic-matrix form for {method:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::gaussian_qkv;
    use crate::rng::Pcg64;

    fn probe(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Pcg64::seed(seed);
        gaussian_qkv(n, d, 1.0, 1.0, &mut rng)
    }

    #[test]
    fn softmax_matrix_is_stochastic() {
        let (q, k, _) = probe(64, 32, 1);
        assert!(softmax_attention_matrix(&q, &k).is_stochastic(1e-4));
    }

    #[test]
    fn lln_matrix_is_stochastic() {
        let (q, k, _) = probe(64, 32, 2);
        assert!(lln_attention_matrix(&q, &k, 2.0, 2.0).is_stochastic(1e-4));
    }

    #[test]
    fn linear_attention_matches_explicit_matrix_route() {
        let (q, k, v) = probe(64, 16, 3);
        let pq = lln_features(&q, 1.5);
        let pk = lln_features(&k, 1.5);
        let fast = linear_attention(&pq, &pk, &v);
        let slow = linear_attention_matrix(&pq, &pk).matmul(&v);
        assert!(fast.max_abs_diff(&slow) < 1e-3, "{}", fast.max_abs_diff(&slow));
    }

    #[test]
    fn softmax_output_in_value_hull() {
        let (q, k, v) = probe(48, 16, 4);
        let out = softmax_attention(&q, &k, &v);
        let vmax = v.data().iter().cloned().fold(f32::MIN, f32::max);
        let vmin = v.data().iter().cloned().fold(f32::MAX, f32::min);
        assert!(out.data().iter().all(|&x| x <= vmax + 1e-4 && x >= vmin - 1e-4));
    }

    #[test]
    fn blockdiag_matches_softmax_when_block_is_full() {
        let (q, k, v) = probe(32, 16, 5);
        let full = softmax_attention(&q, &k, &v);
        let blocked = blockdiag_attention(&q, &k, &v, 32);
        assert!(full.max_abs_diff(&blocked) < 1e-4);
    }

    #[test]
    fn blockdiag_blocks_are_independent() {
        // Perturbing tokens in block 1 must not change block 0's output.
        let (q, k, v) = probe(64, 16, 6);
        let base = blockdiag_attention(&q, &k, &v, 32);
        let mut k2 = k.clone();
        for j in 32..64 {
            for t in 0..16 {
                k2.set(j, t, 9.9);
            }
        }
        let pert = blockdiag_attention(&q, &k2, &v, 32);
        for i in 0..32 {
            for t in 0..16 {
                assert!((base.get(i, t) - pert.get(i, t)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn newton_schulz_inverts_well_conditioned() {
        let mut rng = Pcg64::seed(7);
        // Diagonally-dominant stochastic-ish matrix: well-conditioned.
        let mut a = Mat::gaussian(16, 16, 0.05, &mut rng);
        for i in 0..16 {
            let v = a.get(i, i);
            a.set(i, i, v + 1.0);
        }
        let inv = newton_schulz_pinv(&a, 18);
        let prod = a.matmul(&inv);
        let err = prod.max_abs_diff(&Mat::eye(16));
        assert!(err < 1e-2, "err {err}");
    }

    #[test]
    fn nystrom_close_to_softmax_on_smooth_inputs() {
        // With low-rank-ish structure, Nystrom approximates SA decently.
        let mut rng = Pcg64::seed(8);
        let (q, k, v) = gaussian_qkv(64, 16, 0.3, 0.3, &mut rng);
        let exact = softmax_attention(&q, &k, &v);
        let approx = nystrom_attention(&q, &k, &v, 16);
        let denom = exact.data().iter().map(|x| x.abs()).fold(0.0, f32::max);
        assert!(exact.max_abs_diff(&approx) / denom < 0.35);
    }

    #[test]
    fn performer_approximates_softmax_rowdist() {
        // Performer's matrix should correlate with SA's on mild inputs.
        let mut rng = Pcg64::seed(9);
        let (q, k, _) = gaussian_qkv(48, 32, 0.5, 0.5, &mut rng);
        let proj = performer_projection(32, 128, 11);
        let pf = performer_attention_matrix(&q, &k, &proj);
        assert!(pf.is_stochastic(1e-3));
    }

    #[test]
    fn lln_diag_is_average_of_parts() {
        let (q, k, v) = probe(64, 16, 10);
        let combo = lln_diag_attention(&q, &k, &v, 2.0, 2.0, 32);
        let a = lln_attention(&q, &k, &v, 2.0, 2.0);
        let b = blockdiag_attention(&q, &k, &v, 32);
        for i in 0..combo.data().len() {
            let want = 0.5 * (a.data()[i] + b.data()[i]);
            assert!((combo.data()[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn linformer_reduces_context_length() {
        let (q, k, v) = probe(64, 16, 11);
        let mut rng = Pcg64::seed(12);
        let e = Mat::gaussian(64, 8, 0.1, &mut rng);
        let f = Mat::gaussian(64, 8, 0.1, &mut rng);
        let out = linformer_attention(&q, &k, &v, &e, &f);
        assert_eq!(out.shape(), (64, 16));
        assert!(out.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn clamped_exp_is_finite_at_extremes() {
        assert!(clamped_exp(1e6).is_finite());
        assert!(clamped_exp(-1e6) > 0.0);
    }

    #[test]
    fn streamed_linear_attention_matches_naive() {
        let (q, k, v) = probe(96, 24, 12);
        let pq = lln_features(&q, 1.2);
        let pk = lln_features(&k, 1.2);
        let naive = linear_attention(&pq, &pk, &v);
        for (chunk, threads) in [(1, 1), (7, 2), (32, 3), (96, 1), (200, 2), (0, 0)] {
            let fast = linear_attention_streamed(&pq, &pk, &v, chunk, threads);
            let err = fast.max_abs_diff(&naive);
            assert!(err < 1e-4, "chunk={chunk} threads={threads}: {err}");
        }
    }

    #[test]
    fn streamed_handles_rectangular_value_dims() {
        let mut rng = Pcg64::seed(13);
        let pq = Mat::gaussian(40, 8, 0.5, &mut rng).map(|x| x.abs());
        let pk = Mat::gaussian(56, 8, 0.5, &mut rng).map(|x| x.abs());
        let v = Mat::gaussian(56, 5, 1.0, &mut rng);
        let naive = linear_attention(&pq, &pk, &v);
        let fast = linear_attention_streamed(&pq, &pk, &v, 9, 2);
        assert!(fast.max_abs_diff(&naive) < 1e-4);
    }

    #[test]
    fn par_blockdiag_matches_serial() {
        let (q, k, v) = probe(128, 16, 14);
        let serial = blockdiag_attention(&q, &k, &v, 32);
        for threads in [1usize, 2, 3, 0] {
            let par = par_blockdiag_attention(&q, &k, &v, 32, threads);
            assert!(serial.max_abs_diff(&par) < 1e-6, "threads={threads}");
        }
    }

    #[test]
    fn fused_softmax_matches_dense_route() {
        let (q, k, v) = probe(96, 24, 20);
        let dense = softmax_attention_matrix(&q, &k).matmul(&v);
        // Tiles that divide n, tiles that don't, tile == 1, tile > n,
        // every unroll mode, and thread counts beyond the row count.
        for (tile, unroll, threads) in
            [(16, 4, 1), (0, 0, 0), (7, 1, 3), (1, 2, 2), (200, 8, 4), (96, 3, 128)]
        {
            let fused = fused_softmax_attention(&q, &k, &v, tile, unroll, threads);
            let err = fused.max_abs_diff(&dense);
            assert!(err < 1e-5, "tile={tile} unroll={unroll} threads={threads}: {err}");
        }
    }

    #[test]
    fn fused_softmax_handles_rectangular_shapes() {
        let mut rng = Pcg64::seed(21);
        let q = Mat::gaussian(37, 16, 0.8, &mut rng);
        let k = Mat::gaussian(53, 16, 0.8, &mut rng);
        let v = Mat::gaussian(53, 5, 1.0, &mut rng);
        let dense = softmax_attention_matrix(&q, &k).matmul(&v);
        let fused = fused_softmax_attention(&q, &k, &v, 8, 4, 2);
        assert!(fused.max_abs_diff(&dense) < 1e-5);
    }

    #[test]
    fn fused_softmax_stable_at_extreme_scores() {
        // Rows with huge score spread: the online max must keep every
        // exp() in range, exactly like the dense stable softmax.
        let mut rng = Pcg64::seed(22);
        let mut q = Mat::gaussian(16, 8, 1.0, &mut rng);
        for t in 0..8 {
            q.set(0, t, 300.0);
            q.set(1, t, -300.0);
        }
        let k = Mat::gaussian(48, 8, 1.0, &mut rng);
        let v = Mat::gaussian(48, 4, 1.0, &mut rng);
        let out = fused_softmax_attention(&q, &k, &v, 16, 4, 2);
        assert!(out.data().iter().all(|x| x.is_finite()));
        let dense = softmax_attention_matrix(&q, &k).matmul(&v);
        assert!(out.max_abs_diff(&dense) < 1e-4);
    }

    #[test]
    fn fused_softmax_output_in_value_hull() {
        let (q, k, v) = probe(64, 16, 23);
        let out = fused_softmax_attention(&q, &k, &v, 24, 4, 3);
        let vmax = v.data().iter().cloned().fold(f32::MIN, f32::max);
        let vmin = v.data().iter().cloned().fold(f32::MAX, f32::min);
        assert!(out.data().iter().all(|&x| x <= vmax + 1e-4 && x >= vmin - 1e-4));
    }

    #[test]
    fn fused_quadratic_matches_matrix_route() {
        let (q, k, v) = probe(80, 16, 24);
        let dense = quadratic_attention_matrix(&q, &k).matmul(&v);
        for (tile, unroll, threads) in [(16, 4, 1), (0, 0, 0), (13, 2, 3), (300, 1, 2)] {
            let fused = fused_quadratic_attention(&q, &k, &v, tile, unroll, threads);
            let err = fused.max_abs_diff(&dense);
            assert!(err < 1e-4, "tile={tile} unroll={unroll} threads={threads}: {err}");
        }
    }

    #[test]
    fn fused_kernels_handle_degenerate_shapes() {
        let empty_q = Mat::zeros(0, 8);
        let k = Mat::zeros(4, 8);
        let v = Mat::zeros(4, 3);
        assert_eq!(fused_softmax_attention(&empty_q, &k, &v, 0, 0, 0).shape(), (0, 3));
        let one = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let kv = Mat::from_vec(1, 2, vec![0.5, -0.5]);
        let vv = Mat::from_vec(1, 1, vec![3.0]);
        // n=1: softmax over a single key is exactly that value row.
        let out = fused_softmax_attention(&one, &kv, &vv, 64, 4, 8);
        assert!((out.get(0, 0) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn blockdiag_matrix_is_stochastic_and_matches_forward() {
        let (q, k, v) = probe(96, 16, 15);
        let p = blockdiag_attention_matrix(&q, &k, 32);
        assert!(p.is_stochastic(1e-4));
        // Off-tile entries are exact zeros.
        for i in 0..96 {
            for j in 0..96 {
                if i / 32 != j / 32 {
                    assert_eq!(p.get(i, j), 0.0);
                }
            }
        }
        let via_matrix = p.matmul(&v);
        let direct = blockdiag_attention(&q, &k, &v, 32);
        assert!(via_matrix.max_abs_diff(&direct) < 1e-5);
    }

    // -- AttnSpec (causal / padded) kernels ---------------------------------

    #[test]
    fn masked_softmax_matrix_shape_and_mass() {
        let (q, k, _) = probe(48, 16, 30);
        let causal = softmax_attention_matrix_spec(&q, &k, &AttnSpec::CAUSAL);
        assert!(causal.is_stochastic(1e-4));
        for i in 0..48 {
            for j in (i + 1)..48 {
                assert_eq!(causal.get(i, j), 0.0, "future key {j} leaked into row {i}");
            }
        }
        let padded = softmax_attention_matrix_spec(&q, &k, &AttnSpec::padded(20));
        for i in 0..48 {
            let s: f32 = padded.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
            for j in 20..48 {
                assert_eq!(padded.get(i, j), 0.0);
            }
        }
        // key_len == 0: no mass anywhere.
        let dead = softmax_attention_matrix_spec(&q, &k, &AttnSpec::padded(0));
        assert!(dead.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn full_spec_matrix_is_bitwise_the_unmasked_matrix() {
        let (q, k, _) = probe(32, 8, 31);
        let a = softmax_attention_matrix(&q, &k);
        let b = softmax_attention_matrix_spec(&q, &k, &AttnSpec::FULL);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn fused_causal_softmax_matches_masked_dense() {
        let (q, k, v) = probe(96, 24, 32);
        let spec = AttnSpec::CAUSAL;
        let dense = softmax_attention_matrix_spec(&q, &k, &spec).matmul(&v);
        // Off-tile n, tile == 1, tile > n, threads > rows.
        for (tile, unroll, threads) in
            [(16, 4, 1), (0, 0, 0), (7, 1, 3), (1, 2, 2), (200, 8, 4), (96, 3, 128)]
        {
            let fused = fused_softmax_attention_spec(&q, &k, &v, &spec, tile, unroll, threads);
            let err = fused.max_abs_diff(&dense);
            assert!(err < 1e-5, "tile={tile} unroll={unroll} threads={threads}: {err}");
        }
    }

    #[test]
    fn fused_causal_padded_softmax_matches_masked_dense() {
        let (q, k, v) = probe(80, 16, 33);
        for key_len in [0usize, 1, 13, 40, 80, 200] {
            let spec = AttnSpec::causal_padded(key_len);
            let dense = softmax_attention_matrix_spec(&q, &k, &spec).matmul(&v);
            let fused = fused_softmax_attention_spec(&q, &k, &v, &spec, 17, 3, 2);
            let err = fused.max_abs_diff(&dense);
            assert!(err < 1e-5, "key_len={key_len}: {err}");
        }
    }

    #[test]
    fn fused_spec_honors_scale_override() {
        let (q, k, v) = probe(40, 16, 34);
        let spec = AttnSpec { scale: Some(0.05), ..AttnSpec::FULL };
        let dense = softmax_attention_matrix_spec(&q, &k, &spec).matmul(&v);
        let fused = fused_softmax_attention_spec(&q, &k, &v, &spec, 16, 4, 2);
        assert!(fused.max_abs_diff(&dense) < 1e-5);
    }

    #[test]
    fn fused_causal_quadratic_matches_masked_dense() {
        let (q, k, v) = probe(72, 16, 35);
        for spec in [AttnSpec::CAUSAL, AttnSpec::causal_padded(30), AttnSpec::padded(50)] {
            let dense = quadratic_attention_matrix_spec(&q, &k, &spec).matmul(&v);
            for (tile, unroll, threads) in [(16, 4, 1), (13, 2, 3), (300, 1, 2)] {
                let fused = fused_quadratic_attention_spec(&q, &k, &v, &spec, tile, unroll, threads);
                let err = fused.max_abs_diff(&dense);
                assert!(err < 1e-4, "{spec:?} tile={tile}: {err}");
            }
        }
    }

    #[test]
    fn causal_linear_matches_masked_dense() {
        let (q, k, v) = probe(96, 16, 36);
        let pq = lln_features(&q, 1.2);
        let pk = lln_features(&k, 1.2);
        let spec = AttnSpec::CAUSAL;
        let dense = linear_attention_matrix_spec(&pq, &pk, &spec).matmul(&v);
        for (chunk, threads) in [(1, 1), (7, 2), (32, 3), (96, 1), (200, 2), (0, 0)] {
            let fast = linear_attention_causal(&pq, &pk, &v, None, chunk, threads);
            let err = fast.max_abs_diff(&dense);
            assert!(err < 1e-4, "chunk={chunk} threads={threads}: {err}");
        }
    }

    #[test]
    fn causal_linear_respects_key_padding() {
        let (q, k, v) = probe(64, 12, 37);
        let pq = elu_features(&q);
        let pk = elu_features(&k);
        for key_len in [0usize, 1, 20, 64] {
            let spec = AttnSpec::causal_padded(key_len);
            let dense = linear_attention_matrix_spec(&pq, &pk, &spec).matmul(&v);
            let fast = linear_attention_causal(&pq, &pk, &v, Some(key_len), 9, 3);
            let err = fast.max_abs_diff(&dense);
            assert!(err < 1e-4, "key_len={key_len}: {err}");
        }
    }

    #[test]
    fn linear_spec_padding_truncates_keys() {
        let (q, k, v) = probe(48, 8, 38);
        let pq = lln_features(&q, 0.9);
        let pk = lln_features(&k, 0.9);
        let spec = AttnSpec::padded(17);
        let dense = linear_attention_matrix_spec(&pq, &pk, &spec).matmul(&v);
        let fast = linear_attention_spec(&pq, &pk, &v, &spec, 5, 2);
        assert!(fast.max_abs_diff(&dense) < 1e-4);
        // And the full spec stays on the streamed path.
        let full = linear_attention_spec(&pq, &pk, &v, &AttnSpec::FULL, 5, 2);
        let streamed = linear_attention_streamed(&pq, &pk, &v, 5, 2);
        assert_eq!(full.data(), streamed.data());
    }

    #[test]
    fn causal_blockdiag_tiles_are_lower_triangular() {
        let (q, k, v) = probe(64, 16, 39);
        let p = blockdiag_attention_matrix_spec(&q, &k, 32, &AttnSpec::CAUSAL);
        for i in 0..64 {
            for j in 0..64 {
                if j > i || i / 32 != j / 32 {
                    assert_eq!(p.get(i, j), 0.0, "({i},{j})");
                }
            }
        }
        assert!(p.is_stochastic(1e-4));
        let direct = blockdiag_attention_spec(&q, &k, &v, 32, &AttnSpec::CAUSAL);
        let par = par_blockdiag_attention_spec(&q, &k, &v, 32, 3, &AttnSpec::CAUSAL);
        assert!(direct.max_abs_diff(&p.matmul(&v)) < 1e-5);
        assert!(direct.max_abs_diff(&par) < 1e-6);
    }

    #[test]
    fn causal_spans_cover_rows_and_balance_pairs() {
        let spec = AttnSpec::CAUSAL;
        for (n, t) in [(1000usize, 4usize), (97, 3), (8, 8), (5, 16), (1, 2)] {
            let spans = balanced_causal_spans(n, n, &spec, t);
            // Exact in-order coverage, no empty spans, at most t spans.
            assert!(spans.len() <= t.max(1).min(n));
            let mut next = 0;
            for &(row0, len) in &spans {
                assert_eq!(row0, next);
                assert!(len >= 1);
                next += len;
            }
            assert_eq!(next, n);
            // Live-pair load is balanced: no span carries more than
            // ~25% above the mean (an even row split would give the
            // last of 4 workers ~75% above).
            if n >= 100 && spans.len() == t {
                let load = |&(row0, len): &(usize, usize)| -> f64 {
                    (row0..row0 + len).map(|i| (i + 1) as f64).sum()
                };
                let total: f64 = spans.iter().map(load).sum();
                let mean = total / spans.len() as f64;
                for s in &spans {
                    assert!(load(s) <= 1.25 * mean, "span {s:?} overloaded in n={n} t={t}");
                }
            }
        }
    }

    #[test]
    fn accumulate_state_dispatch_is_bitwise() {
        // The monomorphized state fold must be bitwise-equal to the
        // generic fold for every dispatch value, at specialized and
        // unspecialized value dims alike (mismatched pins fall back).
        let mut rng = Pcg64::seed(41);
        for dv in [5usize, 32, 64, 128] {
            let m = 24;
            let krow = {
                let mut r = vec![0.0f32; m];
                rng.fill_gaussian(&mut r, 0.0, 1.0);
                r[3] = 0.0; // exercise the kf == 0 skip
                r
            };
            let mut vrow = vec![0.0f32; dv];
            rng.fill_gaussian(&mut vrow, 0.0, 1.0);
            let mut kv_ref = vec![0.1f32; m * dv];
            let mut z_ref = vec![0.2f32; m];
            accumulate_state(&mut kv_ref, &mut z_ref, &krow, &vrow, dv);
            for kern in [
                KernelDispatch::Auto,
                KernelDispatch::Generic,
                KernelDispatch::D32,
                KernelDispatch::D64,
                KernelDispatch::D128,
            ] {
                let mut kv = vec![0.1f32; m * dv];
                let mut z = vec![0.2f32; m];
                accumulate_state_dispatch(kern, &mut kv, &mut z, &krow, &vrow, dv);
                assert_eq!(kv, kv_ref, "kv diverged: {kern:?} dv={dv}");
                assert_eq!(z, z_ref, "z diverged: {kern:?} dv={dv}");
            }
        }
    }

    #[test]
    fn dispatched_kernels_are_bitwise_across_dispatch_values() {
        // Every dispatch value (including mismatched pins) must give
        // bitwise-identical outputs on the fused forwards, the decode
        // steps, and the causal prefix recurrence — at a specialized
        // head dim (64) and an unspecialized one (24).
        for d in [24usize, 64] {
            let (q, k, v) = probe(48, d, 42);
            let spec = AttnSpec::CAUSAL;
            let base_sm = fused_softmax_attention_spec(&q, &k, &v, &spec, 16, 4, 2);
            let base_qd = fused_quadratic_attention_spec(&q, &k, &v, &spec, 16, 4, 2);
            let scale = 1.0 / (d as f32).sqrt();
            let base_step =
                fused_softmax_decode_step(q.row(0), k.data(), v.data(), 48, d, d, scale, 16);
            let pq = lln_features(&q, 1.1);
            let pk = lln_features(&k, 1.1);
            let base_lin = linear_attention_causal(&pq, &pk, &v, None, 16, 2);
            for kern in [
                KernelDispatch::Auto,
                KernelDispatch::Generic,
                KernelDispatch::D32,
                KernelDispatch::D64,
                KernelDispatch::D128,
            ] {
                let sm = fused_softmax_attention_dispatch(&q, &k, &v, &spec, 16, 4, 2, kern);
                assert_eq!(sm.data(), base_sm.data(), "softmax: {kern:?} d={d}");
                let qd = fused_quadratic_attention_dispatch(&q, &k, &v, &spec, 16, 4, 2, kern);
                assert_eq!(qd.data(), base_qd.data(), "quadratic: {kern:?} d={d}");
                let st = fused_softmax_decode_step_dispatch(
                    q.row(0),
                    k.data(),
                    v.data(),
                    48,
                    d,
                    d,
                    scale,
                    16,
                    kern,
                );
                assert_eq!(st, base_step, "decode step: {kern:?} d={d}");
                let lin = linear_attention_causal_dispatch(&pq, &pk, &v, None, 16, 2, kern);
                assert_eq!(lin.data(), base_lin.data(), "linear: {kern:?} d={d}");
            }
        }
    }

    #[test]
    fn fused_causal_long_sequence_runs_in_tile_memory() {
        // The acceptance smoke: a causal fused forward at n=8192 never
        // touches an n×n buffer (its working set is O(ur·(tile+dv)) per
        // worker by construction) — this would OOM/time out long before
        // finishing if it materialized 8192² scores.
        let n = 8192;
        let mut rng = Pcg64::seed(40);
        let q = Mat::gaussian(n, 4, 0.8, &mut rng);
        let k = Mat::gaussian(n, 4, 0.8, &mut rng);
        let v = Mat::gaussian(n, 2, 1.0, &mut rng);
        let out = fused_softmax_attention_spec(&q, &k, &v, &AttnSpec::CAUSAL, 256, 0, 0);
        assert_eq!(out.shape(), (n, 2));
        assert!(out.data().iter().all(|x| x.is_finite()));
        // Row 0 attends only to key 0: exactly v[0].
        assert!((out.get(0, 0) - v.get(0, 0)).abs() < 1e-6);
        assert!((out.get(0, 1) - v.get(0, 1)).abs() < 1e-6);
    }
}
