"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes, block sizes, and input scales; assert_allclose
against ref.py is THE core correctness signal for the compute layer.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import autodiff as ad
from compile.kernels.linear_attn import linear_attention_pallas
from compile.kernels.flash_softmax import softmax_attention_pallas
from compile.kernels.blockdiag import blockdiag_attention_pallas

RTOL, ATOL = 2e-4, 2e-5
GRAD_RTOL, GRAD_ATOL = 7e-3, 5e-5


def make_qkv(seed, n, d, scale=1.0):
    rng = np.random.default_rng(seed)
    return tuple(
        jnp.asarray(rng.normal(0.0, scale, size=(n, d)), jnp.float32) for _ in range(3)
    )


# -- shape/scale sweeps -------------------------------------------------------

shape_strategy = st.tuples(
    st.sampled_from([64, 128, 256, 512]),     # n
    st.sampled_from([16, 32, 64]),            # d
    st.integers(0, 2**31 - 1),                # seed
    st.floats(0.3, 1.8),                      # input scale
)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_lln_kernel_matches_ref(args):
    n, d, seed, scale = args
    q, k, v = make_qkv(seed, n, d, scale)
    a, b = jnp.float32(0.9), jnp.float32(1.1)
    got = linear_attention_pallas(q, k, v, a, b, feature_map="lln", block_q=64, block_k=64)
    want = ref.lln_attention(q, k, v, a, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_flash_softmax_matches_ref(args):
    n, d, seed, scale = args
    q, k, v = make_qkv(seed, n, d, scale)
    got = softmax_attention_pallas(q, k, v, block_q=64, block_k=64)
    want = ref.softmax_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(shape_strategy, st.sampled_from([16, 32, 64]))
def test_blockdiag_matches_ref(args, block):
    n, d, seed, scale = args
    q, k, v = make_qkv(seed, n, d, scale)
    got = blockdiag_attention_pallas(q, k, v, block_size=block)
    want = ref.blockdiag_attention(q, k, v, block)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=8, deadline=None)
@given(shape_strategy)
def test_elu_kernel_matches_ref(args):
    n, d, seed, scale = args
    q, k, v = make_qkv(seed, n, d, scale)
    got = linear_attention_pallas(
        q, k, v, jnp.float32(1), jnp.float32(1), feature_map="elu", block_q=64, block_k=64
    )
    want = ref.elu_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


# -- block-size invariance ----------------------------------------------------

@pytest.mark.parametrize("bq,bk", [(32, 32), (32, 128), (128, 32), (256, 256)])
def test_lln_block_size_invariance(bq, bk):
    q, k, v = make_qkv(3, 256, 32)
    a = b = jnp.float32(0.8)
    base = ref.lln_attention(q, k, v, a, b)
    got = linear_attention_pallas(q, k, v, a, b, feature_map="lln", block_q=bq, block_k=bk)
    np.testing.assert_allclose(got, base, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bq,bk", [(32, 32), (32, 128), (128, 32)])
def test_flash_block_size_invariance(bq, bk):
    q, k, v = make_qkv(4, 256, 32)
    base = ref.softmax_attention(q, k, v)
    got = softmax_attention_pallas(q, k, v, block_q=bq, block_k=bk)
    np.testing.assert_allclose(got, base, rtol=RTOL, atol=ATOL)


def test_bad_block_size_raises():
    q, k, v = make_qkv(0, 100, 16)
    with pytest.raises(ValueError):
        linear_attention_pallas(q, k, v, 1.0, 1.0, block_q=64, block_k=64)
    with pytest.raises(ValueError):
        softmax_attention_pallas(q, k, v, block_q=64)


# -- numerics edge cases ------------------------------------------------------

def test_lln_large_scale_stays_finite():
    """EXP_CLAMP keeps the kernel finite for extreme alpha/sigma."""
    q, k, v = make_qkv(5, 128, 32, scale=8.0)
    out = linear_attention_pallas(q, k, v, jnp.float32(4.0), jnp.float32(4.0))
    assert bool(jnp.all(jnp.isfinite(out)))


def test_flash_softmax_large_scores_match_ref():
    q, k, v = make_qkv(6, 128, 32, scale=4.0)
    got = softmax_attention_pallas(q, k, v)
    want = ref.softmax_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-5)


def test_attention_rows_are_convex_combinations():
    """Output of softmax attention lies in the convex hull of V rows."""
    q, k, v = make_qkv(7, 64, 16)
    out = softmax_attention_pallas(q, k, v)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-4
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-4


# -- VJP correctness ----------------------------------------------------------

def _check_grads(f_pallas, f_ref, args, argnums):
    gp = jax.grad(lambda *a: jnp.sum(jnp.sin(f_pallas(*a))), argnums)(*args)
    gr = jax.grad(lambda *a: jnp.sum(jnp.sin(f_ref(*a))), argnums)(*args)
    for x, y in zip(gp, gr):
        np.testing.assert_allclose(x, y, rtol=GRAD_RTOL, atol=GRAD_ATOL)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128]), st.sampled_from([16, 32]))
def test_lln_vjp_matches_ref(seed, n, d):
    q, k, v = make_qkv(seed, n, d)
    a, b = jnp.float32(0.7), jnp.float32(1.2)
    _check_grads(
        lambda q, k, v, a, b: ad.lln_attention(q, k, v, a, b, block_q=64, block_k=64),
        ref.lln_attention,
        (q, k, v, a, b),
        (0, 1, 2, 3, 4),
    )


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128]), st.sampled_from([16, 32]))
def test_flash_vjp_matches_ref(seed, n, d):
    q, k, v = make_qkv(seed, n, d)
    _check_grads(
        lambda q, k, v: ad.softmax_attention(q, k, v, 64, 64),
        ref.softmax_attention,
        (q, k, v),
        (0, 1, 2),
    )


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_blockdiag_vjp_matches_ref(seed):
    q, k, v = make_qkv(seed, 128, 32)
    _check_grads(
        lambda q, k, v: ad.blockdiag_attention(q, k, v, 32),
        lambda q, k, v: ref.blockdiag_attention(q, k, v, 32),
        (q, k, v),
        (0, 1, 2),
    )


def test_elu_vjp_matches_ref():
    q, k, v = make_qkv(11, 128, 32)
    _check_grads(
        lambda q, k, v: ad.elu_attention(q, k, v, block_q=64, block_k=64),
        ref.elu_attention,
        (q, k, v),
        (0, 1, 2),
    )


def test_lln_diag_vjp_matches_ref():
    q, k, v = make_qkv(12, 128, 32)
    a, b = jnp.float32(0.7), jnp.float32(1.2)
    _check_grads(
        lambda q, k, v: ad.lln_diag_attention(q, k, v, a, b, 32, block_q=64, block_k=64),
        lambda q, k, v: ref.lln_diag_attention(q, k, v, a, b, 32),
        (q, k, v),
        (0, 1, 2),
    )


def test_vjp_under_vmap():
    """Multi-head usage: grads must survive vmap over a head axis."""
    q, k, v = make_qkv(13, 64, 16)
    qh, kh, vh = (jnp.stack([x, 0.5 * x]) for x in (q, k, v))
    a = b = jnp.float32(0.8)

    def total(att_fn, qh):
        return jnp.sum(jnp.sin(jax.vmap(lambda q, k, v: att_fn(q, k, v))(qh, kh, vh)))

    gp = jax.grad(lambda qh: total(lambda q, k, v: ad.lln_attention(q, k, v, a, b), qh))(qh)
    gr = jax.grad(lambda qh: total(lambda q, k, v: ref.lln_attention(q, k, v, a, b), qh))(qh)
    np.testing.assert_allclose(gp, gr, rtol=GRAD_RTOL, atol=GRAD_ATOL)
