//! Fig 1: temperature, entropy, and spectral gap of every layer's
//! attention matrix over the course of training.
//!
//! Uses the probe artifacts (`probe_<method>`): at intervals during MLM
//! training the probe executes the current parameters on a fixed batch
//! and returns the per-layer stochastic matrices + sigma stats; the Rust
//! analysis instruments then compute the fig. 1 series.

use anyhow::Result;

use super::maybe_write_csv;
use crate::analysis::layer_dynamics;
use crate::cli::Args;
use crate::config::TrainConfig;
use crate::data::Corpus;
use crate::runtime::{artifacts_dir, Engine, HostTensor};
use crate::tensor::Mat;
use crate::training::driver::TrainDriver;
use crate::util::print_table;

pub fn run_fig1(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let steps = args.get_usize("steps", 120)?;
    let probe_every = args.get_usize("probe-every", 30)?;
    let method = args.get_or("method", "softmax").to_string();
    let cfg = TrainConfig { lr: args.get_f64("lr", 5e-4)?, warmup: steps / 10, ..Default::default() };
    let mut engine = Engine::new(&dir)?;

    let train_artifact = format!("train_mlm_{method}");
    let probe_artifact = format!("probe_{method}");
    let probe_spec = engine.manifest().artifact(&probe_artifact)?.clone();
    let n_layers_nn: Vec<usize> = probe_spec.outputs[0].shape.clone(); // (L, N, N)
    let (n_layers, n) = (n_layers_nn[0], n_layers_nn[1]);

    println!("== Fig 1: attention dynamics during {method} MLM training ==");
    println!("   probing every {probe_every} steps; {n_layers} layers, N={n}\n");

    let mut driver = TrainDriver::new(&engine, &dir, &train_artifact)?;
    let mut corpus = Corpus::new(8192, 0);
    let probe_tokens: Vec<i32> = corpus.mlm_batch(2, n, 0.0).labels; // unmasked text

    let mut csv = Vec::new();
    let mut checkpoints: Vec<(usize, Vec<crate::analysis::LayerDynamics>)> = Vec::new();

    let probe = |driver: &TrainDriver, engine: &mut Engine, step: usize, csv: &mut Vec<String>| -> Result<Vec<crate::analysis::LayerDynamics>> {
        // probe inputs: p:* + tokens
        let mut inputs = driver.params().to_literals()?;
        inputs.push(
            HostTensor::I32 { shape: vec![2, n], data: probe_tokens.clone() }.to_literal()?,
        );
        let outs = engine.execute_literals(&probe_artifact, &inputs)?;
        let mats_flat = outs[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let stats = outs[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let mats: Vec<Mat> = (0..n_layers)
            .map(|l| Mat::from_vec(n, n, mats_flat[l * n * n..(l + 1) * n * n].to_vec()))
            .collect();
        let sigmas: Vec<(f64, f64)> = (0..n_layers)
            .map(|l| (stats[l * 4 + 2] as f64, stats[l * 4 + 3] as f64))
            .collect();
        let dyns = layer_dynamics(&mats, &sigmas);
        for d in &dyns {
            csv.push(format!(
                "{step},{},{:.4},{:.4},{:.4}",
                d.layer, d.temperature, d.entropy, d.spectral_gap
            ));
        }
        Ok(dyns)
    };

    checkpoints.push((0, probe(&driver, &mut engine, 0, &mut csv)?));
    for step in 0..steps {
        let b = corpus.mlm_batch(8, n, 0.15);
        driver.step(
            &mut engine,
            cfg.lr_at(step),
            &[
                HostTensor::I32 { shape: vec![8, n], data: b.tokens },
                HostTensor::I32 { shape: vec![8, n], data: b.labels },
                HostTensor::F32 { shape: vec![8, n], data: b.weights },
            ],
        )?;
        if (step + 1) % probe_every == 0 || step + 1 == steps {
            eprintln!("   probe @ step {}", step + 1);
            checkpoints.push((step + 1, probe(&driver, &mut engine, step + 1, &mut csv)?));
        }
    }

    for metric in ["temperature", "entropy", "spectral gap"] {
        println!("\n-- {metric} per layer over training --");
        let mut rows = Vec::new();
        for l in 0..n_layers {
            let mut row = vec![format!("layer {l}")];
            for (_, dyns) in &checkpoints {
                let d = &dyns[l];
                let v = match metric {
                    "temperature" => d.temperature,
                    "entropy" => d.entropy,
                    _ => d.spectral_gap,
                };
                row.push(format!("{v:.3}"));
            }
            rows.push(row);
        }
        let mut headers = vec!["".to_string()];
        headers.extend(checkpoints.iter().map(|(s, _)| format!("step {s}")));
        let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
        print_table(&hrefs, &rows);
    }
    println!("\npaper shape: temperature and entropy fall as training concentrates");
    println!("attention; mid layers concentrate hardest; the spectral gap separates");
    println!("biased from unbiased concentration (it can rise while entropy falls).");
    maybe_write_csv(args, "fig1", "step,layer,temperature,entropy,spectral_gap", &csv)?;
    Ok(())
}
