//! Vendored API stub of the `xla` (xla-rs / PJRT) crate.
//!
//! The build image has no libxla/PJRT shared objects and no crates.io
//! mirror, so this crate reproduces the *type surface* the repo's
//! runtime layer compiles against.  Host-side [`Literal`] construction
//! and readback are fully functional (they are plain byte buffers);
//! anything that would need a real PJRT client — [`PjRtClient::cpu`],
//! compilation, execution — returns [`Error`] at runtime.
//!
//! Every caller in the repo is already gated: integration tests, the
//! serving workers, and the benches check `artifacts_available()` (or
//! fall back to the native backends) before touching PJRT, so the stub
//! turns an unbuildable crate into a buildable one with the PJRT paths
//! cleanly disabled.

use std::borrow::Borrow;
use std::path::Path;

/// Stub error: a static description of the failed operation.
#[derive(Debug, Clone)]
pub struct Error {
    pub msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla stub: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the repo's manifests use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        4
    }
}

/// Marker for element types [`Literal::to_vec`] can read back.
pub trait NativeType: Sized + Copy {
    const ELEMENT_TYPE: ElementType;

    fn from_le(bytes: &[u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;

    fn from_le(bytes: &[u8; 4]) -> Self {
        f32::from_le_bytes(*bytes)
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;

    fn from_le(bytes: &[u8; 4]) -> Self {
        i32::from_le_bytes(*bytes)
    }
}

enum Repr {
    Array { ty: ElementType, dims: Vec<usize>, data: Vec<u8> },
    Tuple(Vec<Literal>),
}

/// Host-side tensor value.  Array construction/readback works fully;
/// tuples only ever come out of (stubbed, failing) execution.
pub struct Literal(Repr);

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product::<usize>().max(1);
        if untyped_data.len() != elems * ty.byte_size() {
            return Err(Error::new(format!(
                "literal {:?} {:?}: {} bytes, expected {}",
                ty,
                dims,
                untyped_data.len(),
                elems * ty.byte_size()
            )));
        }
        Ok(Literal(Repr::Array { ty, dims: dims.to_vec(), data: untyped_data.to_vec() }))
    }

    /// Build a tuple literal (used by tests of the stub itself).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal(Repr::Tuple(parts))
    }

    pub fn element_count(&self) -> usize {
        match &self.0 {
            Repr::Array { ty, data, .. } => data.len() / ty.byte_size(),
            Repr::Tuple(parts) => parts.len(),
        }
    }

    pub fn shape(&self) -> Result<Vec<usize>> {
        match &self.0 {
            Repr::Array { dims, .. } => Ok(dims.clone()),
            Repr::Tuple(_) => Err(Error::new("shape of a tuple literal")),
        }
    }

    /// Read the buffer back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.0 {
            Repr::Array { ty, data, .. } => {
                if *ty != T::ELEMENT_TYPE {
                    return Err(Error::new(format!(
                        "dtype mismatch: literal is {ty:?}, requested {:?}",
                        T::ELEMENT_TYPE
                    )));
                }
                Ok(data
                    .chunks_exact(4)
                    .map(|c| T::from_le(&[c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            Repr::Tuple(_) => Err(Error::new("to_vec on a tuple literal")),
        }
    }

    /// Destructure a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.0 {
            Repr::Tuple(parts) => Ok(parts),
            Repr::Array { .. } => Err(Error::new("to_tuple on an array literal")),
        }
    }
}

/// Parsed HLO module text (held verbatim; compilation is stubbed).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading {}: {e}", path.display())))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper (carried, never executed).
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// PJRT client handle.  [`PjRtClient::cpu`] fails in the stub — the
/// repo's runtime layer surfaces this as "artifacts unavailable".
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(
            "PJRT runtime not linked in this build (vendored stub); \
             native Rust backends remain fully functional",
        ))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new("compile unavailable without a PJRT runtime"))
    }
}

/// Device buffer handle returned by execution (unreachable in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new("device readback unavailable without a PJRT runtime"))
    }
}

/// Loaded executable handle (unreachable in the stub: compile fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("execute unavailable without a PJRT runtime"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let data: Vec<f32> = vec![1.0, -2.5, 3.25, 0.0, 5.0, 6.0];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.shape().unwrap(), vec![2, 3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
    }

    #[test]
    fn literal_round_trip_i32() {
        let data: Vec<i32> = vec![7, -8, 9];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[4], &[0u8; 4])
            .is_err());
    }

    #[test]
    fn tuple_destructures() {
        let bytes = 1f32.to_le_bytes();
        let a = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[], &bytes).unwrap();
        let t = Literal::tuple(vec![a]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![1.0]);
    }

    #[test]
    fn pjrt_paths_fail_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("PJRT"));
    }
}
