//! Dense row-major f32 matrix — the numeric substrate for the native
//! attention baselines and the analysis instruments.
//!
//! Deliberately small: a 2-D owned matrix with the handful of BLAS-2/3
//! operations the paper's math needs.  The matmul is cache-blocked with
//! a k-panel inner loop that autovectorizes well; it is the hot path of
//! the native analysis benches (see EXPERIMENTS.md §Perf).

use std::fmt;

/// Worker count for the parallel kernels: `LLN_THREADS` env override,
/// else the machine's available parallelism.  `0` passed to any `par_*`
/// entry point means "resolve via this function".
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LLN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested worker count: 0 means auto (the single source
/// of the 0-means-auto rule — config and kernels both consult this).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        default_threads()
    } else {
        requested
    }
}

/// Row-major dense matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Gaussian-filled matrix (mean 0, given std).
    pub fn gaussian(rows: usize, cols: usize, std: f32, rng: &mut crate::rng::Pcg64) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_gaussian(&mut m.data, 0.0, std);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// `self @ other` — cache-blocked ikj matmul.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        // ikj order: the inner j loop is a contiguous FMA over `other`'s
        // row and `out`'s row — autovectorizes to the machine width.
        const KB: usize = 64;
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        out
    }

    /// `self @ other` with the output rows partitioned across `threads`
    /// scoped worker threads (0 = auto, see [`default_threads`]).  Each
    /// worker runs the same cache-blocked ikj kernel as [`Mat::matmul`],
    /// in the same per-row floating-point order, so results are bitwise
    /// identical to the scalar path.
    pub fn par_matmul(&self, other: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let t = resolve_threads(threads).min(m.max(1));
        if t <= 1 || m == 0 || n == 0 {
            return self.matmul(other);
        }
        let mut out = Mat::zeros(m, n);
        let rows_per = m.div_ceil(t);
        let a = self.data.as_slice();
        let b = other.data.as_slice();
        std::thread::scope(|scope| {
            for (ti, chunk) in out.data.chunks_mut(rows_per * n).enumerate() {
                let row0 = ti * rows_per;
                scope.spawn(move || {
                    let rows_here = chunk.len() / n;
                    const KB: usize = 64;
                    for kb in (0..k).step_by(KB) {
                        let kend = (kb + KB).min(k);
                        for i in 0..rows_here {
                            let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
                            let orow = &mut chunk[i * n..(i + 1) * n];
                            for kk in kb..kend {
                                let av = arow[kk];
                                if av == 0.0 {
                                    continue;
                                }
                                let brow = &b[kk * n..(kk + 1) * n];
                                for j in 0..n {
                                    orow[j] += av * brow[j];
                                }
                            }
                        }
                    }
                });
            }
        });
        out
    }

    /// `self @ other^T` without materializing the transpose (dot-product
    /// kernel; both operands stream row-contiguously).
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                orow[j] = acc;
            }
        }
        out
    }

    /// `self @ other^T` with output rows partitioned across `threads`
    /// scoped workers (0 = auto).  Per-row FP order matches
    /// [`Mat::matmul_t`] exactly.
    pub fn par_matmul_t(&self, other: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let t = resolve_threads(threads).min(m.max(1));
        if t <= 1 || m == 0 || n == 0 {
            return self.matmul_t(other);
        }
        let mut out = Mat::zeros(m, n);
        let rows_per = m.div_ceil(t);
        let a = self.data.as_slice();
        let b = other.data.as_slice();
        std::thread::scope(|scope| {
            for (ti, chunk) in out.data.chunks_mut(rows_per * n).enumerate() {
                let row0 = ti * rows_per;
                scope.spawn(move || {
                    let rows_here = chunk.len() / n;
                    for i in 0..rows_here {
                        let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
                        let orow = &mut chunk[i * n..(i + 1) * n];
                        for j in 0..n {
                            let brow = &b[j * k..(j + 1) * k];
                            let mut acc = 0.0f32;
                            for kk in 0..k {
                                acc += arow[kk] * brow[kk];
                            }
                            orow[j] = acc;
                        }
                    }
                });
            }
        });
        out
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        let mut out = self.clone();
        out.map_inplace(f);
        out
    }

    pub fn scale(&self, s: f32) -> Mat {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        out
    }

    /// Row-wise softmax in place (numerically stable).
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for x in row.iter_mut() {
                *x = (*x - max).exp();
                sum += *x;
            }
            let inv = 1.0 / sum;
            for x in row.iter_mut() {
                *x *= inv;
            }
        }
    }

    /// Row-wise softmax with rows partitioned across `threads` scoped
    /// workers (0 = auto).  Rows are independent, so results are bitwise
    /// identical to [`Mat::softmax_rows`].
    pub fn par_softmax_rows(&mut self, threads: usize) {
        let (m, n) = (self.rows, self.cols);
        let t = resolve_threads(threads).min(m.max(1));
        if t <= 1 || m == 0 || n == 0 {
            self.softmax_rows();
            return;
        }
        let rows_per = m.div_ceil(t);
        std::thread::scope(|scope| {
            for chunk in self.data.chunks_mut(rows_per * n) {
                scope.spawn(move || {
                    for row in chunk.chunks_mut(n) {
                        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let mut sum = 0.0f32;
                        for x in row.iter_mut() {
                            *x = (*x - max).exp();
                            sum += *x;
                        }
                        let inv = 1.0 / sum;
                        for x in row.iter_mut() {
                            *x *= inv;
                        }
                    }
                });
            }
        });
    }

    /// Normalize each row to sum 1 (entries assumed non-negative).
    pub fn normalize_rows(&mut self, eps: f32) {
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            let sum: f32 = row.iter().sum::<f32>() + eps;
            let inv = 1.0 / sum;
            for x in row.iter_mut() {
                *x *= inv;
            }
        }
    }

    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(i)) {
                *o += x;
            }
        }
        out
    }

    pub fn mean(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }

    pub fn variance(&self) -> f64 {
        let mu = self.mean();
        self.data.iter().map(|&x| (x as f64 - mu).powi(2)).sum::<f64>() / self.data.len() as f64
    }

    /// Matrix–vector product `self @ v`.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(&a, &b)| a * b).sum())
            .collect()
    }

    /// Transposed matrix–vector product `self^T @ v`.
    pub fn matvec_t(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, v.len());
        let mut out = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let vi = v[i];
            for (o, &x) in out.iter_mut().zip(self.row(i)) {
                *o += vi * x;
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Check every row sums to ~1 and entries are non-negative.
    pub fn is_stochastic(&self, tol: f32) -> bool {
        self.data.iter().all(|&x| x >= -tol)
            && self.row_sums().iter().all(|&s| (s - 1.0).abs() < tol)
    }
}

/// Vector helpers shared by linalg/stats.
pub mod vec_ops {
    pub fn dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }
    pub fn norm(a: &[f32]) -> f64 {
        dot(a, a).sqrt()
    }
    pub fn scale_inplace(a: &mut [f32], s: f32) {
        for x in a {
            *x *= s;
        }
    }
    pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }
    pub fn mean(a: &[f32]) -> f64 {
        a.iter().map(|&x| x as f64).sum::<f64>() / a.len() as f64
    }
    pub fn variance(a: &[f32]) -> f64 {
        let mu = mean(a);
        a.iter().map(|&x| (x as f64 - mu).powi(2)).sum::<f64>() / a.len() as f64
    }
    pub fn std(a: &[f32]) -> f64 {
        variance(a).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_matches_matmul_of_transpose() {
        let mut rng = Pcg64::seed(1);
        let a = Mat::gaussian(7, 5, 1.0, &mut rng);
        let b = Mat::gaussian(9, 5, 1.0, &mut rng);
        let via_t = a.matmul_t(&b);
        let explicit = a.matmul(&b.transpose());
        assert!(via_t.max_abs_diff(&explicit) < 1e-5);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Pcg64::seed(2);
        let a = Mat::gaussian(4, 6, 1.0, &mut rng);
        assert_eq!(a, a.transpose().transpose());
    }

    #[test]
    fn softmax_rows_stochastic() {
        let mut rng = Pcg64::seed(3);
        let mut a = Mat::gaussian(10, 16, 3.0, &mut rng);
        a.softmax_rows();
        assert!(a.is_stochastic(1e-5));
    }

    #[test]
    fn softmax_handles_large_scores() {
        let mut a = Mat::from_vec(1, 3, vec![1000.0, 999.0, -1000.0]);
        a.softmax_rows();
        assert!(a.data().iter().all(|x| x.is_finite()));
        assert!((a.row_sums()[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn matvec_consistency() {
        let mut rng = Pcg64::seed(4);
        let a = Mat::gaussian(5, 7, 1.0, &mut rng);
        let v: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let direct = a.matvec(&v);
        let via_mat = a.matmul(&Mat::from_vec(7, 1, v.clone()));
        for (i, &x) in direct.iter().enumerate() {
            assert!((x - via_mat.get(i, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_t_consistency() {
        let mut rng = Pcg64::seed(5);
        let a = Mat::gaussian(5, 7, 1.0, &mut rng);
        let v: Vec<f32> = (0..5).map(|i| i as f32 - 2.0).collect();
        let direct = a.matvec_t(&v);
        let explicit = a.transpose().matvec(&v);
        for (x, y) in direct.iter().zip(&explicit) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn mean_variance() {
        let a = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        assert!((a.mean() - 2.5).abs() < 1e-9);
        assert!((a.variance() - 1.25).abs() < 1e-9);
    }

    #[test]
    fn normalize_rows_sums_to_one() {
        let mut a = Mat::from_vec(2, 3, vec![1.0, 1.0, 2.0, 3.0, 0.0, 1.0]);
        a.normalize_rows(0.0);
        for s in a.row_sums() {
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = a.matmul(&b);
    }

    #[test]
    fn par_matmul_bitwise_matches_scalar() {
        let mut rng = Pcg64::seed(6);
        for (m, k, n) in [(1, 7, 5), (17, 33, 9), (64, 64, 64), (65, 3, 2)] {
            let a = Mat::gaussian(m, k, 1.0, &mut rng);
            let b = Mat::gaussian(k, n, 1.0, &mut rng);
            let serial = a.matmul(&b);
            for t in [1usize, 2, 3, 8, 0] {
                let par = a.par_matmul(&b, t);
                assert_eq!(serial.data(), par.data(), "m={m} k={k} n={n} t={t}");
            }
        }
    }

    #[test]
    fn par_matmul_t_bitwise_matches_scalar() {
        let mut rng = Pcg64::seed(7);
        for (m, k, n) in [(1, 5, 3), (19, 16, 31), (48, 64, 48)] {
            let a = Mat::gaussian(m, k, 1.0, &mut rng);
            let b = Mat::gaussian(n, k, 1.0, &mut rng);
            let serial = a.matmul_t(&b);
            for t in [1usize, 2, 5, 0] {
                let par = a.par_matmul_t(&b, t);
                assert_eq!(serial.data(), par.data(), "m={m} k={k} n={n} t={t}");
            }
        }
    }

    #[test]
    fn par_softmax_rows_bitwise_matches_scalar() {
        let mut rng = Pcg64::seed(8);
        for (m, n) in [(1, 4), (13, 29), (64, 64)] {
            let base = Mat::gaussian(m, n, 3.0, &mut rng);
            let mut serial = base.clone();
            serial.softmax_rows();
            for t in [1usize, 2, 7, 0] {
                let mut par = base.clone();
                par.par_softmax_rows(t);
                assert_eq!(serial.data(), par.data(), "m={m} n={n} t={t}");
            }
        }
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
