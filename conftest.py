"""Make `pytest python/tests/` work from the repo root: the build-time
modules live under python/ (imported as `compile.*`)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
