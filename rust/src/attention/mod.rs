//! Native (pure-Rust) implementations of every attention mechanism the
//! paper compares — mirrors `python/compile/kernels/ref.py` numerically.
//!
//! These power the statistical figures (entropy / spectral gap /
//! histograms run over thousands of sampled matrices — far cheaper here
//! than through PJRT), serve as CPU baselines, and cross-check the AOT
//! kernels in integration tests.

pub mod backend;
pub mod kernels;
pub mod moment_matching;

pub use backend::{all_backends, backend_for, default_backend, AttentionBackend, BackendParams};
pub use kernels::*;
pub use moment_matching::MomentMatcher;

use crate::tensor::Mat;

/// Matches ref.py's EXP_CLAMP: keeps exp() finite in f32.
pub const EXP_CLAMP: f32 = 30.0;

/// Every attention method in the repo (paper Table 1/2 comparisons).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Softmax,
    Lln,
    LlnDiag,
    Elu,
    Relu,
    Quadratic,
    Performer,
    Nystrom,
    BlockDiag,
    Linformer,
}

impl Method {
    pub const ALL: [Method; 10] = [
        Method::Softmax,
        Method::Lln,
        Method::LlnDiag,
        Method::Elu,
        Method::Relu,
        Method::Quadratic,
        Method::Performer,
        Method::Nystrom,
        Method::BlockDiag,
        Method::Linformer,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Softmax => "softmax",
            Method::Lln => "lln",
            Method::LlnDiag => "lln_diag",
            Method::Elu => "elu",
            Method::Relu => "relu",
            Method::Quadratic => "quadratic",
            Method::Performer => "performer",
            Method::Nystrom => "nystrom",
            Method::BlockDiag => "blockdiag",
            Method::Linformer => "linformer",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Method::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// Memory/compute complexity class in sequence length.
    pub fn is_linear(&self) -> bool {
        !matches!(self, Method::Softmax | Method::Quadratic)
    }
}

/// Analytic memory model (bytes) for a single attention head's forward
/// pass — the Table 2 "Memory" column, parameterized like the paper
/// (the full matrix is kept for backward, so Softmax/Quadratic charge
/// n×n here even though the native *inference* forwards now run the
/// fused O(n·tile) kernels).  `n` sequence length, `d` head dim, f32
/// everywhere.
pub fn memory_model_bytes(method: Method, n: usize, d: usize) -> usize {
    let f = 4; // f32
    let io = 3 * n * d * f + n * d * f; // q, k, v, out
    match method {
        // Full N x N attention matrix is materialized for backward.
        Method::Softmax | Method::Quadratic => io + n * n * f,
        // Feature maps + (d x d) accumulator + normalizer.
        Method::Lln | Method::Elu | Method::Relu => io + 2 * n * d * f + d * d * f + d * f,
        // LLN + the block-diagonal tile stack (n/b blocks of b x b).
        Method::LlnDiag => {
            let b = 64.min(n);
            io + 2 * n * d * f + d * d * f + d * f + (n / b.max(1)) * b * b * f
        }
        Method::BlockDiag => {
            let b = 64.min(n);
            io + (n / b.max(1)) * b * b * f
        }
        // m features / landmarks / projected length.
        Method::Performer => io + 2 * n * d * f + d * d * f,
        Method::Nystrom => {
            let m = 32.min(n);
            io + 2 * n * m * f + m * m * f
        }
        Method::Linformer => {
            let k = 64.min(n);
            io + 2 * k * d * f + n * k * f
        }
    }
}

/// Sample Gaussian q, k (and optionally v) with given stds — the probe
/// inputs used throughout §3/§4 analysis.
pub fn gaussian_qkv(
    n: usize,
    d: usize,
    sigma_q: f32,
    sigma_k: f32,
    rng: &mut crate::rng::Pcg64,
) -> (Mat, Mat, Mat) {
    (
        Mat::gaussian(n, d, sigma_q, rng),
        Mat::gaussian(n, d, sigma_k, rng),
        Mat::gaussian(n, d, 1.0, rng),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_round_trip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn memory_model_quadratic_vs_linear() {
        let d = 64;
        // Quadratic methods blow up 16x when N quadruples; linear ~4x.
        let sm_1k = memory_model_bytes(Method::Softmax, 1024, d) as f64;
        let sm_4k = memory_model_bytes(Method::Softmax, 4096, d) as f64;
        assert!(sm_4k / sm_1k > 10.0);
        let lln_1k = memory_model_bytes(Method::Lln, 1024, d) as f64;
        let lln_4k = memory_model_bytes(Method::Lln, 4096, d) as f64;
        assert!(lln_4k / lln_1k < 5.0);
    }

    #[test]
    fn linear_classification() {
        assert!(!Method::Softmax.is_linear());
        assert!(Method::Lln.is_linear());
        assert!(Method::LlnDiag.is_linear());
    }
}
