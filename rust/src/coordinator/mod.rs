//! L3 serving coordinator: request router, sequence-length-bucketed
//! dynamic batcher, and a PJRT worker pool (vLLM-router-shaped, scaled
//! to the encoder-serving workload this paper implies).
//!
//! Dataflow:
//!   submit() -> admission queue (bounded; Full = backpressure/reject)
//!     -> router assigns a seq-len bucket (pad-up to {128, 512})
//!     -> per-bucket batcher drains up to max_batch or waits batch_timeout
//!     -> worker thread (own PJRT [`Engine`]) executes serve_<m>_b{B}_n{N}
//!     -> per-request logits returned through its response channel.
//!
//! PJRT handles never cross threads (the xla crate types are !Send);
//! workers own engines, queues move plain vectors.

pub mod batcher;
pub mod native;
pub mod router;
pub mod server;

pub use batcher::{desired_workers, plan_batches, BatchPlan};
pub use native::NativeEncoder;
pub use router::HashRing;
pub use server::{ClassWindow, Coordinator, DecodeSession, ReqSpec, ServeStats};

use crate::data::special;

/// A classification request: tokens in, logits out.  `tokens.len()` is
/// the request's *live* length — the batcher pads it up to its bucket,
/// and the native executors mask the padding out of attention via the
/// per-request key length instead of attending PAD embeddings.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Run this request under the causal (autoregressive) mask.
    /// Batches may freely mix causal and bidirectional members: the
    /// native executor applies each request's own
    /// [`AttnSpec`](crate::attention::AttnSpec) member by member.
    pub causal: bool,
    /// Per-request score-temperature override (the
    /// [`AttnSpec::scale`](crate::attention::AttnSpec) field); `None`
    /// = the method's default `1/sqrt(d)`.  Honored by the native
    /// executors for maskable methods (linear-class kernels without a
    /// score temperature ignore it, like the kernels do); rejected per
    /// request by the PJRT path (its AOT executables bake the default
    /// in) and by Nystrom/Linformer (their encoders degrade non-full
    /// specs wholesale, which would drop it silently).
    pub scale: Option<f32>,
    pub enqueued_at: std::time::Instant,
    /// Hard completion deadline: past it the coordinator sheds the
    /// request queue-side (terminal [`RespError::DeadlineExceeded`])
    /// instead of spending executor time on an answer nobody is
    /// waiting for.  `None` = no deadline.  Decode steps carry no
    /// deadline — a live session already holds its slot.
    pub deadline: Option<std::time::Instant>,
    pub resp: std::sync::mpsc::Sender<Response>,
}

/// Why a request did not produce logits.  Every submitted request gets
/// exactly one terminal outcome: `Ok(logits)`, or one of these — the
/// serving report counts each kind separately so shed load is never
/// laundered as executor errors (or vice versa).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RespError {
    /// Shed before execution (backpressure, admission, thrash guard).
    Rejected(String),
    /// The request's deadline passed while it waited in a queue.
    DeadlineExceeded(String),
    /// Execution failed (executor error/panic, poisoned session,
    /// buried shard).
    Failed(String),
}

impl RespError {
    /// The human-readable detail, without the kind prefix.
    pub fn message(&self) -> &str {
        match self {
            RespError::Rejected(m) | RespError::DeadlineExceeded(m) | RespError::Failed(m) => m,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            RespError::Rejected(_) => "rejected",
            RespError::DeadlineExceeded(_) => "deadline-exceeded",
            RespError::Failed(_) => "failed",
        }
    }
}

impl std::fmt::Display for RespError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Failed keeps the bare message (the historical error strings
        // that tests and examples match on); shed outcomes carry their
        // kind so a caller's log line can't mistake them for crashes.
        match self {
            RespError::Failed(m) => write!(f, "{m}"),
            RespError::Rejected(m) => write!(f, "rejected: {m}"),
            RespError::DeadlineExceeded(m) => write!(f, "deadline exceeded: {m}"),
        }
    }
}

/// The reply for one request (or one decode-session open/step).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub result: Result<Vec<f32>, RespError>,
    /// Wall time from admission to completion.
    pub latency_ms: f64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

/// Ask a bucket worker to open an incremental decode session.  The
/// worker validates its executor can decode (native path + maskable
/// method), registers the session state, and replies — `Err` rides the
/// same [`Response`] channel, so mask-incapable executors (PJRT
/// artifacts, Nystrom/Linformer) reject opens loudly without panicking
/// a worker thread.
#[derive(Debug)]
pub struct SessionOpen {
    pub id: u64,
    pub enqueued_at: std::time::Instant,
    pub resp: std::sync::mpsc::Sender<Response>,
}

/// One token's decode step for an open session.  `pos` is the token's
/// position (sessions replay strictly in order; the worker pool keeps
/// steps of one session serialized even when several workers drain the
/// same bucket queue).  The step's logits come back over `resp` — the
/// streaming channel: [`DecodeSession::stream`] shares one sender
/// across many steps so tokens arrive as they decode.
#[derive(Debug)]
pub struct SessionStep {
    pub id: u64,
    pub pos: usize,
    pub token: i32,
    pub enqueued_at: std::time::Instant,
    pub resp: std::sync::mpsc::Sender<Response>,
}

/// Everything a bucket queue carries: prefill (classification) requests
/// and decode-session traffic share the batcher, so one drained batch
/// can mix both (`NativeEncoder` executes the prefill members batched
/// and the decode steps statefully).  Session *close* does not ride the
/// queue: [`DecodeSession`] removes its slot from the bucket registry
/// directly, so a full queue can never leak server-side decode state.
#[derive(Debug)]
pub enum Work {
    Infer(Request),
    Open(SessionOpen),
    Step(SessionStep),
}

impl Work {
    /// Admission time, for batch-timeout accounting.
    pub fn enqueued_at(&self) -> std::time::Instant {
        match self {
            Work::Infer(r) => r.enqueued_at,
            Work::Open(o) => o.enqueued_at,
            Work::Step(s) => s.enqueued_at,
        }
    }

    /// Session items bypass the batch-timeout wait: a decode step is
    /// single-token, latency-bound work that should never idle behind
    /// the prefill batcher's fill timer.
    pub fn is_session_work(&self) -> bool {
        !matches!(self, Work::Infer(_))
    }

    /// The item's completion deadline, if any.  Only prefill carries
    /// one; session opens/steps are exempt by design.
    pub fn deadline(&self) -> Option<std::time::Instant> {
        match self {
            Work::Infer(r) => r.deadline,
            Work::Open(_) | Work::Step(_) => None,
        }
    }
}

/// SLO payload classes: every completion is accounted to exactly one,
/// each with its own bounded latency window — mixed traffic no longer
/// smears sub-millisecond decode steps into the prefill percentiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PayloadClass {
    /// Prefill riding the smallest configured bucket.
    PrefillShort,
    /// Prefill in any larger bucket.
    PrefillLong,
    /// One decode-session token step.
    DecodeStep,
    /// Session open (state allocation + registration).
    SessionOpen,
}

impl PayloadClass {
    pub const ALL: [PayloadClass; 4] = [
        PayloadClass::PrefillShort,
        PayloadClass::PrefillLong,
        PayloadClass::DecodeStep,
        PayloadClass::SessionOpen,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PayloadClass::PrefillShort => "prefill-short",
            PayloadClass::PrefillLong => "prefill-long",
            PayloadClass::DecodeStep => "decode-step",
            PayloadClass::SessionOpen => "session-open",
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }
}

/// Pick the smallest bucket that fits `len`; None if it exceeds all.
pub fn pick_bucket(buckets: &[usize], len: usize) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= len).min()
}

/// Pad a token sequence to the bucket length with PAD.
pub fn pad_to_bucket(tokens: &[i32], bucket: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(bucket);
    out.extend_from_slice(&tokens[..tokens.len().min(bucket)]);
    while out.len() < bucket {
        out.push(special::PAD);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = [128, 512];
        assert_eq!(pick_bucket(&buckets, 1), Some(128));
        assert_eq!(pick_bucket(&buckets, 128), Some(128));
        assert_eq!(pick_bucket(&buckets, 129), Some(512));
        assert_eq!(pick_bucket(&buckets, 512), Some(512));
        assert_eq!(pick_bucket(&buckets, 513), None);
    }

    #[test]
    fn padding() {
        let p = pad_to_bucket(&[5, 6, 7], 8);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..3], &[5, 6, 7]);
        assert!(p[3..].iter().all(|&t| t == special::PAD));
    }

    #[test]
    fn bucket_properties() {
        crate::testkit::check(128, |g| {
            let buckets = [64usize, 128, 512];
            let len = g.usize_in(1, 600);
            match pick_bucket(&buckets, len) {
                Some(b) => {
                    crate::testkit::prop_assert(b >= len, format!("bucket {b} < len {len}"))?;
                    // minimality: no smaller bucket fits
                    crate::testkit::prop_assert(
                        buckets.iter().all(|&x| x >= b || x < len),
                        "bucket not minimal",
                    )
                }
                None => crate::testkit::prop_assert(len > 512, "refused a fitting length"),
            }
        });
    }
}
