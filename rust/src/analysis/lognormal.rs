//! Log-normality of the attention matrix (paper Prop 3.1 / figs. 5, 7).

use crate::attention::kernels::{lln_attention_matrix, softmax_attention_matrix};
use crate::rng::Pcg64;
use crate::stats::{self, Histogram};
use crate::tensor::Mat;

/// Comparison of measured vs theoretical log-normal parameters of P^(SM).
#[derive(Clone, Copy, Debug)]
pub struct LogNormalCheck {
    pub sigma_q: f64,
    pub sigma_k: f64,
    /// Theoretical sigma^2_sm = sigma_q^2 sigma_k^2 (+ C_cross ~ 0 here).
    pub theory_sigma2: f64,
    pub measured_sigma2: f64,
    /// Theoretical mu = -ln N - sigma^2/2 (Prop 3.1).
    pub theory_mu: f64,
    pub measured_mu: f64,
}

/// Fig 5a: measure SA's log-mean/log-variance against Prop 3.1 theory.
pub fn sa_lognormal_check(sigma_q: f64, sigma_k: f64, n: usize, d: usize, seed: u64) -> LogNormalCheck {
    let mut rng = Pcg64::seed(seed);
    let q = Mat::gaussian(n, d, sigma_q as f32, &mut rng);
    let k = Mat::gaussian(n, d, sigma_k as f32, &mut rng);
    let p = softmax_attention_matrix(&q, &k);
    let s2 = sigma_q * sigma_q * sigma_k * sigma_k;
    LogNormalCheck {
        sigma_q,
        sigma_k,
        theory_sigma2: s2,
        measured_sigma2: stats::log_variance(&p, 1e-30),
        theory_mu: -(n as f64).ln() - 0.5 * s2,
        measured_mu: stats::log_mean(&p, 1e-30),
    }
}

/// Fig 7: log-domain histograms of SA vs LLN (matched and unmatched),
/// plus KS distances between the log-entry samples.
pub struct HistogramStudy {
    pub sa: Histogram,
    pub lln_matched: Histogram,
    pub lln_unmatched: Histogram,
    pub ks_matched: f64,
    pub ks_unmatched: f64,
}

pub fn histogram_study(
    sigma: f64,
    n: usize,
    d: usize,
    bins: usize,
    mm: &crate::attention::MomentMatcher,
    seed: u64,
) -> HistogramStudy {
    let mut rng = Pcg64::seed(seed);
    let q = Mat::gaussian(n, d, sigma as f32, &mut rng);
    let k = Mat::gaussian(n, d, sigma as f32, &mut rng);
    let p_sa = softmax_attention_matrix(&q, &k);
    let (alpha, beta) = mm.alpha_beta(sigma, sigma);
    let p_m = lln_attention_matrix(&q, &k, alpha, beta);
    let p_u = lln_attention_matrix(&q, &k, 1.0, 1.0);

    let logs = |p: &Mat| -> Vec<f32> {
        p.data().iter().map(|&x| (x.max(1e-30)).ln()).collect()
    };
    let (la, lm, lu) = (logs(&p_sa), logs(&p_m), logs(&p_u));
    let lo = la.iter().chain(&lm).chain(&lu).cloned().fold(f32::MAX, f32::min) as f64;
    let hi = la.iter().chain(&lm).chain(&lu).cloned().fold(f32::MIN, f32::max) as f64 + 1e-6;

    let mk = |xs: &[f32]| {
        let mut h = Histogram::new(lo, hi, bins);
        h.add_all(xs.iter().map(|&x| x as f64));
        h
    };
    HistogramStudy {
        sa: mk(&la),
        lln_matched: mk(&lm),
        lln_unmatched: mk(&lu),
        ks_matched: stats::ks_distance(&la, &lm),
        ks_unmatched: stats::ks_distance(&la, &lu),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::MomentMatcher;

    #[test]
    fn prop_3_1_variance_matches() {
        for (sq, sk) in [(0.8, 0.8), (1.0, 1.2), (1.4, 1.4)] {
            let c = sa_lognormal_check(sq, sk, 256, 64, 5);
            let rel = (c.measured_sigma2 - c.theory_sigma2).abs() / c.theory_sigma2;
            assert!(rel < 0.3, "{c:?}");
        }
    }

    #[test]
    fn prop_3_1_mean_tracks_theory() {
        let c = sa_lognormal_check(1.0, 1.0, 256, 64, 6);
        // mu = -ln N - s2/2; allow the Fenton correction slack.
        assert!((c.measured_mu - c.theory_mu).abs() < 1.0, "{c:?}");
    }

    #[test]
    fn matched_histogram_closer_than_unmatched() {
        let mm = MomentMatcher::from_artifacts(std::path::Path::new("artifacts"))
            .unwrap_or(MomentMatcher { a: 0.21, b: -1.08 });
        let study = histogram_study(1.2, 192, 64, 50, &mm, 7);
        assert!(
            study.ks_matched < study.ks_unmatched,
            "matched KS {} vs unmatched {}",
            study.ks_matched,
            study.ks_unmatched
        );
    }

    #[test]
    fn histograms_cover_all_entries() {
        let mm = MomentMatcher { a: 0.21, b: -1.08 };
        let study = histogram_study(1.0, 96, 32, 40, &mm, 8);
        assert_eq!(study.sa.total as usize, 96 * 96);
        assert_eq!(study.lln_matched.total as usize, 96 * 96);
    }
}
