//! Table 1 (GLUE-like accuracy across methods) and Fig 10 (fixed
//! alpha/beta ablation).
//!
//! Both the GLUE-like and LRA-lite harnesses run artifact-free: with
//! no `artifacts/` directory (or under `--native`) classification
//! trains through [`NativeStep`] as a single-position MLM — the CLS
//! slot predicts the class id — mirroring
//! [`experiments::pretrain::build_step`](crate::experiments::pretrain::build_step)'s
//! degraded mode.

use anyhow::{anyhow, Result};

use super::maybe_write_csv;
use crate::cli::Args;
use crate::data::tasks::{GlueGen, GlueTask};
use crate::data::MlmBatch;
use crate::runtime::{artifacts_available, artifacts_dir, Engine, HostTensor};
use crate::training::driver::{accuracy_from_logits, TrainDriver};
use crate::training::native::{NativeShape, NativeStep, TrainStep};
use crate::util::print_table;

/// Train a classification artifact on a generator and return
/// (final accuracy, max grad norm, final loss).
pub fn train_and_eval_cls(
    engine: &mut Engine,
    dir: &std::path::Path,
    artifact: &str,
    train_gen: &mut dyn FnMut() -> (Vec<i32>, Vec<i32>, usize, usize),
    eval_gen: &mut dyn FnMut() -> (Vec<i32>, Vec<i32>, usize, usize),
    steps: usize,
    eval_batches: usize,
    lr: f64,
    num_classes: usize,
) -> Result<(f64, f64, f32)> {
    let mut driver = TrainDriver::new(engine, dir, artifact)?;
    let mut max_gnorm = 0.0f64;
    let mut last_loss = f32::NAN;
    for step in 0..steps {
        let (tokens, labels, b, n) = train_gen();
        // Linear warmup over the first 10%.
        let warm = (steps / 10).max(1);
        let lr_t = if step < warm {
            lr * (step + 1) as f64 / warm as f64
        } else {
            lr
        };
        let out = driver.step(
            engine,
            lr_t,
            &[
                HostTensor::I32 { shape: vec![b, n], data: tokens },
                HostTensor::I32 { shape: vec![b], data: labels },
            ],
        )?;
        max_gnorm = max_gnorm.max(out.grad_norm as f64);
        last_loss = out.loss;
    }
    // Held-out accuracy.
    let mut correct_weighted = 0.0;
    let mut total = 0usize;
    for _ in 0..eval_batches {
        let (tokens, labels, b, n) = eval_gen();
        let outs = driver.eval(engine, &[HostTensor::I32 { shape: vec![b, n], data: tokens }])?;
        let logits = outs[0].as_f32()?;
        correct_weighted += accuracy_from_logits(logits, &labels, num_classes) * b as f64;
        total += b;
    }
    Ok((correct_weighted / total as f64, max_gnorm, last_loss))
}

/// A classification batch recast as single-position MLM: position 0
/// (the CLS slot) carries the class id with weight 1.0; every other
/// position carries zero loss weight.
fn cls_as_mlm(tokens: &[i32], labels: &[i32], b: usize, n: usize) -> MlmBatch {
    let mut mlm_labels = vec![0i32; b * n];
    let mut weights = vec![0.0f32; b * n];
    for (s, &label) in labels.iter().enumerate() {
        mlm_labels[s * n] = label;
        weights[s * n] = 1.0;
    }
    MlmBatch { tokens: tokens.to_vec(), labels: mlm_labels, weights, batch: b }
}

/// `true` when `method` cannot train natively (artifact-only mixing) —
/// the degraded mode skips it with a note instead of failing the table.
pub fn native_untrainable(method: &str) -> bool {
    matches!(
        crate::attention::Method::parse(method),
        Some(crate::attention::Method::Nystrom) | Some(crate::attention::Method::Linformer)
    )
}

/// Native (artifact-free) counterpart of [`train_and_eval_cls`]: a
/// [`NativeStep`] encoder trained on the CLS-as-MLM recast, evaluated
/// by arg-maxing the class-id slice of the CLS position's vocab
/// logits.  Same return shape: (accuracy, max grad norm, final loss).
#[allow(clippy::too_many_arguments)]
pub fn train_and_eval_cls_native(
    method: &str,
    train_gen: &mut dyn FnMut() -> (Vec<i32>, Vec<i32>, usize, usize),
    eval_gen: &mut dyn FnMut() -> (Vec<i32>, Vec<i32>, usize, usize),
    steps: usize,
    eval_batches: usize,
    lr: f64,
    vocab: usize,
    num_classes: usize,
) -> Result<(f64, f64, f32)> {
    let m = crate::attention::Method::parse(method)
        .ok_or_else(|| anyhow!("unknown attention method {method:?}"))?;
    let mut stepper: Option<NativeStep> = None;
    let mut max_gnorm = 0.0f64;
    let mut last_loss = f32::NAN;
    for s in 0..steps {
        let (tokens, labels, b, n) = train_gen();
        if stepper.is_none() {
            // Shape follows the first batch; a deliberately small
            // encoder — this is the degraded smoke path, not a tuned
            // reproduction run.
            let shape = NativeShape {
                batch: b,
                seqlen: n,
                d_model: 32,
                heads: 2,
                layers: 2,
                ff: 64,
                vocab,
                seed: 7,
            };
            stepper = Some(NativeStep::new(m, shape)?);
        }
        let stepper = stepper.as_mut().expect("native step built");
        let batch = cls_as_mlm(&tokens, &labels, b, n);
        let warm = (steps / 10).max(1);
        let lr_t = if s < warm {
            lr * (s + 1) as f64 / warm as f64
        } else {
            lr
        };
        let out = stepper.step(lr_t, &batch)?;
        max_gnorm = max_gnorm.max(out.grad_norm as f64);
        last_loss = out.loss;
    }
    let stepper = stepper.ok_or_else(|| anyhow!("native classification ran zero steps"))?;
    let mut correct_weighted = 0.0;
    let mut total = 0usize;
    for _ in 0..eval_batches {
        let (tokens, labels, b, n) = eval_gen();
        let logits = stepper.eval_logits(&tokens, b)?;
        // Row s·n is sequence s's CLS position; classify over the
        // class-id prefix of the vocab head.
        let mut cls_logits = Vec::with_capacity(b * num_classes);
        for s in 0..b {
            cls_logits.extend_from_slice(&logits.row(s * n)[..num_classes]);
        }
        correct_weighted += accuracy_from_logits(&cls_logits, &labels, num_classes) * b as f64;
        total += b;
    }
    Ok((correct_weighted / total.max(1) as f64, max_gnorm, last_loss))
}

const TABLE1_METHODS: &[&str] = &["softmax", "lln", "lln_diag", "elu", "performer", "nystrom"];

pub fn run_table1(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let steps = args.get_usize("steps", 250)?;
    let eval_batches = args.get_usize("eval-batches", 12)?;
    let lr = args.get_f64("lr", 1e-3)?;
    let methods = args.get_list("methods", &TABLE1_METHODS.join(","));
    let native = args.get_bool("native") || !artifacts_available(&dir);
    let mut engine = if native {
        None
    } else {
        Some(Engine::new(&dir)?)
    };

    let tag = if native { " [native]" } else { "" };
    println!("== Table 1: accuracy on the GLUE-like synthetic suite{tag} ==");
    println!("   ({} train steps/task, batch 16 x 128 tokens; chance = 33%/50%)\n", steps);

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for method in &methods {
        if native && native_untrainable(method) {
            eprintln!("   [{method}] skipped: no native backward (artifact-only method)");
            continue;
        }
        let artifact = format!("train_glue_{method}");
        let mut accs = Vec::new();
        for task in GlueTask::ALL {
            let mut tg = GlueGen::new(task, 512, 128, 100);
            let mut eg = GlueGen::new(task, 512, 128, 999); // held-out stream
            let mut train_fn = || {
                let b = tg.batch(16);
                (b.tokens, b.labels, 16usize, 128usize)
            };
            let mut eval_fn = || {
                let b = eg.batch(16);
                (b.tokens, b.labels, 16usize, 128usize)
            };
            let (acc, _gn, _loss) = match engine.as_mut() {
                Some(engine) => train_and_eval_cls(
                    engine,
                    &dir,
                    &artifact,
                    &mut train_fn,
                    &mut eval_fn,
                    steps,
                    eval_batches,
                    lr,
                    4,
                )?,
                None => train_and_eval_cls_native(
                    method,
                    &mut train_fn,
                    &mut eval_fn,
                    steps,
                    eval_batches,
                    lr,
                    512,
                    4,
                )?,
            };
            accs.push(acc);
            eprintln!("   [{method}] {}: {:.1}%", task.name(), acc * 100.0);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        let mut row = vec![method.to_string()];
        row.extend(accs.iter().map(|a| format!("{:.1}", a * 100.0)));
        row.push(format!("{:.1}", avg * 100.0));
        csv.push(format!(
            "{method},{}",
            accs
                .iter()
                .chain(std::iter::once(&avg))
                .map(|a| format!("{:.3}", a * 100.0))
                .collect::<Vec<_>>()
                .join(",")
        ));
        rows.push(row);
    }
    print_table(
        &["method", "MNLI-like", "QNLI-like", "QQP-like", "SST2-like", "Avg"],
        &rows,
    );
    println!("\npaper shape: LLN+Diag ~ softmax > LLN > ELU > Performer-class baselines");
    maybe_write_csv(args, "table1", "method,nli,qnli,qqp,sst2,avg", &csv)?;
    Ok(())
}

pub fn run_fig10(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let steps = args.get_usize("steps", 200)?;
    let lr = args.get_f64("lr", 1e-3)?;
    let mut engine = Engine::new(&dir)?;

    println!("== Fig 10: LLN with fixed alpha = beta (SST2-like task) ==\n");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for alpha in ["0p5", "1p0", "2p0", "3p0", "4p0"] {
        let artifact = format!("train_fig10_a{alpha}");
        let mut tg = GlueGen::new(GlueTask::Sst2, 512, 128, 100);
        let mut eg = GlueGen::new(GlueTask::Sst2, 512, 128, 999);
        let mut train_fn = || {
            let b = tg.batch(16);
            (b.tokens, b.labels, 16usize, 128usize)
        };
        let mut eval_fn = || {
            let b = eg.batch(16);
            (b.tokens, b.labels, 16usize, 128usize)
        };
        let (acc, max_gnorm, _) = train_and_eval_cls(
            &mut engine, &dir, &artifact, &mut train_fn, &mut eval_fn, steps, 10, lr, 4,
        )?;
        let a = alpha.replace('p', ".");
        rows.push(vec![a.clone(), format!("{:.1}", acc * 100.0), format!("{max_gnorm:.2}")]);
        csv.push(format!("{a},{},{max_gnorm}", acc * 100.0));
    }
    print_table(&["alpha=beta", "accuracy [%]", "max grad-norm"], &rows);
    println!("\npaper shape: accuracy plateaus for alpha >= ~2 (the moment-matching");
    println!("range); grad-norm (the FP16 loss-scale telemetry proxy) grows with alpha.");
    maybe_write_csv(args, "fig10", "alpha,accuracy,max_grad_norm", &csv)?;
    Ok(())
}
