//! Deterministic pseudo-random number generation (substrate for the
//! unavailable `rand` crate, layered on `rand_core`).
//!
//! * [`Pcg64`] — PCG-XSH-RR 64/32 folded to 64-bit output; fast, solid
//!   statistical quality, tiny state, trivially seedable.
//! * Gaussian sampling via Box–Muller (cached spare), Zipf sampling via
//!   rejection-inversion (Hörmann–Derflinger style bound), plus the
//!   categorical / permutation helpers the data generators need.

use rand_core::{Error, RngCore, SeedableRng};

/// Splitmix64: used to expand user seeds into full PCG state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR with 128-bit state emulated as two 64-bit lanes.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// Cached second Box–Muller output.
    spare_gauss: Option<f64>,
}

impl Pcg64 {
    const MULT: u64 = 6364136223846793005;

    /// Construct from a user seed and a stream id; distinct streams are
    /// statistically independent (odd increments).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ (0xDA3E_39CB_94B9_5BDB ^ stream.rotate_left(17));
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Self { state, inc, spare_gauss: None };
        rng.next_u64(); // warm-up step decorrelates near-zero seeds
        rng
    }

    /// Single-argument convenience constructor (stream 0).
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    fn step(&mut self) -> u64 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        let lo = xorshifted.rotate_right(rot) as u64;
        // Second extraction for the high half keeps the generator 64-bit-out.
        let old2 = self.state;
        self.state = old2.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted2 = (((old2 >> 18) ^ old2) >> 27) as u32;
        let rot2 = (old2 >> 59) as u32;
        let hi = xorshifted2.rotate_right(rot2) as u64;
        (hi << 32) | lo
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        if let Some(s) = self.spare_gauss.take() {
            return s;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_gauss = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (mean as f64 + std as f64 * self.gauss()) as f32
    }

    /// Fill a slice with N(mean, std^2) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for x in out {
            *x = self.normal_f32(mean, std);
        }
    }

    /// Zipf(s) over {0, .., n-1} by inverse-CDF on precomputed weights is
    /// O(n) setup; this standalone sampler is O(1) amortized via
    /// rejection-inversion and suits repeated draws with static (n, s).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        // For s == 1 the harmonic integral needs its own closed form.
        let h = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let h_inv = |y: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                y.exp() - 1.0
            } else {
                ((1.0 - s) * y + 1.0).powf(1.0 / (1.0 - s)) - 1.0
            }
        };
        let hx0 = h(0.5) - 1.0;
        let hn = h(n as f64 - 0.5);
        loop {
            let u = hx0 + self.f64() * (hn - hx0);
            let x = h_inv(u);
            let k = (x + 0.5).floor().clamp(0.0, n as f64 - 1.0);
            // Acceptance test against the true pmf envelope.
            if k - x <= (1.0 + k).powf(-s).recip().recip() || u >= h(k + 0.5) - (1.0 + k).powf(-s) {
                return k as u64;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

impl RngCore for Pcg64 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        self.step() as u32
    }
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.step()
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        let mut chunks = dest.chunks_exact_mut(8);
        for chunk in &mut chunks {
            chunk.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let bytes = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&bytes[..rem.len()]);
        }
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

impl SeedableRng for Pcg64 {
    type Seed = [u8; 8];
    fn from_seed(seed: Self::Seed) -> Self {
        Self::seed(u64::from_le_bytes(seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map(|_| 0).scan(Pcg64::seed(42), |r, _| Some(r.next_u64())).collect();
        let b: Vec<u64> = (0..8).map(|_| 0).scan(Pcg64::seed(42), |r, _| Some(r.next_u64())).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map(|_| 0).scan(Pcg64::seed(43), |r, _| Some(r.next_u64())).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg64::seed(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seed(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut r = Pcg64::seed(3);
        let n = 20_000;
        let mut counts = vec![0usize; 100];
        for _ in 0..n {
            counts[r.zipf(100, 1.2) as usize] += 1;
        }
        assert!(counts[0] > counts[10], "{:?}", &counts[..12]);
        assert!(counts[0] > n / 20);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed(4);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seed(5);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "{hits:?}");
        let frac2 = hits[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "{frac2}");
    }

    #[test]
    fn fill_bytes_covers_remainder() {
        let mut r = Pcg64::seed(6);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
