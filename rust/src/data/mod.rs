//! Synthetic data pipeline — the repo's substitute for WikiText-103,
//! GLUE, LRA, and Dogs-vs-Cats (see DESIGN.md §5 "Substitutions").
//!
//! Every generator plants a *controlled* statistical structure so that
//! (a) losses/accuracies are meaningfully learnable, and (b) tasks
//! separate short-range from long-range attention quality, which is the
//! axis the paper's comparisons live on.

pub mod corpus;
pub mod images;
pub mod lra;
pub mod tasks;

pub use corpus::{Corpus, MlmBatch, Tokenizer};
pub use images::VitBatch;
pub use lra::LraTask;
pub use tasks::{ClsBatch, GlueTask};

/// Special token ids shared across all token-mode datasets.
pub mod special {
    pub const PAD: i32 = 0;
    pub const MASK: i32 = 1;
    pub const CLS: i32 = 2;
    pub const SEP: i32 = 3;
    /// First id available to content tokens.
    pub const FIRST_CONTENT: i32 = 4;
}
