//! Parameter + optimizer-state store for the training driver.
//!
//! Holds the flat (canonical-order) parameter arrays as xla Literals —
//! PJRT CPU shares the host buffer, so one `execute` per train step moves
//! no parameter bytes.  Checkpointing writes the same raw-f32 format the
//! AOT exporter uses for initial weights.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use xla::Literal;

use super::engine::HostTensor;
use super::manifest::ModelSpec;

/// Flat parameter set in canonical (sorted-name) order.
pub struct ParamStore {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    /// Host copies (always current — outputs are copied back each step).
    pub values: Vec<Vec<f32>>,
}

impl ParamStore {
    /// Load initial parameters from the AOT `params_<tag>.bin` blob.
    pub fn load_initial(dir: &Path, model: &ModelSpec) -> Result<Self> {
        let path = dir.join(&model.params_file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        let total: usize = model
            .param_order
            .iter()
            .map(|k| model.param_shapes[k].iter().product::<usize>())
            .sum();
        if bytes.len() != total * 4 {
            bail!("{}: {} bytes, schema wants {}", path.display(), bytes.len(), total * 4);
        }
        let mut values = Vec::with_capacity(model.param_order.len());
        let mut shapes = Vec::with_capacity(model.param_order.len());
        let mut off = 0usize;
        for name in &model.param_order {
            let shape = model.param_shapes[name].clone();
            let n: usize = shape.iter().product();
            let mut v = vec![0f32; n];
            let chunk = &bytes[off * 4..(off + n) * 4];
            for (i, w) in chunk.chunks_exact(4).enumerate() {
                v[i] = f32::from_le_bytes([w[0], w[1], w[2], w[3]]);
            }
            off += n;
            values.push(v);
            shapes.push(shape);
        }
        Ok(Self { names: model.param_order.clone(), shapes, values })
    }

    /// Zero-initialized store with the same schema (Adam m/v states).
    pub fn zeros_like(other: &Self) -> Self {
        Self {
            names: other.names.clone(),
            shapes: other.shapes.clone(),
            values: other.values.iter().map(|v| vec![0f32; v.len()]).collect(),
        }
    }

    pub fn total_elements(&self) -> usize {
        self.values.iter().map(Vec::len).sum()
    }

    /// Build literals for all arrays (the per-step input assembly).
    pub fn to_literals(&self) -> Result<Vec<Literal>> {
        self.names
            .iter()
            .zip(&self.shapes)
            .zip(&self.values)
            .map(|((name, shape), data)| {
                HostTensor::F32 { shape: shape.clone(), data: data.clone() }
                    .to_literal()
                    .with_context(|| format!("param {name}"))
            })
            .collect()
    }

    /// Copy a train step's output literals back into the store.
    pub fn update_from_literals(&mut self, lits: &[Literal]) -> Result<()> {
        if lits.len() != self.values.len() {
            bail!("update: {} literals for {} params", lits.len(), self.values.len());
        }
        for (i, lit) in lits.iter().enumerate() {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("param {}: {e:?}", self.names[i]))?;
            if v.len() != self.values[i].len() {
                bail!("param {}: {} vs {}", self.names[i], v.len(), self.values[i].len());
            }
            self.values[i] = v;
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.names.iter().position(|n| n == name).map(|i| self.values[i].as_slice())
    }

    /// Serialize to the raw-f32 checkpoint format.
    ///
    /// The write is atomic: bytes land in a sibling temp file first and
    /// are `rename`d over the final path only once fully written, so a
    /// crash (or full disk) mid-write can never leave a truncated
    /// checkpoint where [`load_checkpoint`](Self::load_checkpoint)
    /// expects a complete one — the previous checkpoint, if any,
    /// survives intact.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut bytes = Vec::with_capacity(self.total_elements() * 4);
        for v in &self.values {
            for x in v {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
        }
        let file_name = path
            .file_name()
            .ok_or_else(|| anyhow!("checkpoint path {} has no file name", path.display()))?;
        let tmp = path.with_file_name(format!("{}.tmp", file_name.to_string_lossy()));
        if let Err(e) = std::fs::write(&tmp, &bytes) {
            // Best-effort cleanup; the final path was never touched.
            std::fs::remove_file(&tmp).ok();
            return Err(e).with_context(|| format!("writing {}", tmp.display()));
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("committing {} -> {}", tmp.display(), path.display()))
    }

    /// Load a checkpoint saved by [`ParamStore::save`] (same schema).
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != self.total_elements() * 4 {
            bail!("checkpoint size mismatch");
        }
        let mut off = 0usize;
        for v in &mut self.values {
            for x in v.iter_mut() {
                let b = &bytes[off..off + 4];
                *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                off += 4;
            }
        }
        Ok(())
    }

    /// L2 norm over all parameters (divergence telemetry).
    pub fn global_norm(&self) -> f64 {
        self.values
            .iter()
            .flat_map(|v| v.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir, Manifest};

    #[test]
    fn loads_initial_params_when_artifacts_present() {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let model = m.model("tinymlm_lln").unwrap();
        let store = ParamStore::load_initial(&dir, model).unwrap();
        assert_eq!(store.names.len(), model.param_order.len());
        assert_eq!(store.total_elements(), model.total_params());
        // Embeddings initialized to ~N(0, 0.02): nonzero, small.
        let emb = store.get("emb.tok").unwrap();
        assert!(emb.iter().any(|&x| x != 0.0));
        assert!(emb.iter().all(|&x| x.abs() < 1.0));
        let norm = store.global_norm();
        assert!(norm > 0.0 && norm.is_finite());
    }

    #[test]
    fn checkpoint_round_trip() {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let model = m.model("tinymlm_lln").unwrap();
        let mut store = ParamStore::load_initial(&dir, model).unwrap();
        let tmp = std::env::temp_dir().join("lln_ckpt_test.bin");
        store.save(&tmp).unwrap();
        let orig = store.values[3].clone();
        for x in &mut store.values[3] {
            *x = 0.0;
        }
        store.load_checkpoint(&tmp).unwrap();
        assert_eq!(store.values[3], orig);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn zeros_like_matches_schema() {
        let store = ParamStore {
            names: vec!["a".into(), "b".into()],
            shapes: vec![vec![2, 3], vec![4]],
            values: vec![vec![1.0; 6], vec![2.0; 4]],
        };
        let z = ParamStore::zeros_like(&store);
        assert_eq!(z.total_elements(), 10);
        assert!(z.values.iter().flatten().all(|&x| x == 0.0));
    }

    fn small_store(fill: f32) -> ParamStore {
        ParamStore {
            names: vec!["a".into(), "b".into()],
            shapes: vec![vec![2, 3], vec![4]],
            values: vec![vec![fill; 6], vec![fill + 1.0; 4]],
        }
    }

    #[test]
    fn save_is_atomic_and_never_exposes_a_truncated_checkpoint() {
        let dir = std::env::temp_dir().join(format!("lln_atomic_save_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");

        // A stale temp file from a crashed prior writer must not
        // corrupt anything: save overwrites it and commits cleanly.
        std::fs::write(dir.join("ckpt.bin.tmp"), b"garbage from a crashed writer").unwrap();
        let old = small_store(1.0);
        old.save(&path).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            (old.total_elements() * 4) as u64,
            "the final path must only ever hold a complete checkpoint"
        );
        assert!(!dir.join("ckpt.bin.tmp").exists(), "the temp file is consumed by the rename");

        // Regression: a failed write never truncates the existing
        // checkpoint.  Making the temp path unwritable (a directory
        // squats on it) forces the data write to fail — with the old
        // direct `fs::write(path)` scheme this same failure mode (dying
        // mid-write) left a short file at the final path.
        std::fs::create_dir(dir.join("ckpt.bin.tmp")).unwrap();
        let new = small_store(9.0);
        assert!(new.save(&path).is_err(), "the squatted temp path must fail the save");
        std::fs::remove_dir(dir.join("ckpt.bin.tmp")).ok();
        let mut reread = small_store(0.0);
        reread.load_checkpoint(&path).unwrap();
        assert_eq!(reread.values, old.values, "a failed save must leave the old checkpoint intact");

        // A successful overwrite replaces it whole.
        new.save(&path).unwrap();
        reread.load_checkpoint(&path).unwrap();
        assert_eq!(reread.values, new.values);
        std::fs::remove_dir_all(&dir).ok();
    }
}
