//! LRA-lite: five long-sequence tasks mirroring the Long Range Arena
//! suite (Tables 4/5 stand-ins), byte-level vocab (260 = 256 + specials),
//! 10-way labels (tasks with fewer classes use a prefix of the range).

use super::special;
use super::tasks::ClsBatch;
use crate::rng::Pcg64;

/// The five LRA-lite tasks (paper Table 4/5 columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LraTask {
    /// "Text": class-conditional byte-bigram stream (binary).
    Text,
    /// "ListOps": nested max/min/median over digits (10-way).
    ListOps,
    /// "Retrieval": do the two documents share the rare marker? (binary)
    Retrieval,
    /// "Pathfinder": is there an unbroken successor chain between the
    /// two endpoint markers? (binary)
    Pathfinder,
    /// "Image": 16x16 synthetic glyph, flattened grayscale bytes (binary).
    Image,
}

pub const LRA_VOCAB: usize = 260;

impl LraTask {
    pub const ALL: [LraTask; 5] =
        [LraTask::Text, LraTask::ListOps, LraTask::Retrieval, LraTask::Pathfinder, LraTask::Image];

    pub fn name(&self) -> &'static str {
        match self {
            LraTask::Text => "Text",
            LraTask::ListOps => "ListOps",
            LraTask::Retrieval => "Retrieval",
            LraTask::Pathfinder => "Pathfinder",
            LraTask::Image => "Image",
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            LraTask::ListOps => 10,
            _ => 2,
        }
    }
}

/// Byte token helper: bytes are offset past the special ids.
fn byte_tok(b: u8) -> i32 {
    special::FIRST_CONTENT + b as i32
}

pub struct LraGen {
    pub task: LraTask,
    pub seqlen: usize,
    rng: Pcg64,
}

impl LraGen {
    pub fn new(task: LraTask, seqlen: usize, seed: u64) -> Self {
        Self { task, seqlen, rng: Pcg64::new(seed, 0x17A + task as u64) }
    }

    pub fn example(&mut self) -> (Vec<i32>, i32) {
        let (mut t, l) = match self.task {
            LraTask::Text => self.text(),
            LraTask::ListOps => self.listops(),
            LraTask::Retrieval => self.retrieval(),
            LraTask::Pathfinder => self.pathfinder(),
            LraTask::Image => self.image(),
        };
        while t.len() < self.seqlen {
            t.push(special::PAD);
        }
        t.truncate(self.seqlen);
        (t, l)
    }

    pub fn batch(&mut self, batch: usize) -> ClsBatch {
        let mut tokens = Vec::with_capacity(batch * self.seqlen);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (t, l) = self.example();
            tokens.extend_from_slice(&t);
            labels.push(l);
        }
        ClsBatch { batch, seqlen: self.seqlen, tokens, labels }
    }

    /// Class-conditional bigram streams: class c walks bytes with step
    /// pattern +c-dependent increments.
    fn text(&mut self) -> (Vec<i32>, i32) {
        let label = self.rng.below(2) as i32;
        let step: u8 = if label == 0 { 7 } else { 11 };
        let mut b = self.rng.below(256) as u8;
        let mut out = vec![special::CLS];
        for _ in 0..self.seqlen - 1 {
            // Mostly deterministic walk + noise: bigram statistics differ
            // by class while unigram marginals stay uniform.
            b = if self.rng.f64() < 0.8 { b.wrapping_add(step) } else { self.rng.below(256) as u8 };
            out.push(byte_tok(b));
        }
        (out, label)
    }

    /// Nested list operations rendered as tokens; evaluated result is the
    /// label.  Op bytes: 252=MAX, 253=MIN, 254=MED, 255=CLOSE; depth <= 3.
    fn listops(&mut self) -> (Vec<i32>, i32) {
        let budget = self.seqlen - 2;
        let mut out = vec![special::CLS];
        let value = self.gen_expr(&mut out, 3, budget);
        (out, value)
    }

    fn gen_expr(&mut self, out: &mut Vec<i32>, depth: usize, budget: usize) -> i32 {
        const OPS: [(u8, u8); 3] = [(252, 0), (253, 1), (254, 2)];
        if depth == 0 || budget < 8 || self.rng.f64() < 0.3 {
            let d = self.rng.below(10) as i32;
            out.push(byte_tok(d as u8));
            return d;
        }
        let (op_tok, op) = OPS[self.rng.below(3) as usize];
        out.push(byte_tok(op_tok));
        let arity = 2 + self.rng.below(3) as usize;
        let mut vals = Vec::with_capacity(arity);
        let per = budget / arity;
        for _ in 0..arity {
            vals.push(self.gen_expr(out, depth - 1, per.saturating_sub(2)));
        }
        out.push(byte_tok(255));
        match op {
            0 => vals.iter().copied().max().unwrap(),
            1 => vals.iter().copied().min().unwrap(),
            _ => {
                vals.sort_unstable();
                vals[vals.len() / 2]
            }
        }
    }

    /// Two documents separated by [SEP]; label 1 iff both contain the
    /// rare marker byte 250 — requires matching across the whole span.
    fn retrieval(&mut self) -> (Vec<i32>, i32) {
        let half = (self.seqlen - 3) / 2;
        let positive = self.rng.below(2) == 1;
        let doc = |has_marker: bool, rng: &mut Pcg64| -> Vec<i32> {
            let mut d: Vec<i32> =
                (0..half).map(|_| byte_tok((rng.below(249)) as u8)).collect();
            if has_marker {
                let pos = rng.below(half as u64) as usize;
                d[pos] = byte_tok(250);
            }
            d
        };
        let first_marker = positive || self.rng.below(2) == 1;
        let second_marker = positive;
        let a = doc(first_marker, &mut self.rng);
        let b = doc(second_marker, &mut self.rng);
        let mut out = vec![special::CLS];
        out.extend(a);
        out.push(special::SEP);
        out.extend(b);
        (out, positive as i32)
    }

    /// 1-D pathfinder: two endpoint markers (byte 251) placed far apart;
    /// positive examples carry an arithmetic "trail" of increasing bytes
    /// linking them, negatives have a broken trail.
    fn pathfinder(&mut self) -> (Vec<i32>, i32) {
        let n = self.seqlen - 1;
        let mut bytes: Vec<u8> = (0..n).map(|_| self.rng.below(200) as u8).collect();
        let a = self.rng.below((n / 4) as u64) as usize;
        let b = n - 1 - self.rng.below((n / 4) as u64) as usize;
        let positive = self.rng.below(2) == 1;
        // Trail: every k-th position between a and b carries byte 201+step
        let k = ((b - a) / 16).max(1);
        let mut step = 0u8;
        let mut i = a + k;
        while i < b {
            bytes[i] = 201 + (step % 40);
            step += 1;
            if !positive && step == 4 {
                // break the chain early for negatives
                break;
            }
            i += k;
        }
        let mut out = vec![special::CLS];
        for (idx, &byte) in bytes.iter().enumerate() {
            if idx == a || idx == b {
                out.push(byte_tok(251));
            } else {
                out.push(byte_tok(byte.min(250)));
            }
        }
        (out, positive as i32)
    }

    /// 16x16 glyph: circle (label 0) vs cross (label 1), grayscale bytes.
    fn image(&mut self) -> (Vec<i32>, i32) {
        let side = 16usize;
        let label = self.rng.below(2) as i32;
        let cx = 7.5 + self.rng.f64() * 1.0 - 0.5;
        let cy = 7.5 + self.rng.f64() * 1.0 - 0.5;
        let r = 4.0 + self.rng.f64() * 2.0;
        let mut out = vec![special::CLS];
        for y in 0..side {
            for x in 0..side {
                let (fx, fy) = (x as f64, y as f64);
                let on = if label == 0 {
                    let d = ((fx - cx).powi(2) + (fy - cy).powi(2)).sqrt();
                    (d - r).abs() < 1.2
                } else {
                    (fx - cx).abs() < 1.2 || (fy - cy).abs() < 1.2
                };
                let noise = self.rng.below(60) as u8;
                let v: u8 = if on { 200u8.saturating_add(noise) } else { noise };
                out.push(byte_tok(v));
            }
        }
        (out, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_shape_and_label_ranges() {
        for task in LraTask::ALL {
            let mut g = LraGen::new(task, 512, 1);
            for _ in 0..10 {
                let (t, l) = g.example();
                assert_eq!(t.len(), 512, "{task:?}");
                assert!((l as usize) < task.num_classes(), "{task:?}: {l}");
                assert!(
                    t.iter().all(|&x| (0..LRA_VOCAB as i32).contains(&x)),
                    "{task:?} out-of-vocab"
                );
            }
        }
    }

    #[test]
    fn listops_labels_cover_digits() {
        let mut g = LraGen::new(LraTask::ListOps, 512, 2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            let (_, l) = g.example();
            seen.insert(l);
        }
        assert!(seen.len() >= 6, "only {} distinct results", seen.len());
    }

    #[test]
    fn retrieval_marker_semantics() {
        let mut g = LraGen::new(LraTask::Retrieval, 512, 3);
        for _ in 0..40 {
            let (t, l) = g.example();
            let sep = t.iter().position(|&x| x == special::SEP).unwrap();
            let marker = byte_tok(250);
            let in_a = t[1..sep].contains(&marker);
            let in_b = t[sep + 1..].contains(&marker);
            assert_eq!((in_a && in_b) as i32, l);
        }
    }

    #[test]
    fn text_classes_have_distinct_bigrams() {
        let mut g = LraGen::new(LraTask::Text, 512, 4);
        // Count the class-0 step (+7) frequency among adjacent byte pairs.
        let mut step7 = [0usize; 2];
        let mut total = [0usize; 2];
        for _ in 0..60 {
            let (t, l) = g.example();
            for w in t.windows(2) {
                let (a, b) = (w[0] - special::FIRST_CONTENT, w[1] - special::FIRST_CONTENT);
                if (0..256).contains(&a) && (0..256).contains(&b) {
                    if (a + 7) % 256 == b % 256 {
                        step7[l as usize] += 1;
                    }
                    total[l as usize] += 1;
                }
            }
        }
        let f0 = step7[0] as f64 / total[0] as f64;
        let f1 = step7[1] as f64 / total[1] as f64;
        assert!(f0 > 3.0 * f1, "class bigram signal missing: {f0} vs {f1}");
    }

    #[test]
    fn image_classes_differ_in_mass_distribution() {
        let mut g = LraGen::new(LraTask::Image, 512, 5);
        // Crosses put bright pixels along full rows/cols; circles on a ring.
        // Just verify both classes generate and are bright somewhere.
        for _ in 0..10 {
            let (t, _l) = g.example();
            let bright = t.iter().filter(|&&x| x >= byte_tok(200)).count();
            assert!(bright > 10, "{bright}");
        }
    }
}
