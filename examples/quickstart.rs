//! Quickstart: drive the native `AttentionBackend` registry through the
//! `AttnSpec` mask API (full, padded, causal), demo moment matching and
//! the causal prefix-state decode, then — when AOT artifacts are built —
//! cross-check the PJRT LLN kernel against the native implementation.
//!
//!     cargo run --release --example quickstart          # native only
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use lln::attention::{self, backend_for, AttnSpec, BackendParams, Method, MomentMatcher};
use lln::rng::Pcg64;
use lln::runtime::{artifacts_dir, Engine, HostTensor};
use lln::tensor::Mat;

fn main() -> Result<()> {
    // 1. Moment matching (paper eq. 10): derive alpha/beta from live
    //    stats — the AOT-fitted constants when artifacts exist, the
    //    identity model otherwise.
    let mm = MomentMatcher::from_artifacts(&artifacts_dir(None))
        .unwrap_or(MomentMatcher { a: 1.0, b: 0.0 });
    let (sigma_q, sigma_k) = (1.1f64, 0.9f64);
    let (alpha, beta) = mm.alpha_beta(sigma_q, sigma_k);
    println!(
        "moment matching: sigma_q={sigma_q} sigma_k={sigma_k} -> alpha={alpha:.3} beta={beta:.3}"
    );

    // 2. One backend, three masks.  Every forward carries an AttnSpec:
    //    AttnSpec::FULL is bidirectional encoder attention,
    //    AttnSpec::CAUSAL the decoder mask, AttnSpec::padded(len) a
    //    right-padding key mask (what `lln serve` uses for batching
    //    variable-length requests).
    let (n, d) = (256usize, 64usize);
    let mut rng = Pcg64::seed(0);
    let q = Mat::gaussian(n, d, sigma_q as f32, &mut rng);
    let k = Mat::gaussian(n, d, sigma_k as f32, &mut rng);
    let v = Mat::gaussian(n, d, 1.0, &mut rng);
    let lln_bk = backend_for(Method::Lln, BackendParams { alpha, beta, ..Default::default() });
    let full = lln_bk.forward(&q, &k, &v, &AttnSpec::FULL);
    let causal = lln_bk.forward(&q, &k, &v, &AttnSpec::CAUSAL);
    let padded = lln_bk.forward(&q, &k, &v, &AttnSpec::padded(192));
    println!(
        "lln forward under masks: full[0][0]={:+.4}  causal[0][0]={:+.4}  padded[0][0]={:+.4}",
        full.get(0, 0),
        causal.get(0, 0),
        padded.get(0, 0)
    );

    // 3. Causal decoding: the prefix-state recurrence means token i sees
    //    exactly tokens 0..=i — the last row of a causal forward over a
    //    t-token prefix IS the decode step for token t.  Check the
    //    first decode step against its closed form (one visible key),
    //    and the full-causal forward against incremental prefixes.
    let step0 = causal.row(0);
    let expect: Vec<f32> = v.row(0).to_vec();
    let err0: f32 = step0
        .iter()
        .zip(&expect)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    println!("causal decode step 0 vs closed form (v[0]): max |diff| = {err0:.2e}");
    assert!(err0 < 1e-5);
    // Decoding t tokens = causal forward over the t-prefix; the causal
    // key mask makes the two identical without re-slicing any matrix.
    let t = 64usize;
    let prefix = lln_bk.forward(&q, &k, &v, &AttnSpec::causal_padded(t));
    let err_t: f32 = prefix
        .row(t - 1)
        .iter()
        .zip(causal.row(t - 1))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    println!("causal decode step {t} vs full causal forward: max |diff| = {err_t:.2e}");
    assert!(err_t < 1e-5);

    // 4. Exact softmax under the same masks, through the fused
    //    O(n·tile) kernels — including the causal variant that streams
    //    only prefix tiles.
    let sm_bk = backend_for(Method::Softmax, BackendParams::default());
    let sm_causal = sm_bk.forward(&q, &k, &v, &AttnSpec::CAUSAL);
    let dense = attention::softmax_attention_matrix_spec(&q, &k, &AttnSpec::CAUSAL).matmul(&v);
    let err = sm_causal.max_abs_diff(&dense);
    println!("fused causal softmax vs masked dense reference: max |diff| = {err:.2e}");
    assert!(err < 1e-4);

    // 5. LLN concentration matches softmax (paper fig. 2 instruments).
    let p_lln = attention::lln_attention_matrix(&q, &k, alpha, beta);
    let p_sm = attention::softmax_attention_matrix(&q, &k);
    println!(
        "entropy:      lln={:.3}   softmax={:.3}",
        lln::stats::attention_entropy(&p_lln),
        lln::stats::attention_entropy(&p_sm),
    );
    println!(
        "spectral gap: lln={:.3}        softmax={:.3}",
        lln::linalg::spectral_gap(&p_lln, 400, 1e-8).gap,
        lln::linalg::spectral_gap(&p_sm, 400, 1e-8).gap,
    );

    // 6. PJRT cross-check (optional: needs `make artifacts`).
    let dir = artifacts_dir(None);
    match Engine::new(&dir) {
        Ok(mut engine) => {
            let outs = engine.execute(
                "attn_lln_n256",
                &[
                    HostTensor::from_mat(&q),
                    HostTensor::from_mat(&k),
                    HostTensor::from_mat(&v),
                    HostTensor::scalar_f32(alpha),
                    HostTensor::scalar_f32(beta),
                ],
            )?;
            let kernel_out = outs[0].to_mat()?;
            let native = attention::lln_attention(&q, &k, &v, alpha, beta);
            let err = kernel_out.max_abs_diff(&native);
            println!("PJRT kernel vs native Rust: max |diff| = {err:.2e}");
            assert!(err < 2e-3);
        }
        Err(e) => {
            println!("(skipping PJRT cross-check: {e:#}; run `make artifacts` to enable)");
        }
    }
    println!("quickstart OK");
    Ok(())
}
