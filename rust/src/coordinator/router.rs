//! Consistent-hash session router for the sharded coordinator front.
//!
//! Sessions are pinned to a shard for their lifetime (their decode
//! state lives in that shard's registry), so the router must be stable:
//! when the shard count grows from `n` to `n+1`, only the keys whose
//! ring arc the new shard claims may move — and every moved key lands
//! on the *new* shard.  A plain `key % n` would reshuffle nearly
//! everything.  Each shard contributes `replicas` virtual points to a
//! sorted ring; a key routes to the first point clockwise of its hash.

/// SplitMix64 finalizer: cheap, well-mixed 64-bit hash for ring points
/// and keys (session ids are sequential, so mixing matters).
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Immutable consistent-hash ring over `shards` shards.
pub struct HashRing {
    /// (point hash, shard) sorted by hash.
    points: Vec<(u64, usize)>,
    shards: usize,
}

/// Virtual points per shard; enough to keep the load split within a few
/// percent of uniform at single-digit shard counts.
pub const RING_REPLICAS: usize = 64;

impl HashRing {
    pub fn new(shards: usize) -> Self {
        Self::with_replicas(shards, RING_REPLICAS)
    }

    pub fn with_replicas(shards: usize, replicas: usize) -> Self {
        let shards = shards.max(1);
        let replicas = replicas.max(1);
        let mut points = Vec::with_capacity(shards * replicas);
        for s in 0..shards {
            for r in 0..replicas {
                points.push((mix(((s as u64) << 32) | r as u64), s));
            }
        }
        points.sort_unstable();
        Self { points, shards }
    }

    /// Rebuild the ring with `dead` shards removed.  Surviving shards
    /// keep their exact virtual points (the point hash is a pure
    /// function of `(shard, replica)`), so only keys that routed to a
    /// dead shard remap — the failover guarantee the supervisor relies
    /// on when it marks a shard out of the ring.  If every shard is
    /// dead the ring degenerates to shard 0 (callers check liveness
    /// before enqueueing).
    pub fn excluding(shards: usize, dead: &[usize]) -> Self {
        Self::excluding_with_replicas(shards, dead, RING_REPLICAS)
    }

    pub fn excluding_with_replicas(shards: usize, dead: &[usize], replicas: usize) -> Self {
        let shards = shards.max(1);
        let replicas = replicas.max(1);
        let mut points = Vec::with_capacity(shards * replicas);
        for s in 0..shards {
            if dead.contains(&s) {
                continue;
            }
            for r in 0..replicas {
                points.push((mix(((s as u64) << 32) | r as u64), s));
            }
        }
        if points.is_empty() {
            return Self::with_replicas(1, replicas);
        }
        points.sort_unstable();
        Self { points, shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard owning `key`: first ring point clockwise of `mix(key)`.
    pub fn route(&self, key: u64) -> usize {
        let h = mix(key);
        let i = self.points.partition_point(|&(p, _)| p < h);
        self.points[if i == self.points.len() { 0 } else { i }].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_routes_everything_to_zero() {
        let ring = HashRing::new(1);
        for k in 0..1000u64 {
            assert_eq!(ring.route(k), 0);
        }
    }

    #[test]
    fn load_is_roughly_uniform() {
        let ring = HashRing::new(4);
        let mut counts = [0usize; 4];
        for k in 0..40_000u64 {
            counts[ring.route(k)] += 1;
        }
        for &c in &counts {
            // Within 30% of the uniform 10k per shard.
            assert!((7_000..=13_000).contains(&c), "skewed shard load: {counts:?}");
        }
    }

    #[test]
    fn growing_the_ring_only_remaps_onto_the_new_shard() {
        // The consistency property the session registry depends on:
        // adding shard n never moves a key between two old shards.
        for n in 1..6usize {
            let old = HashRing::new(n);
            let new = HashRing::new(n + 1);
            let mut moved = 0usize;
            for k in 0..20_000u64 {
                let (a, b) = (old.route(k), new.route(k));
                if a != b {
                    assert_eq!(b, n, "key {k} remapped {a}->{b}, not to the new shard {n}");
                    moved += 1;
                }
            }
            // The new shard claims roughly 1/(n+1) of the keyspace.
            let expect = 20_000 / (n + 1);
            assert!(
                moved < 2 * expect,
                "shard growth {n}->{} moved {moved} keys (expected ~{expect})",
                n + 1
            );
            assert!(moved > 0, "the new shard must claim some keys");
        }
    }

    #[test]
    fn routing_is_deterministic() {
        let a = HashRing::new(3);
        let b = HashRing::new(3);
        for k in 0..512u64 {
            assert_eq!(a.route(k), b.route(k));
        }
    }

    #[test]
    fn removing_a_shard_moves_only_its_keys() {
        // The failover property: when shard `d` dies, every key it
        // owned remaps to a *surviving* shard, and no key owned by a
        // surviving shard moves at all.
        for n in 2..6usize {
            for d in 0..n {
                let full = HashRing::new(n);
                let cut = HashRing::excluding(n, &[d]);
                let mut moved = 0usize;
                for k in 0..20_000u64 {
                    let (a, b) = (full.route(k), cut.route(k));
                    assert_ne!(b, d, "key {k} routed to the dead shard {d}");
                    if a != d {
                        assert_eq!(a, b, "survivor key {k} moved {a}->{b} when {d} died");
                    } else {
                        moved += 1;
                    }
                }
                assert!(moved > 0, "the dead shard {d}/{n} must have owned some keys");
            }
        }
    }

    #[test]
    fn ring_with_dead_shards_stays_covered_and_roughly_uniform() {
        // 4 shards, one dead: the survivors split its arc between them.
        let cut = HashRing::excluding(4, &[2]);
        let mut counts = [0usize; 4];
        for k in 0..30_000u64 {
            counts[cut.route(k)] += 1;
        }
        assert_eq!(counts[2], 0, "dead shard must receive nothing");
        for (s, &c) in counts.iter().enumerate() {
            if s != 2 {
                // Within 30% of the uniform 10k per surviving shard.
                assert!((7_000..=13_000).contains(&c), "skewed survivor load: {counts:?}");
            }
        }
    }

    #[test]
    fn rebuilds_are_deterministic_and_compose() {
        // Rebuilding the same live set twice routes identically, and
        // excluding nothing is exactly the full ring.
        let a = HashRing::excluding(5, &[1, 3]);
        let b = HashRing::excluding(5, &[3, 1]);
        let full = HashRing::new(5);
        let none = HashRing::excluding(5, &[]);
        for k in 0..4_096u64 {
            assert_eq!(a.route(k), b.route(k), "dead-set order must not matter");
            assert_eq!(full.route(k), none.route(k), "empty dead set = full ring");
        }
        // All-dead degenerates to shard 0 instead of panicking.
        let dead = HashRing::excluding(3, &[0, 1, 2]);
        assert_eq!(dead.route(42), 0);
    }

    #[test]
    fn sequential_removals_compose_with_single_rebuild() {
        // Killing shard 1 then shard 3 routes the same as rebuilding
        // once with both dead — supervisors on different shards may
        // condemn in any order.
        let step = HashRing::excluding(5, &[1]);
        let both = HashRing::excluding(5, &[1, 3]);
        for k in 0..8_192u64 {
            let s = step.route(k);
            if s != 3 {
                assert_eq!(s, both.route(k), "key {k} moved although shard {s} survived");
            }
        }
    }
}
