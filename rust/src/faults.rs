//! Deterministic, seeded fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a schedule, not a dice roll: every fault site in
//! the coordinator consults a [`FaultPoint`] whose firing pattern is a
//! pure function of how many times the site has been reached
//! (`start` / `every` / `limit`), so the same plan against the same
//! request sequence injects the same faults.  `lln serve --chaos-seed`
//! derives a full plan from a single seed (see
//! [`FaultsConfig::chaos`](crate::config::FaultsConfig::chaos)); tests
//! construct plans directly.
//!
//! Fault sites:
//!   * **executor call** — panic the Nth prefill batch execution (the
//!     panic is raised inside the worker's `catch_panic` scope, so it
//!     routes into the bounded-retry path, never a crashed worker);
//!   * **worker item** — delay a worker before processing an item, kill
//!     a single worker (the supervisor must respawn it), or condemn a
//!     whole shard once the global item counter crosses a threshold;
//!   * **page allocation** — fail a `PagePool` page acquisition
//!     (exercising the recompute / poison / failover paths).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::FaultsConfig;

/// SplitMix64 — the same finalizer the session router uses; here it
/// seeds chaos-plan derivation and deterministic retry jitter.
pub fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Deterministic backoff with jitter for prefill retries: exponential
/// in the attempt number (1-based), jittered by a pure hash of
/// `(salt, attempt)` so two coordinators replaying the same request ids
/// sleep the same schedule.  Returns milliseconds.
pub fn backoff_ms(base_ms: u64, attempt: u32, salt: u64) -> u64 {
    let base = base_ms.max(1);
    // Cap the exponent so a misconfigured retry_max cannot overflow.
    let exp = base.saturating_mul(1u64 << attempt.min(10).saturating_sub(1));
    let jitter = splitmix(salt ^ (attempt as u64).wrapping_mul(0x9E37)) % (exp / 2 + 1);
    exp / 2 + jitter
}

/// A single schedulable fault site: fires on the `start`-th arrival
/// (1-based), then every `every` arrivals after that, at most `limit`
/// times (`0` = unlimited).  `start == 0` disables the point.
#[derive(Debug, Default)]
pub struct FaultPoint {
    start: u64,
    every: u64,
    limit: u64,
    hits: AtomicU64,
    fired: AtomicU64,
}

impl FaultPoint {
    pub fn new(start: u64, every: u64, limit: u64) -> Self {
        Self { start, every, limit, hits: AtomicU64::new(0), fired: AtomicU64::new(0) }
    }

    /// A point that never fires.
    pub fn disabled() -> Self {
        Self::new(0, 0, 0)
    }

    /// Fire exactly once, on the `n`-th arrival (1-based).
    pub fn once_at(n: u64) -> Self {
        Self::new(n, 0, 1)
    }

    pub fn is_enabled(&self) -> bool {
        self.start > 0
    }

    /// Count one arrival at this site and decide whether the fault
    /// fires for it.  Thread-safe; the arrival order across threads is
    /// whatever the scheduler produced, but the *pattern* over arrival
    /// indices is fixed.
    pub fn fire(&self) -> bool {
        if self.start == 0 {
            return false;
        }
        let n = self.hits.fetch_add(1, Ordering::SeqCst) + 1;
        if n < self.start {
            return false;
        }
        let offset = n - self.start;
        let periodic = if self.every == 0 { offset == 0 } else { offset % self.every == 0 };
        if !periodic {
            return false;
        }
        if self.limit == 0 {
            self.fired.fetch_add(1, Ordering::SeqCst);
            return true;
        }
        self.fired
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |f| {
                if f < self.limit {
                    Some(f + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    /// How many times this point has actually fired.
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::SeqCst)
    }
}

/// What a worker should do with the item it just picked up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerFault {
    /// Sleep this many milliseconds before processing (a slow worker).
    Delay(u64),
    /// Die: the worker re-queues or buries its pending items and
    /// returns an error, exercising the supervisor's respawn path.
    Die,
}

/// The full seeded fault schedule shared by every worker/supervisor.
#[derive(Debug)]
pub struct FaultPlan {
    /// Panic the Nth prefill batch execution.  Decode calls
    /// (`begin_decode` / `decode_step`) are deliberately not wired to
    /// this point: a panicked step would poison its session, and the
    /// chaos acceptance test needs decode to stay deterministic so
    /// failover can be checked bitwise.
    pub exec_panic: FaultPoint,
    /// Delay a worker before the Nth picked-up item.
    pub delay: FaultPoint,
    pub delay_ms: u64,
    /// Fail the Nth fresh PagePool page acquisition.
    pub page_alloc_fail: FaultPoint,
    /// Kill the worker that picks up the Nth item.
    pub kill_worker: FaultPoint,
    /// Condemn this shard's whole worker pool once the global
    /// worker-item counter reaches `kill_shard_at`.
    pub kill_shard: Option<usize>,
    pub kill_shard_at: u64,
    items: AtomicU64,
    shard_killed: AtomicBool,
}

impl FaultPlan {
    /// Build the shared plan from a parsed `[faults]` section; `None`
    /// when every knob is off (the fast path stays fault-free).
    pub fn from_config(cfg: &FaultsConfig) -> Option<Arc<FaultPlan>> {
        if !cfg.enabled() {
            return None;
        }
        Some(Arc::new(FaultPlan {
            exec_panic: FaultPoint::new(cfg.exec_panic_start, cfg.exec_panic_every, cfg.exec_panic_limit),
            delay: FaultPoint::new(cfg.delay_start, cfg.delay_every, cfg.delay_limit),
            delay_ms: cfg.delay_ms,
            page_alloc_fail: FaultPoint::new(cfg.page_fail_start, cfg.page_fail_every, cfg.page_fail_limit),
            kill_worker: FaultPoint::new(cfg.kill_worker_start, cfg.kill_worker_every, cfg.kill_worker_limit),
            kill_shard: usize::try_from(cfg.kill_shard).ok(),
            kill_shard_at: cfg.kill_shard_at,
            items: AtomicU64::new(0),
            shard_killed: AtomicBool::new(false),
        }))
    }

    /// One executor invocation is about to run; `true` = panic it.
    pub fn on_exec_call(&self) -> bool {
        self.exec_panic.fire()
    }

    /// A worker on `shard` picked up one work item.  Advances the
    /// global item counter (which drives the shard-kill schedule) and
    /// returns the fault, if any, the worker must act out.
    pub fn on_worker_item(&self, shard: usize) -> Option<WorkerFault> {
        let n = self.items.fetch_add(1, Ordering::SeqCst) + 1;
        if self.kill_shard.is_some() && n >= self.kill_shard_at.max(1) {
            self.shard_killed.store(true, Ordering::SeqCst);
        }
        if self.shard_condemned(shard) {
            return Some(WorkerFault::Die);
        }
        if self.kill_worker.fire() {
            return Some(WorkerFault::Die);
        }
        if self.delay.fire() {
            return Some(WorkerFault::Delay(self.delay_ms.max(1)));
        }
        None
    }

    /// Has the shard-kill schedule condemned this shard?  Once true it
    /// stays true: the supervisor buries the shard instead of
    /// respawning into it.
    pub fn shard_condemned(&self, shard: usize) -> bool {
        self.kill_shard == Some(shard) && self.shard_killed.load(Ordering::SeqCst)
    }

    /// A fresh (non-resident) page acquisition is about to allocate;
    /// `true` = fail it.
    pub fn on_page_alloc(&self) -> bool {
        self.page_alloc_fail.fire()
    }

    /// Total faults actually injected so far (mirrored into
    /// `ServeStats::faults_injected` by the workers).
    pub fn injected(&self) -> u64 {
        self.exec_panic.fired()
            + self.delay.fired()
            + self.page_alloc_fail.fired()
            + self.kill_worker.fired()
            + u64::from(self.shard_killed.load(Ordering::SeqCst))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_point_never_fires() {
        let p = FaultPoint::disabled();
        for _ in 0..100 {
            assert!(!p.fire());
        }
        assert_eq!(p.fired(), 0);
        assert!(!p.is_enabled());
    }

    #[test]
    fn once_at_fires_exactly_once_at_n() {
        let p = FaultPoint::once_at(3);
        let fires: Vec<bool> = (0..8).map(|_| p.fire()).collect();
        assert_eq!(fires, vec![false, false, true, false, false, false, false, false]);
        assert_eq!(p.fired(), 1);
    }

    #[test]
    fn periodic_point_respects_start_every_limit() {
        // start=2, every=3, limit=2 -> fires on arrivals 2 and 5 only.
        let p = FaultPoint::new(2, 3, 2);
        let fired: Vec<u64> = (1..=12).filter(|_| p.fire()).collect();
        assert_eq!(p.fired(), 2);
        assert_eq!(fired.len(), 2);
        // Unlimited: fires on 2, 5, 8, 11 within 12 arrivals.
        let p = FaultPoint::new(2, 3, 0);
        let n = (1..=12).filter(|_| p.fire()).count();
        assert_eq!(n, 4);
    }

    #[test]
    fn schedule_is_deterministic_across_replays() {
        let pattern = |p: &FaultPoint| -> Vec<bool> { (0..64).map(|_| p.fire()).collect() };
        let a = pattern(&FaultPoint::new(5, 4, 3));
        let b = pattern(&FaultPoint::new(5, 4, 3));
        assert_eq!(a, b, "same schedule must replay identically");
    }

    #[test]
    fn shard_kill_trips_at_threshold_and_latches() {
        let plan = FaultPlan {
            exec_panic: FaultPoint::disabled(),
            delay: FaultPoint::disabled(),
            delay_ms: 0,
            page_alloc_fail: FaultPoint::disabled(),
            kill_worker: FaultPoint::disabled(),
            kill_shard: Some(1),
            kill_shard_at: 3,
            items: AtomicU64::new(0),
            shard_killed: AtomicBool::new(false),
        };
        // Shard 0 items advance the counter but shard 0 never dies.
        assert_eq!(plan.on_worker_item(0), None);
        assert_eq!(plan.on_worker_item(0), None);
        assert!(!plan.shard_condemned(1), "threshold not reached yet");
        assert_eq!(plan.on_worker_item(0), None, "shard 0 is not the target");
        assert!(plan.shard_condemned(1), "threshold reached: shard 1 condemned");
        assert!(!plan.shard_condemned(0));
        assert_eq!(plan.on_worker_item(1), Some(WorkerFault::Die));
        // Latched: stays condemned forever.
        assert_eq!(plan.on_worker_item(1), Some(WorkerFault::Die));
        assert_eq!(plan.injected(), 1, "one shard kill counts as one injected fault");
    }

    #[test]
    fn worker_faults_delay_then_die() {
        let plan = FaultPlan {
            exec_panic: FaultPoint::disabled(),
            delay: FaultPoint::once_at(1),
            delay_ms: 7,
            page_alloc_fail: FaultPoint::disabled(),
            kill_worker: FaultPoint::once_at(2),
            kill_shard: None,
            kill_shard_at: 0,
            items: AtomicU64::new(0),
            shard_killed: AtomicBool::new(false),
        };
        assert_eq!(plan.on_worker_item(0), Some(WorkerFault::Delay(7)));
        assert_eq!(plan.on_worker_item(0), Some(WorkerFault::Die));
        assert_eq!(plan.on_worker_item(0), None);
        assert_eq!(plan.injected(), 2);
    }

    #[test]
    fn backoff_is_deterministic_bounded_and_grows() {
        let a = backoff_ms(5, 1, 42);
        let b = backoff_ms(5, 1, 42);
        assert_eq!(a, b, "jitter must be a pure function of (base, attempt, salt)");
        assert!(backoff_ms(5, 1, 42) != backoff_ms(5, 1, 43) || backoff_ms(5, 2, 42) != backoff_ms(5, 2, 43));
        for attempt in 1..=6u32 {
            let exp = 5u64 << (attempt - 1);
            let ms = backoff_ms(5, attempt, 9);
            assert!(ms >= exp / 2 && ms <= exp, "attempt {attempt}: {ms} outside [{}, {exp}]", exp / 2);
        }
        // Degenerate inputs stay sane (no panic, no overflow).
        let _ = backoff_ms(0, 1, 0);
        assert!(backoff_ms(u64::MAX / 2, 30, 1) > 0, "saturates instead of overflowing");
    }

    #[test]
    fn plan_from_config_gates_on_enabled() {
        let off = FaultsConfig::default();
        assert!(FaultPlan::from_config(&off).is_none(), "all-off config must not allocate a plan");
        let on = FaultsConfig { exec_panic_start: 2, ..Default::default() };
        let plan = FaultPlan::from_config(&on).expect("enabled config builds a plan");
        assert!(!plan.on_exec_call());
        assert!(plan.on_exec_call());
        assert_eq!(plan.injected(), 1);
    }

    #[test]
    fn chaos_derivation_is_deterministic_and_in_range() {
        let a = FaultsConfig::chaos(7, 2);
        let b = FaultsConfig::chaos(7, 2);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "same seed -> same plan");
        assert!(a.enabled());
        let shard = usize::try_from(a.kill_shard).expect("chaos with >1 shard kills one shard");
        assert!(shard < 2);
        // A different seed must produce a different schedule somewhere.
        let c = FaultsConfig::chaos(8, 2);
        assert_ne!(format!("{a:?}"), format!("{c:?}"));
    }
}
