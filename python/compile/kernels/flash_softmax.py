"""Pallas kernel: blocked (flash-style) softmax attention baseline.

Online-softmax over K/V chunks: grid is (q_blocks, k_blocks) with the
k axis innermost and sequential; the running max / normalizer / output
accumulator are carried in re-visited output blocks (constant index map
over the k axis), which interpret mode executes with the same
sequential-grid semantics as a TPU VMEM scratch.

The final `out / l` normalization happens outside the kernel — it keeps
the kernel single-purpose and XLA fuses the divide anyway.

This is the *quadratic-time, linear-memory* baseline: nothing N x N is
materialized, but the grid still has q_blocks * k_blocks steps, so
compute remains O(N^2) — exactly the SA column of paper Table 2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, scale, nk):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    s = (q_ref[...] @ k_ref[...].T) * scale                    # (bq, bk)
    m_prev = m_ref[...]                                        # (bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                                     # (bq, bk)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o_ref[...] * corr + p @ v_ref[...]
    m_ref[...] = m_cur


def softmax_attention_pallas(
    q, k, v, *, block_q=DEFAULT_BLOCK, block_k=DEFAULT_BLOCK, interpret=True
):
    """Flash-style softmax attention over one head: q, k, v are (N, d)."""
    n, d = q.shape
    block_q = min(block_q, n)
    block_k = min(block_k, n)
    if n % block_q or n % block_k:
        raise ValueError(f"N={n} must be divisible by block sizes ({block_q}, {block_k})")
    nq, nk = n // block_q, n // block_k
    scale = 1.0 / (d ** 0.5)

    out, _m, l = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, nk=nk),
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out / l
