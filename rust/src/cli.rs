//! Command-line parsing substrate (clap substitute).
//!
//! Subcommand + `--flag value` / `--flag=value` / boolean `--flag` model,
//! with typed accessors, defaults, and generated help text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Declared flag (for help + validation).
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub takes_value: bool,
}

/// A parsed invocation: subcommand, flags, and positional args.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError(format!("--{name} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError(format!("--{name} expects a number, got {v:?}"))),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some(""))
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str, default: &str) -> Vec<String> {
        self.get_or(name, default)
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect()
    }
}

/// A subcommand declaration.
#[derive(Clone)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

/// Top-level CLI: named subcommands with flag validation + help.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl Cli {
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        if argv.is_empty() || argv[0] == "help" || argv[0] == "--help" || argv[0] == "-h" {
            return Err(CliError(self.help()));
        }
        let cmd_name = argv[0].clone();
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError(format!("unknown command {cmd_name:?}\n\n{}", self.help())))?;

        let mut args = Args { command: cmd_name, ..Default::default() };
        // Apply defaults first.
        for f in &cmd.flags {
            if let Some(d) = f.default {
                args.flags.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError(self.command_help(cmd)));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = cmd
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError(format!("unknown flag --{name} for {}\n\n{}", cmd.name, self.command_help(cmd))))?;
                let val = if let Some(v) = inline_val {
                    v
                } else if spec.takes_value {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| CliError(format!("--{name} expects a value")))?
                } else {
                    String::new()
                };
                args.flags.insert(name, val);
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [flags]\n\nCOMMANDS:\n", self.bin, self.about, self.bin);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        s.push_str(&format!("\nRun `{} <command> --help` for command flags.\n", self.bin));
        s
    }

    fn command_help(&self, cmd: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nFLAGS:\n", self.bin, cmd.name, cmd.about);
        for f in &cmd.flags {
            let d = f.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        s
    }
}

/// Shorthand for building flag specs.
pub fn flag(name: &'static str, help: &'static str, default: Option<&'static str>) -> FlagSpec {
    FlagSpec { name, help, default, takes_value: true }
}

pub fn switch(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, default: None, takes_value: false }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "lln",
            about: "test",
            commands: vec![Command {
                name: "train",
                about: "train a model",
                flags: vec![
                    flag("steps", "number of steps", Some("100")),
                    flag("method", "attention method", Some("lln")),
                    switch("verbose", "chatty"),
                ],
            }],
        }
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&sv(&["train"])).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert_eq!(a.get("method"), Some("lln"));
    }

    #[test]
    fn equals_and_space_forms() {
        let a = cli().parse(&sv(&["train", "--steps=5", "--method", "softmax"])).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 5);
        assert_eq!(a.get("method"), Some("softmax"));
    }

    #[test]
    fn boolean_switch() {
        let a = cli().parse(&sv(&["train", "--verbose"])).unwrap();
        assert!(a.get_bool("verbose"));
        let b = cli().parse(&sv(&["train"])).unwrap();
        assert!(!b.get_bool("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(cli().parse(&sv(&["train", "--nope", "1"])).is_err());
    }

    #[test]
    fn unknown_command_rejected() {
        assert!(cli().parse(&sv(&["fly"])).is_err());
    }

    #[test]
    fn bad_int_rejected() {
        let a = cli().parse(&sv(&["train", "--steps", "abc"])).unwrap();
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn list_flag() {
        let mut c = cli();
        c.commands[0].flags.push(flag("methods", "list", Some("a,b")));
        let a = c.parse(&sv(&["train", "--methods", "x, y ,z"])).unwrap();
        assert_eq!(a.get_list("methods", ""), vec!["x", "y", "z"]);
    }
}
