"""Differentiable Pallas attention: custom_vjp with hand-derived backward
kernels.

`pallas_call` has no general reverse-mode rule (and naive linearization
of accumulator-style kernels is silently wrong), so every attention
primitive used inside the AOT train step gets an analytic VJP whose
forward AND backward are Pallas kernels.

Backward math
-------------
Linear attention (num = Pq KV, den = Pq z + eps, out = num/den):
    h_i   = g_i / den_i                      (N, d)
    s_i   = (g_i . out_i) / den_i            (N,)
    dPq   = h KV^T - s (x) z                 (N, d)
    dKV   = Pq^T h                           (d, d)
    dz    = -Pq^T s                          (d,)
    dV    = Pk dKV
    dPk   = V dKV^T + 1 (x) dz
    feature-map chain rule:
      lln: dq = dPq * Pq * alpha, dalpha = sum(dPq * Pq * q)  (clamp mask)
      elu: dx = dPx * elu'(x)

Flash softmax (p_ij = exp(s_ij - m_i) / l_i):
    D_i  = g_i . out_i
    ds   = p * (g V^T - D)
    dq   = scale * ds K;  dk = scale * ds^T Q;  dv = p^T g

dq accumulates over the K axis and dk/dv over the Q axis, so they are
two separate kernels with transposed grids — each accumulator varies
only along its innermost grid axis (the TPU-valid revisit pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EXP_CLAMP

DEFAULT_BLOCK = 128
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Shared feature-map helpers (value and derivative)
# ---------------------------------------------------------------------------

def _phi(x, scale, feature_map):
    if feature_map == "lln":
        return jnp.exp(jnp.clip(scale * x, -EXP_CLAMP, EXP_CLAMP))
    if feature_map == "elu":
        return jax.nn.elu(x) + 1.0
    raise ValueError(f"unknown feature map {feature_map!r}")


def _dphi_dx(x, scale, phi_x, feature_map):
    """d phi(x) / d x given phi(x) (saves an exp)."""
    if feature_map == "lln":
        active = (jnp.abs(scale * x) < EXP_CLAMP).astype(phi_x.dtype)
        return scale * phi_x * active
    if feature_map == "elu":
        return jnp.where(x > 0, 1.0, phi_x)  # elu' = 1 (x>0) else e^x = phi
    raise ValueError(feature_map)


# ---------------------------------------------------------------------------
# Linear attention forward (keeps den as a residual for the VJP)
# ---------------------------------------------------------------------------

def _kv_fwd_kernel(k_ref, v_ref, beta_ref, kv_ref, z_ref, *, feature_map):
    pk = _phi(k_ref[...], beta_ref[0, 0], feature_map)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        kv_ref[...] = jnp.zeros_like(kv_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    kv_ref[...] += pk.T @ v_ref[...]
    z_ref[...] += jnp.sum(pk, axis=0, keepdims=True)


def _out_fwd_kernel(q_ref, alpha_ref, kv_ref, z_ref, o_ref, den_ref, *, feature_map, eps):
    pq = _phi(q_ref[...], alpha_ref[0, 0], feature_map)
    den = pq @ z_ref[...].T + eps                            # (bq, 1)
    o_ref[...] = (pq @ kv_ref[...]) / den
    den_ref[...] = den


def _linear_fwd(q, k, v, alpha, beta, feature_map, block_q, block_k, eps, interpret):
    n, d = q.shape
    bq, bk = min(block_q, n), min(block_k, n)
    a2 = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    b2 = jnp.asarray(beta, jnp.float32).reshape(1, 1)

    kv, z = pl.pallas_call(
        functools.partial(_kv_fwd_kernel, feature_map=feature_map),
        grid=(n // bk,),
        in_specs=[
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(k, v, b2)

    out, den = pl.pallas_call(
        functools.partial(_out_fwd_kernel, feature_map=feature_map, eps=eps),
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, a2, kv, z)
    return out, den, kv, z


# ---------------------------------------------------------------------------
# Linear attention backward kernels
# ---------------------------------------------------------------------------

def _q_bwd_kernel(
    q_ref, g_ref, out_ref, den_ref, alpha_ref, kv_ref, z_ref,
    dq_ref, dkv_ref, dz_ref, dalpha_ref, *, feature_map,
):
    """Grid over Q chunks: emits dq per chunk, accumulates dKV, dz, dalpha."""
    alpha = alpha_ref[0, 0]
    q = q_ref[...]
    pq = _phi(q, alpha, feature_map)                 # (bq, d)
    g = g_ref[...]
    den = den_ref[...]                               # (bq, 1)
    h = g / den                                      # (bq, d)
    s = jnp.sum(g * out_ref[...], axis=-1, keepdims=True) / den  # (bq, 1)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dkv_ref[...] = jnp.zeros_like(dkv_ref)
        dz_ref[...] = jnp.zeros_like(dz_ref)
        dalpha_ref[...] = jnp.zeros_like(dalpha_ref)

    dpq = h @ kv_ref[...].T - s * z_ref[...]         # (bq, d)
    dq_ref[...] = dpq * _dphi_dx(q, alpha, pq, feature_map)
    if feature_map == "lln":
        active = (jnp.abs(alpha * q) < EXP_CLAMP).astype(pq.dtype)
        dalpha_ref[...] += jnp.sum(dpq * pq * q * active).reshape(1, 1)
    dkv_ref[...] += pq.T @ h
    dz_ref[...] += -(s.T @ pq)                       # (1, d)


def _k_bwd_kernel(
    k_ref, v_ref, beta_ref, dkv_ref, dz_ref, dk_ref, dv_ref, dbeta_ref, *, feature_map,
):
    """Grid over K/V chunks: emits dk, dv per chunk, accumulates dbeta."""
    beta = beta_ref[0, 0]
    k = k_ref[...]
    pk = _phi(k, beta, feature_map)                  # (bk, d)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dbeta_ref[...] = jnp.zeros_like(dbeta_ref)

    dpk = v_ref[...] @ dkv_ref[...].T + dz_ref[...]  # (bk, d), dz broadcasts
    dk_ref[...] = dpk * _dphi_dx(k, beta, pk, feature_map)
    if feature_map == "lln":
        active = (jnp.abs(beta * k) < EXP_CLAMP).astype(pk.dtype)
        dbeta_ref[...] += jnp.sum(dpk * pk * k * active).reshape(1, 1)
    dv_ref[...] = pk @ dkv_ref[...]


def _linear_bwd(feature_map, block_q, block_k, eps, interpret, res, g):
    q, k, v, alpha, beta, out, den, kv, z = res
    n, d = q.shape
    bq, bk = min(block_q, n), min(block_k, n)
    a2 = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    b2 = jnp.asarray(beta, jnp.float32).reshape(1, 1)

    dq, dkv, dz, dalpha = pl.pallas_call(
        functools.partial(_q_bwd_kernel, feature_map=feature_map),
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),   # q
            pl.BlockSpec((bq, d), lambda i: (i, 0)),   # g
            pl.BlockSpec((bq, d), lambda i: (i, 0)),   # out
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),   # den
            pl.BlockSpec((1, 1), lambda i: (0, 0)),    # alpha
            pl.BlockSpec((d, d), lambda i: (0, 0)),    # kv
            pl.BlockSpec((1, d), lambda i: (0, 0)),    # z
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),   # dq
            pl.BlockSpec((d, d), lambda i: (0, 0)),    # dkv
            pl.BlockSpec((1, d), lambda i: (0, 0)),    # dz
            pl.BlockSpec((1, 1), lambda i: (0, 0)),    # dalpha
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, g, out, den, a2, kv, z)

    dk, dv, dbeta = pl.pallas_call(
        functools.partial(_k_bwd_kernel, feature_map=feature_map),
        grid=(n // bk,),
        in_specs=[
            pl.BlockSpec((bk, d), lambda i: (i, 0)),   # k
            pl.BlockSpec((bk, d), lambda i: (i, 0)),   # v
            pl.BlockSpec((1, 1), lambda i: (0, 0)),    # beta
            pl.BlockSpec((d, d), lambda i: (0, 0)),    # dkv
            pl.BlockSpec((1, d), lambda i: (0, 0)),    # dz
        ],
        out_specs=[
            pl.BlockSpec((bk, d), lambda i: (i, 0)),   # dk
            pl.BlockSpec((bk, d), lambda i: (i, 0)),   # dv
            pl.BlockSpec((1, 1), lambda i: (0, 0)),    # dbeta
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(k, v, b2, dkv, dz)

    return dq, dk, dv, dalpha.reshape(()), dbeta.reshape(())


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def linear_attention(
    q, k, v, alpha, beta,
    feature_map="lln", block_q=DEFAULT_BLOCK, block_k=DEFAULT_BLOCK,
    eps=1e-6, interpret=True,
):
    """Differentiable chunked linear attention (one head, (N, d) inputs)."""
    out, _, _, _ = _linear_fwd(q, k, v, alpha, beta, feature_map, block_q, block_k, eps, interpret)
    return out


def _linear_vjp_fwd(q, k, v, alpha, beta, feature_map, block_q, block_k, eps, interpret):
    out, den, kv, z = _linear_fwd(q, k, v, alpha, beta, feature_map, block_q, block_k, eps, interpret)
    return out, (q, k, v, alpha, beta, out, den, kv, z)


def _linear_vjp_bwd(feature_map, block_q, block_k, eps, interpret, res, g):
    return _linear_bwd(feature_map, block_q, block_k, eps, interpret, res, g)


linear_attention.defvjp(_linear_vjp_fwd, _linear_vjp_bwd)


def lln_attention(q, k, v, alpha, beta, **kw):
    return linear_attention(q, k, v, alpha, beta, "lln", **kw)


def elu_attention(q, k, v, **kw):
    one = jnp.ones((), jnp.float32)
    return linear_attention(q, k, v, one, one, "elu", **kw)


# ---------------------------------------------------------------------------
# Flash softmax forward
# ---------------------------------------------------------------------------

def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, scale):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    s = (q_ref[...] @ k_ref[...].T) * scale
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o_ref[...] * corr + p @ v_ref[...]
    m_ref[...] = m_cur


def _flash_fwd(q, k, v, block_q, block_k, interpret):
    n, d = q.shape
    bq, bk = min(block_q, n), min(block_k, n)
    scale = 1.0 / (d ** 0.5)
    acc, m, l = pl.pallas_call(
        functools.partial(_flash_fwd_kernel, scale=scale),
        grid=(n // bq, n // bk),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return acc / l, m, l


# ---------------------------------------------------------------------------
# Flash softmax backward (two kernels, transposed grids)
# ---------------------------------------------------------------------------

def _flash_dq_kernel(q_ref, k_ref, v_ref, g_ref, m_ref, l_ref, dd_ref, dq_ref, *, scale):
    """Grid (i, j), j innermost: dq_i accumulates over K blocks."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    s = (q_ref[...] @ k_ref[...].T) * scale                  # (bq, bk)
    p = jnp.exp(s - m_ref[...]) / l_ref[...]
    gv = g_ref[...] @ v_ref[...].T                           # (bq, bk)
    ds = p * (gv - dd_ref[...])
    dq_ref[...] += (ds @ k_ref[...]) * scale


def _flash_dkv_kernel(q_ref, k_ref, v_ref, g_ref, m_ref, l_ref, dd_ref, dk_ref, dv_ref, *, scale):
    """Grid (j, i), i innermost: dk_j / dv_j accumulate over Q blocks."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    s = (q_ref[...] @ k_ref[...].T) * scale                  # (bq, bk)
    p = jnp.exp(s - m_ref[...]) / l_ref[...]
    dv_ref[...] += p.T @ g_ref[...]
    gv = g_ref[...] @ v_ref[...].T                           # (bq, bk)
    ds = p * (gv - dd_ref[...])
    dk_ref[...] += (ds.T @ q_ref[...]) * scale


def _flash_bwd(block_q, block_k, interpret, res, g):
    q, k, v, out, m, l = res
    n, d = q.shape
    bq, bk = min(block_q, n), min(block_k, n)
    scale = 1.0 / (d ** 0.5)
    dd = jnp.sum(g * out, axis=-1, keepdims=True)            # (n, 1)

    dq = pl.pallas_call(
        functools.partial(_flash_dq_kernel, scale=scale),
        grid=(n // bq, n // bk),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),      # q
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),      # k
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),      # v
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),      # g
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),      # m
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),      # l
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),      # dd
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(q, k, v, g, m, l, dd)

    dk, dv = pl.pallas_call(
        functools.partial(_flash_dkv_kernel, scale=scale),
        grid=(n // bk, n // bq),                             # j outer, i inner
        in_specs=[
            pl.BlockSpec((bq, d), lambda j, i: (i, 0)),      # q
            pl.BlockSpec((bk, d), lambda j, i: (j, 0)),      # k
            pl.BlockSpec((bk, d), lambda j, i: (j, 0)),      # v
            pl.BlockSpec((bq, d), lambda j, i: (i, 0)),      # g
            pl.BlockSpec((bq, 1), lambda j, i: (i, 0)),      # m
            pl.BlockSpec((bq, 1), lambda j, i: (i, 0)),      # l
            pl.BlockSpec((bq, 1), lambda j, i: (i, 0)),      # dd
        ],
        out_specs=[
            pl.BlockSpec((bk, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bk, d), lambda j, i: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, g, m, l, dd)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def softmax_attention(q, k, v, block_q=DEFAULT_BLOCK, block_k=DEFAULT_BLOCK, interpret=True):
    """Differentiable flash softmax attention (one head, (N, d) inputs)."""
    out, _, _ = _flash_fwd(q, k, v, block_q, block_k, interpret)
    return out


def _flash_vjp_fwd(q, k, v, block_q, block_k, interpret):
    out, m, l = _flash_fwd(q, k, v, block_q, block_k, interpret)
    return out, (q, k, v, out, m, l)


def _flash_vjp_bwd(block_q, block_k, interpret, res, g):
    return _flash_bwd(block_q, block_k, interpret, res, g)


softmax_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# Block-diagonal softmax (self-contained per block — single bwd kernel)
# ---------------------------------------------------------------------------

def _diag_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    s = (q_ref[...] @ k_ref[...].T) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = p @ v_ref[...]


def _diag_bwd_kernel(q_ref, k_ref, v_ref, g_ref, dq_ref, dk_ref, dv_ref, *, scale):
    s = (q_ref[...] @ k_ref[...].T) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    g = g_ref[...]
    dv_ref[...] = p.T @ g
    dp = g @ v_ref[...].T                                    # (b, b)
    ds = p * (dp - jnp.sum(p * dp, axis=-1, keepdims=True))
    dq_ref[...] = (ds @ k_ref[...]) * scale
    dk_ref[...] = (ds.T @ q_ref[...]) * scale


def _diag_specs(block, d):
    return [pl.BlockSpec((block, d), lambda i: (i, 0)) for _ in range(3)]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def blockdiag_attention(q, k, v, block_size=64, interpret=True):
    """Differentiable block-diagonal softmax attention."""
    n, d = q.shape
    block = min(block_size, n)
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_diag_fwd_kernel, scale=scale),
        grid=(n // block,),
        in_specs=_diag_specs(block, d),
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(q, k, v)


def _diag_vjp_fwd(q, k, v, block_size, interpret):
    return blockdiag_attention(q, k, v, block_size, interpret), (q, k, v)


def _diag_vjp_bwd(block_size, interpret, res, g):
    q, k, v = res
    n, d = q.shape
    block = min(block_size, n)
    scale = 1.0 / (d ** 0.5)
    dq, dk, dv = pl.pallas_call(
        functools.partial(_diag_bwd_kernel, scale=scale),
        grid=(n // block,),
        in_specs=_diag_specs(block, d) + [pl.BlockSpec((block, d), lambda i: (i, 0))],
        out_specs=_diag_specs(block, d),
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.float32)] * 3,
        interpret=interpret,
    )(q, k, v, g)
    return dq, dk, dv


blockdiag_attention.defvjp(_diag_vjp_fwd, _diag_vjp_bwd)


def lln_diag_attention(q, k, v, alpha, beta, block_size=64, **kw):
    """Differentiable LLN+Diag (paper sec 4.2): mean of both paths."""
    long_range = lln_attention(q, k, v, alpha, beta, **kw)
    short_range = blockdiag_attention(q, k, v, block_size)
    return 0.5 * (long_range + short_range)
