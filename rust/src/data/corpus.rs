//! Synthetic pretraining corpus + MLM masking (WikiText-103 stand-in).
//!
//! Token stream = order-1 Markov chain with Zipf-distributed marginals:
//! each token's successor is drawn from a per-token sparse transition
//! table (deterministic pseudo-grammar) with probability `coherence`,
//! else from the global Zipf unigram.  The chain gives MLM something
//! real to learn (bigram structure drops loss well below the unigram
//! entropy floor), while Zipf marginals match natural-text statistics.

use super::special;
use crate::rng::Pcg64;

/// Word-level tokenizer over the synthetic vocabulary: the "text" form
/// is `w<id>` words — round-trips exactly (stands in for BPE).
pub struct Tokenizer {
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Self {
        assert!(vocab_size > special::FIRST_CONTENT as usize);
        Self { vocab_size }
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .map(|w| match w {
                "[PAD]" => special::PAD,
                "[MASK]" => special::MASK,
                "[CLS]" => special::CLS,
                "[SEP]" => special::SEP,
                w => w
                    .strip_prefix('w')
                    .and_then(|n| n.parse::<i32>().ok())
                    .filter(|&t| (t as usize) < self.vocab_size)
                    .unwrap_or(special::PAD),
            })
            .collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| match t {
                special::PAD => "[PAD]".to_string(),
                special::MASK => "[MASK]".to_string(),
                special::CLS => "[CLS]".to_string(),
                special::SEP => "[SEP]".to_string(),
                t => format!("w{t}"),
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// One MLM training batch in the exact layout the AOT train step takes.
#[derive(Clone, Debug)]
pub struct MlmBatch {
    pub batch: usize,
    pub seqlen: usize,
    /// Masked input tokens, row-major (B, N).
    pub tokens: Vec<i32>,
    /// Original tokens (prediction targets).
    pub labels: Vec<i32>,
    /// 1.0 at positions that count toward the loss.
    pub weights: Vec<f32>,
}

/// Synthetic Markov/Zipf corpus.
pub struct Corpus {
    pub vocab_size: usize,
    /// Probability of following the grammar vs. the unigram.
    pub coherence: f64,
    /// Zipf exponent of the unigram.
    pub zipf_s: f64,
    rng: Pcg64,
}

impl Corpus {
    pub fn new(vocab_size: usize, seed: u64) -> Self {
        Self { vocab_size, coherence: 0.75, zipf_s: 1.1, rng: Pcg64::new(seed, 0xC0E9) }
    }

    fn content_range(&self) -> u64 {
        (self.vocab_size as i32 - special::FIRST_CONTENT) as u64
    }

    fn zipf_token(&mut self) -> i32 {
        special::FIRST_CONTENT + self.rng.zipf(self.content_range(), self.zipf_s) as i32
    }

    /// Deterministic sparse "grammar": each token has 4 plausible
    /// successors derived by hashing; the chain mostly walks these.
    fn grammar_successor(&mut self, prev: i32) -> i32 {
        let slot = self.rng.below(4);
        let h = (prev as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(slot.wrapping_mul(0xBF58476D1CE4E5B9));
        special::FIRST_CONTENT + (h % self.content_range()) as i32
    }

    /// Sample a fresh sequence of exactly `n` tokens.
    pub fn sequence(&mut self, n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        let mut prev = self.zipf_token();
        out.push(prev);
        for _ in 1..n {
            let tok = if self.rng.f64() < self.coherence {
                self.grammar_successor(prev)
            } else {
                self.zipf_token()
            };
            out.push(tok);
            prev = tok;
        }
        out
    }

    /// RoBERTa-style MLM masking: `mask_prob` of positions are targets;
    /// of those 80% -> [MASK], 10% -> random token, 10% -> unchanged.
    pub fn mlm_batch(&mut self, batch: usize, seqlen: usize, mask_prob: f64) -> MlmBatch {
        let mut tokens = Vec::with_capacity(batch * seqlen);
        let mut labels = Vec::with_capacity(batch * seqlen);
        let mut weights = vec![0f32; batch * seqlen];
        for b in 0..batch {
            let seq = self.sequence(seqlen);
            for (i, &orig) in seq.iter().enumerate() {
                labels.push(orig);
                let idx = b * seqlen + i;
                if self.rng.f64() < mask_prob {
                    weights[idx] = 1.0;
                    let r = self.rng.f64();
                    let tok = if r < 0.8 {
                        special::MASK
                    } else if r < 0.9 {
                        self.zipf_token()
                    } else {
                        orig
                    };
                    tokens.push(tok);
                } else {
                    tokens.push(orig);
                }
            }
        }
        // Guarantee at least one target per batch (degenerate-draw guard).
        if weights.iter().all(|&w| w == 0.0) {
            weights[0] = 1.0;
            tokens[0] = special::MASK;
        }
        MlmBatch { batch, seqlen, tokens, labels, weights }
    }

    /// Unigram entropy floor (bits) of the Zipf marginal — the loss a
    /// context-blind predictor converges to; used as a sanity line in
    /// the fig. 8 report.
    pub fn unigram_entropy_bits(&self) -> f64 {
        let v = self.content_range() as usize;
        let weights: Vec<f64> = (1..=v).map(|r| 1.0 / (r as f64).powf(self.zipf_s)).collect();
        let z: f64 = weights.iter().sum();
        -weights.iter().map(|w| (w / z) * (w / z).log2()).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_round_trip() {
        let tk = Tokenizer::new(512);
        let toks = vec![special::CLS, 17, 300, special::SEP, special::MASK, 4];
        assert_eq!(tk.encode(&tk.decode(&toks)), toks);
    }

    #[test]
    fn sequences_are_in_vocab() {
        let mut c = Corpus::new(512, 1);
        let seq = c.sequence(256);
        assert_eq!(seq.len(), 256);
        assert!(seq.iter().all(|&t| t >= special::FIRST_CONTENT && (t as usize) < 512));
    }

    #[test]
    fn corpus_has_bigram_structure() {
        // Successors of a fixed token should concentrate on few values.
        let mut c = Corpus::new(512, 2);
        let mut successors = std::collections::HashMap::new();
        let mut prev_target = false;
        let target = {
            let seq = c.sequence(10_000);
            seq[0]
        };
        let seq = c.sequence(200_000);
        for w in seq.windows(2) {
            if w[0] == target {
                *successors.entry(w[1]).or_insert(0usize) += 1;
                prev_target = true;
            }
        }
        assert!(prev_target, "target token never appeared");
        let total: usize = successors.values().sum();
        let mut counts: Vec<usize> = successors.values().copied().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top4: usize = counts.iter().take(4).sum();
        assert!(
            top4 as f64 / total as f64 > 0.5,
            "no grammar concentration: top4={top4} total={total}"
        );
    }

    #[test]
    fn mlm_batch_masks_roughly_right_fraction() {
        let mut c = Corpus::new(512, 3);
        let b = c.mlm_batch(8, 128, 0.15);
        let frac = b.weights.iter().sum::<f32>() as f64 / b.weights.len() as f64;
        assert!((frac - 0.15).abs() < 0.05, "{frac}");
        assert_eq!(b.tokens.len(), 8 * 128);
        assert_eq!(b.labels.len(), 8 * 128);
    }

    #[test]
    fn mlm_labels_preserve_originals() {
        let mut c = Corpus::new(512, 4);
        let b = c.mlm_batch(2, 64, 0.15);
        for i in 0..b.tokens.len() {
            if b.weights[i] == 0.0 {
                assert_eq!(b.tokens[i], b.labels[i], "unmasked positions unchanged");
            }
            assert!(b.labels[i] >= special::FIRST_CONTENT);
        }
        // Masked positions mostly carry [MASK].
        let masked: Vec<usize> = (0..b.tokens.len()).filter(|&i| b.weights[i] == 1.0).collect();
        let n_mask_tok = masked.iter().filter(|&&i| b.tokens[i] == special::MASK).count();
        assert!(n_mask_tok as f64 / masked.len() as f64 > 0.6);
    }

    #[test]
    fn unigram_entropy_is_reasonable() {
        let c = Corpus::new(8192, 5);
        let h = c.unigram_entropy_bits();
        assert!(h > 6.0 && h < 13.0, "{h}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(512, 7);
        let mut b = Corpus::new(512, 7);
        assert_eq!(a.sequence(64), b.sequence(64));
    }
}
