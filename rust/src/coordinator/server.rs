//! The threaded serving coordinator.
//!
//! Workers are generic over a [`BatchExec`] — either the PJRT engine
//! path (AOT artifacts) or the native [`AttentionBackend`] encoder
//! ([`super::native`]) when artifacts/PJRT are unavailable — so the
//! batching loop, stats, and backpressure behave identically on both.
//!
//! Beyond batch prefill, the coordinator runs **incremental decode
//! sessions** ([`Coordinator::open_session`] → [`DecodeSession`]):
//! token-by-token causal attention whose per-session state (KV cache or
//! linear prefix state — see [`crate::attention::DecodeState`]) lives
//! in a per-bucket registry shared by all of the bucket's workers, so
//! concurrent sessions' single-token steps co-batch with prefill
//! traffic through the same queues and stream their logits back over
//! per-session channels.  Executors that cannot decode (PJRT artifacts
//! are batch-prefill full-attention only; Nystrom/Linformer cannot be
//! masked) reject session opens with an `Err` response — never a
//! worker panic.
//!
//! Worker pools autoscale per bucket: `ServeConfig::worker_band()`
//! gives a `[min, max]` band, a scaler thread spawns extra workers from
//! queue depth ([`desired_workers`]), and idle extras retire back to
//! the floor.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use super::batcher::{deadline_expired, desired_workers, plan_batches, projected_wait_ms, should_fire};
use super::native::NativeEncoder;
use super::router::HashRing;
use super::{
    pad_to_bucket, pick_bucket, PayloadClass, Request, RespError, Response, SessionOpen,
    SessionStep, Work,
};
use crate::attention::paged::{PagePool, PagedKvCache};
use crate::attention::{DecodeState, Method};
use crate::config::ServeConfig;
use crate::faults::{backoff_ms, FaultPlan, WorkerFault};
use crate::runtime::{Engine, HostTensor, ParamStore};
use crate::util::pool::{Channel, SendError};

/// How long an idle surplus worker lingers before retiring back to the
/// bucket's `min_workers` floor.
const IDLE_RETIRE: Duration = Duration::from_millis(250);
/// How long a decode step waits for its predecessor (another worker may
/// still be executing the session's previous position) before erroring.
const STEP_ORDER_TIMEOUT: Duration = Duration::from_secs(5);
/// Latency samples kept per payload class: a bounded window (old
/// samples are overwritten round-robin) so a long-lived streaming
/// server — one sample per decoded token — holds O(1) stats memory.
const LATENCY_WINDOW: usize = 65_536;
/// Recent batch sizes kept (bounded, like the latency windows — the
/// flat vector used to grow one `usize` per batch forever).
const BATCH_WINDOW: usize = 4_096;
/// Backoff between scaler spawn attempts after a worker death, so a
/// persistently failing executor cannot drive a spawn/die hot loop.
const SPAWN_BACKOFF: Duration = Duration::from_millis(500);

/// One payload class's bounded latency window.  The ring has its *own*
/// wrapping cursor: the old implementation indexed by the shared
/// `completed` counter, which also advances on paths that never record
/// a sample, so once full the overwrites were uneven and could clobber
/// the same slot repeatedly.
#[derive(Clone, Debug)]
pub struct ClassWindow {
    samples: Vec<f64>,
    cursor: usize,
    cap: usize,
    /// Completions accounted to this class (lifetime, not windowed).
    pub completed: u64,
}

impl Default for ClassWindow {
    fn default() -> Self {
        Self::with_capacity(LATENCY_WINDOW)
    }
}

impl ClassWindow {
    pub fn with_capacity(cap: usize) -> Self {
        Self { samples: Vec::new(), cursor: 0, cap: cap.max(1), completed: 0 }
    }

    /// Record one completion latency (overwrites the oldest sample once
    /// the window fills — every slot is overwritten evenly).
    pub fn record(&mut self, ms: f64) {
        self.completed += 1;
        if self.samples.len() < self.cap {
            self.samples.push(ms);
        } else {
            self.samples[self.cursor] = ms;
        }
        self.cursor = (self.cursor + 1) % self.cap;
    }

    /// The windowed samples (unordered ring contents).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Windowed latency percentile; 0.0 with no traffic.
    pub fn percentile(&self, q: f64) -> f64 {
        crate::stats::percentile(&self.samples, q)
    }

    /// Windowed mean latency; 0.0 with no traffic.  Feeds the
    /// deadline-aware admission's projected-wait estimate.
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }
}

/// Rolling serving metrics (shared across all shards' workers).
pub struct ServeStats {
    /// Total completions across every payload class (prefill requests,
    /// decode steps, and session opens).
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    /// Per-[`PayloadClass`] latency windows, indexed by
    /// `PayloadClass::index()`.
    pub classes: [ClassWindow; 4],
    /// Recent batch sizes (bounded ring; see `batches` /
    /// `batch_members` for the exact lifetime mean).
    pub batch_sizes: Vec<usize>,
    batch_cursor: usize,
    /// Batches executed (lifetime).
    pub batches: u64,
    /// Live members across all batches (lifetime).
    pub batch_members: u64,
    /// Decode sessions successfully opened.
    pub sessions_opened: u64,
    /// Session slots reclaimed from oldest-idle sessions by admission.
    pub sessions_evicted: u64,
    /// Decode-session steps successfully served (also counted in
    /// `completed` / the decode-step class window).
    pub decode_steps: u64,
    /// Workers spawned by the per-bucket autoscaler beyond the floor.
    pub workers_spawned: u64,
    /// Prefill items stolen from sibling shards' same-bucket queues.
    pub steals: u64,
    /// KV pages evicted from idle sessions under the pool budget.
    pub pages_evicted: u64,
    /// KV pages refilled from token history (recompute-on-miss).
    pub pages_recomputed: u64,
    /// Faults fired by the deterministic chaos plan (mirror of
    /// [`FaultPlan::injected`]; 0 without a `[faults]` section).
    pub faults_injected: u64,
    /// Dead workers respawned by the per-shard supervisor back to the
    /// `min_workers` floor.
    pub worker_restarts: u64,
    /// Failed prefill batches re-executed under the retry budget.
    pub retries: u64,
    /// Requests shed with `DeadlineExceeded` — queue-side expiry or
    /// members dropped while a batch backed off between retries.
    pub deadline_drops: u64,
    /// Decode sessions failed over (replayed bit-exactly onto a healthy
    /// shard after a poison or shard death).
    pub sessions_restored: u64,
    /// Session opens shed by the thrash guard (page-pool churn per
    /// decode step above `thrash_shed_ratio`).
    pub thrash_sheds: u64,
}

impl Default for ServeStats {
    fn default() -> Self {
        Self {
            completed: 0,
            rejected: 0,
            errors: 0,
            classes: std::array::from_fn(|_| ClassWindow::default()),
            batch_sizes: Vec::new(),
            batch_cursor: 0,
            batches: 0,
            batch_members: 0,
            sessions_opened: 0,
            sessions_evicted: 0,
            decode_steps: 0,
            workers_spawned: 0,
            steals: 0,
            pages_evicted: 0,
            pages_recomputed: 0,
            faults_injected: 0,
            worker_restarts: 0,
            retries: 0,
            deadline_drops: 0,
            sessions_restored: 0,
            thrash_sheds: 0,
        }
    }
}

impl ServeStats {
    /// Account one completion to its payload class.
    pub fn record(&mut self, class: PayloadClass, ms: f64) {
        self.completed += 1;
        self.classes[class.index()].record(ms);
    }

    /// One class's window.
    pub fn class(&self, class: PayloadClass) -> &ClassWindow {
        &self.classes[class.index()]
    }

    /// Windowed percentile for one payload class; 0.0 with no traffic.
    pub fn class_percentile(&self, class: PayloadClass, q: f64) -> f64 {
        self.classes[class.index()].percentile(q)
    }

    /// Mixed-traffic percentile over every class's window (the legacy
    /// single-number view; per-class numbers are the honest ones).
    pub fn mixed_percentile(&self, q: f64) -> f64 {
        let all: Vec<f64> =
            self.classes.iter().flat_map(|c| c.samples().iter().copied()).collect();
        crate::stats::percentile(&all, q)
    }

    pub fn p50_latency(&self) -> f64 {
        self.mixed_percentile(50.0)
    }
    pub fn p95_latency(&self) -> f64 {
        self.mixed_percentile(95.0)
    }

    /// Record one executed batch's live-member count (bounded ring +
    /// exact lifetime counters).
    pub fn record_batch(&mut self, real: usize) {
        self.batches += 1;
        self.batch_members += real as u64;
        if self.batch_sizes.len() < BATCH_WINDOW {
            self.batch_sizes.push(real);
        } else {
            self.batch_sizes[self.batch_cursor] = real;
        }
        self.batch_cursor = (self.batch_cursor + 1) % BATCH_WINDOW;
    }

    /// Exact lifetime mean batch size (counters, not the window).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_members as f64 / self.batches as f64
        }
    }

    /// Zero every counter and window (serve_bench calls this after
    /// warmup so compile/first-touch requests don't skew percentiles).
    pub fn reset(&mut self) {
        *self = ServeStats::default();
    }
}

/// One open decode session's server-side state: the attention state,
/// the next expected position, and a poison marker so a failed step
/// fails the session's tail loudly instead of silently decoding on a
/// stale state.
struct SessionSlot {
    state: DecodeState,
    pos: usize,
    failed: Option<String>,
    /// Token history, recorded only for paged states: the deterministic
    /// input recompute-on-miss re-embeds evicted pages from (4 bytes
    /// per token, bounded by the bucket length).
    tokens: Vec<i32>,
}

/// Per-(shard, bucket) registry of open sessions.  Any worker of the
/// shard's bucket can step any of its sessions (native executors of a
/// bucket are deterministic replicas), so the registry — not a worker —
/// owns the state.
type SessionMap = Arc<Mutex<HashMap<u64, Arc<Mutex<SessionSlot>>>>>;

/// Where one live session lives (for slot eviction and close): its
/// shard/bucket registry plus its last-touch tick for oldest-idle
/// selection.
struct SessionMeta {
    sessions: SessionMap,
    touched: Arc<AtomicU64>,
}

/// Coordinator-wide registry of live sessions (slot budget + eviction).
type SessionRegistry = Arc<Mutex<HashMap<u64, SessionMeta>>>;

/// A token bucket: `rate` units/second refill with a one-second burst
/// capacity.  `rate == 0` disables the budget entirely.  A request
/// costing more than the capacity can never be admitted — rejected
/// deterministically, not "after waiting".
struct TokenBucket {
    rate: f64,
    state: Mutex<(f64, Instant)>, // (tokens, last refill)
}

impl TokenBucket {
    fn new(rate: f64) -> Self {
        Self { rate, state: Mutex::new((rate.max(0.0), Instant::now())) }
    }

    fn admit(&self, cost: f64) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let mut st = self.state.lock().unwrap();
        let now = Instant::now();
        let (ref mut tokens, ref mut last) = *st;
        *tokens = (*tokens + now.duration_since(*last).as_secs_f64() * self.rate).min(self.rate);
        *last = now;
        if *tokens >= cost {
            *tokens -= cost;
            true
        } else {
            false
        }
    }
}

/// Per-class admission budgets (the priority mechanism: decode steps
/// are exempt because a live session already holds its slot; everything
/// else competes for its class's token budget).
struct Admission {
    short: TokenBucket,
    long: TokenBucket,
    opens: TokenBucket,
}

/// One shard of the front: its own per-bucket queues and session
/// registries.  Workers are per (shard, bucket); sessions pin here via
/// the consistent-hash router.
struct Shard {
    queues: Vec<(usize, Channel<Work>)>, // (bucket_len, queue)
    sessions: Vec<(usize, SessionMap)>,
}

impl Shard {
    fn queue(&self, bucket: usize) -> &Channel<Work> {
        &self.queues.iter().find(|(b, _)| *b == bucket).unwrap().1
    }
    fn session_map(&self, bucket: usize) -> &SessionMap {
        &self.sessions.iter().find(|(b, _)| *b == bucket).unwrap().1
    }
}

/// The running coordinator: submit requests, open decode sessions, read
/// stats, shut down.
pub struct Coordinator {
    cfg: ServeConfig,
    shards: Vec<Shard>,
    /// Consistent-hash session router (stable under shard growth).
    /// Mutex-shared with the supervisors: condemning a dead shard
    /// rebuilds the ring without its points, so new sessions route
    /// around it and failed-over sessions land on survivors.
    ring: Arc<Mutex<HashRing>>,
    /// Shards condemned by their supervisors (dead worker pools).  A
    /// dead shard's queues are closed and its queued work buried with
    /// terminal `Failed` replies; it never rejoins the ring.
    dead_shards: Arc<Mutex<Vec<usize>>>,
    /// Live-session registry for the slot budget / oldest-idle eviction.
    registry: SessionRegistry,
    /// Logical touch clock: sessions stamp their last activity from it.
    touch_clock: Arc<AtomicU64>,
    /// Per-class admission budgets (stateful token buckets).
    admission: Admission,
    /// Shared KV page pool (None = unpaged legacy sessions).
    pool: Option<PagePool>,
    /// (page-pool churn, decode steps) at the last admitted open — the
    /// thrash guard sheds new opens when the delta ratio spikes.
    thrash_mark: Mutex<(u64, u64)>,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    stats: Arc<Mutex<ServeStats>>,
    next_id: AtomicU64,
    draining: Arc<AtomicBool>,
    started_at: Instant,
}

/// Everything one worker thread needs; cheap to clone for dynamically
/// scaled-up workers.
#[derive(Clone)]
struct WorkerCtx {
    cfg: ServeConfig,
    dir: std::path::PathBuf,
    shard: usize,
    bucket: usize,
    queue: Channel<Work>,
    /// Same-bucket queues of the *other* shards: an idle worker steals
    /// queued prefill (never session work — sessions are shard-pinned)
    /// from these.
    victims: Vec<Channel<Work>>,
    stats: Arc<Mutex<ServeStats>>,
    draining: Arc<AtomicBool>,
    sessions: SessionMap,
    /// Shared KV page pool (None = unpaged legacy sessions).
    pool: Option<PagePool>,
    /// This bucket is the smallest configured bucket (its prefill is
    /// the `PrefillShort` class; larger buckets are `PrefillLong`).
    short_bucket: bool,
    /// Live worker count of this (shard, bucket) — autoscaler reads,
    /// retiring workers CAS-decrement.
    live: Arc<AtomicUsize>,
    /// Workers of this (shard, bucket) that died abnormally (executor
    /// construction/runtime failure) — the scaler backs off on growth.
    deaths: Arc<AtomicUsize>,
    min_workers: usize,
    /// Deterministic chaos plan (None without a `[faults]` section).
    plan: Option<Arc<FaultPlan>>,
}

impl Coordinator {
    /// Spawn each bucket's worker-pool floor (`worker_band().0`) and,
    /// when the band allows scaling, a per-bucket scaler thread that
    /// grows the pool from queue depth up to the ceiling.  Each worker
    /// owns its executor — a PJRT engine with the bucket's executables
    /// + resident params, or the native-backend encoder fallback — and
    /// all workers of a bucket drain the same MPMC queue and share the
    /// bucket's decode-session registry.
    pub fn start(cfg: ServeConfig, artifacts: &std::path::Path) -> Result<Self> {
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let draining = Arc::new(AtomicBool::new(false));
        let workers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let (min_w, max_w) = cfg.worker_band();
        let n_shards = cfg.shards.max(1);
        let short_len = cfg.buckets.iter().copied().min().unwrap_or(0);
        // Deterministic chaos plan (None unless `[faults]` arms one).
        let plan = FaultPlan::from_config(&cfg.faults);
        let ring = Arc::new(Mutex::new(HashRing::new(n_shards)));
        let dead_shards: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        // One shared page pool across every shard and bucket: paging is
        // a *global* memory budget, so sessions on any shard compete
        // for the same pages.  Native decode states are all
        // NATIVE_D_MODEL-dimensional.
        let pool = if cfg.page_pool_pages > 0 {
            // Pages store K/V at `[compute] precision`: the same pool
            // budget holds 2x the tokens at bf16/f16 and ~3.5x at
            // int8-kv (admission math reads the pool's own byte
            // accounting, so it follows automatically).
            let p = PagePool::with_precision(
                cfg.page_pool_pages,
                cfg.page_tokens.max(1),
                super::native::NATIVE_D_MODEL,
                super::native::NATIVE_D_MODEL,
                cfg.compute.precision,
            )
            .with_faults(plan.clone());
            Some(p)
        } else {
            None
        };
        // Two passes: queues/registries first so every worker can see
        // every sibling shard's same-bucket queue as a steal victim.
        let mut shards = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let mut queues = Vec::new();
            let mut session_maps: Vec<(usize, SessionMap)> = Vec::new();
            for &bucket in &cfg.buckets {
                queues.push((bucket, Channel::bounded(cfg.queue_capacity)));
                session_maps.push((bucket, Arc::new(Mutex::new(HashMap::new()))));
            }
            shards.push(Shard { queues, sessions: session_maps });
        }
        for (s, shard) in shards.iter().enumerate() {
            for &bucket in &cfg.buckets {
                let victims: Vec<Channel<Work>> = shards
                    .iter()
                    .enumerate()
                    .filter(|&(o, _)| o != s)
                    .map(|(_, other)| other.queue(bucket).clone())
                    .collect();
                let ctx = WorkerCtx {
                    cfg: cfg.clone(),
                    dir: artifacts.to_path_buf(),
                    shard: s,
                    bucket,
                    queue: shard.queue(bucket).clone(),
                    victims,
                    stats: Arc::clone(&stats),
                    draining: Arc::clone(&draining),
                    sessions: Arc::clone(shard.session_map(bucket)),
                    pool: pool.clone(),
                    short_bucket: bucket == short_len,
                    live: Arc::new(AtomicUsize::new(min_w)),
                    deaths: Arc::new(AtomicUsize::new(0)),
                    min_workers: min_w,
                    plan: plan.clone(),
                };
                for w in 0..min_w {
                    workers.lock().unwrap().push(spawn_worker(ctx.clone(), w));
                }
                // Every (shard, bucket) gets a supervisor: it respawns
                // dead workers back to the floor, condemns the shard
                // when the floor cannot be held (or the chaos plan
                // kills it), and — when the band allows — grows the
                // pool from queue depth up to the ceiling.
                workers.lock().unwrap().push(spawn_supervisor(
                    ctx,
                    max_w,
                    Arc::clone(&workers),
                    Arc::clone(&ring),
                    Arc::clone(&dead_shards),
                    n_shards,
                ));
            }
        }
        let admission = Admission {
            short: TokenBucket::new(cfg.short_tokens_per_s),
            long: TokenBucket::new(cfg.long_tokens_per_s),
            opens: TokenBucket::new(cfg.opens_per_s),
        };
        Ok(Self {
            cfg,
            shards,
            ring,
            dead_shards,
            registry: Arc::new(Mutex::new(HashMap::new())),
            touch_clock: Arc::new(AtomicU64::new(1)),
            admission,
            pool,
            thrash_mark: Mutex::new((0, 0)),
            workers,
            stats,
            next_id: AtomicU64::new(1),
            draining,
            started_at: Instant::now(),
        })
    }

    fn bucket_for(&self, len: usize) -> Result<usize> {
        pick_bucket(&self.cfg.buckets, len)
            .ok_or_else(|| anyhow!("sequence length {len} exceeds all buckets"))
    }

    /// Prefill shard choice: least-loaded same-bucket queue among live
    /// shards (work stealing rebalances whatever this heuristic gets
    /// wrong).  `None` once every shard has been condemned.
    fn least_loaded_shard(&self, bucket: usize) -> Option<usize> {
        let dead = self.dead_shards.lock().unwrap();
        (0..self.shards.len())
            .filter(|s| !dead.contains(s))
            .min_by_key(|&s| self.shards[s].queue(bucket).len())
    }

    /// The shard/bucket the admission token budgets classify `len` as.
    fn prefill_class(&self, bucket: usize) -> PayloadClass {
        let short_len = self.cfg.buckets.iter().copied().min().unwrap_or(0);
        if bucket == short_len {
            PayloadClass::PrefillShort
        } else {
            PayloadClass::PrefillLong
        }
    }

    fn enqueue(&self, queue: &Channel<Work>, bucket: usize, work: Work) -> Result<()> {
        match queue.try_send(work) {
            Ok(()) => Ok(()),
            Err(SendError::Full(_)) => {
                self.stats.lock().unwrap().rejected += 1;
                bail!("backpressure: bucket n{bucket} queue full")
            }
            Err(SendError::Closed(_)) => bail!("coordinator shutting down"),
        }
    }

    /// Submit a bidirectional request; returns the response receiver.
    /// Errors on over-length input or queue-full backpressure.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<mpsc::Receiver<Response>> {
        self.submit_with(tokens, false)
    }

    /// Submit a request with an explicit causal flag.  The request's
    /// live length rides along as its attention key mask: workers pad
    /// to the bucket but mask the padding out of attention, so buckets
    /// batch variable-length (and mixed causal/bidirectional) traffic
    /// instead of assuming square full attention.
    pub fn submit_with(&self, tokens: Vec<i32>, causal: bool) -> Result<mpsc::Receiver<Response>> {
        self.submit_spec(tokens, causal, None)
    }

    /// Submit a request carrying its full per-request attention spec:
    /// causal flag plus an optional score-temperature override.  The
    /// request's own spec always wins over the worker-wide `[compute]
    /// causal` default's implied fields — in particular the scale
    /// rides all the way into the executor's
    /// [`AttnSpec`](crate::attention::AttnSpec) instead of being
    /// silently dropped when the worker default flips masks.
    pub fn submit_spec(
        &self,
        tokens: Vec<i32>,
        causal: bool,
        scale: Option<f32>,
    ) -> Result<mpsc::Receiver<Response>> {
        self.submit_deadline(tokens, causal, scale, None)
    }

    /// Submit with an explicit per-request deadline in milliseconds
    /// from now (`None` inherits `[serve] default_deadline_ms`; 0
    /// disables).  Deadlines are enforced twice: here at admission —
    /// when the projected queue wait (recent mean batch latency for the
    /// request's class times the batches ahead of it) already exceeds
    /// the deadline, rejecting now is strictly better than queueing a
    /// request that can only expire — and again queue-side, where
    /// workers shed already-expired items with a terminal
    /// `DeadlineExceeded` instead of spending executor time on them.
    pub fn submit_deadline(
        &self,
        tokens: Vec<i32>,
        causal: bool,
        scale: Option<f32>,
        deadline_ms: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>> {
        let bucket = self.bucket_for(tokens.len())?;
        // Admission: each prefill class pays its live token count
        // against its budget.  Decode steps are exempt — a live session
        // already holds its slot (session-aware admission).
        let class = self.prefill_class(bucket);
        let budget = match class {
            PayloadClass::PrefillShort => &self.admission.short,
            _ => &self.admission.long,
        };
        if !budget.admit(tokens.len() as f64) {
            self.stats.lock().unwrap().rejected += 1;
            bail!("admission: token budget exhausted for bucket n{bucket}");
        }
        let shard = self
            .least_loaded_shard(bucket)
            .ok_or_else(|| anyhow!("no live shard left for bucket n{bucket}"))?;
        let queue = self.shards[shard].queue(bucket);
        let ms = deadline_ms.unwrap_or(self.cfg.default_deadline_ms);
        let deadline = (ms > 0).then(|| Instant::now() + Duration::from_millis(ms));
        if let Some(d) = deadline {
            let batch_ms = self.stats.lock().unwrap().class(class).mean();
            let wait = projected_wait_ms(queue.len(), self.cfg.max_batch, batch_ms);
            let remaining = d.saturating_duration_since(Instant::now()).as_secs_f64() * 1e3;
            if wait > remaining {
                self.stats.lock().unwrap().rejected += 1;
                bail!(
                    "admission: projected queue wait {wait:.1} ms exceeds the request \
                     deadline ({remaining:.1} ms remaining)"
                );
            }
        }
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            causal,
            scale,
            enqueued_at: Instant::now(),
            deadline,
            resp: tx,
        };
        self.enqueue(queue, bucket, Work::Infer(req))?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        let rx = self.submit(tokens)?;
        rx.recv().map_err(|_| anyhow!("worker dropped response"))
    }

    /// Submit with a causal flag and block for the result.
    pub fn infer_with(&self, tokens: Vec<i32>, causal: bool) -> Result<Response> {
        let rx = self.submit_with(tokens, causal)?;
        rx.recv().map_err(|_| anyhow!("worker dropped response"))
    }

    /// Submit with an explicit spec (causal + scale) and block.
    pub fn infer_spec(
        &self,
        tokens: Vec<i32>,
        causal: bool,
        scale: Option<f32>,
    ) -> Result<Response> {
        let rx = self.submit_spec(tokens, causal, scale)?;
        rx.recv().map_err(|_| anyhow!("worker dropped response"))
    }

    /// Open an incremental decode session that can grow to `max_len`
    /// tokens: the session routes to the smallest bucket that fits and
    /// holds O(1)-per-token attention state there (KV cache for the
    /// exact class, the `Σ φ(k)vᵀ` prefix state for the linear class).
    /// Blocks until a worker accepts; errors loudly when the bucket's
    /// executor cannot decode — PJRT artifacts (batch-prefill,
    /// full-attention only) and unmaskable methods (Nystrom/Linformer)
    /// — or on backpressure.
    pub fn open_session(&self, max_len: usize) -> Result<DecodeSession> {
        let bucket = self.bucket_for(max_len)?;
        if !self.admission.opens.admit(1.0) {
            self.stats.lock().unwrap().rejected += 1;
            bail!("admission: session-open budget exhausted");
        }
        // Thrash guard (graceful degradation): when the page pool is
        // churning — evictions + recomputes per decode step since the
        // last admitted open above `thrash_shed_ratio` — another
        // session would push every live session deeper into recompute
        // storms and degrade their p99.  Shed the *new* open instead;
        // the mark is left in place so the guard stays armed until
        // churn actually subsides.
        if self.cfg.thrash_shed_ratio > 0.0 {
            if let Some(pool) = &self.pool {
                let c = pool.counters();
                let churn = c.evicted + c.recomputed;
                let steps = self.stats.lock().unwrap().decode_steps;
                let mut mark = self.thrash_mark.lock().unwrap();
                let d_churn = churn.saturating_sub(mark.0);
                let d_steps = steps.saturating_sub(mark.1);
                if d_steps > 0 && d_churn as f64 > self.cfg.thrash_shed_ratio * d_steps as f64 {
                    drop(mark);
                    let mut st = self.stats.lock().unwrap();
                    st.rejected += 1;
                    st.thrash_sheds += 1;
                    bail!(
                        "thrash guard: {d_churn} pages churned over the last {d_steps} decode \
                         steps (over {} per step); retry once live sessions stop thrashing",
                        self.cfg.thrash_shed_ratio
                    );
                }
                *mark = (churn, steps);
            }
        }
        // Slot budget: a live session holds its slot; when full, the
        // oldest-idle session (smallest touch stamp) is evicted to make
        // room.  Removing its slot drops the decode state — for paged
        // states that releases its pages back to the pool.
        if self.cfg.max_sessions > 0 {
            let mut reg = self.registry.lock().unwrap();
            if reg.len() >= self.cfg.max_sessions {
                let victim = reg
                    .iter()
                    .min_by_key(|(vid, meta)| (meta.touched.load(Ordering::Relaxed), **vid))
                    .map(|(vid, _)| *vid);
                match victim {
                    Some(vid) => {
                        let meta = reg.remove(&vid).unwrap();
                        meta.sessions.lock().unwrap().remove(&vid);
                        self.stats.lock().unwrap().sessions_evicted += 1;
                    }
                    None => bail!("session slot budget exhausted"),
                }
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // Sessions pin to their consistent-hash shard: their decode
        // state lives in that shard's registry, and stealing skips
        // session work, so steps always execute where the state is.
        // The ring only holds live shards; a session stranded on a
        // later-condemned shard moves via `restore_session`.
        let shard = self.ring.lock().unwrap().route(id);
        if self.dead_shards.lock().unwrap().contains(&shard) {
            bail!("no live shard left for session {id}");
        }
        let queue = self.shards[shard].queue(bucket);
        let (tx, rx) = mpsc::channel();
        let open = SessionOpen { id, enqueued_at: Instant::now(), resp: tx };
        self.enqueue(queue, bucket, Work::Open(open))?;
        let resp = rx.recv().map_err(|_| anyhow!("worker dropped session-open response"))?;
        resp.result.map_err(|e| anyhow!(e))?;
        let sessions = Arc::clone(self.shards[shard].session_map(bucket));
        let touched =
            Arc::new(AtomicU64::new(self.touch_clock.fetch_add(1, Ordering::Relaxed) + 1));
        self.registry.lock().unwrap().insert(
            id,
            SessionMeta { sessions: Arc::clone(&sessions), touched: Arc::clone(&touched) },
        );
        Ok(DecodeSession {
            id,
            bucket,
            shard,
            queue: queue.clone(),
            sessions,
            registry: Arc::clone(&self.registry),
            touched,
            touch_clock: Arc::clone(&self.touch_clock),
            stats: Arc::clone(&self.stats),
            next_pos: 0,
            closed: false,
            tokens: Vec::new(),
            synced: true,
        })
    }

    /// Fail a session over to a healthy shard: re-open its id on the
    /// live ring and replay its confirmed token history against a
    /// fresh decode state.  A bucket's native encoders are
    /// deterministic replicas across shards, so the restored state —
    /// and every logit it produces from here on — is bitwise identical
    /// to an unfaulted session fed the same tokens.  The replay is a
    /// *fresh state lineage*: the old (poisoned, evicted, or
    /// shard-dead) state is discarded, never advanced twice, so a
    /// failed step can be resubmitted post-restore without ever
    /// re-executing against an already-advanced state.
    ///
    /// Requires a *synced* handle: only the blocking
    /// [`DecodeSession::step`] keeps confirmed history.  After
    /// pipelined `submit_step`/`stream` the handle cannot know which
    /// tokens actually executed, so failover refuses rather than
    /// guess at the session's contents.
    pub fn restore_session(&self, session: &mut DecodeSession) -> Result<()> {
        if !session.synced {
            bail!(
                "session {} used pipelined steps; failover needs the confirmed \
                 history only blocking step() keeps",
                session.id
            );
        }
        // Drop the old slot and registry entry first: whatever state
        // remains on the old shard is now orphaned, and any in-flight
        // step against it gets a terminal "unknown session" reply.
        session.sessions.lock().unwrap().remove(&session.id);
        self.registry.lock().unwrap().remove(&session.id);
        let shard = self.ring.lock().unwrap().route(session.id);
        if self.dead_shards.lock().unwrap().contains(&shard) {
            bail!("no live shard left to restore session {}", session.id);
        }
        let queue = self.shards[shard].queue(session.bucket);
        let (tx, rx) = mpsc::channel();
        let open = SessionOpen { id: session.id, enqueued_at: Instant::now(), resp: tx };
        self.enqueue(queue, session.bucket, Work::Open(open))?;
        let resp = rx.recv().map_err(|_| anyhow!("worker dropped session-restore response"))?;
        resp.result.map_err(|e| anyhow!("session restore reopen failed: {e}"))?;
        // Serial replay of the confirmed history: decode order demands
        // each step lands before the next, and every reply is checked —
        // a replay failure is loud, never a silent hole in the state.
        for (pos, &token) in session.tokens.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            let step =
                SessionStep { id: session.id, pos, token, enqueued_at: Instant::now(), resp: tx };
            self.enqueue(queue, session.bucket, Work::Step(step))?;
            let resp = rx.recv().map_err(|_| anyhow!("worker dropped replay response"))?;
            resp.result
                .map_err(|e| anyhow!("replay of token {pos} for session {}: {e}", session.id))?;
        }
        // Re-point the handle at its new home.
        session.queue = queue.clone();
        session.sessions = Arc::clone(self.shards[shard].session_map(session.bucket));
        session.shard = shard;
        session.next_pos = session.tokens.len();
        session.closed = false;
        session
            .touched
            .store(self.touch_clock.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
        self.registry.lock().unwrap().insert(
            session.id,
            SessionMeta {
                sessions: Arc::clone(&session.sessions),
                touched: Arc::clone(&session.touched),
            },
        );
        self.stats.lock().unwrap().sessions_restored += 1;
        Ok(())
    }

    /// Shards condemned by their supervisors (empty in a healthy front).
    pub fn dead_shards(&self) -> Vec<usize> {
        self.dead_shards.lock().unwrap().clone()
    }

    pub fn stats(&self) -> Arc<Mutex<ServeStats>> {
        Arc::clone(&self.stats)
    }

    /// The shared KV page pool, when `[serve] page_pool_pages > 0`
    /// configured one (benches read its budget/occupancy/counters).
    pub fn page_pool(&self) -> Option<&PagePool> {
        self.pool.as_ref()
    }

    pub fn uptime_secs(&self) -> f64 {
        self.started_at.elapsed().as_secs_f64()
    }

    /// Drain queues and join workers (including scaler threads and any
    /// autoscaled extras).
    pub fn shutdown(self) {
        self.draining.store(true, Ordering::SeqCst);
        for shard in &self.shards {
            for (_, q) in &shard.queues {
                q.close();
            }
        }
        loop {
            // Scalers may still be pushing handles while we join; drain
            // until the registry stays empty.
            let batch: Vec<JoinHandle<()>> = {
                let mut w = self.workers.lock().unwrap();
                w.drain(..).collect()
            };
            if batch.is_empty() {
                break;
            }
            for h in batch {
                h.join().ok();
            }
        }
    }
}

/// A client handle to one incremental decode session: submit tokens one
/// at a time ([`step`](Self::step)) or pipeline a whole stretch and
/// read the logits back as they decode ([`stream`](Self::stream)).
/// Steps are serialized per session server-side; the handle enforces
/// the bucket-length cap client-side.  Dropping the handle closes the
/// session (releases its server-side state).
pub struct DecodeSession {
    id: u64,
    bucket: usize,
    /// Hosting shard (updated on failover by `restore_session`).
    shard: usize,
    queue: Channel<Work>,
    sessions: SessionMap,
    /// Coordinator-wide live-session registry (slot accounting).
    registry: SessionRegistry,
    /// This session's last-activity stamp (oldest-idle eviction reads
    /// it; every step bumps it from the shared clock).
    touched: Arc<AtomicU64>,
    touch_clock: Arc<AtomicU64>,
    stats: Arc<Mutex<ServeStats>>,
    next_pos: usize,
    closed: bool,
    /// Confirmed token history: tokens whose logits the blocking
    /// [`step`](Self::step) has seen come back.  Powers
    /// [`Coordinator::restore_session`]'s bit-exact failover replay.
    tokens: Vec<i32>,
    /// False once pipelined submission (`submit_step` / `stream`) is
    /// used: the handle no longer knows which tokens definitely
    /// executed, so failover refuses rather than guess.
    synced: bool,
}

impl DecodeSession {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The bucket length this session can grow to.
    pub fn capacity(&self) -> usize {
        self.bucket
    }

    /// The shard currently hosting this session's decode state.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// The confirmed (blocking-step) token history.
    pub fn history(&self) -> &[i32] {
        &self.tokens
    }

    /// Tokens submitted so far.
    pub fn len(&self) -> usize {
        self.next_pos
    }

    pub fn is_empty(&self) -> bool {
        self.next_pos == 0
    }

    /// `block_on_full` selects the backpressure mode: `false` fails
    /// fast (the prefill-style 429 semantics), `true` blocks until the
    /// workers drain a slot — how [`stream`](Self::stream) pipelines
    /// stretches longer than the bucket queue without losing steps.
    fn enqueue_step(
        &mut self,
        token: i32,
        resp: mpsc::Sender<Response>,
        block_on_full: bool,
    ) -> Result<()> {
        if self.closed {
            bail!("decode session already closed");
        }
        if self.next_pos >= self.bucket {
            bail!("decode session reached its bucket length n{}", self.bucket);
        }
        // Session-aware admission: activity protects the slot from
        // oldest-idle eviction.
        self.touched
            .store(self.touch_clock.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
        let step = SessionStep {
            id: self.id,
            pos: self.next_pos,
            token,
            enqueued_at: Instant::now(),
            resp,
        };
        let sent = if block_on_full {
            // Channel::send only errors when closed; Full blocks until
            // a worker drains (workers always make progress on session
            // items, so this terminates unless the pool is gone).
            self.queue.send(Work::Step(step)).map_err(|_| anyhow!("coordinator shutting down"))
        } else {
            match self.queue.try_send(Work::Step(step)) {
                Ok(()) => Ok(()),
                Err(SendError::Full(_)) => {
                    // Same 429 accounting as prefill backpressure.
                    self.stats.lock().unwrap().rejected += 1;
                    Err(anyhow!("backpressure: bucket n{} queue full", self.bucket))
                }
                Err(SendError::Closed(_)) => Err(anyhow!("coordinator shutting down")),
            }
        };
        sent?;
        self.next_pos += 1;
        Ok(())
    }

    /// Submit one token without waiting; the step's logits arrive on
    /// the returned receiver.  Fails fast on a full bucket queue
    /// (backpressure), like prefill submission.  Pipelining forfeits
    /// failover: the handle stops tracking confirmed history.
    pub fn submit_step(&mut self, token: i32) -> Result<mpsc::Receiver<Response>> {
        self.synced = false;
        let (tx, rx) = mpsc::channel();
        self.enqueue_step(token, tx, false)?;
        Ok(rx)
    }

    /// Submit one token and block for its logits.  A confirmed step is
    /// appended to the handle's token history, keeping the session
    /// restorable via [`Coordinator::restore_session`].
    pub fn step(&mut self, token: i32) -> Result<Vec<f32>> {
        let (tx, rx) = mpsc::channel();
        self.enqueue_step(token, tx, false)?;
        let resp = rx.recv().map_err(|_| anyhow!("worker dropped decode response"))?;
        let logits = resp.result.map_err(|e| anyhow!(e))?;
        self.tokens.push(token);
        Ok(logits)
    }

    /// Pipeline a stretch of tokens and stream the per-token responses
    /// back in decode order over one channel — the streaming serving
    /// path.  Enqueueing blocks when the bucket queue fills (flow
    /// control: responses buffer unboundedly on the returned channel,
    /// so stretches longer than the queue capacity pipeline cleanly).
    /// Consume the receiver fully before closing the session.
    pub fn stream(&mut self, tokens: &[i32]) -> Result<mpsc::Receiver<Response>> {
        self.synced = false;
        let (tx, rx) = mpsc::channel();
        for &t in tokens {
            self.enqueue_step(t, tx.clone(), true)?;
        }
        Ok(rx)
    }

    /// Close the session, releasing its server-side state.  (Dropping
    /// the handle does the same.)
    pub fn close(mut self) {
        self.send_close();
    }

    fn send_close(&mut self) {
        if !self.closed {
            // Remove the slot from the bucket registry directly — a
            // full queue must never be able to leak server-side decode
            // state.  In-flight steps keep the slot alive through their
            // own Arc; steps still queued reply "unknown session".
            self.sessions.lock().unwrap().remove(&self.id);
            self.registry.lock().unwrap().remove(&self.id);
            self.closed = true;
        }
    }
}

impl Drop for DecodeSession {
    fn drop(&mut self) {
        self.send_close();
    }
}

/// One member's attention shape inside a padded batch: its live token
/// count (the key mask), its causal flag, and its optional score
/// scale.  Built per request by [`run_batch`] so a single bucket batch
/// can mix variable-length, mixed-mask, and mixed-scale traffic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReqSpec {
    pub key_len: usize,
    pub causal: bool,
    /// The request's score-temperature override — carried verbatim
    /// into the member's [`AttnSpec`](crate::attention::AttnSpec)
    /// (regression: this used to be dropped to `None` by the native
    /// executor, so a request's scale was silently ignored).
    pub scale: Option<f32>,
}

/// One worker's batch executor: given the bucket-padded token buffer,
/// produce per-request logits rows.  The batching loop above is the
/// same for every implementation.
trait BatchExec {
    /// Executable batch capacity to plan for (PJRT batches are static;
    /// the native path accepts any size up to `max_batch`).
    fn plan_capacity(&self, members: usize, max_batch: usize) -> usize;

    /// Whether this executor can honor the causal mask.  [`run_batch`]
    /// rejects causal members *individually* (their co-batched
    /// bidirectional requests still run) when it cannot.
    fn supports_causal(&self) -> bool;

    /// Whether this executor can honor a per-request score-scale
    /// override.  Like the causal capability, members carrying a scale
    /// are rejected individually when it cannot (the PJRT executables
    /// bake the default `1/sqrt(d)` in).
    fn supports_scale(&self) -> bool;

    /// `tokens` holds `capacity * bucket` ids (`real` live rows, the
    /// rest phantom padding); `specs` holds one [`ReqSpec`] per live
    /// row.  Returns `real` logit rows.
    fn run(
        &mut self,
        tokens: Vec<i32>,
        specs: &[ReqSpec],
        capacity: usize,
        real: usize,
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>>;

    /// Open a decode-session attention state.  `Err` — never a panic —
    /// when this executor cannot decode (PJRT batch artifacts,
    /// unmaskable methods): the message rides the session-open
    /// response.
    fn begin_decode(&self) -> Result<DecodeState, String>;

    /// One decode-session step: embed `token` at `pos` and advance the
    /// state by one token, returning the token's logits.
    fn decode_step(
        &mut self,
        state: &mut DecodeState,
        pos: usize,
        token: i32,
    ) -> Result<Vec<f32>, String>;

    /// Recompute the K/V rows of `token` at `pos` into `k`/`v` — the
    /// paged cache's recompute-on-miss refill.  Only executors with a
    /// deterministic embedding can honor it.
    fn recompute_kv(
        &self,
        _token: i32,
        _pos: usize,
        _k: &mut [f32],
        _v: &mut [f32],
    ) -> Result<(), String> {
        Err("this executor cannot recompute evicted KV pages".into())
    }
}

/// PJRT path: resident params + the bucket's b1/bN executables.
struct PjrtExec {
    engine: Engine,
    exe_b1: String,
    exe_bn: String,
    param_lits: Vec<Literal>,
    num_classes: usize,
}

impl PjrtExec {
    fn new(cfg: &ServeConfig, dir: &std::path::Path, bucket: usize) -> Result<Self> {
        let mut engine = Engine::new(dir)?;
        let exe_b1 = format!("serve_{}_b1_n{}", cfg.method, bucket);
        let exe_bn = format!("serve_{}_b{}_n{}", cfg.method, cfg.max_batch, bucket);
        engine.warmup(&[&exe_b1, &exe_bn])?;

        // Resident parameters: built once, reused for every call.
        let model_tag = engine.manifest().artifact(&exe_b1)?.meta.get("model").cloned()
            .ok_or_else(|| anyhow!("{exe_b1}: missing model meta"))?;
        let model = engine.manifest().model(&model_tag)?.clone();
        let params = ParamStore::load_initial(dir, &model)?;
        let param_lits: Vec<Literal> = params.to_literals()?;
        let num_classes: usize = {
            let spec = engine.manifest().artifact(&exe_b1)?;
            *spec.outputs[0].shape.last().unwrap_or(&4)
        };
        Ok(Self { engine, exe_b1, exe_bn, param_lits, num_classes })
    }
}

impl BatchExec for PjrtExec {
    fn plan_capacity(&self, members: usize, max_batch: usize) -> usize {
        if members == 1 {
            1
        } else {
            max_batch
        }
    }

    fn supports_causal(&self) -> bool {
        // The AOT executables are compiled as full bidirectional
        // attention over the padded bucket (key-length padding keeps
        // the historical attend-the-PAD-rows semantics): causal
        // members are rejected per request by `run_batch`.
        false
    }

    fn supports_scale(&self) -> bool {
        // The default 1/sqrt(d) scale is baked into the AOT HLO; a
        // per-request override cannot be honored here.
        false
    }

    fn run(
        &mut self,
        tokens: Vec<i32>,
        specs: &[ReqSpec],
        capacity: usize,
        real: usize,
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        // Defensive: run_batch filters causal members out before this
        // executor sees them.
        if let Some(s) = specs.iter().find(|s| s.causal) {
            bail!(
                "causal request (key_len {}) reached the PJRT executor: AOT serve artifacts are \
                 full-attention only; serve causal traffic via the native backend path \
                 (`[serve] force_native = true`)",
                s.key_len
            );
        }
        let exe = if capacity == 1 { self.exe_b1.clone() } else { self.exe_bn.clone() };
        let tok_lit = HostTensor::I32 { shape: vec![capacity, bucket], data: tokens }.to_literal()?;
        let mut args: Vec<&Literal> = self.param_lits.iter().collect();
        args.push(&tok_lit);
        let outs = self.engine.execute_literals(&exe, &args)?;
        let logits = outs[0].to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        let nc = self.num_classes;
        Ok((0..real).map(|i| logits[i * nc..(i + 1) * nc].to_vec()).collect())
    }

    fn begin_decode(&self) -> Result<DecodeState, String> {
        Err("decode sessions require the native backend path: the AOT serve artifacts are \
             batch-prefill, full-attention executables with no incremental state; set `[serve] \
             force_native = true` (with a maskable method) to serve decode sessions"
            .into())
    }

    fn decode_step(
        &mut self,
        _state: &mut DecodeState,
        _pos: usize,
        _token: i32,
    ) -> Result<Vec<f32>, String> {
        Err("decode step reached the PJRT executor (sessions cannot be opened here)".into())
    }
}

/// Native path: the [`AttentionBackend`](crate::attention::AttentionBackend)
/// encoder — no artifacts, no PJRT, still the full serving pipeline.
struct NativeExec {
    encoder: NativeEncoder,
}

impl NativeExec {
    fn new(cfg: &ServeConfig, bucket: usize) -> Result<Self> {
        // A typo'd method must fail loudly, not silently serve lln_diag.
        let method = Method::parse(&cfg.method)
            .ok_or_else(|| anyhow!("unknown serving method {:?}", cfg.method))?;
        Ok(Self {
            encoder: NativeEncoder::new(
                method,
                super::native::NATIVE_D_MODEL,
                super::native::NATIVE_NUM_CLASSES,
                bucket,
                super::native::NATIVE_SEED,
                &cfg.compute,
            ),
        })
    }
}

impl BatchExec for NativeExec {
    fn plan_capacity(&self, members: usize, _max_batch: usize) -> usize {
        members
    }

    fn supports_causal(&self) -> bool {
        // Nystrom/Linformer structurally cannot be masked; their causal
        // requests must be rejected, not silently served bidirectional.
        self.encoder.method().supports_masking()
    }

    fn supports_scale(&self) -> bool {
        // Maskable methods take the scale through the AttnSpec
        // (linear-class kernels without a score temperature ignore it,
        // exactly like the kernels themselves — that is the AttnSpec
        // contract, not a drop).  Nystrom/Linformer cannot: the
        // encoder degrades their non-full specs wholesale to FULL
        // (`NativeEncoder::infer_spec`), which would *silently* discard
        // the override — reject those members per request instead.
        self.encoder.method().supports_masking()
    }

    fn run(
        &mut self,
        tokens: Vec<i32>,
        specs: &[ReqSpec],
        _capacity: usize,
        real: usize,
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        Ok((0..real)
            .map(|i| {
                let spec = crate::attention::AttnSpec {
                    causal: specs[i].causal,
                    key_len: Some(specs[i].key_len),
                    scale: specs[i].scale,
                };
                self.encoder.infer_spec(&tokens[i * bucket..(i + 1) * bucket], &spec)
            })
            .collect())
    }

    fn begin_decode(&self) -> Result<DecodeState, String> {
        // Unmaskable methods (Nystrom/Linformer) reject here with the
        // backend's own message — an Err response, not a panic.
        self.encoder.begin_decode()
    }

    fn decode_step(
        &mut self,
        state: &mut DecodeState,
        pos: usize,
        token: i32,
    ) -> Result<Vec<f32>, String> {
        Ok(self.encoder.decode_step(state, pos, token))
    }

    fn recompute_kv(
        &self,
        token: i32,
        pos: usize,
        k: &mut [f32],
        v: &mut [f32],
    ) -> Result<(), String> {
        // The native embedding is deterministic in (token, pos), so an
        // evicted page is recomputed bit-for-bit.
        self.encoder.recompute_kv_rows(token, pos, k, v);
        Ok(())
    }
}

thread_local! {
    /// True while this thread is inside [`catch_panic`]: the scoped
    /// hook below drops those panics' backtraces — they are *expected*
    /// (capability asserts, injected chaos faults) and become error
    /// responses, so spewing a full backtrace per occurrence buries
    /// real failures in noise.
    static PANIC_SUPPRESSED: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Install (once, process-wide) a panic hook that stays silent for
/// panics caught by [`catch_panic`] and defers to the previous hook
/// for everything else.
fn install_scoped_panic_hook() {
    static HOOK: std::sync::Once = std::sync::Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !PANIC_SUPPRESSED.with(|s| s.get()) {
                prev(info);
            }
        }));
    });
}

/// Run `f` with panics converted to `Err` — backend capability and
/// shape asserts (and injected chaos faults) reached from a worker
/// thread become per-request error responses through the coordinator
/// instead of killing the worker.  The scoped hook suppresses the
/// default backtrace spew for exactly these expected panics; anything
/// panicking outside `catch_panic` still reports normally.
fn catch_panic<T>(f: impl FnOnce() -> T) -> Result<T, String> {
    install_scoped_panic_hook();
    // Save/restore (rather than set/clear) so nested catch_panic calls
    // keep suppression alive for the whole outer scope.
    let was = PANIC_SUPPRESSED.with(|s| s.replace(true));
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
    PANIC_SUPPRESSED.with(|s| s.set(was));
    result.map_err(|payload| {
        let msg = if let Some(s) = payload.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else {
            "unknown panic payload".to_string()
        };
        format!("worker panicked: {msg}")
    })
}

fn spawn_worker(ctx: WorkerCtx, index: usize) -> JoinHandle<()> {
    let shard = ctx.shard;
    let bucket = ctx.bucket;
    let live = Arc::clone(&ctx.live);
    let deaths = Arc::clone(&ctx.deaths);
    std::thread::Builder::new()
        .name(format!("lln-worker-s{shard}-n{bucket}-{index}"))
        .spawn(move || {
            if let Err(e) = worker_loop(ctx) {
                // A worker that dies (e.g. executor construction
                // failure) must release its live-count slot, or the
                // autoscaler would count phantom workers forever — and
                // the death is recorded so the scaler backs off instead
                // of hot-respawning a doomed executor.
                live.fetch_sub(1, Ordering::SeqCst);
                deaths.fetch_add(1, Ordering::SeqCst);
                eprintln!("worker n{bucket}-{index} died: {e:#}");
            }
        })
        .expect("spawn worker")
}

/// Consecutive failed respawn waves (the floor still short after each)
/// before the supervisor gives up and condemns the shard's bucket: a
/// persistently failing executor gets terminal `Failed` replies instead
/// of either a spawn/die hot loop or requests hanging forever.
const MAX_RESPAWN_WAVES: usize = 3;

/// Per-(shard, bucket) supervisor: respawns dead workers back to the
/// `min_workers` floor, condemns the shard when the floor cannot be
/// held (or when the chaos plan kills the shard outright), and — when
/// the band allows — grows the pool from queue depth toward the
/// ceiling exactly like the old autoscaler (idle extras still retire
/// themselves in [`worker_loop`]).  Exits when the coordinator drains
/// or the shard is condemned.
fn spawn_supervisor(
    ctx: WorkerCtx,
    max_workers: usize,
    registry: Arc<Mutex<Vec<JoinHandle<()>>>>,
    ring: Arc<Mutex<HashRing>>,
    dead_shards: Arc<Mutex<Vec<usize>>>,
    n_shards: usize,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("lln-supervisor-s{}-n{}", ctx.shard, ctx.bucket))
        .spawn(move || {
            let poll = Duration::from_millis(ctx.cfg.batch_timeout_ms.clamp(1, 20));
            let mut seq = ctx.min_workers;
            let mut deaths_seen = 0usize;
            let mut failed_waves = 0usize;
            while !ctx.draining.load(Ordering::SeqCst) {
                // Condemnation — the chaos plan killed this shard, a
                // sibling bucket's supervisor already declared it dead,
                // or this bucket's floor would not hold after repeated
                // respawn waves.  Bury the bucket's queue (terminal
                // replies) and exit; dead shards never rejoin the ring.
                let condemned = ctx
                    .plan
                    .as_ref()
                    .is_some_and(|p| p.shard_condemned(ctx.shard))
                    || dead_shards.lock().unwrap().contains(&ctx.shard)
                    || failed_waves >= MAX_RESPAWN_WAVES;
                if condemned {
                    bury_shard_bucket(&ctx, &ring, &dead_shards, n_shards);
                    return;
                }
                let cur = ctx.live.load(Ordering::SeqCst);
                if cur < ctx.min_workers {
                    // Dead workers below the floor: respawn the wave,
                    // then back off so a persistently failing executor
                    // cannot drive a spawn/die hot loop at the poll
                    // rate.  `failed_waves` resets only once the floor
                    // holds through a full poll.
                    for _ in cur..ctx.min_workers {
                        ctx.live.fetch_add(1, Ordering::SeqCst);
                        ctx.stats.lock().unwrap().worker_restarts += 1;
                        registry.lock().unwrap().push(spawn_worker(ctx.clone(), seq));
                        seq += 1;
                    }
                    failed_waves += 1;
                    deaths_seen = ctx.deaths.load(Ordering::SeqCst);
                    std::thread::sleep(SPAWN_BACKOFF);
                    continue;
                }
                failed_waves = 0;
                // Back off growth whenever a worker died since the last
                // poll (the floor survived, but the pool is clearly not
                // healthy enough to grow into).
                let deaths_now = ctx.deaths.load(Ordering::SeqCst);
                if deaths_now > deaths_seen {
                    deaths_seen = deaths_now;
                    std::thread::sleep(SPAWN_BACKOFF);
                    continue;
                }
                let depth = ctx.queue.len();
                let want =
                    desired_workers(depth, ctx.cfg.max_batch, ctx.min_workers, max_workers);
                if want > cur && max_workers > ctx.min_workers {
                    for _ in cur..want {
                        ctx.live.fetch_add(1, Ordering::SeqCst);
                        ctx.stats.lock().unwrap().workers_spawned += 1;
                        registry.lock().unwrap().push(spawn_worker(ctx.clone(), seq));
                        seq += 1;
                    }
                }
                // Reap retired workers' handles (dropping a finished
                // thread's handle detaches a dead thread) so spawn /
                // retire churn never grows the registry unboundedly.
                registry.lock().unwrap().retain(|h| !h.is_finished());
                std::thread::sleep(poll);
            }
        })
        .expect("spawn supervisor")
}

/// Condemn one (shard, bucket): record the shard dead, rebuild the
/// session ring without it, close the bucket queue, and reply a
/// terminal `Failed` to everything still queued — a request must never
/// hang on a shard that can no longer serve it.  The ring rebuild
/// happens *before* the queue closes, so by the time any client
/// observes the failure, new routing already avoids the dead shard.
fn bury_shard_bucket(
    ctx: &WorkerCtx,
    ring: &Arc<Mutex<HashRing>>,
    dead_shards: &Arc<Mutex<Vec<usize>>>,
    n_shards: usize,
) {
    {
        let mut dead = dead_shards.lock().unwrap();
        if !dead.contains(&ctx.shard) {
            dead.push(ctx.shard);
            *ring.lock().unwrap() = HashRing::excluding(n_shards, &dead);
            eprintln!(
                "supervisor: shard {} condemned; new sessions route to survivors",
                ctx.shard
            );
        }
    }
    ctx.queue.close();
    let buried = ctx.queue.drain_up_to(usize::MAX);
    if buried.is_empty() {
        return;
    }
    let mut st = ctx.stats.lock().unwrap();
    for work in buried {
        st.errors += 1;
        let msg = format!("shard {} is dead (worker pool condemned)", ctx.shard);
        reply_failed(work, msg);
    }
}

/// Terminal `Failed` reply for an un-executable work item (dead shard
/// burial, dying-worker fallback).  Best-effort send: the caller may
/// already be gone.
fn reply_failed(work: Work, msg: String) {
    match work {
        Work::Infer(r) => {
            let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
            r.resp
                .send(Response {
                    id: r.id,
                    result: Err(RespError::Failed(msg)),
                    latency_ms,
                    batch_size: 0,
                })
                .ok();
        }
        Work::Open(o) => {
            let latency_ms = o.enqueued_at.elapsed().as_secs_f64() * 1e3;
            o.resp
                .send(Response {
                    id: o.id,
                    result: Err(RespError::Failed(msg)),
                    latency_ms,
                    batch_size: 0,
                })
                .ok();
        }
        Work::Step(s) => {
            let latency_ms = s.enqueued_at.elapsed().as_secs_f64() * 1e3;
            s.resp
                .send(Response {
                    id: s.id,
                    result: Err(RespError::Failed(msg)),
                    latency_ms,
                    batch_size: 0,
                })
                .ok();
        }
    }
}

/// Per-bucket worker: owns its executor and loops batching until the
/// queue closes (or, for autoscaled extras, until idle long enough to
/// retire back to the bucket's floor).
fn worker_loop(ctx: WorkerCtx) -> Result<()> {
    let WorkerCtx {
        cfg,
        dir,
        shard,
        bucket,
        queue,
        victims,
        stats,
        draining,
        sessions,
        pool,
        short_bucket,
        live,
        min_workers,
        plan,
        ..
    } = ctx;
    let prefill_class =
        if short_bucket { PayloadClass::PrefillShort } else { PayloadClass::PrefillLong };
    let mut exec: Box<dyn BatchExec> = if cfg.force_native {
        // Causal serving and mask-sensitive traffic skip PJRT outright:
        // the AOT executables are full bidirectional attention.
        Box::new(NativeExec::new(&cfg, bucket)?)
    } else {
        match PjrtExec::new(&cfg, &dir, bucket) {
            Ok(e) => Box::new(e),
            Err(e) if cfg.native_fallback => {
                eprintln!(
                    "worker n{bucket}: PJRT path unavailable ({e:#}); serving via native {} \
                     backend (degraded: untrained weights)",
                    cfg.method
                );
                Box::new(NativeExec::new(&cfg, bucket)?)
            }
            Err(e) => return Err(e),
        }
    };

    let mut pending: Vec<Work> = Vec::new();
    // Pending items already charged against the fault plan's arrival
    // counter — an item waiting out the batch timer across iterations
    // must be counted exactly once.
    let mut counted = 0usize;
    let mut idle_since: Option<Instant> = None;
    loop {
        // Top up the pending set.
        let drain = draining.load(Ordering::SeqCst);
        if pending.len() < cfg.max_batch {
            match queue.recv_timeout(Duration::from_millis(cfg.batch_timeout_ms.max(1))) {
                Ok(Some(req)) => {
                    pending.push(req);
                    // opportunistically grab whatever else is queued
                    pending.extend(queue.drain_up_to(cfg.max_batch - pending.len()));
                }
                Ok(None) => {}
                Err(_) if pending.is_empty() => return Ok(()), // closed + drained
                Err(_) => {}
            }
        }
        if pending.is_empty() && !victims.is_empty() {
            // Work stealing: an idle shard relieves a loaded sibling's
            // same-bucket queue.  Only the FIFO prefix of *prefill*
            // items moves — session work is shard-pinned (its decode
            // state lives in the victim shard's registry) and stealing
            // past it would reorder the queue.
            for v in &victims {
                let stolen = v.steal_up_to(cfg.max_batch - pending.len(), |w| !w.is_session_work());
                if !stolen.is_empty() {
                    stats.lock().unwrap().steals += stolen.len() as u64;
                    pending.extend(stolen);
                }
                if pending.len() >= cfg.max_batch {
                    break;
                }
            }
        }
        // Deterministic chaos: each newly picked-up item advances the
        // plan's global arrival counter and may fire a worker fault.
        if let Some(p) = &plan {
            let mut delay_ms = 0u64;
            let mut die = false;
            while counted < pending.len() {
                counted += 1;
                match p.on_worker_item(shard) {
                    Some(WorkerFault::Delay(ms)) => delay_ms += ms,
                    Some(WorkerFault::Die) => {
                        die = true;
                        break;
                    }
                    None => {}
                }
            }
            if !pending.is_empty() {
                // Assignment, not accumulation: `injected` is the
                // plan's lifetime total, shared across all workers.
                stats.lock().unwrap().faults_injected = p.injected();
            }
            if die {
                // A dying worker must never strand a request: give
                // un-executed items back to the queue (the respawned
                // worker or a sibling picks them up), or bury them with
                // a terminal reply when the queue is already closed.
                for work in pending.drain(..) {
                    if let Err(e) = queue.try_send(work) {
                        let work = match e {
                            SendError::Full(w) | SendError::Closed(w) => w,
                        };
                        stats.lock().unwrap().errors += 1;
                        reply_failed(
                            work,
                            format!(
                                "worker on shard {shard} killed with its bucket n{bucket} \
                                 queue unavailable"
                            ),
                        );
                    }
                }
                bail!("injected fault: worker killed by chaos plan");
            }
            if delay_ms > 0 {
                std::thread::sleep(Duration::from_millis(delay_ms));
            }
        }
        if pending.is_empty() {
            // Surplus (autoscaled) workers retire after lingering idle;
            // the floor never shrinks below min_workers.
            let idle = *idle_since.get_or_insert_with(Instant::now);
            if idle.elapsed() >= IDLE_RETIRE {
                let mut cur = live.load(Ordering::SeqCst);
                while cur > min_workers {
                    match live.compare_exchange(
                        cur,
                        cur - 1,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    ) {
                        Ok(_) => return Ok(()),
                        Err(now) => cur = now,
                    }
                }
                idle_since = None;
            }
            continue;
        }
        idle_since = None;
        // Session work (single-token decode steps, opens, closes) never
        // waits out the prefill batcher's fill timer.
        let has_session_work = pending.iter().any(Work::is_session_work);
        let infer_count = pending.iter().filter(|w| !w.is_session_work()).count();
        let oldest_ms = pending
            .iter()
            .map(|w| w.enqueued_at().elapsed().as_secs_f64() * 1e3)
            .fold(0.0, f64::max);
        if !has_session_work
            && !should_fire(infer_count, cfg.max_batch, oldest_ms, cfg.batch_timeout_ms as f64, drain)
        {
            continue;
        }
        // One drained set can mix prefill and decode traffic: session
        // items run statefully in arrival order, prefill members batch
        // through the executor as before.  Already-expired prefill is
        // shed here — a terminal `DeadlineExceeded` beats burning
        // executor time on a response nobody is waiting for.
        let mut infers: Vec<Request> = Vec::new();
        let now = Instant::now();
        for work in pending.drain(..) {
            match work {
                Work::Infer(r) => {
                    if deadline_expired(r.deadline, now) {
                        let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                        stats.lock().unwrap().deadline_drops += 1;
                        r.resp
                            .send(Response {
                                id: r.id,
                                result: Err(RespError::DeadlineExceeded(format!(
                                    "deadline passed after {latency_ms:.1} ms in queue"
                                ))),
                                latency_ms,
                                batch_size: 0,
                            })
                            .ok();
                    } else {
                        infers.push(r);
                    }
                }
                Work::Open(open) => {
                    run_session_open(exec.as_mut(), &sessions, open, pool.as_ref(), &stats)
                }
                Work::Step(step) => run_session_step(
                    exec.as_mut(),
                    &sessions,
                    step,
                    cfg.recompute_on_miss,
                    &stats,
                ),
            }
        }
        counted = 0;
        for batch_plan in plan_batches(infers.len(), cfg.max_batch) {
            let batch: Vec<Request> = infers.drain(..batch_plan.members.len()).collect();
            let capacity = exec.plan_capacity(batch.len(), cfg.max_batch);
            run_batch(
                exec.as_mut(),
                &cfg,
                capacity,
                bucket,
                batch,
                prefill_class,
                plan.as_ref(),
                &stats,
            );
        }
    }
}

/// Open one decode session on this worker's executor: validate, stash
/// the state in the bucket registry, reply.  Capability failures are
/// `Err` responses, never panics.
fn run_session_open(
    exec: &mut dyn BatchExec,
    sessions: &SessionMap,
    open: SessionOpen,
    pool: Option<&PagePool>,
    stats: &Arc<Mutex<ServeStats>>,
) {
    match catch_panic(|| exec.begin_decode()).and_then(|r| r) {
        Ok(state) => {
            // KV-cache states back onto the shared page pool when one
            // is configured: the session's memory becomes pool pages
            // (evictable, LRU across sessions) instead of a private
            // unbounded buffer.  Non-KV states (the linear class's
            // constant prefix state) stay as they are.
            let state = match (state, pool) {
                (DecodeState::Cache(c), Some(p))
                    if c.is_empty() && c.d() == p.d() && c.dv() == p.dv() =>
                {
                    DecodeState::Paged(PagedKvCache::new(p, open.id, c.d(), c.dv()))
                }
                (s, _) => s,
            };
            sessions.lock().unwrap().insert(
                open.id,
                Arc::new(Mutex::new(SessionSlot { state, pos: 0, failed: None, tokens: Vec::new() })),
            );
            let latency_ms = open.enqueued_at.elapsed().as_secs_f64() * 1e3;
            let mut st = stats.lock().unwrap();
            st.sessions_opened += 1;
            // Session opens are their own payload class: they complete
            // work (state allocation + registration) and count toward
            // `completed` like every other finished item.
            st.record(PayloadClass::SessionOpen, latency_ms);
            drop(st);
            open.resp
                .send(Response { id: open.id, result: Ok(Vec::new()), latency_ms, batch_size: 1 })
                .ok();
        }
        Err(e) => {
            let latency_ms = open.enqueued_at.elapsed().as_secs_f64() * 1e3;
            stats.lock().unwrap().errors += 1;
            open.resp
                .send(Response {
                    id: open.id,
                    result: Err(RespError::Failed(e)),
                    latency_ms,
                    batch_size: 0,
                })
                .ok();
        }
    }
}

/// Execute one decode step against the session registry.  Steps of one
/// session are serialized on the slot's position counter — a worker
/// holding position `t` waits (bounded) for `t-1` to land when another
/// worker still runs it — so co-batched concurrent sessions never
/// contaminate each other's state and a session's own steps never
/// reorder.
fn run_session_step(
    exec: &mut dyn BatchExec,
    sessions: &SessionMap,
    step: SessionStep,
    recompute_on_miss: bool,
    stats: &Arc<Mutex<ServeStats>>,
) {
    let reply_err = |msg: String| {
        stats.lock().unwrap().errors += 1;
        let latency_ms = step.enqueued_at.elapsed().as_secs_f64() * 1e3;
        step.resp
            .send(Response {
                id: step.id,
                result: Err(RespError::Failed(msg)),
                latency_ms,
                batch_size: 0,
            })
            .ok();
    };
    let slot = sessions.lock().unwrap().get(&step.id).cloned();
    let Some(slot) = slot else {
        return reply_err(format!("unknown decode session {} (closed or never opened)", step.id));
    };
    let deadline = Instant::now() + STEP_ORDER_TIMEOUT;
    let mut guard = slot.lock().unwrap();
    while guard.pos < step.pos {
        // The deadline check runs while holding the lock with pos still
        // behind, so a predecessor landing at the last instant is never
        // mistaken for a timeout (we simply loop and execute).  On a
        // real timeout, poison AND advance pos so the pipelined tail
        // fails fast instead of each successor re-waiting the full
        // timeout (a late-landing predecessor then errors as stale).
        if Instant::now() >= deadline {
            let msg =
                format!("decode step {} timed out waiting for its predecessor", step.pos);
            guard.failed = Some(msg.clone());
            guard.pos = step.pos + 1;
            drop(guard);
            return reply_err(msg);
        }
        drop(guard);
        std::thread::sleep(Duration::from_micros(100));
        guard = slot.lock().unwrap();
    }
    if let Some(e) = &guard.failed {
        return reply_err(format!("decode session poisoned by an earlier failure: {e}"));
    }
    if guard.pos > step.pos {
        return reply_err(format!(
            "stale decode step: position {} already advanced past {}",
            guard.pos, step.pos
        ));
    }
    let SessionSlot { state, tokens, .. } = &mut *guard;
    // Paged sessions: pin the session's pages for the whole step (the
    // ensure/push/gather sequence spans several pool calls), bump its
    // LRU stamp, and — when enabled — recompute any evicted pages from
    // the recorded token history before the kernel runs.
    let mut pin = None;
    let mut pool_counters = None;
    if let DecodeState::Paged(paged) = state {
        pin = Some(paged.pool().pin(step.id));
        paged.touch();
        if recompute_on_miss {
            let hist: &[i32] = tokens.as_slice();
            let refill = catch_panic(|| {
                paged.ensure_resident(|pos, k, v| {
                    let tok = *hist
                        .get(pos)
                        .ok_or_else(|| format!("no recorded token at position {pos}"))?;
                    exec.recompute_kv(tok, pos, k, v)
                })
            })
            .and_then(|r| r);
            if let Err(e) = refill {
                drop(pin);
                guard.pos = step.pos + 1;
                guard.failed = Some(e.clone());
                drop(guard);
                return reply_err(format!("paged KV refill failed: {e}"));
            }
        }
    }
    let result =
        catch_panic(|| exec.decode_step(&mut *state, step.pos, step.token)).and_then(|r| r);
    if let DecodeState::Paged(paged) = state {
        // Token history powers recompute-on-miss; record only on
        // success (a failed step poisons the session anyway).
        if result.is_ok() {
            tokens.push(step.token);
        }
        pool_counters = Some(paged.pool().counters());
    }
    drop(pin);
    match result {
        Ok(logits) => {
            guard.pos += 1;
            let latency_ms = step.enqueued_at.elapsed().as_secs_f64() * 1e3;
            let mut st = stats.lock().unwrap();
            st.decode_steps += 1;
            st.record(PayloadClass::DecodeStep, latency_ms);
            if let Some(c) = pool_counters {
                // Mirror the pool's lifetime counters (shared across
                // shards, so assignment — not accumulation — is right).
                st.pages_evicted = c.evicted;
                st.pages_recomputed = c.recomputed;
            }
            drop(st);
            step.resp
                .send(Response { id: step.id, result: Ok(logits), latency_ms, batch_size: 1 })
                .ok();
        }
        Err(e) => {
            // Poison the session: its state did not advance, so letting
            // later steps run would silently decode on a stale prefix.
            guard.pos += 1;
            guard.failed = Some(e.clone());
            reply_err(e);
        }
    }
}

/// Execute one padded batch through the worker's executor and fan
/// results back out.  `[compute] causal` is OR-ed with each request's
/// own flag; causal members an executor cannot honor are rejected
/// *individually* — their co-batched bidirectional requests still run.
/// Executor panics are caught and routed back as per-request error
/// responses (the worker thread survives).
///
/// Failed executions retry up to `[serve] retry_max` times with
/// jittered exponential backoff — prefill only, and only here: a
/// prefill batch that never produced logits is side-effect-free to
/// re-execute, unlike a decode step whose state may have advanced.
/// Members whose deadline expires while the batch backs off are shed
/// (`DeadlineExceeded`) instead of riding the retry.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    exec: &mut dyn BatchExec,
    cfg: &ServeConfig,
    capacity: usize,
    bucket: usize,
    batch: Vec<Request>,
    class: PayloadClass,
    plan: Option<&Arc<FaultPlan>>,
    stats: &Arc<Mutex<ServeStats>>,
) {
    let default_causal = cfg.compute.causal;
    let mut batch = batch;
    if !exec.supports_causal() {
        let mut kept = Vec::with_capacity(batch.len());
        for r in batch {
            if r.causal || default_causal {
                let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                stats.lock().unwrap().errors += 1;
                r.resp
                    .send(Response {
                        id: r.id,
                        result: Err(RespError::Failed(
                            "causal attention is not available on this worker's executor \
                             (AOT serve artifacts and the nystrom/linformer methods are \
                             full-attention only); serve a maskable method with `[serve] \
                             force_native = true`"
                                .into(),
                        )),
                        latency_ms,
                        batch_size: 0,
                    })
                    .ok();
            } else {
                kept.push(r);
            }
        }
        batch = kept;
        if batch.is_empty() {
            return;
        }
    }
    if !exec.supports_scale() {
        let mut kept = Vec::with_capacity(batch.len());
        for r in batch {
            if r.scale.is_some() {
                let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                stats.lock().unwrap().errors += 1;
                r.resp
                    .send(Response {
                        id: r.id,
                        result: Err(RespError::Failed(
                            "per-request attention scale is not available on this worker's \
                             executor (AOT serve artifacts bake the default 1/sqrt(d) in, and \
                             the nystrom/linformer encoders drop non-full specs wholesale); \
                             serve a maskable method with `[serve] force_native = true`"
                                .into(),
                        )),
                        latency_ms,
                        batch_size: 0,
                    })
                    .ok();
            } else {
                kept.push(r);
            }
        }
        batch = kept;
        if batch.is_empty() {
            return;
        }
    }
    // Jitter salt: the first member's id — deterministic for a
    // replayed request sequence, decorrelated across batches.
    let salt = batch.first().map_or(0, |r| r.id);
    let mut attempt: u32 = 0;
    loop {
        // (Re)build the padded buffer + specs for the current
        // membership — retries may have shed expired members.  One
        // attention spec per live row: the request's pre-padding length
        // becomes its key mask, its causal flag (or the worker-wide
        // default) and its scale override ride along — the request's
        // own spec always wins over what the worker default implies.
        let real = batch.len();
        let mut tokens = Vec::with_capacity(capacity * bucket);
        let mut specs = Vec::with_capacity(real);
        for r in &batch {
            specs.push(ReqSpec {
                key_len: r.tokens.len().min(bucket),
                causal: r.causal || default_causal,
                scale: r.scale,
            });
            tokens.extend(pad_to_bucket(&r.tokens, bucket));
        }
        // Pad phantom rows up to the executor's static batch.
        tokens.resize(capacity * bucket, crate::data::special::PAD);

        let inject = plan.is_some_and(|p| p.on_exec_call());
        let result = match catch_panic(|| {
            if inject {
                panic!("injected fault: executor panic (chaos schedule)");
            }
            exec.run(tokens, &specs, capacity, real, bucket)
        }) {
            Ok(r) => r,
            Err(panic_msg) => Err(anyhow!(panic_msg)),
        };

        match result {
            Ok(rows) => {
                let mut st = stats.lock().unwrap();
                st.record_batch(real);
                if let Some(p) = plan {
                    st.faults_injected = p.injected();
                }
                for (r, row) in batch.into_iter().zip(rows) {
                    let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                    st.record(class, latency_ms);
                    r.resp
                        .send(Response { id: r.id, result: Ok(row), latency_ms, batch_size: real })
                        .ok();
                }
                return;
            }
            Err(e) if attempt < cfg.retry_max => {
                attempt += 1;
                stats.lock().unwrap().retries += 1;
                std::thread::sleep(Duration::from_millis(backoff_ms(
                    cfg.retry_backoff_ms,
                    attempt,
                    salt,
                )));
                // Shed members whose deadline passed during the
                // backoff — retrying them would spend executor time on
                // already-dead load.
                let now = Instant::now();
                let mut kept = Vec::with_capacity(batch.len());
                for r in batch {
                    if deadline_expired(r.deadline, now) {
                        let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                        stats.lock().unwrap().deadline_drops += 1;
                        r.resp
                            .send(Response {
                                id: r.id,
                                result: Err(RespError::DeadlineExceeded(format!(
                                    "deadline passed while retrying a failed batch ({e:#})"
                                ))),
                                latency_ms,
                                batch_size: 0,
                            })
                            .ok();
                    } else {
                        kept.push(r);
                    }
                }
                batch = kept;
                if batch.is_empty() {
                    return;
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                let mut st = stats.lock().unwrap();
                st.record_batch(real);
                if let Some(p) = plan {
                    st.faults_injected = p.injected();
                }
                for r in batch {
                    let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                    st.errors += 1;
                    r.resp
                        .send(Response {
                            id: r.id,
                            result: Err(RespError::Failed(msg.clone())),
                            latency_ms,
                            batch_size: real,
                        })
                        .ok();
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{special, tasks::GlueGen, GlueTask};
    use crate::runtime::{artifacts_available, artifacts_dir};

    fn coordinator() -> Option<Coordinator> {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            return None;
        }
        let cfg = ServeConfig {
            method: "lln_diag".into(),
            queue_capacity: 64,
            max_batch: 8,
            batch_timeout_ms: 3,
            buckets: vec![128, 512],
            // These tests exist to exercise the PJRT path; a fallback
            // here would silently mask PJRT regressions.
            native_fallback: false,
            ..Default::default()
        };
        Some(Coordinator::start(cfg, &dir).unwrap())
    }

    /// A coordinator guaranteed to be on the native-backend path (the
    /// artifacts dir does not exist), exercising the full serving stack
    /// without PJRT.
    fn native_coordinator(method: &str, workers: usize) -> Coordinator {
        let cfg = ServeConfig {
            method: method.into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers,
            buckets: vec![32, 64],
            native_fallback: true,
            ..Default::default()
        };
        Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap()
    }

    #[test]
    fn native_fallback_serves_single_request() {
        let c = native_coordinator("lln_diag", 1);
        let resp = c.infer(vec![special::CLS; 20]).unwrap();
        let logits = resp.result.unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        c.shutdown();
    }

    #[test]
    fn native_fallback_batches_bursts() {
        let c = native_coordinator("lln", 1);
        let rxs: Vec<_> = (0..16)
            .map(|i| c.submit(vec![4 + (i as i32) % 7; 24]).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "{:?}", resp.result);
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.completed, 16);
        assert!(st.mean_batch_size() >= 1.0);
        assert!(st.p95_latency() >= st.p50_latency());
        drop(st);
        c.shutdown();
    }

    #[test]
    fn native_fallback_scales_workers_per_bucket() {
        let c = native_coordinator("softmax", 2);
        let rxs: Vec<_> = (0..12).map(|_| c.submit(vec![9i32; 50]).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        assert_eq!(c.stats().lock().unwrap().completed, 12);
        c.shutdown();
    }

    #[test]
    fn native_fallback_is_deterministic_per_request() {
        let c = native_coordinator("elu", 1);
        let a = c.infer(vec![11i32; 30]).unwrap().result.unwrap();
        let b = c.infer(vec![11i32; 30]).unwrap().result.unwrap();
        assert_eq!(a, b);
        c.shutdown();
    }

    #[test]
    fn native_fallback_still_rejects_over_length() {
        let c = native_coordinator("lln_diag", 1);
        let err = c.submit(vec![special::CLS; 1000]).unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
        c.shutdown();
    }

    #[test]
    fn force_native_skips_pjrt_entirely() {
        // force_native must serve without ever probing the artifacts
        // dir (no native_fallback needed).
        let cfg = ServeConfig {
            method: "lln_diag".into(),
            force_native: true,
            native_fallback: false,
            buckets: vec![32],
            ..Default::default()
        };
        let c = Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap();
        let resp = c.infer_with(vec![special::CLS; 16], true).unwrap();
        assert!(resp.result.is_ok());
        c.shutdown();
    }

    #[test]
    fn native_fallback_serves_causal_requests() {
        let c = native_coordinator("lln", 1);
        let tokens: Vec<i32> = (0..30).map(|i| 4 + i % 9).collect();
        let causal = c.infer_with(tokens.clone(), true).unwrap().result.unwrap();
        let bidi = c.infer_with(tokens.clone(), false).unwrap().result.unwrap();
        assert_eq!(causal.len(), 4);
        assert!(causal.iter().all(|x| x.is_finite()));
        // The mask must actually change the served function...
        assert_ne!(causal, bidi);
        // ...deterministically.
        assert_eq!(causal, c.infer_with(tokens, true).unwrap().result.unwrap());
        c.shutdown();
    }

    #[test]
    fn unmaskable_method_rejects_causal_requests_individually() {
        // Nystrom cannot honor the causal mask: its causal members get
        // a per-request error while bidirectional members in the same
        // bucket still serve.
        let c = native_coordinator("nystrom", 1);
        let causal_rx = c.submit_with(vec![7i32; 32], true).unwrap();
        let bidi_rx = c.submit_with(vec![7i32; 32], false).unwrap();
        let causal = causal_rx.recv().unwrap();
        let bidi = bidi_rx.recv().unwrap();
        let err = causal.result.unwrap_err();
        assert!(err.message().contains("causal"), "unexpected error: {err}");
        assert!(bidi.result.is_ok(), "bidirectional co-request must still serve");
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.errors, 1);
        assert_eq!(st.completed, 1);
        drop(st);
        c.shutdown();
    }

    #[test]
    fn native_fallback_batches_mixed_causal_and_lengths() {
        // One bucket batch mixing causal/bidirectional members and
        // different live lengths: every member gets its own mask.
        let c = native_coordinator("softmax", 1);
        let rxs: Vec<_> = (0..12)
            .map(|i| {
                let len = 8 + (i % 3) * 7;
                c.submit_with(vec![5 + i as i32; len], i % 2 == 0).unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "{:?}", resp.result);
        }
        assert_eq!(c.stats().lock().unwrap().completed, 12);
        c.shutdown();
    }

    #[test]
    fn mixed_scale_batch_honors_each_requests_spec() {
        // Regression: the native executor used to rebuild every
        // member's AttnSpec with `scale: None`, silently dropping a
        // request's score-temperature override — most visibly when
        // `[compute] causal = true` flipped the worker default and the
        // spec was rebuilt server-side.  Each member's own spec must
        // win.
        let cfg = ServeConfig {
            method: "softmax".into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers: 1,
            buckets: vec![32],
            native_fallback: true,
            compute: crate::config::ComputeConfig { causal: true, ..Default::default() },
            ..Default::default()
        };
        let c = Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap();
        let tokens: Vec<i32> = (0..24).map(|i| 4 + i % 9).collect();
        let base_rx = c.submit_spec(tokens.clone(), false, None).unwrap();
        let hot_rx = c.submit_spec(tokens.clone(), false, Some(0.02)).unwrap();
        // The explicit default scale must serve exactly like None.
        let pinned = 1.0 / (super::super::native::NATIVE_D_MODEL as f32).sqrt();
        let pinned_rx = c.submit_spec(tokens.clone(), false, Some(pinned)).unwrap();
        let base = base_rx.recv().unwrap().result.unwrap();
        let hot = hot_rx.recv().unwrap().result.unwrap();
        let pinned_logits = pinned_rx.recv().unwrap().result.unwrap();
        assert!(base.iter().all(|x| x.is_finite()));
        assert_ne!(base, hot, "per-request scale override was dropped");
        assert_eq!(base, pinned_logits, "explicit default scale must match None");
        // And the blocking helper carries the spec too.
        let again = c.infer_spec(tokens, false, Some(0.02)).unwrap().result.unwrap();
        assert_eq!(again, hot);
        c.shutdown();
    }

    #[test]
    fn unmaskable_method_rejects_scale_override_individually() {
        // Nystrom's encoder degrades non-full specs wholesale to FULL,
        // which would silently drop a per-request scale — such members
        // must be rejected per request (their scale-free co-requests
        // still serve), mirroring the causal rejection policy.
        let c = native_coordinator("nystrom", 1);
        let scaled_rx = c.submit_spec(vec![7i32; 32], false, Some(0.05)).unwrap();
        let plain_rx = c.submit_with(vec![7i32; 32], false).unwrap();
        let scaled = scaled_rx.recv().unwrap();
        let plain = plain_rx.recv().unwrap();
        let err = scaled.result.unwrap_err();
        assert!(err.message().contains("scale"), "unexpected error: {err}");
        assert!(plain.result.is_ok(), "scale-free co-request must still serve");
        c.shutdown();
    }

    #[test]
    fn padding_is_masked_out_of_native_serving() {
        // The same live tokens served through different bucket sizes
        // (32-pad vs 64-pad) must produce near-identical logits now
        // that key_len masks the pad tail out of attention and pooling.
        let mk = |buckets: Vec<usize>| {
            let cfg = ServeConfig {
                method: "lln".into(),
                buckets,
                native_fallback: true,
                ..Default::default()
            };
            Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap()
        };
        let live: Vec<i32> = (0..20).map(|i| 4 + i % 11).collect();
        let c32 = mk(vec![32]);
        let small = c32.infer(live.clone()).unwrap().result.unwrap();
        c32.shutdown();
        let c64 = mk(vec![64]);
        let big = c64.infer(live).unwrap().result.unwrap();
        c64.shutdown();
        for (x, y) in small.iter().zip(&big) {
            assert!((x - y).abs() < 1e-4, "bucket choice leaked into logits: {small:?} vs {big:?}");
        }
    }

    // -- decode sessions ----------------------------------------------------

    #[test]
    fn decode_session_streams_tokens_matching_the_causal_forward() {
        // Stepping a session token-by-token must reproduce the per-row
        // logits of the full causal batch forward over the same tokens
        // (bitwise for LLN's prefix-state path).
        let c = native_coordinator("lln", 1);
        let tokens: Vec<i32> = (0..24).map(|i| 4 + (i % 13) as i32).collect();
        let mut session = c.open_session(32).unwrap();
        let rx = session.stream(&tokens).unwrap();
        let got: Vec<Vec<f32>> = (0..tokens.len())
            .map(|i| {
                let resp = rx.recv().unwrap();
                resp.result.unwrap_or_else(|e| panic!("step {i}: {e}"))
            })
            .collect();
        // Reference: the same encoder the bucket-32 workers built.
        let enc = NativeEncoder::new(
            Method::Lln,
            super::super::native::NATIVE_D_MODEL,
            super::super::native::NATIVE_NUM_CLASSES,
            32,
            super::super::native::NATIVE_SEED,
            &crate::config::ComputeConfig::default(),
        );
        let want = enc.decode_logits_reference(&tokens);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "decode step {i} diverged from the causal forward row");
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.sessions_opened, 1);
        assert_eq!(st.decode_steps, tokens.len() as u64);
        drop(st);
        session.close();
        c.shutdown();
    }

    #[test]
    fn interleaved_sessions_do_not_contaminate_each_other() {
        // Two co-batched sessions stepped in lockstep must produce
        // exactly what each produces when decoded alone.
        let toks_a: Vec<i32> = (0..16).map(|i| 5 + (i % 7) as i32).collect();
        let toks_b: Vec<i32> = (0..16).map(|i| 40 + (i % 11) as i32).collect();

        let solo = |tokens: &[i32]| -> Vec<Vec<f32>> {
            let c = native_coordinator("lln", 1);
            let mut s = c.open_session(32).unwrap();
            let out = tokens.iter().map(|&t| s.step(t).unwrap()).collect();
            s.close();
            c.shutdown();
            out
        };
        let want_a = solo(&toks_a);
        let want_b = solo(&toks_b);

        // Interleave through one coordinator with two workers draining
        // the same bucket queue.
        let c = native_coordinator("lln", 2);
        let mut sa = c.open_session(32).unwrap();
        let mut sb = c.open_session(32).unwrap();
        for i in 0..toks_a.len() {
            let la = sa.step(toks_a[i]).unwrap();
            let lb = sb.step(toks_b[i]).unwrap();
            assert_eq!(la, want_a[i], "session A step {i} contaminated");
            assert_eq!(lb, want_b[i], "session B step {i} contaminated");
        }
        sa.close();
        sb.close();
        c.shutdown();
    }

    #[test]
    fn decode_sessions_co_batch_with_prefill_traffic() {
        // Mixed traffic: a decode session streaming while prefill
        // requests flow through the same bucket queue.
        let c = native_coordinator("softmax", 1);
        let mut session = c.open_session(30).unwrap();
        let mut rxs = Vec::new();
        let mut step_rxs = Vec::new();
        for i in 0..10 {
            rxs.push(c.submit(vec![4 + i as i32; 20]).unwrap());
            step_rxs.push(session.submit_step(7 + i as i32).unwrap());
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        for (i, rx) in step_rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            let logits = resp.result.unwrap_or_else(|e| panic!("step {i}: {e}"));
            assert_eq!(logits.len(), 4);
            assert!(logits.iter().all(|x| x.is_finite()));
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        // 10 prefill + 10 decode steps + 1 session open (opens are a
        // payload class of their own and count as completed work).
        assert_eq!(st.completed, 21);
        assert_eq!(st.decode_steps, 10);
        assert_eq!(st.class(PayloadClass::SessionOpen).completed, 1);
        assert_eq!(st.class(PayloadClass::DecodeStep).completed, 10);
        assert_eq!(st.class(PayloadClass::PrefillShort).completed, 10);
        drop(st);
        session.close();
        c.shutdown();
    }

    #[test]
    fn unmaskable_method_rejects_session_open_as_err() {
        // Nystrom cannot decode causally: the open must come back as a
        // clean Err response (no worker panic), and the worker must
        // keep serving afterwards.
        let c = native_coordinator("nystrom", 1);
        let err = c.open_session(32).unwrap_err();
        let msg = format!("{err}");
        assert!(
            msg.contains("causal") || msg.contains("decode"),
            "unexpected open error: {msg}"
        );
        // The same worker still serves bidirectional prefill traffic.
        let resp = c.infer(vec![7i32; 32]).unwrap();
        assert!(resp.result.is_ok(), "worker died after rejecting a session open");
        c.shutdown();
    }

    #[test]
    fn session_respects_bucket_capacity_client_side() {
        let c = native_coordinator("elu", 1);
        let mut s = c.open_session(8).unwrap(); // routes to bucket 32
        assert_eq!(s.capacity(), 32);
        for i in 0..32 {
            s.step(4 + i as i32).unwrap();
        }
        let err = s.step(5).unwrap_err();
        assert!(format!("{err}").contains("bucket length"), "{err}");
        s.close();
        c.shutdown();
    }

    #[test]
    fn autoscaler_serves_bursts_within_the_band() {
        // A burst through a [1, 3] band: everything completes, any
        // scale-ups stay within the ceiling.
        let cfg = ServeConfig {
            method: "lln".into(),
            queue_capacity: 128,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers: 1,
            max_workers: 3,
            buckets: vec![32],
            native_fallback: true,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap();
        let rxs: Vec<_> = (0..40).map(|i| c.submit(vec![4 + i as i32 % 9; 24]).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.completed, 40);
        // The scaler can only ever add up to ceiling - floor workers at
        // a time, but retirements may free room for later spawns; the
        // invariant worth pinning without timing races is that scaling
        // happened within the configured band's reach.
        assert!(st.workers_spawned <= 40, "runaway scaler: {}", st.workers_spawned);
        drop(st);
        c.shutdown();
    }

    #[test]
    fn catch_panic_routes_payloads_as_errors() {
        assert_eq!(catch_panic(|| 7).unwrap(), 7);
        let e = catch_panic(|| panic!("boom {}", 3)).unwrap_err();
        assert!(e.contains("boom 3"), "{e}");
        let e = catch_panic(|| panic!("static boom")).unwrap_err();
        assert!(e.contains("static boom"), "{e}");
    }

    // -- per-class stats ----------------------------------------------------

    #[test]
    fn batch_size_window_stays_bounded() {
        // Regression: batch_sizes grew one entry per drained batch for
        // the life of the server.  The ring must cap at BATCH_WINDOW
        // while the mean stays exact over the whole lifetime.
        let mut st = ServeStats::default();
        let n = BATCH_WINDOW + 1234;
        for i in 0..n {
            st.record_batch(1 + (i % 3));
        }
        assert!(st.batch_sizes.len() <= BATCH_WINDOW, "unbounded: {}", st.batch_sizes.len());
        assert_eq!(st.batches, n as u64);
        let exact: f64 =
            (0..n).map(|i| (1 + i % 3) as f64).sum::<f64>() / n as f64;
        assert!((st.mean_batch_size() - exact).abs() < 1e-12);
    }

    #[test]
    fn class_window_wraps_preserving_recency() {
        // Regression: the latency window's write index used to be
        // `completed % window`, but `completed` also advanced on paths
        // that never recorded a latency, so wraparound skipped slots
        // and overwrote fresh samples.  The window now owns its cursor.
        let mut w = ClassWindow::with_capacity(8);
        for i in 0..20 {
            w.record(i as f64);
        }
        assert_eq!(w.completed, 20);
        assert_eq!(w.samples().len(), 8);
        let mut got: Vec<f64> = w.samples().to_vec();
        got.sort_by(|a, b| a.total_cmp(b));
        // Exactly the 8 most recent samples survive.
        assert_eq!(got, (12..20).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn cursor_survives_foreign_completed_bumps() {
        // The shared `completed` counter moving (e.g. another payload
        // class completing) must not disturb a class's write cursor.
        let mut st = ServeStats::default();
        for i in 0..4 {
            st.record(PayloadClass::PrefillShort, 10.0 + i as f64);
            st.record(PayloadClass::DecodeStep, 0.1); // advances completed
        }
        let w = st.class(PayloadClass::PrefillShort);
        assert_eq!(w.samples(), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(st.completed, 8);
    }

    #[test]
    fn per_class_windows_do_not_cross_contaminate() {
        let mut st = ServeStats::default();
        for _ in 0..100 {
            st.record(PayloadClass::PrefillShort, 1.0);
            st.record(PayloadClass::PrefillLong, 100.0);
            st.record(PayloadClass::DecodeStep, 0.01);
        }
        assert_eq!(st.class_percentile(PayloadClass::PrefillShort, 99.0), 1.0);
        assert_eq!(st.class_percentile(PayloadClass::PrefillLong, 50.0), 100.0);
        assert_eq!(st.class_percentile(PayloadClass::DecodeStep, 99.0), 0.01);
        // The empty class reads 0.0, not a panic.
        assert_eq!(st.class_percentile(PayloadClass::SessionOpen, 99.0), 0.0);
        // The legacy mixed view merges all classes.
        let mixed = st.mixed_percentile(50.0);
        assert!(mixed >= 0.01 && mixed <= 100.0);
    }

    // -- paged KV sessions --------------------------------------------------

    /// Stream `tokens` through one decode session on `c`, returning the
    /// per-step logits.
    fn stream_all(c: &Coordinator, tokens: &[i32]) -> Vec<Vec<f32>> {
        let mut s = c.open_session(32).unwrap();
        let rx = s.stream(tokens).unwrap();
        let out = (0..tokens.len())
            .map(|i| {
                rx.recv().unwrap().result.unwrap_or_else(|e| panic!("step {i}: {e}"))
            })
            .collect();
        s.close();
        out
    }

    #[test]
    fn paged_session_replay_is_bitwise_identical_to_unpaged() {
        // The acceptance bar: the same token stream through a paged
        // softmax KV session and a legacy unpaged one must produce
        // bitwise-identical logits at every step.
        let tokens: Vec<i32> = (0..28).map(|i| 4 + (i % 13) as i32).collect();
        let unpaged = native_coordinator("softmax", 1);
        let want = stream_all(&unpaged, &tokens);
        unpaged.shutdown();

        let cfg = ServeConfig {
            method: "softmax".into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers: 1,
            buckets: vec![32, 64],
            native_fallback: true,
            page_pool_pages: 64, // roomy: no eviction on this path
            page_tokens: 4,
            ..Default::default()
        };
        let paged =
            Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap();
        let got = stream_all(&paged, &tokens);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "paged replay diverged at step {i}");
        }
        paged.shutdown();
    }

    #[test]
    fn paged_sessions_share_a_budget_smaller_than_their_total_kv() {
        // Three live sessions over a pool that can hold barely more
        // than one session's KV: eviction + recompute-on-miss must keep
        // every session bitwise-correct while the pool never exceeds
        // its byte budget.
        let tokens_for = |salt: i32| -> Vec<i32> {
            (0..24).map(|i| 4 + (i + salt) % 17).collect()
        };
        let solo = native_coordinator("softmax", 1);
        let wants: Vec<Vec<Vec<f32>>> =
            (0..3).map(|s| stream_all(&solo, &tokens_for(s))).collect();
        solo.shutdown();

        let cfg = ServeConfig {
            method: "softmax".into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers: 1, // one worker: steps serialize, evictions interleave
            buckets: vec![32, 64],
            native_fallback: true,
            page_pool_pages: 8, // 8 pages * 4 tokens = one 32-token session
            page_tokens: 4,
            recompute_on_miss: true,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap();
        let pool = c.page_pool().expect("pool configured").clone();
        let toks: Vec<Vec<i32>> = (0..3).map(tokens_for).collect();
        let mut sessions: Vec<DecodeSession> =
            (0..3).map(|_| c.open_session(32).unwrap()).collect();
        // Interleave: each round steps every session once, so sessions
        // keep stealing each other's pages back.
        for i in 0..24 {
            for (s, sess) in sessions.iter_mut().enumerate() {
                let logits = sess.step(toks[s][i]).unwrap();
                assert_eq!(
                    logits, wants[s][i],
                    "paged session {s} diverged at step {i} under eviction pressure"
                );
                assert!(
                    pool.held_bytes() <= pool.budget_bytes(),
                    "pool exceeded its budget: {} > {}",
                    pool.held_bytes(),
                    pool.budget_bytes()
                );
            }
        }
        let counters = pool.counters();
        assert!(counters.evicted > 0, "three sessions over a one-session budget must evict");
        assert!(counters.recomputed > 0, "evicted pages must be recomputed on touch");
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.pages_evicted, counters.evicted);
        assert_eq!(st.pages_recomputed, counters.recomputed);
        drop(st);
        for s in sessions.drain(..) {
            s.close();
        }
        c.shutdown();
    }

    #[test]
    fn int8_kv_pool_shrinks_pages_and_survives_eviction_bitwise() {
        // `[compute] precision = "int8-kv"`: pages hold quantized K/V,
        // so the same pool budget covers >2x the tokens, and because
        // quantization is a pure per-row function, recompute-on-miss
        // refills must reproduce the exact bytes — a tight (evicting)
        // pool serves bitwise-identically to a roomy one.
        let tokens_for = |salt: i32| -> Vec<i32> {
            (0..24).map(|i| 4 + (i + salt) % 17).collect()
        };
        let cfg_at = |pages: usize| ServeConfig {
            method: "softmax".into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers: 1,
            buckets: vec![32, 64],
            native_fallback: true,
            page_pool_pages: pages,
            page_tokens: 4,
            recompute_on_miss: true,
            compute: crate::config::ComputeConfig {
                precision: crate::lowp::Precision::Int8Kv,
                ..Default::default()
            },
            ..Default::default()
        };
        let roomy = Coordinator::start(
            cfg_at(64), // no eviction at this budget
            std::path::Path::new("definitely-not-artifacts"),
        )
        .unwrap();
        let wants: Vec<Vec<Vec<f32>>> =
            (0..3).map(|s| stream_all(&roomy, &tokens_for(s))).collect();
        roomy.shutdown();

        let tight = Coordinator::start(
            cfg_at(8), // one 32-token session's worth: forces eviction
            std::path::Path::new("definitely-not-artifacts"),
        )
        .unwrap();
        let pool = tight.page_pool().expect("pool configured").clone();
        assert_eq!(pool.precision(), crate::lowp::Precision::Int8Kv);
        let f32_pool = PagePool::new(
            8,
            4,
            super::super::native::NATIVE_D_MODEL,
            super::super::native::NATIVE_D_MODEL,
        );
        assert!(
            2 * pool.page_bytes() <= f32_pool.page_bytes(),
            "int8-kv pages must be less than half the f32 size: {} vs {}",
            pool.page_bytes(),
            f32_pool.page_bytes()
        );
        let toks: Vec<Vec<i32>> = (0..3).map(tokens_for).collect();
        let mut sessions: Vec<DecodeSession> =
            (0..3).map(|_| tight.open_session(32).unwrap()).collect();
        for i in 0..24 {
            for (s, sess) in sessions.iter_mut().enumerate() {
                let logits = sess.step(toks[s][i]).unwrap();
                assert_eq!(
                    logits, wants[s][i],
                    "int8 paged session {s} diverged at step {i} under eviction"
                );
            }
        }
        assert!(pool.counters().evicted > 0, "tight int8 pool must evict");
        for s in sessions.drain(..) {
            s.close();
        }
        tight.shutdown();
    }

    // -- sharding, eviction, admission --------------------------------------

    #[test]
    fn sharded_front_serves_prefill_and_sessions() {
        let cfg = ServeConfig {
            method: "lln".into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers: 1,
            shards: 3,
            buckets: vec![32, 64],
            native_fallback: true,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap();
        // Sessions pin to their ring shard; prefill goes least-loaded.
        let tokens: Vec<i32> = (0..16).map(|i| 4 + i % 9).collect();
        let mut sessions: Vec<DecodeSession> =
            (0..4).map(|_| c.open_session(32).unwrap()).collect();
        let rxs: Vec<_> = (0..24).map(|i| c.submit(vec![5 + i as i32 % 7; 20]).unwrap()).collect();
        for (i, sess) in sessions.iter_mut().enumerate() {
            for &t in &tokens {
                let logits = sess.step(t).unwrap();
                assert!(logits.iter().all(|x| x.is_finite()), "session {i}");
            }
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.class(PayloadClass::PrefillShort).completed, 24);
        assert_eq!(st.class(PayloadClass::SessionOpen).completed, 4);
        assert_eq!(st.decode_steps, 64);
        drop(st);
        for s in sessions.drain(..) {
            s.close();
        }
        c.shutdown();
    }

    #[test]
    fn slot_budget_evicts_the_oldest_idle_session() {
        let cfg = ServeConfig {
            method: "lln".into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers: 1,
            max_sessions: 2,
            buckets: vec![32, 64],
            native_fallback: true,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap();
        let mut a = c.open_session(32).unwrap();
        let mut b = c.open_session(32).unwrap();
        a.step(5).unwrap();
        b.step(6).unwrap();
        a.step(7).unwrap(); // b is now the oldest-idle session
        let mut d = c.open_session(32).unwrap(); // third slot: evicts b
        d.step(8).unwrap();
        let err = b.step(9).unwrap_err();
        assert!(
            format!("{err}").contains("unknown decode session"),
            "evicted session should be gone: {err}"
        );
        let live = a.step(10).unwrap();
        assert!(live.iter().all(|x| x.is_finite()), "recently-active session must survive");
        assert_eq!(c.stats().lock().unwrap().sessions_evicted, 1);
        a.close();
        b.close();
        d.close();
        c.shutdown();
    }

    #[test]
    fn admission_budget_rejects_oversized_class_deterministically() {
        // An 8-token/s short budget has a burst capacity of 8 tokens: a
        // 20-token request can never be admitted, while decode-session
        // traffic (exempt: a live session holds its slot) still flows.
        let cfg = ServeConfig {
            method: "lln".into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers: 1,
            short_tokens_per_s: 8.0,
            buckets: vec![32, 64],
            native_fallback: true,
            ..Default::default()
        };
        let c = Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap();
        for _ in 0..3 {
            let err = c.submit(vec![7i32; 20]).unwrap_err();
            assert!(format!("{err}").contains("admission"), "{err}");
        }
        assert_eq!(c.stats().lock().unwrap().rejected, 3);
        // Small requests fit the burst capacity (a rejection never
        // deducts tokens, so the budget is still whole).
        let ok = c.infer(vec![7i32; 4]).unwrap();
        assert!(ok.result.is_ok());
        // Decode sessions are budget-exempt.
        let mut s = c.open_session(32).unwrap();
        for i in 0..16 {
            s.step(4 + i).unwrap();
        }
        s.close();
        c.shutdown();
    }

    #[test]
    fn serves_single_request() {
        let Some(c) = coordinator() else { return };
        let mut gen = GlueGen::new(GlueTask::Sst2, 512, 128, 1);
        let (tokens, _) = gen.example();
        let resp = c.infer(tokens).unwrap();
        let logits = resp.result.unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        c.shutdown();
    }

    #[test]
    fn serves_concurrent_burst_with_batching() {
        let Some(c) = coordinator() else { return };
        let mut gen = GlueGen::new(GlueTask::Qqp, 512, 128, 2);
        let rxs: Vec<_> = (0..24).map(|_| c.submit(gen.example().0).unwrap()).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "{:?}", resp.result);
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.completed, 24);
        assert!(st.mean_batch_size() > 1.0, "burst should batch: {}", st.mean_batch_size());
        drop(st);
        c.shutdown();
    }

    #[test]
    fn routes_long_sequences_to_big_bucket() {
        let Some(c) = coordinator() else { return };
        let tokens = vec![special::CLS; 300]; // > 128, <= 512
        let resp = c.infer(tokens).unwrap();
        assert!(resp.result.is_ok());
        c.shutdown();
    }

    #[test]
    fn rejects_over_length() {
        let Some(c) = coordinator() else { return };
        let err = c.submit(vec![special::CLS; 1000]).unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
        c.shutdown();
    }

    #[test]
    fn pjrt_path_rejects_session_opens_loudly() {
        let Some(c) = coordinator() else { return };
        let err = c.open_session(64).unwrap_err();
        assert!(format!("{err}").contains("force_native"), "{err}");
        c.shutdown();
    }

    // -- chaos: fault injection, deadlines, supervision, failover -----------

    use crate::config::FaultsConfig;

    /// A native single-shard front with the given fault plan armed.
    fn resilient_cfg(faults: FaultsConfig) -> ServeConfig {
        ServeConfig {
            method: "softmax".into(),
            queue_capacity: 64,
            max_batch: 4,
            batch_timeout_ms: 3,
            workers: 1,
            buckets: vec![32, 64],
            native_fallback: true,
            faults,
            ..Default::default()
        }
    }

    fn start_native(cfg: ServeConfig) -> Coordinator {
        Coordinator::start(cfg, std::path::Path::new("definitely-not-artifacts")).unwrap()
    }

    #[test]
    fn injected_exec_panic_is_retried_to_success() {
        let faults =
            FaultsConfig { exec_panic_start: 1, exec_panic_limit: 1, ..Default::default() };
        let cfg = ServeConfig { retry_max: 2, retry_backoff_ms: 1, ..resilient_cfg(faults) };
        let c = start_native(cfg);
        // The first executor call panics (injected); the retry budget
        // re-executes the batch and the client sees a clean Ok.
        let resp = c.infer(vec![7i32; 16]).unwrap();
        assert!(resp.result.is_ok(), "retry must absorb the injected panic: {:?}", resp.result);
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert!(st.retries >= 1, "the recovery must be visible in the retry counter");
        assert!(st.faults_injected >= 1);
        assert_eq!(st.errors, 0, "a retried batch is not an error");
        assert_eq!(st.completed, 1);
        drop(st);
        c.shutdown();
    }

    #[test]
    fn injected_exec_panic_without_retry_is_one_terminal_failure() {
        let faults =
            FaultsConfig { exec_panic_start: 1, exec_panic_limit: 1, ..Default::default() };
        let c = start_native(resilient_cfg(faults)); // retry_max = 0
        let rx = c.submit(vec![7i32; 16]).unwrap();
        let resp = rx.recv().unwrap();
        let err = resp.result.unwrap_err();
        assert_eq!(err.kind(), "failed");
        assert!(err.message().contains("injected"), "{err}");
        assert!(rx.try_recv().is_err(), "exactly one terminal response per request");
        // The fault point is spent (limit 1): the next request serves.
        assert!(c.infer(vec![8i32; 16]).unwrap().result.is_ok());
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.errors, 1);
        assert_eq!(st.retries, 0);
        drop(st);
        c.shutdown();
    }

    #[test]
    fn supervisor_respawns_a_killed_worker_and_the_request_completes() {
        let faults =
            FaultsConfig { kill_worker_start: 1, kill_worker_limit: 1, ..Default::default() };
        let c = start_native(resilient_cfg(faults));
        // The first item kills its worker; the dying worker requeues
        // the item, the supervisor respawns the floor, and the fresh
        // worker serves it — the client just sees a slower Ok.
        let resp = c.infer(vec![7i32; 16]).unwrap();
        assert!(resp.result.is_ok(), "{:?}", resp.result);
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert!(st.worker_restarts >= 1, "the supervisor must have respawned the floor");
        assert!(st.faults_injected >= 1);
        assert_eq!(st.completed, 1);
        drop(st);
        assert!(c.dead_shards().is_empty(), "a respawned floor is not a dead shard");
        c.shutdown();
    }

    #[test]
    fn expired_deadline_is_shed_queue_side_with_a_terminal_response() {
        // The injected 40 ms worker delay sits between pickup and the
        // drain-side deadline check, so the 5 ms deadline is expired by
        // the time the worker would execute — shed, never executed.
        let faults = FaultsConfig {
            delay_start: 1,
            delay_limit: 1,
            delay_ms: 40,
            ..Default::default()
        };
        let cfg = ServeConfig { default_deadline_ms: 5, ..resilient_cfg(faults) };
        let c = start_native(cfg);
        let rx = c.submit(vec![7i32; 16]).unwrap();
        let resp = rx.recv().unwrap();
        let err = resp.result.unwrap_err();
        assert_eq!(err.kind(), "deadline-exceeded", "{err}");
        assert!(rx.try_recv().is_err(), "exactly one terminal response");
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.deadline_drops, 1);
        assert_eq!(st.completed, 0);
        assert_eq!(st.errors, 0, "shed load must not be laundered as executor errors");
        drop(st);
        // The delay point is spent: a roomy deadline now serves fine.
        let rx = c.submit_deadline(vec![8i32; 16], false, None, Some(5_000)).unwrap();
        assert!(rx.recv().unwrap().result.is_ok());
        c.shutdown();
    }

    #[test]
    fn admission_rejects_when_projected_wait_exceeds_the_deadline() {
        let c = start_native(resilient_cfg(FaultsConfig::default()));
        // Prime the short-prefill class with 50 ms batch history so the
        // projected wait has something to stand on.
        c.stats().lock().unwrap().record(PayloadClass::PrefillShort, 50.0);
        let err = c.submit_deadline(vec![7i32; 16], false, None, Some(2)).unwrap_err();
        assert!(format!("{err}").contains("projected queue wait"), "{err}");
        assert_eq!(c.stats().lock().unwrap().rejected, 1);
        // A roomy deadline admits and serves.
        let rx = c.submit_deadline(vec![7i32; 16], false, None, Some(5_000)).unwrap();
        assert!(rx.recv().unwrap().result.is_ok());
        c.shutdown();
    }

    #[test]
    fn thrash_guard_sheds_new_session_opens_under_page_churn() {
        let cfg = ServeConfig {
            page_pool_pages: 8, // one 32-token session's worth
            page_tokens: 4,
            recompute_on_miss: true,
            thrash_shed_ratio: 0.5,
            ..resilient_cfg(FaultsConfig::default())
        };
        let c = start_native(cfg);
        // Three sessions over a one-session page budget: every step
        // evicts + recomputes, driving churn-per-step far above 0.5.
        let mut sessions: Vec<DecodeSession> =
            (0..3).map(|_| c.open_session(32).unwrap()).collect();
        for i in 0..24 {
            for (s, sess) in sessions.iter_mut().enumerate() {
                sess.step(4 + ((i + s) % 17) as i32).unwrap();
            }
        }
        let err = c.open_session(32).unwrap_err();
        assert!(format!("{err}").contains("thrash guard"), "{err}");
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.thrash_sheds, 1);
        assert!(st.rejected >= 1);
        drop(st);
        // Live sessions keep serving: degradation sheds *new* load only.
        for (s, sess) in sessions.iter_mut().enumerate() {
            assert!(sess.step(5 + s as i32).is_ok(), "live session {s} must survive the shed");
        }
        for s in sessions.drain(..) {
            s.close();
        }
        c.shutdown();
    }

    #[test]
    fn chaos_shard_kill_fails_over_sessions_bit_exactly() {
        // The acceptance bar (chaos plan: an executor panic, a worker
        // delay, and a whole shard killed mid-decode): every request
        // gets exactly one terminal response, and a failed-over
        // session's logits — confirmed history replayed onto a
        // surviving shard — are bitwise identical to an unfaulted solo
        // run of the same tokens.
        let tokens: Vec<i32> = (0..14).map(|i| 4 + (i % 13) as i32).collect();
        let solo = native_coordinator("softmax", 1);
        let want = stream_all(&solo, &tokens);
        solo.shutdown();

        // Session ids are 1 and 2 (opened first, ids start at 1); kill
        // the shard hosting session 1 so failover is always exercised.
        let killed = HashRing::new(2).route(1);
        let faults = FaultsConfig {
            // Items: open A = 1, open B = 2, prefill p1 = 3, p2 = 4,
            // then interleaved steps.  Exec calls count prefill batch
            // executions only: p1 = call 1, p2 = call 2 (panics, retry
            // recovers).  The delay lands on item 4 (p2's pickup).
            // Item 10 (the sixth step) latches the shard kill.
            exec_panic_start: 2,
            exec_panic_limit: 1,
            delay_start: 4,
            delay_limit: 1,
            delay_ms: 10,
            kill_shard: killed as i64,
            kill_shard_at: 10,
            ..Default::default()
        };
        let cfg = ServeConfig {
            shards: 2,
            retry_max: 2,
            retry_backoff_ms: 1,
            ..resilient_cfg(faults)
        };
        let c = start_native(cfg);
        let mut sa = c.open_session(32).unwrap();
        let mut sb = c.open_session(32).unwrap();
        assert_eq!(sa.shard(), killed, "session 1 must start on the to-be-killed shard");

        // Two prefills while the executors are still healthy-ish: p2
        // rides the injected panic + retry.  Exactly one terminal
        // response each.
        for salt in [7i32, 8] {
            let rx = c.submit(vec![salt; 16]).unwrap();
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "prefill must survive the chaos plan: {:?}", resp.result);
            assert!(rx.try_recv().is_err(), "exactly one terminal response");
        }

        // Serial decode on both sessions through the shard kill: a
        // failed step triggers failover, then the same token is
        // resubmitted against the restored (fresh-lineage) state.
        let mut got_a: Vec<Vec<f32>> = Vec::new();
        let mut got_b: Vec<Vec<f32>> = Vec::new();
        let mut restored = 0u64;
        for (i, &t) in tokens.iter().enumerate() {
            for (sess, got) in [(&mut sa, &mut got_a), (&mut sb, &mut got_b)] {
                let logits = match sess.step(t) {
                    Ok(l) => l,
                    Err(first) => {
                        c.restore_session(sess).unwrap_or_else(|e| {
                            panic!("failover after step {i} failed ({first:#}): {e:#}")
                        });
                        restored += 1;
                        sess.step(t).unwrap_or_else(|e| {
                            panic!("restored session must serve step {i}: {e:#}")
                        })
                    }
                };
                got.push(logits);
            }
        }
        assert!(restored >= 1, "the shard kill must force at least one failover");
        assert_eq!(c.dead_shards(), vec![killed]);
        assert_ne!(sa.shard(), killed, "session 1 must have moved off the dead shard");
        for (i, (g, w)) in got_a.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "session A diverged at step {i} after failover");
        }
        for (i, (g, w)) in got_b.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "session B diverged at step {i}");
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.sessions_restored, restored);
        assert!(st.retries >= 1, "the injected exec panic must have been retried");
        assert!(st.faults_injected >= 3, "panic + delay + shard kill: {}", st.faults_injected);
        drop(st);
        sa.close();
        sb.close();
        c.shutdown();
    }

    #[test]
    fn pipelined_sessions_refuse_failover_instead_of_guessing() {
        let c = start_native(resilient_cfg(FaultsConfig::default()));
        let mut s = c.open_session(32).unwrap();
        s.step(5).unwrap();
        let rx = s.stream(&[6, 7]).unwrap();
        for _ in 0..2 {
            rx.recv().unwrap().result.unwrap();
        }
        let err = c.restore_session(&mut s).unwrap_err();
        assert!(format!("{err}").contains("pipelined"), "{err}");
        s.close();
        c.shutdown();
    }

    #[test]
    fn restore_onto_the_same_ring_replays_a_poison_free_state() {
        // Failover is also the poison-recovery path on a healthy ring:
        // restoring replays the confirmed history onto a fresh state
        // lineage and the session continues bit-exactly.
        let tokens: Vec<i32> = (0..10).map(|i| 4 + (i % 13) as i32).collect();
        let solo = native_coordinator("softmax", 1);
        let want = stream_all(&solo, &tokens);
        solo.shutdown();

        let c = start_native(resilient_cfg(FaultsConfig::default()));
        let mut s = c.open_session(32).unwrap();
        let mut got: Vec<Vec<f32>> = Vec::new();
        for &t in &tokens[..5] {
            got.push(s.step(t).unwrap());
        }
        c.restore_session(&mut s).unwrap();
        for &t in &tokens[5..] {
            got.push(s.step(t).unwrap());
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "restored session diverged at step {i}");
        }
        assert_eq!(c.stats().lock().unwrap().sessions_restored, 1);
        s.close();
        c.shutdown();
    }
}
