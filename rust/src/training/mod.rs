//! Training orchestration: the Rust-side loop around either the AOT
//! train-step executables or the native backprop trainer (paper figs.
//! 8/9 pipelines; Table 1/3/4 task training).
//!
//! [`native::TrainStep`] is the seam: [`native::ArtifactStep`] wraps
//! the PJRT [`TrainDriver`] path, [`native::NativeStep`] backprops
//! through the native attention backends (fused recompute kernels, no
//! artifacts), and the experiment harnesses pick automatically.

pub mod driver;
pub mod metrics;
pub mod native;

pub use driver::{StepTelemetry, TrainDriver};
pub use metrics::MetricsLog;
pub use native::{Adam, ArtifactStep, NativeShape, NativeStep, Tape, TrainStep};
