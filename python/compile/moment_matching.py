"""Moment matching between LLN and Softmax attention (paper App. A.7).

The paper's broad-regime model (Prop 4.1):  sigma^2_lln = a * s~^2 + b
where  s~^2 = alpha^2 sigma_q^2 + beta^2 sigma_k^2.

`fit_broad_constants` estimates (a, b) once, offline, by injecting
uncorrelated Gaussian probes into the *explicit* LLN attention matrix
and linearly regressing the variance of its log-entries on s~^2 over
the broad range s~^2 in [1, 4].

At training/serving time alpha and beta are then derived from live
query/key standard deviations (Eq. 10):

    s~ = sqrt((sigma_q^2 sigma_k^2 - b) / a)
    alpha = s~ / (sqrt(2) sigma_q);   beta = s~ / (sqrt(2) sigma_k)

`alpha_beta` is jnp-traceable so the derivation lowers into the same
HLO as the train step — no Python on the hot path.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import ref

# Broad-regime probe grid for s~^2.  The paper targets sigma^2_sm in
# [1, 4] (fig. 5b); at head dim d=64 the LLN log-variance reaches that
# band for s~^2 in roughly [8, 28], where its growth is linear (Romeo's
# broad case) — fitting lower (Fenton's moderate, logarithmic regime)
# would underestimate the slope and break the match.
DEFAULT_SIGMA2_GRID = np.linspace(8.0, 28.0, 11)


def log_variance_of_attention(p, eps=1e-30):
    """Variance of log-entries of an attention matrix (the log-normal sigma^2)."""
    logs = jnp.log(jnp.maximum(p, eps))
    return jnp.var(logs)


def measure_lln_log_variance(sigma2_tilde, n=256, d=64, seed=0):
    """Measured sigma^2_lln for Gaussian probes at a given s~^2 (alpha=beta=1)."""
    rng = np.random.default_rng(seed)
    # alpha = beta = 1 and sigma_q = sigma_k  =>  s~^2 = 2 sigma^2.
    sigma = np.sqrt(sigma2_tilde / 2.0)
    q = jnp.asarray(rng.normal(0.0, sigma, size=(n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0.0, sigma, size=(n, d)), jnp.float32)
    p = ref.lln_attention_matrix(q, k, 1.0, 1.0)
    return float(log_variance_of_attention(p))


def measure_sm_log_variance(sigma_q, sigma_k, n=256, d=64, seed=0):
    """Measured sigma^2_sm (variance of log P^(SM)) for Gaussian probes."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0.0, sigma_q, size=(n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0.0, sigma_k, size=(n, d)), jnp.float32)
    p = ref.softmax_attention_matrix(q, k)
    return float(log_variance_of_attention(p))


def fit_broad_constants(sigma2_grid=DEFAULT_SIGMA2_GRID, n=256, d=64, seeds=(0, 1, 2)):
    """Least-squares fit of sigma^2_lln = a s~^2 + b over the broad regime.

    Returns (a, b) as python floats (baked into the AOT graphs).
    """
    xs, ys = [], []
    for s2 in sigma2_grid:
        for seed in seeds:
            xs.append(float(s2))
            ys.append(measure_lln_log_variance(s2, n=n, d=d, seed=seed))
    x = np.asarray(xs)
    y = np.asarray(ys)
    a, b = np.polyfit(x, y, 1)
    return float(a), float(b)


def alpha_beta(sigma_q, sigma_k, a, b, min_sigma2=1e-4):
    """Eq. 10: derive (alpha, beta) from live input stds.  jnp-traceable.

    sigma_q/sigma_k may be traced scalars; a, b are baked floats.
    """
    s2_sm = jnp.square(sigma_q) * jnp.square(sigma_k)
    s2_tilde = jnp.maximum((s2_sm - b) / a, min_sigma2)
    s_tilde = jnp.sqrt(s2_tilde)
    inv_sqrt2 = 1.0 / jnp.sqrt(jnp.float32(2.0))
    alpha = s_tilde * inv_sqrt2 / jnp.maximum(sigma_q, 1e-6)
    beta = s_tilde * inv_sqrt2 / jnp.maximum(sigma_k, 1e-6)
    return alpha, beta


def verify_matching(a, b, sigma_q=1.2, sigma_k=1.2, n=256, d=64, seed=7):
    """Diagnostic: relative error between matched LLN variance and SA variance."""
    al, be = alpha_beta(jnp.float32(sigma_q), jnp.float32(sigma_k), a, b)
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0.0, sigma_q, size=(n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0.0, sigma_k, size=(n, d)), jnp.float32)
    v_lln = float(log_variance_of_attention(ref.lln_attention_matrix(q, k, al, be)))
    v_sm = float(log_variance_of_attention(ref.softmax_attention_matrix(q, k)))
    return v_lln, v_sm, abs(v_lln - v_sm) / max(v_sm, 1e-9)
