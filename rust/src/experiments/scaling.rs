//! Table 2: memory usage and time per iteration vs sequence length.
//!
//! Time: measured wall-clock of the AOT attention executables on the
//! PJRT CPU client.  Memory: the analytic per-head activation model from
//! `attention::memory_model_bytes` scaled to the paper's RoBERTa-base
//! training setup (12 layers x 12 heads, fwd+bwd stash ~ 3x activations,
//! plus a fixed model/optimizer baseline), reported in GB alongside the
//! process RSS delta actually observed.
//!
//! The paper's "OOM" entries for softmax at N >= 8192 map to quadratic
//! blow-up here: we never exported those executables (the interpreter
//! would need the same O(N^2) buffers), and print OOM* in their place.

use anyhow::Result;

use super::maybe_write_csv;
use crate::attention::{backend_for, memory_model_bytes, AttnSpec, BackendParams, Method};
use crate::cli::Args;
use crate::rng::Pcg64;
use crate::runtime::{artifacts_dir, Engine, HostTensor};
use crate::tensor::Mat;
use crate::util::{current_rss_mb, print_table, Stopwatch};

const NS: [usize; 5] = [256, 1024, 4096, 8192, 16384];
const METHODS: [(&str, Method); 5] = [
    ("softmax", Method::Softmax),
    ("nystrom", Method::Nystrom),
    ("lln", Method::Lln),
    ("lln_diag", Method::LlnDiag),
    ("elu", Method::Elu),
];

/// Paper-scale memory extrapolation: RoBERTa-base-ish (L=12, H=12),
/// fwd+bwd activation stash factor 3, + 4 GB parameter/optimizer floor
/// (matches the paper's ~4 GB at N=512 baseline row).  Full
/// bidirectional attention — the paper's encoder setting.
fn model_memory_gb(method: Method, n: usize) -> f64 {
    let per_head = memory_model_bytes(method, n, 64, &AttnSpec::FULL) as f64;
    let layers_heads = 12.0 * 12.0;
    let stash = 3.0;
    4.0 + per_head * layers_heads * stash / 1e9
}

/// Native-registry fallback for Table 2's time column: measure each
/// method's `AttentionBackend::forward` instead of the AOT kernels.
/// Softmax past 4096 is skipped (same OOM regime the paper reports).
fn run_table2_native(args: &Args, iters: usize) -> Result<()> {
    let d = 64usize;
    let mut rng = Pcg64::seed(7);
    println!("   (artifacts absent: timing the native AttentionBackend registry)\n");
    let mut time_rows = Vec::new();
    let mut mem_rows = Vec::new();
    let mut csv = Vec::new();
    for (name, method) in METHODS {
        let bk = backend_for(method, BackendParams { alpha: 2.2, beta: 2.2, ..Default::default() });
        let mut trow = vec![name.to_string()];
        let mut mrow = vec![name.to_string()];
        for &n in &NS {
            let gb = model_memory_gb(method, n);
            mrow.push(if gb > 40.0 {
                "OOM".into()
            } else {
                format!("{gb:.1}")
            });
            if !method.is_linear() && n > 4096 {
                trow.push("OOM*".into());
                csv.push(format!("{name},{n},oom,{gb:.2}"));
                continue;
            }
            let q = Mat::gaussian(n, d, 1.0, &mut rng);
            let k = Mat::gaussian(n, d, 1.0, &mut rng);
            let v = Mat::gaussian(n, d, 1.0, &mut rng);
            bk.forward(&q, &k, &v, &AttnSpec::FULL); // warm
            let sw = Stopwatch::start();
            for _ in 0..iters {
                crate::bench::black_box(bk.forward(&q, &k, &v, &AttnSpec::FULL));
            }
            let secs = sw.elapsed_secs() / iters as f64;
            trow.push(if secs < 1.0 {
                format!("{:.0}ms", secs * 1e3)
            } else {
                format!("{secs:.2}s")
            });
            csv.push(format!("{name},{n},{secs:.5},{gb:.2}"));
        }
        time_rows.push(trow);
        mem_rows.push(mrow);
    }
    let mut headers: Vec<String> = vec!["method".into()];
    headers.extend(NS.iter().map(|n| n.to_string()));
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    println!("-- Memory [GB] (paper-scale model; card = 40 GB) --");
    print_table(&hrefs, &mem_rows);
    println!("\n-- Time per fwd [native backend, measured] --");
    print_table(&hrefs, &time_rows);
    maybe_write_csv(args, "table2", "method,n,secs,model_gb", &csv)?;
    Ok(())
}

pub fn run_table2(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let iters = args.get_usize("iters", 3)?;
    let mut engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(_) => {
            println!("== Table 2: memory + time vs sequence length ==");
            return run_table2_native(args, iters);
        }
    };
    let mut rng = Pcg64::seed(7);
    let d = 64usize;

    println!("== Table 2: memory + time vs sequence length ==");
    println!("   time = measured PJRT fwd of the AOT kernel (d={d}, {iters} iters)");
    println!("   mem  = analytic model @ paper scale (12L x 12H, fwd+bwd)\n");

    let mut time_rows = Vec::new();
    let mut mem_rows = Vec::new();
    let mut csv = Vec::new();
    for (name, method) in METHODS {
        let mut trow = vec![name.to_string()];
        let mut mrow = vec![name.to_string()];
        for &n in &NS {
            // Memory column (analytic; OOM past the paper's 40 GB card).
            let gb = model_memory_gb(method, n);
            mrow.push(if gb > 40.0 {
                "OOM".into()
            } else {
                format!("{gb:.1}")
            });

            // Time column (measured; softmax artifacts stop at 4096).
            let artifact = format!("attn_{name}_n{n}");
            if engine.manifest().artifact(&artifact).is_err() {
                trow.push("OOM*".into());
                csv.push(format!("{name},{n},oom,{gb:.2}"));
                continue;
            }
            let q = HostTensor::F32 {
                shape: vec![n, d],
                data: (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            };
            let k = HostTensor::F32 {
                shape: vec![n, d],
                data: (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            };
            let v = HostTensor::F32 {
                shape: vec![n, d],
                data: (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            };
            let inputs: Vec<HostTensor> = if name == "lln" || name == "lln_diag" {
                vec![q, k, v, HostTensor::scalar_f32(2.2), HostTensor::scalar_f32(2.2)]
            } else {
                vec![q, k, v]
            };
            // warmup (compile + first run)
            let rss0 = current_rss_mb();
            engine.execute(&artifact, &inputs)?;
            let sw = Stopwatch::start();
            for _ in 0..iters {
                engine.execute(&artifact, &inputs)?;
            }
            let secs = sw.elapsed_secs() / iters as f64;
            let rss_delta = (current_rss_mb() - rss0).max(0.0);
            trow.push(if secs < 1.0 {
                format!("{:.0}ms", secs * 1e3)
            } else {
                format!("{secs:.2}s")
            });
            csv.push(format!("{name},{n},{secs:.5},{gb:.2},{rss_delta:.1}"));
        }
        time_rows.push(trow);
        mem_rows.push(mrow);
    }

    let mut headers: Vec<String> = vec!["method".into()];
    headers.extend(NS.iter().map(|n| n.to_string()));
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    println!("-- Memory [GB] (paper-scale model; card = 40 GB) --");
    print_table(&hrefs, &mem_rows);
    println!("\n-- Time per fwd [measured] --");
    print_table(&hrefs, &time_rows);
    println!("\n* softmax kernels past 4096 are not exported: the O(N^2) buffers");
    println!("  are the paper's OOM — see EXPERIMENTS.md T2 notes.");
    println!("paper shape: softmax superlinear + OOM by 8k; LLN/Nystrom linear;");
    println!("LLN faster than Nystrom; +Diag a ~10-15% overhead.");
    maybe_write_csv(args, "table2", "method,n,secs,model_gb,rss_delta_mb", &csv)?;
    Ok(())
}
