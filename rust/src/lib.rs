//! # lln-attention — Linear Log-Normal Attention, full-system reproduction
//!
//! Reproduction of *"Linear Log-Normal Attention with Unbiased
//! Concentration"* (ICLR 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build-time Python)** — Pallas kernels + a RoBERTa-lite JAX
//!   encoder, AOT-lowered once to HLO-text artifacts (`python/compile`).
//! * **L3 (this crate)** — coordinator: serving router + dynamic batcher,
//!   the training driver, the paper's analysis instruments (temperature,
//!   entropy, spectral gap, log-normal fitting, moment matching), native
//!   CPU backends of every attention method, and the per-table/figure
//!   experiment harnesses.  Python is never on a request path.
//!
//! ## The `AttentionBackend` registry
//!
//! Every attention method is dispatched through one trait,
//! [`attention::AttentionBackend`] (`forward` / `explicit_matrix` /
//! `flops_model` / `name`), constructed from the
//! [`attention::backend_for`] registry.  Every entry point carries an
//! [`attention::AttnSpec`] — `causal` flag, optional `key_len` padding
//! mask, score `scale` — so kernels, serving, benches, and analysis
//! speak one mask vocabulary ([`attention::AttnSpec::FULL`] is the
//! bidirectional encoder setting).  Backends implement the *fast*
//! path — fused tiled streaming-softmax for the exact class
//! ([`attention::fused_softmax_attention_spec`], O(n·tile) memory, no
//! n×n score matrix; under causal it streams only the prefix tiles,
//! ~half the score work), register-blocked multi-threaded
//! matmul/softmax ([`tensor::micro`], [`tensor::Mat::par_matmul`],
//! [`tensor::Mat::par_matmul_t`], [`tensor::Mat::par_softmax_rows`]),
//! the chunked O(N) streaming linear-attention formulation
//! ([`attention::linear_attention_streamed`]) that accumulates the
//! (m, dv) KV state once instead of per row, and the causal O(N)
//! prefix-state recurrence ([`attention::linear_attention_causal`],
//! chunked with per-chunk state carry) for the decoder setting.  The
//! single-threaded free functions in [`attention::kernels`] (and the
//! `Mat::*_ref` scalar loops) stay as the reference, with
//! [`attention::softmax_attention_matrix_spec`] /
//! [`attention::linear_attention_matrix_spec`] as the dense *masked*
//! references; the property suite (`rust/tests/prop_kernels.rs`, built
//! on [`testkit`]) pins fast-vs-scalar parity,
//! forward-vs-explicit-matrix parity (full and masked),
//! row-stochasticity, and the future-keys-have-zero-influence causal
//! invariant across random shapes.  The serving coordinator, the
//! benches, and the experiment harnesses all call through the
//! registry — the coordinator batches padded variable-length requests
//! (each request's live length is its key mask; causal rides per
//! request via `Coordinator::submit_with` or `[compute] causal`), runs
//! token-by-token **decode sessions**
//! ([`coordinator::Coordinator::open_session`] →
//! [`coordinator::DecodeSession`], built on
//! [`attention::AttentionBackend::begin_decode`] /
//! [`attention::DecodeState`]: a KV cache for the exact class, the
//! O(d²) `Σ φ(k)vᵀ` prefix state for the linear class — O(1)/token,
//! bitwise-consistent with the chunked causal kernel), autoscales each
//! bucket's worker pool inside the `[serve] min_workers`/`max_workers`
//! band, and can fall back to a native-backend encoder
//! ([`coordinator::NativeEncoder`]) when PJRT artifacts are absent
//! (opt-in via `ServeConfig::native_fallback`; the `lln serve` demo and
//! its benches opt in automatically when artifacts are missing).
//!
//! To add a method: add the [`attention::Method`] variant, implement
//! `AttentionBackend` (honoring the spec, or `Method::supports_masking`
//! = false if the structure cannot), register it in `backend_for`, and
//! extend `EXPLICIT_METHODS` in `prop_kernels.rs` (or the
//! implicit-method property if it has no dense matrix).  ROADMAP.md
//! tracks this.
//!
//! The crate mirror of this image is offline, so several substrates that
//! would normally be dependencies are implemented here (see DESIGN.md §3):
//! [`cli`], [`config`], [`util::json`], [`rng`], [`tensor`], [`linalg`],
//! [`stats`], [`testkit`], [`bench`] — and the would-be external crates
//! `anyhow`, `rand_core`, and `xla` are vendored under `rust/vendor/`
//! (the `xla` crate as an API stub; PJRT execution is gated behind
//! [`runtime::artifacts_available`]).

pub mod analysis;
pub mod attention;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod faults;
pub mod linalg;
pub mod lowp;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod tensor;
pub mod testkit;
pub mod training;
pub mod util;

/// Default artifacts directory relative to the repo root / cwd.
pub const ARTIFACTS_DIR: &str = "artifacts";
