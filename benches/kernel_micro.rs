//! Bench: native Rust attention kernels (the analysis hot path) across
//! methods and sequence lengths — tracks the §Perf L3-native numbers.
//!
//! Two tiers per method:
//!   * scalar reference — the single-threaded `attention::kernels` free
//!     functions (what the parity suite pins everything against);
//!   * backend hot path — the `AttentionBackend` registry's fused /
//!     register-blocked, multi-threaded / chunk-streamed paths.
//! The speedup lines at the end are the acceptance signals: the
//! blocked+threaded backends must beat the scalar baseline at n=1024,
//! and the fused O(n·tile) softmax must beat the PR-1
//! `par_matmul_t`+`par_softmax_rows` pipeline by ≥ 2x at n=4096.
//!
//! Flags (after `cargo bench --bench kernel_micro --`):
//!   --json <path>   write the kernel trajectory as BENCH_kernels.json
//!   --tile <n>      fused-kernel K/V tile rows (0 = auto)
//!   --unroll <n>    fused-kernel query register block (0 = auto)

use lln::attention::{self as att, backend_for, AttnSpec, BackendParams, Method};
use lln::bench::{bench_arg, bench_arg_usize, run_attention_backend, run_kernel_bench, Bench};
use lln::rng::Pcg64;
use lln::tensor::{default_threads, Mat};

fn main() {
    let d = 64usize;
    let threads = default_threads();
    let tile = bench_arg_usize("tile").unwrap_or(0);
    let unroll = bench_arg_usize("unroll").unwrap_or(0);
    let full = AttnSpec::FULL;
    let mut rng = Pcg64::seed(1);
    let mut b = Bench::new();

    println!("== native attention kernels (d={d}, {threads} worker threads) ==");
    let mut speedups: Vec<(String, usize, f64)> = Vec::new();
    for n in [256usize, 1024] {
        let q = Mat::gaussian(n, d, 1.0, &mut rng);
        let k = Mat::gaussian(n, d, 1.0, &mut rng);
        let v = Mat::gaussian(n, d, 1.0, &mut rng);

        let t_sm_scalar =
            b.run(&format!("scalar softmax n={n}"), n as f64, || att::softmax_attention(&q, &k, &v))
                .mean();
        let sm = backend_for(Method::Softmax, BackendParams::default());
        let t_sm_backend = run_attention_backend(&mut b, sm.as_ref(), n, d, 2, &full);
        speedups.push(("softmax".into(), n, t_sm_scalar / t_sm_backend));

        let t_lln_scalar =
            b.run(&format!("scalar lln n={n}"), n as f64, || att::lln_attention(&q, &k, &v, 2.2, 2.2))
                .mean();
        let lln = backend_for(
            Method::Lln,
            BackendParams { alpha: 2.2, beta: 2.2, ..Default::default() },
        );
        let t_lln_backend = run_attention_backend(&mut b, lln.as_ref(), n, d, 3, &full);
        speedups.push(("lln".into(), n, t_lln_scalar / t_lln_backend));

        let t_diag_scalar = b
            .run(&format!("scalar lln_diag n={n}"), n as f64, || {
                att::lln_diag_attention(&q, &k, &v, 2.2, 2.2, 64)
            })
            .mean();
        let diag = backend_for(
            Method::LlnDiag,
            BackendParams { alpha: 2.2, beta: 2.2, ..Default::default() },
        );
        let t_diag_backend = run_attention_backend(&mut b, diag.as_ref(), n, d, 4, &full);
        speedups.push(("lln_diag".into(), n, t_diag_scalar / t_diag_backend));

        b.run(&format!("scalar elu n={n}"), n as f64, || att::elu_attention(&q, &k, &v));
        run_attention_backend(&mut b, att::default_backend(Method::Elu).as_ref(), n, d, 5, &full);
        if n <= 1024 {
            b.run(&format!("scalar nystrom n={n}"), n as f64, || {
                att::nystrom_attention(&q, &k, &v, 32)
            });
        }

        // Causal rows: fused prefix-tile softmax vs the masked dense
        // materialized route (parallel, unfused backend), and the
        // prefix-state LLN.
        let causal = AttnSpec::CAUSAL;
        let dense_causal = backend_for(
            Method::Softmax,
            BackendParams { fused: false, ..Default::default() },
        );
        let t_dense_causal = b
            .run(&format!("masked dense causal softmax n={n}"), n as f64, || {
                dense_causal.forward(&q, &k, &v, &causal)
            })
            .mean();
        let t_fused_causal = run_attention_backend(&mut b, sm.as_ref(), n, d, 6, &causal);
        speedups.push(("softmax_causal".into(), n, t_dense_causal / t_fused_causal));
        run_attention_backend(&mut b, lln.as_ref(), n, d, 7, &causal);
    }

    println!("\n== tensor substrate: scalar vs blocked+threaded ==");
    for n in [512usize, 1024] {
        let a = Mat::gaussian(n, d, 1.0, &mut rng);
        let c = Mat::gaussian(n, d, 1.0, &mut rng);
        b.run(&format!("scalar matmul_t {n}x{d}"), 2.0 * (n * n * d) as f64, || a.matmul_t(&c));
        b.run(&format!("par    matmul_t {n}x{d}"), 2.0 * (n * n * d) as f64, || {
            a.par_matmul_t(&c, 0)
        });
        let p = Mat::gaussian(n, n, 1.0, &mut rng);
        b.run(&format!("scalar softmax_rows {n}x{n}"), (n * n) as f64, || {
            let mut s = p.clone();
            s.softmax_rows();
            s
        });
        b.run(&format!("par    softmax_rows {n}x{n}"), (n * n) as f64, || {
            let mut s = p.clone();
            s.par_softmax_rows(0);
            s
        });
    }

    println!("\n== analysis instruments (N x N stochastic matrices) ==");
    for n in [128usize, 256] {
        let q = Mat::gaussian(n, d, 1.0, &mut rng);
        let k = Mat::gaussian(n, d, 1.0, &mut rng);
        let p = att::softmax_attention_matrix(&q, &k);
        b.run(&format!("entropy n={n}"), 1.0, || lln::stats::attention_entropy(&p));
        b.run(&format!("spectral_gap n={n}"), 1.0, || lln::linalg::spectral_gap(&p, 400, 1e-8));
        b.run(&format!("log_variance n={n}"), 1.0, || lln::stats::log_variance(&p, 1e-30));
    }

    println!("\n== backend vs scalar speedups ==");
    let mut ok = true;
    for (name, n, s) in &speedups {
        println!("speedup {name:<14} n={n:<5} {s:.2}x (fast backend vs reference route)");
        if *n == 1024 && (name == "softmax" || name == "lln") && *s <= 1.0 {
            ok = false;
        }
    }
    if ok {
        println!("PASS: blocked+threaded softmax and LLN beat the scalar baseline at n=1024");
    } else {
        println!("WARN: backend slower than scalar at n=1024 — check LLN_THREADS / core count");
    }

    // Fused O(n·tile) kernels vs the materialized pipelines — the
    // BENCH_kernels.json trajectory (shared with `lln bench`).
    println!("\n== fused O(n·tile) kernels vs materialized pipelines (tile={tile}, unroll={unroll}) ==");
    let params = BackendParams { tile, unroll, ..Default::default() };
    let mut qb = Bench::quick();
    let report = run_kernel_bench(&mut qb, &[1024, 4096], d, params);
    println!("\n== fused vs pipeline speedups ==");
    for (fast, slow, n, sp) in report.speedups() {
        println!("speedup {fast:<24} vs {slow:<26} n={n:<6} {sp:.2}x");
    }
    match report.speedup("softmax_fused", "softmax_pipeline_pr1", 4096) {
        Some(sp) if sp >= 2.0 => {
            println!("PASS: fused softmax beats the PR-1 pipeline {sp:.2}x (>= 2x) at n=4096")
        }
        Some(sp) => println!("WARN: fused softmax only {sp:.2}x vs PR-1 pipeline at n=4096"),
        None => println!("WARN: missing fused/pr1 measurement at n=4096"),
    }
    // Causal acceptance: fused causal must run in <= ~0.6x the time of
    // the masked dense causal route (speedup >= 1/0.6 ≈ 1.67x).
    match report.speedup("softmax_fused_causal", "softmax_masked_dense_causal", 4096) {
        Some(sp) if sp >= 1.0 / 0.6 => println!(
            "PASS: fused causal softmax runs in {:.2}x the masked-dense time (<= 0.6x) at n=4096",
            1.0 / sp
        ),
        Some(sp) => println!(
            "WARN: fused causal softmax at {:.2}x the masked-dense time (> 0.6x) at n=4096",
            1.0 / sp
        ),
        None => println!("WARN: missing causal fused/dense measurement at n=4096"),
    }
    if let Some(path) = bench_arg("json") {
        report.write_json(std::path::Path::new(&path)).expect("write BENCH_kernels.json");
        println!("wrote {path}");
    }
}
