//! Vendored, dependency-free subset of the `rand_core` trait surface:
//! just enough for `lln::rng::Pcg64` to implement the standard RNG
//! interfaces ([`RngCore`], [`SeedableRng`]) without the crates.io
//! mirror being reachable.

use std::fmt;

/// Error type surfaced by [`RngCore::try_fill_bytes`].
#[derive(Debug)]
pub struct Error {
    msg: &'static str,
}

impl Error {
    pub fn new(msg: &'static str) -> Self {
        Self { msg }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rng error: {}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Core uniform-bit generator interface.
pub trait RngCore {
    fn next_u32(&mut self) -> u32;
    fn next_u64(&mut self) -> u64;
    fn fill_bytes(&mut self, dest: &mut [u8]);
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error>;
}

/// Deterministic construction from a fixed-width seed.
pub trait SeedableRng: Sized {
    type Seed;

    fn from_seed(seed: Self::Seed) -> Self;
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Lcg(u64);

    impl RngCore for Lcg {
        fn next_u32(&mut self) -> u32 {
            self.next_u64() as u32
        }
        fn next_u64(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1);
            self.0
        }
        fn fill_bytes(&mut self, dest: &mut [u8]) {
            for b in dest {
                *b = self.next_u64() as u8;
            }
        }
        fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
            self.fill_bytes(dest);
            Ok(())
        }
    }

    impl SeedableRng for Lcg {
        type Seed = [u8; 8];
        fn from_seed(seed: Self::Seed) -> Self {
            Lcg(u64::from_le_bytes(seed))
        }
    }

    #[test]
    fn trait_surface_is_usable() {
        let mut r = Lcg::from_seed([1, 0, 0, 0, 0, 0, 0, 0]);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        let mut buf = [0u8; 5];
        r.try_fill_bytes(&mut buf).unwrap();
    }
}
