//! Fig 8 (+ Fig 9): MLM pretraining loss curves, LLN vs softmax, on the
//! synthetic corpus — the repo's end-to-end driver (examples/train_mlm.rs
//! wraps this runner).
//!
//! For each method we train the RoBERTa-lite MLM model, logging train
//! loss, held-out eval loss, grad-norm (fig 8b's loss-scale proxy) and
//! per-layer alpha/beta (fig 9).  The step executes through a
//! [`TrainStep`]: the AOT artifact driver when `artifacts/` exists, or
//! the **native** backprop trainer ([`NativeStep`], fused recompute
//! backward through the attention backends) when it does not — so the
//! fig. 8 pipeline runs artifact-free end to end.  `--native` (or
//! `TrainConfig::native`) forces the native path even with artifacts
//! present.

use anyhow::{anyhow, Result};

use super::maybe_write_csv;
use crate::cli::Args;
use crate::config::TrainConfig;
use crate::data::Corpus;
use crate::runtime::{artifacts_available, artifacts_dir};
use crate::training::metrics::{sparkline, MetricsLog, Record};
use crate::training::native::{ArtifactStep, NativeShape, NativeStep, TrainStep};
use crate::util::print_table;

pub struct PretrainResult {
    pub method: String,
    pub log: MetricsLog,
    pub eval_losses: Vec<(usize, f32)>,
    pub alpha_series: Vec<(usize, f32)>,
}

/// Build the [`TrainStep`] for a `(method, size)` pair: the AOT
/// artifact driver when artifacts exist and `force_native` is off,
/// else the native backprop trainer.
pub fn build_step(
    dir: &std::path::Path,
    method: &str,
    size: &str,
    force_native: bool,
    cfg: &TrainConfig,
) -> Result<Box<dyn TrainStep>> {
    if !force_native && !cfg.native && artifacts_available(dir) {
        let artifact = format!("train_{size}_{method}");
        return Ok(Box::new(ArtifactStep::new(dir, &artifact)?));
    }
    let m = crate::attention::Method::parse(method)
        .ok_or_else(|| anyhow!("unknown attention method {method:?}"))?;
    let mut shape = NativeShape::for_size(size);
    if cfg.batch != 0 {
        shape.batch = cfg.batch;
    }
    if cfg.seqlen != 0 {
        shape.seqlen = cfg.seqlen;
    }
    if cfg.heads != 0 {
        shape.heads = cfg.heads;
    }
    shape.seed = cfg.seed;
    let mut step = NativeStep::new(m, shape)?;
    step.set_checkpoint_segments(cfg.checkpoint_segments);
    step.set_data_parallel(cfg.data_parallel);
    Ok(Box::new(step))
}

/// Train one method's MLM model for `steps`; returns full telemetry.
/// `force_native` skips the artifact path even when artifacts exist
/// (`lln train --native`); with no artifacts directory the native
/// trainer is picked automatically.
pub fn pretrain(
    dir: &std::path::Path,
    method: &str,
    size: &str,
    steps: usize,
    cfg: &TrainConfig,
    log_path: Option<&std::path::Path>,
    force_native: bool,
) -> Result<PretrainResult> {
    let mut step_exec = build_step(dir, method, size, force_native, cfg)?;
    eprintln!("   [{method}] stepping via {}", step_exec.name());
    let (b, n) = step_exec.batch_shape();
    let vocab = step_exec.vocab();
    let mut corpus = Corpus::new(vocab, cfg.seed);
    let mut eval_corpus = Corpus::new(vocab, cfg.seed ^ 0xE7A1);
    // Fixed held-out batch: comparable eval losses across methods.
    let eval_batch = eval_corpus.mlm_batch(b, n, 0.15);

    let mut log = match log_path {
        Some(p) => MetricsLog::create(p)?,
        None => MetricsLog::ephemeral(),
    };
    let mut eval_losses = Vec::new();
    let mut alpha_series = Vec::new();

    for step in 0..steps {
        let batch = corpus.mlm_batch(b, n, 0.15);
        let lr = cfg.lr_at(step);
        let out = step_exec.step(lr, &batch)?;
        let (alpha, beta) = out
            .layer_stats
            .first()
            .map(|s| (s[0], s[1]))
            .unwrap_or((0.0, 0.0));
        if alpha > 0.0 {
            alpha_series.push((out.step, alpha));
        }
        // Per-head dilution telemetry (native path only): the mean and
        // max attention entropy over all (layer, head) slots, plus the
        // step's peak live tape — checkpointing visibly shrinks it.
        let mut extra = Vec::new();
        if out.peak_bytes > 0 {
            extra.push(("peak_bytes".to_string(), out.peak_bytes as f64));
        }
        let head_ents: Vec<f64> = out
            .head_stats
            .iter()
            .flatten()
            .map(|h| h[0] as f64)
            .filter(|e| e.is_finite())
            .collect();
        if !head_ents.is_empty() {
            let mean = head_ents.iter().sum::<f64>() / head_ents.len() as f64;
            let max = head_ents.iter().cloned().fold(f64::MIN, f64::max);
            extra.push(("head_entropy_mean".to_string(), mean));
            extra.push(("head_entropy_max".to_string(), max));
        }
        log.log(Record {
            step: out.step,
            loss: out.loss,
            grad_norm: out.grad_norm,
            lr,
            alpha: (alpha > 0.0).then_some(alpha),
            beta: (beta > 0.0).then_some(beta),
            extra,
        })?;
        if (step + 1) % cfg.eval_every.max(1) == 0 || step + 1 == steps {
            eval_losses.push((step + 1, step_exec.eval_loss(&eval_batch)?));
        }
        if (step + 1) % cfg.log_every.max(1) == 0 {
            eprintln!(
                "   [{method}] step {:>4}  loss {:.3}  gnorm {:.2}  lr {:.2e}{}",
                step + 1,
                out.loss,
                out.grad_norm,
                lr,
                if alpha > 0.0 {
                    format!("  alpha {alpha:.2}")
                } else {
                    String::new()
                }
            );
        }
    }
    Ok(PretrainResult { method: method.to_string(), log, eval_losses, alpha_series })
}

pub fn run_fig8(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let steps = args.get_usize("steps", 150)?;
    let size = args.get_or("size", "mlm"); // "mlm" (small) or "tinymlm"
    let methods = args.get_list("methods", "softmax,lln");
    let native = args.get_bool("native");
    let cfg = TrainConfig {
        lr: args.get_f64("lr", 5e-4)?,
        warmup: steps / 10,
        eval_every: args.get_usize("eval-every", 25)?,
        log_every: args.get_usize("log-every", 25)?,
        seed: args.get_usize("seed", 0)? as u64,
        ..Default::default()
    };

    let tag = if native || !artifacts_available(&dir) {
        " [native]"
    } else {
        ""
    };
    println!("== Fig 8: MLM pretraining on the synthetic corpus ({steps} steps){tag} ==\n");
    let mut results = Vec::new();
    for method in &methods {
        let log_path = args
            .get("out")
            .map(|o| std::path::Path::new(o).join(format!("fig8_{method}.jsonl")));
        let r = pretrain(&dir, method, size, steps, &cfg, log_path.as_deref(), native)?;
        results.push(r);
    }

    println!("\n-- training loss curves --");
    for r in &results {
        let series: Vec<f64> = r.log.history.iter().map(|x| x.loss as f64).collect();
        println!(
            "{:>10} {}  final {:.3}",
            r.method,
            sparkline(&series, 60),
            r.log.final_loss().unwrap_or(f32::NAN)
        );
    }

    println!("\n-- held-out eval loss --");
    let mut rows = Vec::new();
    if let Some(first) = results.first() {
        for (i, (step, _)) in first.eval_losses.iter().enumerate() {
            let mut row = vec![step.to_string()];
            for r in &results {
                row.push(format!("{:.3}", r.eval_losses.get(i).map(|x| x.1).unwrap_or(f32::NAN)));
            }
            rows.push(row);
        }
    }
    let mut headers = vec!["step".to_string()];
    headers.extend(results.iter().map(|r| r.method.clone()));
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table(&hrefs, &rows);

    println!("\n-- fig 8b analog: max grad-norm (loss-scale pressure) --");
    for r in &results {
        println!("{:>10}  max grad-norm {:.2}", r.method, r.log.max_grad_norm());
    }

    for r in &results {
        if !r.alpha_series.is_empty() {
            println!("\n-- fig 9: layer-0 alpha during {} training --", r.method);
            let series: Vec<f64> = r.alpha_series.iter().map(|x| x.1 as f64).collect();
            println!(
                "   {}  start {:.2} -> end {:.2}",
                sparkline(&series, 60),
                series.first().unwrap(),
                series.last().unwrap()
            );
        }
    }

    let mut csv = Vec::new();
    for r in &results {
        for rec in &r.log.history {
            csv.push(format!(
                "{},{},{},{},{}",
                r.method,
                rec.step,
                rec.loss,
                rec.grad_norm,
                rec.alpha.unwrap_or(0.0)
            ));
        }
    }
    maybe_write_csv(args, "fig8", "method,step,loss,grad_norm,alpha", &csv)?;
    println!("\npaper shape: the LLN curve tracks softmax closely; LLN grad-norm");
    println!("stays within the softmax envelope (training stability, fig 8b).");
    Ok(())
}
