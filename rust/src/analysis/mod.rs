//! The paper's §3 analysis instruments, composed into reusable probes:
//! temperature, attention concentration (entropy + spectral gap),
//! log-normal distribution checks, and the Fenton-approximation study.

pub mod concentration;
pub mod fenton;
pub mod lognormal;

pub use concentration::{concentration_profile, ConcentrationPoint};
pub use fenton::{fenton_sigma2, lognormal_sum_variance, FentonPoint};
pub use lognormal::{sa_lognormal_check, LogNormalCheck};

use crate::tensor::{vec_ops, Mat};

/// Implicit softmax temperature (paper eq. 5):
/// tau = 1 / sqrt(sigma_q^2 sigma_k^2 + C_cross).
/// C_cross is estimated per Goodman (1960) from elementwise samples.
pub fn temperature(q: &Mat, k: &Mat) -> f64 {
    let sq2 = vec_ops::variance(q.data());
    let sk2 = vec_ops::variance(k.data());
    let c_cross = cross_covariance(q, k);
    1.0 / (sq2 * sk2 + c_cross).max(1e-12).sqrt()
}

/// Cov(q^2, k^2) - Cov(q, k)^2 over aligned elements (zero for
/// independent inputs; nonzero as training correlates Q and K).
pub fn cross_covariance(q: &Mat, k: &Mat) -> f64 {
    let n = q.data().len().min(k.data().len());
    let qd = &q.data()[..n];
    let kd = &k.data()[..n];
    let mq = vec_ops::mean(qd);
    let mk = vec_ops::mean(kd);
    let mq2 = qd.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / n as f64;
    let mk2 = kd.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / n as f64;
    let mut cov_qk = 0.0f64;
    let mut cov_q2k2 = 0.0f64;
    for i in 0..n {
        let (qi, ki) = (qd[i] as f64, kd[i] as f64);
        cov_qk += (qi - mq) * (ki - mk);
        cov_q2k2 += (qi * qi - mq2) * (ki * ki - mk2);
    }
    cov_qk /= n as f64;
    cov_q2k2 /= n as f64;
    cov_q2k2 - cov_qk * cov_qk
}

/// Per-layer training-dynamics record (fig. 1 rows).
#[derive(Clone, Copy, Debug)]
pub struct LayerDynamics {
    pub layer: usize,
    pub temperature: f64,
    pub entropy: f64,
    pub spectral_gap: f64,
}

/// Analyze a stack of per-layer stochastic matrices (from the probe
/// artifact) given the matching sigma stats.
pub fn layer_dynamics(matrices: &[Mat], sigmas: &[(f64, f64)]) -> Vec<LayerDynamics> {
    matrices
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let (sq, sk) = sigmas.get(i).copied().unwrap_or((1.0, 1.0));
            LayerDynamics {
                layer: i,
                temperature: 1.0 / (sq * sq * sk * sk).max(1e-12).sqrt(),
                entropy: crate::stats::attention_entropy(p),
                spectral_gap: crate::linalg::spectral_gap(p, 600, 1e-9).gap,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn temperature_decreases_with_input_scale() {
        let mut rng = Pcg64::seed(1);
        let q1 = Mat::gaussian(64, 32, 0.5, &mut rng);
        let k1 = Mat::gaussian(64, 32, 0.5, &mut rng);
        let q2 = Mat::gaussian(64, 32, 2.0, &mut rng);
        let k2 = Mat::gaussian(64, 32, 2.0, &mut rng);
        assert!(temperature(&q1, &k1) > temperature(&q2, &k2));
    }

    #[test]
    fn cross_covariance_near_zero_for_independent() {
        let mut rng = Pcg64::seed(2);
        let q = Mat::gaussian(128, 64, 1.0, &mut rng);
        let k = Mat::gaussian(128, 64, 1.0, &mut rng);
        assert!(cross_covariance(&q, &k).abs() < 0.15);
    }

    #[test]
    fn cross_covariance_positive_for_correlated() {
        let mut rng = Pcg64::seed(3);
        let q = Mat::gaussian(128, 64, 1.0, &mut rng);
        let k = q.map(|x| x * 0.9); // strongly correlated
        assert!(cross_covariance(&q, &k) > 0.1);
    }

    #[test]
    fn layer_dynamics_shapes() {
        let mut rng = Pcg64::seed(4);
        let mats: Vec<Mat> = (0..3)
            .map(|_| {
                let mut p = Mat::gaussian(32, 32, 1.0, &mut rng);
                p.softmax_rows();
                p
            })
            .collect();
        let dyns = layer_dynamics(&mats, &[(1.0, 1.0), (1.2, 1.0), (0.8, 0.9)]);
        assert_eq!(dyns.len(), 3);
        for d in dyns {
            assert!(d.entropy > 0.0 && d.entropy <= 5.0 + 1e-9);
            assert!(d.spectral_gap >= 0.0 && d.spectral_gap <= 1.0);
        }
    }
}
