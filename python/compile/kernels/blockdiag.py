"""Pallas kernel: block-diagonal softmax attention (paper sec. 4.2).

Each grid step handles one diagonal block: softmax over a
(block, block) score tile only — the short-range half of LLN+Diag.
O(N * block) compute and memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64


def _diag_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    s = (q_ref[...] @ k_ref[...].T) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = p @ v_ref[...]


def blockdiag_attention_pallas(q, k, v, *, block_size=DEFAULT_BLOCK, interpret=True):
    """Block-diagonal softmax attention over one head: q, k, v are (N, d)."""
    n, d = q.shape
    block_size = min(block_size, n)
    if n % block_size:
        raise ValueError(f"N={n} must be divisible by block_size={block_size}")
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_diag_kernel, scale=scale),
        grid=(n // block_size,),
        in_specs=[
            pl.BlockSpec((block_size, d), lambda i: (i, 0)),
            pl.BlockSpec((block_size, d), lambda i: (i, 0)),
            pl.BlockSpec((block_size, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_size, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(q, k, v)


def lln_diag_attention_pallas(q, k, v, alpha, beta, *, block_size=DEFAULT_BLOCK, **kw):
    """LLN+Diag: average of the linear long-range and block-diag short-range paths."""
    from .linear_attn import lln_attention_pallas

    long_range = lln_attention_pallas(q, k, v, alpha, beta, **kw)
    short_range = blockdiag_attention_pallas(q, k, v, block_size=block_size)
    return 0.5 * (long_range + short_range)
