//! Quickstart: drive the native `AttentionBackend` registry through the
//! `AttnSpec` mask API (full, padded, causal), demo moment matching and
//! token-by-token decode sessions (`begin_decode` / `decode_step` at
//! the kernel layer, `Coordinator::open_session` streaming at the
//! serving layer), show the `[compute] head_dim` / `precision` perf
//! knobs (monomorphized kernels, int8-kv storage), then — when AOT
//! artifacts are built — cross-check the PJRT LLN kernel against the
//! native implementation.
//!
//!     cargo run --release --example quickstart                  # native only
//!     cargo run --release --example quickstart -- --decode-smoke  # CI decode smoke
//!     make artifacts && cargo run --release --example quickstart

use anyhow::{anyhow, Result};

use lln::attention::{self, backend_for, AttnSpec, BackendParams, Method, MomentMatcher};
use lln::rng::Pcg64;
use lln::runtime::{artifacts_dir, Engine, HostTensor};
use lln::tensor::Mat;

/// Compact streaming-decode exerciser for CI: a native coordinator, one
/// decode session co-batched with prefill traffic, logits streamed back
/// in order.  Fails loudly if any step errors or the stream stalls.
fn decode_smoke() -> Result<()> {
    use lln::config::ServeConfig;
    use lln::coordinator::Coordinator;

    let cfg = ServeConfig {
        method: "lln".into(),
        force_native: true,
        buckets: vec![64],
        ..Default::default()
    };
    let coord = Coordinator::start(cfg, &artifacts_dir(None))?;
    let mut session = coord.open_session(64)?;
    let tokens: Vec<i32> = (0..32).map(|i| 4 + (i % 13)).collect();
    // Co-batch a prefill request with the streaming session.
    let prefill_rx = coord.submit(vec![9i32; 40])?;
    let rx = session.stream(&tokens)?;
    let mut streamed = 0usize;
    for i in 0..tokens.len() {
        let resp = rx.recv().map_err(|_| anyhow!("decode stream dropped at token {i}"))?;
        let logits = resp.result.map_err(|e| anyhow!("decode step {i}: {e}"))?;
        if !logits.iter().all(|x| x.is_finite()) {
            anyhow::bail!("non-finite decode logits at token {i}");
        }
        streamed += 1;
    }
    prefill_rx
        .recv()
        .map_err(|_| anyhow!("prefill co-request dropped"))?
        .result
        .map_err(|e| anyhow!("prefill co-request: {e}"))?;
    session.close();
    coord.shutdown();
    println!("decode smoke OK ({streamed} tokens streamed alongside a prefill request)");
    Ok(())
}

fn main() -> Result<()> {
    if std::env::args().any(|a| a == "--decode-smoke") {
        return decode_smoke();
    }
    // 1. Moment matching (paper eq. 10): derive alpha/beta from live
    //    stats — the AOT-fitted constants when artifacts exist, the
    //    identity model otherwise.
    let mm = MomentMatcher::from_artifacts(&artifacts_dir(None))
        .unwrap_or(MomentMatcher { a: 1.0, b: 0.0 });
    let (sigma_q, sigma_k) = (1.1f64, 0.9f64);
    let (alpha, beta) = mm.alpha_beta(sigma_q, sigma_k);
    println!(
        "moment matching: sigma_q={sigma_q} sigma_k={sigma_k} -> alpha={alpha:.3} beta={beta:.3}"
    );

    // 2. One backend, three masks.  Every forward carries an AttnSpec:
    //    AttnSpec::FULL is bidirectional encoder attention,
    //    AttnSpec::CAUSAL the decoder mask, AttnSpec::padded(len) a
    //    right-padding key mask (what `lln serve` uses for batching
    //    variable-length requests).
    let (n, d) = (256usize, 64usize);
    let mut rng = Pcg64::seed(0);
    let q = Mat::gaussian(n, d, sigma_q as f32, &mut rng);
    let k = Mat::gaussian(n, d, sigma_k as f32, &mut rng);
    let v = Mat::gaussian(n, d, 1.0, &mut rng);
    let lln_bk = backend_for(Method::Lln, BackendParams { alpha, beta, ..Default::default() });
    let full = lln_bk.forward(&q, &k, &v, &AttnSpec::FULL);
    let causal = lln_bk.forward(&q, &k, &v, &AttnSpec::CAUSAL);
    let padded = lln_bk.forward(&q, &k, &v, &AttnSpec::padded(192));
    println!(
        "lln forward under masks: full[0][0]={:+.4}  causal[0][0]={:+.4}  padded[0][0]={:+.4}",
        full.get(0, 0),
        causal.get(0, 0),
        padded.get(0, 0)
    );

    // 3. Token-by-token generation: begin_decode opens an O(d²)
    //    prefix-state session and decode_step appends one token at a
    //    time — no re-running the causal prefill per token.  The
    //    decoded rows are *bitwise* the causal batch forward's rows
    //    (same chunked prefix-state carry).
    let mut state = lln_bk.begin_decode(d, d).map_err(|e| anyhow!(e))?;
    let mut decoded = Mat::zeros(n, d);
    for i in 0..n {
        let row = lln_bk.decode_step(&mut state, q.row(i), k.row(i), v.row(i));
        decoded.row_mut(i).copy_from_slice(&row);
    }
    assert_eq!(
        decoded.data(),
        causal.data(),
        "decode session must reproduce the causal forward bitwise"
    );
    println!(
        "lln decode session: {n} steps == causal forward rows (bitwise), state = {} bytes (O(d²), \
         flat in n)",
        state.state_bytes()
    );
    // Exact softmax decodes too — a KV cache instead of a prefix state
    // (O(t·d) per step), matching the fused causal forward to
    // streaming-softmax tolerance.
    let sm_decode_bk = backend_for(Method::Softmax, BackendParams::default());
    let mut sm_state = sm_decode_bk.begin_decode(d, d).map_err(|e| anyhow!(e))?;
    let sm_causal_ref = sm_decode_bk.forward(&q, &k, &v, &AttnSpec::CAUSAL);
    let mut sm_err = 0.0f32;
    for i in 0..n {
        let row = sm_decode_bk.decode_step(&mut sm_state, q.row(i), k.row(i), v.row(i));
        for (a, b) in row.iter().zip(sm_causal_ref.row(i)) {
            sm_err = sm_err.max((a - b).abs());
        }
    }
    println!(
        "softmax decode session vs fused causal forward: max |diff| = {sm_err:.2e}, cache = {} \
         bytes (grows with t)",
        sm_state.state_bytes()
    );
    assert!(sm_err < 1e-5);

    // 3b. Perf knobs.  `[compute] head_dim` pins the monomorphized
    //     microkernels at backend construction — d = 64 matches a
    //     specialized instance (D ∈ {32, 64, 128}), and the unrolled
    //     loops are *bitwise* identical to the generic ones, so this
    //     is purely a speed choice.  `[compute] precision` stores K/V
    //     operands narrow (bf16 / f16 / int8-kv) while every dot
    //     product still runs in f32: here the int8-kv decode cache
    //     holds the same session in >3.5x fewer bytes.
    let pinned_bk =
        backend_for(Method::Softmax, BackendParams { head_dim: d, ..Default::default() });
    assert_eq!(
        pinned_bk.forward(&q, &k, &v, &AttnSpec::CAUSAL).data(),
        sm_causal_ref.data(),
        "specialized head-dim kernels must be bitwise identical"
    );
    let int8_bk = backend_for(
        Method::Softmax,
        BackendParams { precision: lln::lowp::Precision::Int8Kv, ..Default::default() },
    );
    let mut int8_state = int8_bk.begin_decode(d, d).map_err(|e| anyhow!(e))?;
    let mut int8_err = 0.0f32;
    for i in 0..n {
        let row = int8_bk.decode_step(&mut int8_state, q.row(i), k.row(i), v.row(i));
        for (a, b) in row.iter().zip(sm_causal_ref.row(i)) {
            int8_err = int8_err.max((a - b).abs());
        }
    }
    println!(
        "int8-kv decode session: cache = {} bytes vs {} at f32 ({:.2}x smaller), max |diff| vs \
         f32 = {int8_err:.2e}",
        int8_state.state_bytes(),
        sm_state.state_bytes(),
        sm_state.state_bytes() as f64 / int8_state.state_bytes() as f64
    );
    assert!(2 * int8_state.state_bytes() <= sm_state.state_bytes());
    // Same tolerance shape as the property suite: the documented
    // int8-kv bound, scaled by the reference magnitude.
    let ref_scale =
        sm_causal_ref.data().iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1.0);
    assert!(
        int8_err < 0.25 * ref_scale,
        "int8-kv storage error out of documented bounds: {int8_err} (scale {ref_scale})"
    );

    // 4. Exact softmax under the same masks, through the fused
    //    O(n·tile) kernels — including the causal variant that streams
    //    only prefix tiles.
    let sm_bk = backend_for(Method::Softmax, BackendParams::default());
    let sm_causal = sm_bk.forward(&q, &k, &v, &AttnSpec::CAUSAL);
    let dense = attention::softmax_attention_matrix_spec(&q, &k, &AttnSpec::CAUSAL).matmul(&v);
    let err = sm_causal.max_abs_diff(&dense);
    println!("fused causal softmax vs masked dense reference: max |diff| = {err:.2e}");
    assert!(err < 1e-4);

    // 5. LLN concentration matches softmax (paper fig. 2 instruments).
    let p_lln = attention::lln_attention_matrix(&q, &k, alpha, beta);
    let p_sm = attention::softmax_attention_matrix(&q, &k);
    println!(
        "entropy:      lln={:.3}   softmax={:.3}",
        lln::stats::attention_entropy(&p_lln),
        lln::stats::attention_entropy(&p_sm),
    );
    println!(
        "spectral gap: lln={:.3}        softmax={:.3}",
        lln::linalg::spectral_gap(&p_lln, 400, 1e-8).gap,
        lln::linalg::spectral_gap(&p_sm, 400, 1e-8).gap,
    );

    // 6. PJRT cross-check (optional: needs `make artifacts`).
    let dir = artifacts_dir(None);
    match Engine::new(&dir) {
        Ok(mut engine) => {
            let outs = engine.execute(
                "attn_lln_n256",
                &[
                    HostTensor::from_mat(&q),
                    HostTensor::from_mat(&k),
                    HostTensor::from_mat(&v),
                    HostTensor::scalar_f32(alpha),
                    HostTensor::scalar_f32(beta),
                ],
            )?;
            let kernel_out = outs[0].to_mat()?;
            let native = attention::lln_attention(&q, &k, &v, alpha, beta);
            let err = kernel_out.max_abs_diff(&native);
            println!("PJRT kernel vs native Rust: max |diff| = {err:.2e}");
            assert!(err < 2e-3);
        }
        Err(e) => {
            println!("(skipping PJRT cross-check: {e:#}; run `make artifacts` to enable)");
        }
    }
    println!("quickstart OK");
    Ok(())
}
