"""Pure-jnp reference oracles for every attention mechanism in the repo.

These are the ground truth the Pallas kernels (and the Rust native
implementations) are validated against.  Everything here is O(N^2) and
materializes full attention matrices — clarity over efficiency.

Shapes follow the paper's notation: q, k, v are (N, d) single-head
slices; batched/multi-head wrappers live in model.py and vmap over
these.  All math is f32.

Numerics: the LLN feature maps exponentiate raw activations, so both the
oracle and the kernels clamp the exponent to +/-EXP_CLAMP before `exp`.
The paper's implementations manage the same blow-up via FP16 loss
scaling (App. A.8.4); a hard clamp is the precision-agnostic equivalent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Keep exp() finite in f32 for any realistic activation scale.
EXP_CLAMP = 30.0


def _clamped_exp(x):
    return jnp.exp(jnp.clip(x, -EXP_CLAMP, EXP_CLAMP))


# ---------------------------------------------------------------------------
# Softmax attention (paper eq. 1-2)
# ---------------------------------------------------------------------------

def softmax_attention(q, k, v):
    """Standard scaled-dot-product attention, one head.

    P_ij = softmax_j(q_i . k_j / sqrt(d));  out_i = sum_j P_ij v_j.
    """
    d = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    p = jax.nn.softmax(scores, axis=-1)
    return p @ v


def softmax_attention_matrix(q, k):
    """The full N x N stochastic matrix P^(SM) (analysis instrument)."""
    d = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    return jax.nn.softmax(scores, axis=-1)


# ---------------------------------------------------------------------------
# Generic linearized attention (paper eq. 4): out = Phi(Q) (Phi(K)^T V) / Z
# ---------------------------------------------------------------------------

def linear_attention(phi_q, phi_k, v, eps=1e-6):
    """Linear attention given pre-computed feature maps, (N, m) each.

    Computed in the O(N m d) associativity order so the oracle exercises
    the same contraction the kernels implement.
    """
    kv = phi_k.T @ v                     # (m, d)
    z = jnp.sum(phi_k, axis=0)           # (m,)
    num = phi_q @ kv                     # (N, d)
    den = phi_q @ z                      # (N,)
    return num / (den[:, None] + eps)


def linear_attention_matrix(phi_q, phi_k, eps=1e-6):
    """Explicit N x N stochastic matrix of a linearized attention."""
    scores = phi_q @ phi_k.T             # (N, N), all entries >= 0
    return scores / (jnp.sum(scores, axis=-1, keepdims=True) + eps)


# ---------------------------------------------------------------------------
# LLN attention (paper eq. 8-9): Phi_Q(q) = e^{alpha q}, Phi_K(k) = e^{beta k}
# ---------------------------------------------------------------------------

def lln_feature_q(q, alpha):
    return _clamped_exp(alpha * q)


def lln_feature_k(k, beta):
    return _clamped_exp(beta * k)


def lln_attention(q, k, v, alpha, beta):
    """Linear Log-Normal attention, one head (paper eq. 8)."""
    return linear_attention(lln_feature_q(q, alpha), lln_feature_k(k, beta), v)


def lln_attention_matrix(q, k, alpha, beta):
    return linear_attention_matrix(lln_feature_q(q, alpha), lln_feature_k(k, beta))


# ---------------------------------------------------------------------------
# ELU linear attention (Katharopoulos et al. 2020): phi(x) = elu(x) + 1
# ---------------------------------------------------------------------------

def elu_feature(x):
    return jax.nn.elu(x) + 1.0


def elu_attention(q, k, v):
    return linear_attention(elu_feature(q), elu_feature(k), v)


def elu_attention_matrix(q, k):
    return linear_attention_matrix(elu_feature(q), elu_feature(k))


# ---------------------------------------------------------------------------
# ReLU / quadratic kernels (fig. 2 comparisons)
# ---------------------------------------------------------------------------

def relu_attention_matrix(q, k):
    return linear_attention_matrix(jax.nn.relu(q), jax.nn.relu(k))


def quadratic_attention_matrix(q, k):
    """kappa(q, k) = (q . k)^2 via the explicit (non-linearized) route."""
    scores = (q @ k.T) ** 2
    return scores / (jnp.sum(scores, axis=-1, keepdims=True) + 1e-6)


# ---------------------------------------------------------------------------
# Performer / FAVOR+ (Choromanski et al. 2020), positive random features
# ---------------------------------------------------------------------------

def performer_features(x, proj, scale):
    """Positive softmax-kernel random features: exp(w^T x - |x|^2 / 2).

    proj: (d, m) random Gaussian projection (fixed at trace time).
    scale: 1/sqrt(m) normalization.
    """
    d = x.shape[-1]
    x = x / jnp.float32(d) ** 0.25
    u = x @ proj                                   # (N, m)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    return scale * _clamped_exp(u - sq)


def performer_attention(q, k, v, proj):
    m = proj.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(m))
    return linear_attention(
        performer_features(q, proj, scale), performer_features(k, proj, scale), v
    )


def performer_attention_matrix(q, k, proj):
    m = proj.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(m))
    return linear_attention_matrix(
        performer_features(q, proj, scale), performer_features(k, proj, scale)
    )


# ---------------------------------------------------------------------------
# Nystromformer (Xiong et al. 2021), segment-mean landmarks
# ---------------------------------------------------------------------------

def _newton_schulz_pinv(a, iters=12):
    """Iterative Moore-Penrose pseudo-inverse of a small (m, m) matrix."""
    # Initialization from the Nystromformer paper (sec 3.2).
    z = a.T / (jnp.max(jnp.sum(jnp.abs(a), axis=0)) * jnp.max(jnp.sum(jnp.abs(a), axis=1)))
    ident = jnp.eye(a.shape[0], dtype=a.dtype)

    def body(_, z):
        az = a @ z
        return z @ (13.0 * ident - az @ (15.0 * ident - az @ (7.0 * ident - az))) / 4.0

    return jax.lax.fori_loop(0, iters, body, z)


def nystrom_attention(q, k, v, num_landmarks=32):
    """Nystrom approximation of softmax attention with mean-pooled landmarks."""
    n, d = q.shape
    m = num_landmarks
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    q_l = q.reshape(m, n // m, d).mean(axis=1)     # (m, d) landmarks
    k_l = k.reshape(m, n // m, d).mean(axis=1)
    f = jax.nn.softmax(q @ k_l.T * scale, axis=-1)        # (n, m)
    a = jax.nn.softmax(q_l @ k_l.T * scale, axis=-1)      # (m, m)
    b = jax.nn.softmax(q_l @ k.T * scale, axis=-1)        # (m, n)
    return f @ (_newton_schulz_pinv(a) @ (b @ v))


# ---------------------------------------------------------------------------
# Block-diagonal softmax attention (sec. 4.2 / Qin et al. 2022b)
# ---------------------------------------------------------------------------

def blockdiag_attention(q, k, v, block_size):
    """Softmax attention restricted to diagonal blocks of size `block_size`."""
    n, d = q.shape
    assert n % block_size == 0, "sequence length must be divisible by block size"
    nb = n // block_size
    qb = q.reshape(nb, block_size, d)
    kb = k.reshape(nb, block_size, d)
    vb = v.reshape(nb, block_size, d)
    scores = jnp.einsum("bqd,bkd->bqk", qb, kb) / jnp.sqrt(jnp.float32(d))
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, vb)
    return out.reshape(n, d)


# ---------------------------------------------------------------------------
# LLN + Diag (sec. 4.2): average of LLN and block-diagonal outputs
# ---------------------------------------------------------------------------

def lln_diag_attention(q, k, v, alpha, beta, block_size):
    long_range = lln_attention(q, k, v, alpha, beta)
    short_range = blockdiag_attention(q, k, v, block_size)
    return 0.5 * (long_range + short_range)
