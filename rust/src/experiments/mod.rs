//! Experiment harnesses — one runner per paper table/figure.
//!
//! Each runner regenerates the corresponding table/figure as terminal
//! output (same rows/series the paper reports) and, where useful, a CSV
//! under `--out`.  See DESIGN.md §5 for the experiment index and
//! EXPERIMENTS.md for recorded paper-vs-measured results.

pub mod figures;
pub mod glue;
pub mod lra;
pub mod pretrain;
pub mod scaling;
pub mod serve_bench;
pub mod training_dynamics;
pub mod vit;

use anyhow::{bail, Result};

use crate::cli::Args;

/// All experiment ids and their one-line descriptions.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("table1", "GLUE-like accuracy across attention methods (paper Table 1)"),
    ("table2", "memory + time scaling vs sequence length (paper Table 2)"),
    ("table3", "ViT-lite image classification (paper Table 3)"),
    ("lra", "LRA-lite speed/memory + score (paper Tables 4-5)"),
    ("fig1", "temperature/entropy/spectral gap during training (paper Fig 1)"),
    ("fig2", "entropy + spectral gap vs temperature per kernel (paper Fig 2)"),
    ("fig5", "SA log-normal stats vs theory; moment matching (paper Fig 5)"),
    ("fig6", "Fenton log-normal-sum approximation (paper Fig 6)"),
    ("fig7", "attention histograms SA vs LLN (paper Fig 7)"),
    ("fig8", "MLM pretraining loss curves LLN vs SA (paper Fig 8 + Fig 9)"),
    ("fig10", "accuracy + grad-norm vs fixed alpha/beta (paper Fig 10)"),
    ("serve", "serving throughput/latency through the coordinator"),
];

/// Dispatch an experiment by id.
pub fn run(name: &str, args: &Args) -> Result<()> {
    match name {
        "table1" => glue::run_table1(args),
        "table2" => scaling::run_table2(args),
        "table3" => vit::run_table3(args),
        "lra" => lra::run_lra(args),
        "fig1" => training_dynamics::run_fig1(args),
        "fig2" => figures::run_fig2(args),
        "fig5" => figures::run_fig5(args),
        "fig6" => figures::run_fig6(args),
        "fig7" => figures::run_fig7(args),
        "fig8" => pretrain::run_fig8(args),
        "fig10" => glue::run_fig10(args),
        "serve" => serve_bench::run_serve(args),
        other => bail!(
            "unknown experiment {other:?}; available: {}",
            EXPERIMENTS
                .iter()
                .map(|(n, _)| *n)
                .collect::<Vec<_>>()
                .join(", ")
        ),
    }
}

/// Write rows as CSV when --out is given.
pub fn maybe_write_csv(args: &Args, name: &str, header: &str, rows: &[String]) -> Result<()> {
    if let Some(dir) = args.get("out") {
        let path = std::path::Path::new(dir);
        std::fs::create_dir_all(path)?;
        let file = path.join(format!("{name}.csv"));
        let mut text = String::from(header);
        text.push('\n');
        for r in rows {
            text.push_str(r);
            text.push('\n');
        }
        std::fs::write(&file, text)?;
        println!("  -> wrote {}", file.display());
    }
    Ok(())
}
