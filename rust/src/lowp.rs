//! Low-precision K/V storage: the `[compute] precision` knob and the
//! encodings behind it.
//!
//! Serving memory is dominated by decode-state bytes — every live
//! session holds its keys and values for the whole decoded window, and
//! at f32 that is `(d + dv) * 4` bytes per token.  This module provides
//! the storage codecs that cut that width: `bf16` (truncated-mantissa
//! f32, round-to-nearest-even), `f16` (IEEE binary16), and `int8-kv`
//! (affine per-row quantization with explicit scale/zero-point).  The
//! contract everywhere is **storage-only** precision: operands are
//! encoded at rest and decoded back to f32 before any arithmetic, so
//! every kernel keeps full f32 accumulation and `precision = "f32"`
//! remains a bitwise no-op escape hatch.
//!
//! Quantization must be a *pure function of the row being stored*: the
//! paged KV cache refills LRU-evicted pages by deterministic recompute
//! (see `attention::paged`), and an evicted-then-refilled page must
//! reproduce the same stored bytes as a never-evicted one.  That is why
//! int8 carries scale/zero-point per row (keyed only by that row's
//! values) rather than any running per-buffer statistic.
//!
//! Documented storage tolerances (relative to the stored f32 value, at
//! normal magnitudes):
//!
//! | precision | max round-trip error            |
//! |-----------|---------------------------------|
//! | `f32`     | exact (bitwise)                 |
//! | `bf16`    | 2⁻⁸ ≈ 0.4% relative             |
//! | `f16`     | 2⁻¹¹ ≈ 0.05% relative           |
//! | `int8-kv` | (row max − row min) / 254 abs   |

/// Storage precision for K/V operands and paged KV-cache pages
/// (`[compute] precision`).  See the module docs for the exact codecs
/// and round-trip tolerances.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Full f32 storage — the bitwise escape hatch (default).
    #[default]
    F32,
    /// bfloat16: f32 with the mantissa truncated to 7 bits (RNE).
    Bf16,
    /// IEEE binary16.
    F16,
    /// Affine int8 with per-row scale/zero-point.
    Int8Kv,
}

impl Precision {
    /// Parse the `[compute] precision` spelling (`f32 | bf16 | f16 |
    /// int8-kv`; `int8_kv`/`int8` accepted as aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(Self::F32),
            "bf16" | "bfloat16" => Some(Self::Bf16),
            "f16" | "fp16" | "float16" | "half" => Some(Self::F16),
            "int8-kv" | "int8_kv" | "int8" => Some(Self::Int8Kv),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::Bf16 => "bf16",
            Self::F16 => "f16",
            Self::Int8Kv => "int8-kv",
        }
    }

    /// Payload bytes per stored K/V element (excluding int8 quant
    /// tables — see [`Precision::row_overhead_bytes`]).
    pub fn kv_bytes(self) -> usize {
        match self {
            Self::F32 => 4,
            Self::Bf16 | Self::F16 => 2,
            Self::Int8Kv => 1,
        }
    }

    /// Metadata bytes per stored *row*: int8 rows carry an f32
    /// scale/zero-point pair, the direct encodings carry nothing.
    pub fn row_overhead_bytes(self) -> usize {
        match self {
            Self::Int8Kv => 8,
            _ => 0,
        }
    }

    /// Total stored bytes for one row of `cols` elements.
    pub fn row_bytes(self, cols: usize) -> usize {
        cols * self.kv_bytes() + self.row_overhead_bytes()
    }

    /// Encode-then-decode one value (the storage round trip for the
    /// direct encodings; int8 depends on row context, see
    /// [`quant_params`]).  `F32` is the identity.
    pub fn roundtrip(self, x: f32) -> f32 {
        match self {
            Self::F32 => x,
            Self::Bf16 => bf16_to_f32(f32_to_bf16(x)),
            Self::F16 => f16_to_f32(f32_to_f16(x)),
            Self::Int8Kv => x,
        }
    }
}

// ---------------------------------------------------------------------------
// bf16
// ---------------------------------------------------------------------------

/// f32 → bf16 with round-to-nearest-even on the dropped 16 bits.
/// Finite inputs only round; NaN payloads are normalized to a quiet
/// NaN so the carry in the rounding add cannot turn a NaN into Inf.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

// ---------------------------------------------------------------------------
// f16 (IEEE binary16)
// ---------------------------------------------------------------------------

/// f32 → f16 with round-to-nearest-even; overflow saturates to Inf,
/// underflow goes through the binary16 subnormal range to signed zero.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN (quiet bit forced so the payload stays a NaN).
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7C00; // overflow -> Inf
    }
    if e >= -14 {
        // Normal range: keep 10 mantissa bits, RNE on the dropped 13.
        let m = man >> 13;
        let rest = man & 0x1FFF;
        let mut h = ((e + 15) as u32) << 10 | m;
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            h += 1; // mantissa carry may bump the exponent: still correct
        }
        return sign | h as u16;
    }
    if e >= -25 {
        // Subnormal range: shift the (implicit-bit) mantissa down.
        // e = -25 keeps the RNE interval above 2^-25 rounding up to
        // the smallest subnormal instead of flushing to zero.
        let man = man | 0x0080_0000;
        let shift = (13 - 14 - e) as u32; // in 1..=11 extra, total 14..=24
        let m = man >> shift;
        let rest = man & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = m;
        if rest > half || (rest == half && (m & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    sign // underflow to signed zero
}

pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = match exp {
        0 => {
            if man == 0 {
                sign // signed zero
            } else {
                // Subnormal: normalize into f32's larger exponent range.
                let shift = man.leading_zeros() - 21; // bring bit 10 up
                let man = (man << shift) & 0x03FF; // mask drops the leading 1
                sign | ((113 - shift) << 23) | (man << 13)
            }
        }
        31 => sign | 0x7F80_0000 | (man << 13), // Inf / NaN
        _ => sign | ((exp + 112) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------------------------
// int8 affine quantization (per-row scale/zero-point)
// ---------------------------------------------------------------------------

/// Affine quantization parameters `(scale, zero)` for one row — a pure
/// function of the row's values (the determinism contract for
/// recompute-on-miss refills).  The row range maps symmetrically onto
/// `[-127, 127]` around its midpoint; degenerate rows (constant, empty,
/// or non-finite) get `scale = 1` so every entry quantizes to the
/// zero-point exactly.
pub fn quant_params(row: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in row {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        let zero = if lo.is_finite() { lo } else { 0.0 };
        return (1.0, zero);
    }
    let zero = 0.5 * (hi + lo);
    let scale = ((hi - zero).max(zero - lo) / 127.0).max(f32::MIN_POSITIVE);
    (scale, zero)
}

#[inline]
pub fn quantize(x: f32, scale: f32, zero: f32) -> i8 {
    (((x - zero) / scale).round()).clamp(-127.0, 127.0) as i8
}

#[inline]
pub fn dequantize(q: i8, scale: f32, zero: f32) -> f32 {
    zero + q as f32 * scale
}

/// Quantize one row: returns the `(scale, zero)` pair written alongside
/// the payload.  `out.len() == row.len()`.
pub fn quantize_row(row: &[f32], out: &mut [i8]) -> (f32, f32) {
    debug_assert_eq!(row.len(), out.len());
    let (scale, zero) = quant_params(row);
    for (o, &x) in out.iter_mut().zip(row) {
        *o = quantize(x, scale, zero);
    }
    (scale, zero)
}

/// Decode one quantized row.
pub fn dequantize_row(q: &[i8], scale: f32, zero: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    for (o, &v) in out.iter_mut().zip(q) {
        *o = dequantize(v, scale, zero);
    }
}

// ---------------------------------------------------------------------------
// Byte-slot row codec (the paged-pool substrate)
// ---------------------------------------------------------------------------

/// Encode one row into a page-resident byte slot: `payload` receives
/// the packed elements (`row.len() * kv_bytes()` little-endian bytes),
/// `quant` the int8 scale/zero pair (`row_overhead_bytes()` bytes —
/// empty for the direct encodings).  Pure in `row`: re-encoding an
/// identical row always produces identical bytes, which is what makes
/// recompute-on-miss refills byte-equal to never-evicted pages.
pub fn encode_row(prec: Precision, row: &[f32], payload: &mut [u8], quant: &mut [u8]) {
    debug_assert_eq!(payload.len(), row.len() * prec.kv_bytes());
    debug_assert_eq!(quant.len(), prec.row_overhead_bytes());
    match prec {
        Precision::F32 => {
            for (dst, &x) in payload.chunks_exact_mut(4).zip(row) {
                dst.copy_from_slice(&x.to_le_bytes());
            }
        }
        Precision::Bf16 => {
            for (dst, &x) in payload.chunks_exact_mut(2).zip(row) {
                dst.copy_from_slice(&f32_to_bf16(x).to_le_bytes());
            }
        }
        Precision::F16 => {
            for (dst, &x) in payload.chunks_exact_mut(2).zip(row) {
                dst.copy_from_slice(&f32_to_f16(x).to_le_bytes());
            }
        }
        Precision::Int8Kv => {
            let (scale, zero) = quant_params(row);
            for (dst, &x) in payload.iter_mut().zip(row) {
                *dst = quantize(x, scale, zero) as u8;
            }
            quant[..4].copy_from_slice(&scale.to_le_bytes());
            quant[4..].copy_from_slice(&zero.to_le_bytes());
        }
    }
}

/// Decode one page-resident row slot (inverse of [`encode_row`]; the
/// f32 path restores the exact stored bits).
pub fn decode_row(prec: Precision, payload: &[u8], quant: &[u8], out: &mut [f32]) {
    debug_assert_eq!(payload.len(), out.len() * prec.kv_bytes());
    debug_assert_eq!(quant.len(), prec.row_overhead_bytes());
    match prec {
        Precision::F32 => {
            for (src, x) in payload.chunks_exact(4).zip(out) {
                *x = f32::from_le_bytes(src.try_into().unwrap());
            }
        }
        Precision::Bf16 => {
            for (src, x) in payload.chunks_exact(2).zip(out) {
                *x = bf16_to_f32(u16::from_le_bytes(src.try_into().unwrap()));
            }
        }
        Precision::F16 => {
            for (src, x) in payload.chunks_exact(2).zip(out) {
                *x = f16_to_f32(u16::from_le_bytes(src.try_into().unwrap()));
            }
        }
        Precision::Int8Kv => {
            let scale = f32::from_le_bytes(quant[..4].try_into().unwrap());
            let zero = f32::from_le_bytes(quant[4..].try_into().unwrap());
            for (src, x) in payload.iter().zip(out) {
                *x = dequantize(*src as i8, scale, zero);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// RowStore: an append-only encoded row buffer (the KvCache substrate)
// ---------------------------------------------------------------------------

/// Append-only store of fixed-width rows encoded at the configured
/// [`Precision`].  Backs the flat `KvCache`: push encodes, decode
/// restores f32 for the kernels (f32 path is zero-copy and bitwise).
#[derive(Clone, Debug)]
pub struct RowStore {
    prec: Precision,
    cols: usize,
    rows: usize,
    f32s: Vec<f32>,  // F32 payload
    words: Vec<u16>, // Bf16 / F16 payload
    bytes: Vec<i8>,  // Int8Kv payload
    quant: Vec<f32>, // Int8Kv per-row (scale, zero) pairs
}

impl RowStore {
    pub fn new(prec: Precision, cols: usize) -> Self {
        Self {
            prec,
            cols,
            rows: 0,
            f32s: Vec::new(),
            words: Vec::new(),
            bytes: Vec::new(),
            quant: Vec::new(),
        }
    }

    pub fn precision(&self) -> Precision {
        self.prec
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn clear(&mut self) {
        self.rows = 0;
        self.f32s.clear();
        self.words.clear();
        self.bytes.clear();
        self.quant.clear();
    }

    /// Stored bytes: encoded payload plus int8 quant tables.  The
    /// transient f32 decode scratch lives with the caller, not here.
    pub fn stored_bytes(&self) -> usize {
        self.rows * self.prec.row_bytes(self.cols)
    }

    pub fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.cols);
        match self.prec {
            Precision::F32 => self.f32s.extend_from_slice(row),
            Precision::Bf16 => self.words.extend(row.iter().map(|&x| f32_to_bf16(x))),
            Precision::F16 => self.words.extend(row.iter().map(|&x| f32_to_f16(x))),
            Precision::Int8Kv => {
                let start = self.bytes.len();
                self.bytes.resize(start + self.cols, 0);
                let (scale, zero) = quantize_row(row, &mut self.bytes[start..]);
                self.quant.push(scale);
                self.quant.push(zero);
            }
        }
        self.rows += 1;
    }

    /// The raw f32 payload — only available at `Precision::F32` (the
    /// zero-copy bitwise path).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self.prec {
            Precision::F32 => Some(&self.f32s),
            _ => None,
        }
    }

    /// Decode rows `[from, to)` into `out` (resized to fit).
    pub fn decode_range_into(&self, from: usize, to: usize, out: &mut Vec<f32>) {
        debug_assert!(from <= to && to <= self.rows);
        let c = self.cols;
        out.clear();
        out.reserve((to - from) * c);
        match self.prec {
            Precision::F32 => out.extend_from_slice(&self.f32s[from * c..to * c]),
            Precision::Bf16 => {
                out.extend(self.words[from * c..to * c].iter().map(|&w| bf16_to_f32(w)))
            }
            Precision::F16 => {
                out.extend(self.words[from * c..to * c].iter().map(|&w| f16_to_f32(w)))
            }
            Precision::Int8Kv => {
                for r in from..to {
                    let (scale, zero) = (self.quant[2 * r], self.quant[2 * r + 1]);
                    out.extend(
                        self.bytes[r * c..(r + 1) * c].iter().map(|&q| dequantize(q, scale, zero)),
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_parsing_and_widths() {
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("bf16"), Some(Precision::Bf16));
        assert_eq!(Precision::parse("F16"), Some(Precision::F16));
        assert_eq!(Precision::parse("int8-kv"), Some(Precision::Int8Kv));
        assert_eq!(Precision::parse("int8_kv"), Some(Precision::Int8Kv));
        assert_eq!(Precision::parse("int4"), None);
        assert_eq!(Precision::default(), Precision::F32);
        for p in [Precision::F32, Precision::Bf16, Precision::F16, Precision::Int8Kv] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::F32.row_bytes(64), 256);
        assert_eq!(Precision::Bf16.row_bytes(64), 128);
        assert_eq!(Precision::F16.row_bytes(64), 128);
        assert_eq!(Precision::Int8Kv.row_bytes(64), 72); // 64 + scale/zero
    }

    #[test]
    fn bf16_round_trip_error_is_bounded() {
        let mut rng = crate::rng::Pcg64::seed(0xB16);
        let mut buf = vec![0.0f32; 4096];
        rng.fill_gaussian(&mut buf, 0.0, 2.0);
        for &x in &buf {
            let y = bf16_to_f32(f32_to_bf16(x));
            assert!((y - x).abs() <= x.abs() * (1.0 / 256.0) + f32::EPSILON, "{x} -> {y}");
        }
        // Exactly-representable values survive bitwise.
        for x in [0.0f32, -0.0, 1.0, -2.0, 0.5, 1.5, 256.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(x)).to_bits(), x.to_bits(), "{x}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn f16_round_trip_error_is_bounded() {
        let mut rng = crate::rng::Pcg64::seed(0xF16);
        let mut buf = vec![0.0f32; 4096];
        rng.fill_gaussian(&mut buf, 0.0, 2.0);
        for &x in &buf {
            let y = f16_to_f32(f32_to_f16(x));
            assert!((y - x).abs() <= x.abs() * (1.0 / 2048.0) + 1e-7, "{x} -> {y}");
        }
        for x in [0.0f32, -0.0, 1.0, -2.0, 0.5, 1.5, 2048.0, 65504.0] {
            assert_eq!(f16_to_f32(f32_to_f16(x)).to_bits(), x.to_bits(), "{x}");
        }
        // Overflow saturates, subnormals and underflow stay signed.
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(-1e6)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        let sub = 3.0e-6f32; // inside binary16's subnormal range
        let y = f16_to_f32(f32_to_f16(sub));
        assert!(y > 0.0 && (y - sub).abs() < 1e-7, "{sub} -> {y}");
        assert_eq!(f16_to_f32(f32_to_f16(1e-9)), 0.0);
        assert_eq!(f16_to_f32(f32_to_f16(-1e-9)).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f16_exhaustive_decode_encode_identity() {
        // Every finite f16 bit pattern must survive decode -> encode
        // exactly (the decoder and encoder agree on the format).
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1F;
            if exp == 31 {
                continue; // Inf/NaN payloads are normalized, skip
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "pattern {h:#06x}");
        }
    }

    #[test]
    fn int8_quantization_is_deterministic_and_bounded() {
        let mut rng = crate::rng::Pcg64::seed(0x18);
        let mut row = vec![0.0f32; 64];
        rng.fill_gaussian(&mut row, 0.3, 1.7);
        let mut q1 = vec![0i8; 64];
        let mut q2 = vec![0i8; 64];
        let (s1, z1) = quantize_row(&row, &mut q1);
        let (s2, z2) = quantize_row(&row, &mut q2);
        // Pure function of the row: identical params and payload.
        assert_eq!((s1.to_bits(), z1.to_bits()), (s2.to_bits(), z2.to_bits()));
        assert_eq!(q1, q2);
        // Error bound: half a quantization step.
        let (lo, hi) = row.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &x| {
            (l.min(x), h.max(x))
        });
        let step = (hi - lo) / 254.0;
        let mut dec = vec![0.0f32; 64];
        dequantize_row(&q1, s1, z1, &mut dec);
        for (&x, &y) in row.iter().zip(&dec) {
            assert!((x - y).abs() <= step * 0.5 + 1e-6, "{x} -> {y} (step {step})");
        }
        // Degenerate rows: constant maps exactly, empty is fine.
        let (s, z) = quant_params(&[3.25; 7]);
        assert_eq!((s, z), (1.0, 3.25));
        assert_eq!(dequantize(quantize(3.25, s, z), s, z), 3.25);
        assert_eq!(quant_params(&[]), (1.0, 0.0));
    }

    #[test]
    fn row_store_round_trips_every_precision() {
        let mut rng = crate::rng::Pcg64::seed(0x57);
        let cols = 24usize;
        let rows: Vec<Vec<f32>> = (0..9)
            .map(|_| {
                let mut r = vec![0.0f32; cols];
                rng.fill_gaussian(&mut r, 0.0, 1.2);
                r
            })
            .collect();
        for prec in [Precision::F32, Precision::Bf16, Precision::F16, Precision::Int8Kv] {
            let mut store = RowStore::new(prec, cols);
            for r in &rows {
                store.push_row(r);
            }
            assert_eq!(store.rows(), rows.len());
            assert_eq!(store.stored_bytes(), rows.len() * prec.row_bytes(cols));
            let mut dec = Vec::new();
            store.decode_range_into(0, rows.len(), &mut dec);
            for (i, r) in rows.iter().enumerate() {
                let got = &dec[i * cols..(i + 1) * cols];
                if prec == Precision::F32 {
                    assert_eq!(got, r.as_slice(), "f32 must be bitwise");
                } else {
                    for (&x, &y) in r.iter().zip(got) {
                        assert!((x - y).abs() <= 0.02 * x.abs().max(1.0), "{prec:?}: {x} vs {y}");
                    }
                }
            }
            // Partial decode agrees with the full decode's slice.
            let mut part = Vec::new();
            store.decode_range_into(3, 7, &mut part);
            assert_eq!(part.as_slice(), &dec[3 * cols..7 * cols]);
            // The zero-copy f32 view exists exactly for F32.
            assert_eq!(store.as_f32().is_some(), prec == Precision::F32);
        }
    }

    #[test]
    fn byte_slot_codec_round_trips_and_is_deterministic() {
        let mut rng = crate::rng::Pcg64::seed(0x58);
        let cols = 16usize;
        let mut row = vec![0.0f32; cols];
        rng.fill_gaussian(&mut row, 0.3, 1.5);
        for prec in [Precision::F32, Precision::Bf16, Precision::F16, Precision::Int8Kv] {
            let mut payload = vec![0u8; cols * prec.kv_bytes()];
            let mut quant = vec![0u8; prec.row_overhead_bytes()];
            encode_row(prec, &row, &mut payload, &mut quant);
            let mut dec = vec![0.0f32; cols];
            decode_row(prec, &payload, &quant, &mut dec);
            if prec == Precision::F32 {
                assert_eq!(dec, row, "f32 slots must restore the exact bits");
            } else {
                for (&x, &y) in row.iter().zip(&dec) {
                    assert!((x - y).abs() <= 0.05 * x.abs().max(1.0), "{prec:?}: {x} vs {y}");
                }
            }
            // Byte-slot decode agrees exactly with the RowStore decode
            // of the same row (one quantization law everywhere).
            let mut store = RowStore::new(prec, cols);
            store.push_row(&row);
            let mut via_store = Vec::new();
            store.decode_range_into(0, 1, &mut via_store);
            assert_eq!(dec, via_store, "{prec:?}: page and flat-cache decode disagree");
            // Re-encoding the identical row reproduces identical bytes —
            // the recompute-on-miss determinism contract.
            let mut payload2 = vec![0u8; payload.len()];
            let mut quant2 = vec![0u8; quant.len()];
            encode_row(prec, &row, &mut payload2, &mut quant2);
            assert_eq!(payload, payload2, "{prec:?}: payload must be deterministic");
            assert_eq!(quant, quant2, "{prec:?}: quant table must be deterministic");
        }
    }
}
