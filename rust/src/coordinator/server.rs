//! The threaded serving coordinator.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use super::batcher::{plan_batches, should_fire};
use super::{pad_to_bucket, pick_bucket, Request, Response};
use crate::config::ServeConfig;
use crate::runtime::{Engine, HostTensor, ParamStore};
use crate::util::pool::{Channel, SendError};

/// Rolling serving metrics (shared across workers).
#[derive(Default)]
pub struct ServeStats {
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub latencies_ms: Vec<f64>,
    pub batch_sizes: Vec<usize>,
}

impl ServeStats {
    pub fn p50_latency(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            crate::stats::percentile(&self.latencies_ms, 50.0)
        }
    }
    pub fn p95_latency(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            crate::stats::percentile(&self.latencies_ms, 95.0)
        }
    }
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }
}

/// The running coordinator: submit requests, read stats, shut down.
pub struct Coordinator {
    cfg: ServeConfig,
    queues: Vec<(usize, Channel<Request>)>, // (bucket_len, queue)
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<ServeStats>>,
    next_id: AtomicU64,
    draining: Arc<AtomicBool>,
    started_at: Instant,
}

impl Coordinator {
    /// Spawn one worker per bucket (each owns a PJRT engine and the
    /// executables + resident params for that bucket).
    pub fn start(cfg: ServeConfig, artifacts: &std::path::Path) -> Result<Self> {
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let draining = Arc::new(AtomicBool::new(false));
        let mut queues = Vec::new();
        let mut workers = Vec::new();
        for &bucket in &cfg.buckets {
            let q: Channel<Request> = Channel::bounded(cfg.queue_capacity);
            queues.push((bucket, q.clone()));
            let cfgc = cfg.clone();
            let dir = artifacts.to_path_buf();
            let statsc = Arc::clone(&stats);
            let drainc = Arc::clone(&draining);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lln-worker-n{bucket}"))
                    .spawn(move || {
                        if let Err(e) = worker_loop(cfgc, dir, bucket, q, statsc, drainc) {
                            eprintln!("worker n{bucket} died: {e:#}");
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Ok(Self {
            cfg,
            queues,
            workers,
            stats,
            next_id: AtomicU64::new(1),
            draining,
            started_at: Instant::now(),
        })
    }

    /// Submit a request; returns the response receiver.  Errors on
    /// over-length input or queue-full backpressure.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<mpsc::Receiver<Response>> {
        let bucket = pick_bucket(&self.cfg.buckets, tokens.len())
            .ok_or_else(|| anyhow!("sequence length {} exceeds all buckets", tokens.len()))?;
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            enqueued_at: Instant::now(),
            resp: tx,
        };
        let queue = &self.queues.iter().find(|(b, _)| *b == bucket).unwrap().1;
        match queue.try_send(req) {
            Ok(()) => Ok(rx),
            Err(SendError::Full(_)) => {
                self.stats.lock().unwrap().rejected += 1;
                bail!("backpressure: bucket n{bucket} queue full")
            }
            Err(SendError::Closed(_)) => bail!("coordinator shutting down"),
        }
    }

    /// Submit and block for the result.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        let rx = self.submit(tokens)?;
        rx.recv().map_err(|_| anyhow!("worker dropped response"))
    }

    pub fn stats(&self) -> Arc<Mutex<ServeStats>> {
        Arc::clone(&self.stats)
    }

    pub fn uptime_secs(&self) -> f64 {
        self.started_at.elapsed().as_secs_f64()
    }

    /// Drain queues and join workers.
    pub fn shutdown(mut self) {
        self.draining.store(true, Ordering::SeqCst);
        for (_, q) in &self.queues {
            q.close();
        }
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

/// Per-bucket worker: owns an Engine, resident param literals, and both
/// batch-size executables; loops batching until the queue closes.
fn worker_loop(
    cfg: ServeConfig,
    dir: std::path::PathBuf,
    bucket: usize,
    queue: Channel<Request>,
    stats: Arc<Mutex<ServeStats>>,
    draining: Arc<AtomicBool>,
) -> Result<()> {
    let mut engine = Engine::new(&dir)?;
    let exe_b1 = format!("serve_{}_b1_n{}", cfg.method, bucket);
    let exe_bn = format!("serve_{}_b{}_n{}", cfg.method, cfg.max_batch, bucket);
    engine.warmup(&[&exe_b1, &exe_bn])?;

    // Resident parameters: built once, reused for every call.
    let model_tag = engine.manifest().artifact(&exe_b1)?.meta.get("model").cloned()
        .ok_or_else(|| anyhow!("{exe_b1}: missing model meta"))?;
    let model = engine.manifest().model(&model_tag)?.clone();
    let params = ParamStore::load_initial(&dir, &model)?;
    let param_lits: Vec<Literal> = params.to_literals()?;
    let num_classes: usize = {
        let spec = engine.manifest().artifact(&exe_b1)?;
        *spec.outputs[0].shape.last().unwrap_or(&4)
    };

    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Top up the pending set.
        let drain = draining.load(Ordering::SeqCst);
        if pending.len() < cfg.max_batch {
            match queue.recv_timeout(Duration::from_millis(cfg.batch_timeout_ms.max(1))) {
                Ok(Some(req)) => {
                    pending.push(req);
                    // opportunistically grab whatever else is queued
                    pending.extend(queue.drain_up_to(cfg.max_batch - pending.len()));
                }
                Ok(None) => {}
                Err(_) if pending.is_empty() => return Ok(()), // closed + drained
                Err(_) => {}
            }
        }
        let oldest_ms = pending
            .first()
            .map(|r| r.enqueued_at.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        if !should_fire(pending.len(), cfg.max_batch, oldest_ms, cfg.batch_timeout_ms as f64, drain) {
            continue;
        }
        for plan in plan_batches(pending.len(), cfg.max_batch) {
            let batch: Vec<Request> = plan.members.iter().map(|_| pending.remove(0)).collect();
            let exe = if plan.capacity == 1 { &exe_b1 } else { &exe_bn };
            run_batch(&mut engine, exe, plan.capacity, bucket, num_classes, &param_lits, batch, &stats);
        }
        pending.clear();
    }
}

/// Execute one padded batch and fan results back out.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    engine: &mut Engine,
    exe: &str,
    capacity: usize,
    bucket: usize,
    num_classes: usize,
    param_lits: &[Literal],
    batch: Vec<Request>,
    stats: &Arc<Mutex<ServeStats>>,
) {
    let real = batch.len();
    let mut tokens = Vec::with_capacity(capacity * bucket);
    for r in &batch {
        tokens.extend(pad_to_bucket(&r.tokens, bucket));
    }
    // Pad phantom rows up to the executable's static batch.
    tokens.resize(capacity * bucket, crate::data::special::PAD);

    let result: Result<Vec<Vec<f32>>> = (|| {
        let tok_lit = HostTensor::I32 { shape: vec![capacity, bucket], data: tokens }.to_literal()?;
        let mut args: Vec<&Literal> = param_lits.iter().collect();
        args.push(&tok_lit);
        let outs = engine.execute_literals(exe, &args)?;
        let logits = outs[0].to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((0..real)
            .map(|i| logits[i * num_classes..(i + 1) * num_classes].to_vec())
            .collect())
    })();

    let mut st = stats.lock().unwrap();
    st.batch_sizes.push(real);
    match result {
        Ok(rows) => {
            for (r, row) in batch.into_iter().zip(rows) {
                let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                st.completed += 1;
                st.latencies_ms.push(latency_ms);
                r.resp
                    .send(Response { id: r.id, result: Ok(row), latency_ms, batch_size: real })
                    .ok();
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for r in batch {
                let latency_ms = r.enqueued_at.elapsed().as_secs_f64() * 1e3;
                st.errors += 1;
                r.resp
                    .send(Response {
                        id: r.id,
                        result: Err(msg.clone()),
                        latency_ms,
                        batch_size: real,
                    })
                    .ok();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{special, tasks::GlueGen, GlueTask};
    use crate::runtime::{artifacts_available, artifacts_dir};

    fn coordinator() -> Option<Coordinator> {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            return None;
        }
        let cfg = ServeConfig {
            method: "lln_diag".into(),
            queue_capacity: 64,
            max_batch: 8,
            batch_timeout_ms: 3,
            workers: 1,
            buckets: vec![128, 512],
        };
        Some(Coordinator::start(cfg, &dir).unwrap())
    }

    #[test]
    fn serves_single_request() {
        let Some(c) = coordinator() else { return };
        let mut gen = GlueGen::new(GlueTask::Sst2, 512, 128, 1);
        let (tokens, _) = gen.example();
        let resp = c.infer(tokens).unwrap();
        let logits = resp.result.unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        c.shutdown();
    }

    #[test]
    fn serves_concurrent_burst_with_batching() {
        let Some(c) = coordinator() else { return };
        let mut gen = GlueGen::new(GlueTask::Qqp, 512, 128, 2);
        let rxs: Vec<_> = (0..24).map(|_| c.submit(gen.example().0).unwrap()).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok(), "{:?}", resp.result);
        }
        let stats = c.stats();
        let st = stats.lock().unwrap();
        assert_eq!(st.completed, 24);
        assert!(st.mean_batch_size() > 1.0, "burst should batch: {}", st.mean_batch_size());
        drop(st);
        c.shutdown();
    }

    #[test]
    fn routes_long_sequences_to_big_bucket() {
        let Some(c) = coordinator() else { return };
        let tokens = vec![special::CLS; 300]; // > 128, <= 512
        let resp = c.infer(tokens).unwrap();
        assert!(resp.result.is_ok());
        c.shutdown();
    }

    #[test]
    fn rejects_over_length() {
        let Some(c) = coordinator() else { return };
        let err = c.submit(vec![special::CLS; 1000]).unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
        c.shutdown();
    }
}
